"""End-to-end solver benchmark: CG on the Wilson-like stencil operator,
halo schedule × channels sweep — the paper's Tables V/VI workload driven to
convergence instead of a single operator application.

``python -m benchmarks.bench_cg --dry`` runs one tiny lattice per schedule
and asserts convergence (the CI stencil smoke job).
"""

from __future__ import annotations

import sys

from benchmarks.common import TIMER_SNIPPET, run_on_devices

_BODY = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator, HALO_SCHEDULES
from repro.core.halo import HaloSpec
from repro.stencil import StencilOp, cg_solve

mesh = compat.make_mesh((2, 2, 2), ("x", "y", "z"))
SPECS = (HaloSpec("x", 0), HaloSpec("y", 1), HaloSpec("z", 2))
op = StencilOp(specs=SPECS, mass=0.5)

def solver(comm, schedule, channels, tol, maxiter):
    def run(b):
        r = cg_solve(op, b, comm, tol=tol, maxiter=maxiter, schedule=schedule,
                     chunks=comm.halo_chunks, channels=channels)
        return r.x, r.iters, r.rel_residual
    return jax.jit(compat.shard_map(run, mesh=mesh,
                                    in_specs=P("x", "y", "z", None),
                                    out_specs=(P("x", "y", "z", None), P(), P()),
                                    check_vma=False))

print("schedule,channels,local_vol,iters,rel_residual,us_per_solve,us_per_iter")
rng = np.random.RandomState(0)
for L in LATTICES:
    b = jnp.asarray(rng.randn(2*L, 2*L, 2*L, C).astype(np.float32))
    for schedule in HALO_SCHEDULES:
        for channels in CHANNELS:
            comm = Communicator(mesh, CommConfig(
                transport="psum", data_axes=("x", "y", "z"),
                channels=channels))
            fn = solver(comm, schedule, channels, TOL, MAXITER)
            x, iters, rel = jax.block_until_ready(fn(b))
            assert float(rel) < TOL, (schedule, channels, float(rel))
            sec = time_call(fn, b)
            it = max(int(iters), 1)
            print(f"{schedule},{channels},{L}^3,{int(iters)},"
                  f"{float(rel):.2e},{sec*1e6:.1f},{sec*1e6/it:.1f}")
print("CG_BENCH_OK")
"""

SWEEP_HEADER = """
LATTICES = [8, 12]
C = 12
CHANNELS = [1, 2, 4]
TOL = 1e-5
MAXITER = 200
"""

DRY_HEADER = """
LATTICES = [4]
C = 4
CHANNELS = [2]
TOL = 1e-5
MAXITER = 100
"""


def run(dry: bool = False) -> str:
    header = DRY_HEADER if dry else SWEEP_HEADER
    return run_on_devices(TIMER_SNIPPET + header + _BODY)


if __name__ == "__main__":
    out = run(dry="--dry" in sys.argv)
    print(out)
    if "CG_BENCH_OK" not in out:
        sys.exit(1)

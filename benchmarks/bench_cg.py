"""End-to-end solver benchmark: the comm-avoiding CG family on the
Wilson-like stencil operator — ``solver ∈ {cg, pipelined, sstep} ×
precond ∈ {none, eo}`` over halo schedules, driven to convergence.  The
``reductions`` column is the predicted inner-product collective count
(:func:`repro.stencil.predicted_reduction_collectives`): the α-latency
budget each variant actually pays, which is the paper's Tables V/VI story
applied to the solver instead of the exchange.

``python -m benchmarks.bench_cg --dry`` runs one tiny lattice over the full
solver × precond grid and asserts convergence (the CI solver smoke job).
"""

from __future__ import annotations

import sys

from benchmarks.common import TIMER_SNIPPET, run_on_devices

_BODY = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator
from repro.core.halo import HaloSpec
from repro.stencil import StencilOp, predicted_reduction_collectives, solve

mesh = compat.make_mesh((2, 2, 2), ("x", "y", "z"))
SPECS = (HaloSpec("x", 0), HaloSpec("y", 1), HaloSpec("z", 2))
op = StencilOp(specs=SPECS, mass=0.5)

def solver_fn(comm, solver, precond, schedule, channels, tol, maxiter):
    def run(b):
        r = solve(op, b, comm, solver=solver, precond=precond, s=SSTEP_S,
                  tol=tol, maxiter=maxiter, schedule=schedule,
                  chunks=comm.halo_chunks, channels=channels)
        return r.x, r.iters, r.rel_residual
    return jax.jit(compat.shard_map(run, mesh=mesh,
                                    in_specs=P("x", "y", "z", None),
                                    out_specs=(P("x", "y", "z", None), P(), P()),
                                    check_vma=False))

print("solver,precond,schedule,channels,local_vol,iters,reductions,"
      "rel_residual,us_per_solve,us_per_iter")
rng = np.random.RandomState(0)
for L in LATTICES:
    b = jnp.asarray(rng.randn(2*L, 2*L, 2*L, C).astype(np.float32))
    for solver in SOLVERS:
        for precond in PRECONDS:
            for schedule in SCHEDULES:
                for channels in CHANNELS:
                    comm = Communicator(mesh, CommConfig(
                        transport="psum", data_axes=("x", "y", "z"),
                        channels=channels))
                    fn = solver_fn(comm, solver, precond, schedule,
                                   channels, TOL, MAXITER)
                    x, iters, rel = jax.block_until_ready(fn(b))
                    assert float(rel) < TOL, \
                        (solver, precond, schedule, channels, float(rel))
                    sec = time_call(fn, b)
                    it = max(int(iters), 1)
                    red = predicted_reduction_collectives(solver, it, s=SSTEP_S)
                    print(f"{solver},{precond},{schedule},{channels},{L}^3,"
                          f"{int(iters)},{red},{float(rel):.2e},"
                          f"{sec*1e6:.1f},{sec*1e6/it:.1f}")
print("CG_BENCH_OK")
"""

SWEEP_HEADER = """
LATTICES = [8, 12]
C = 12
SOLVERS = ["cg", "pipelined", "sstep"]
PRECONDS = ["none", "eo"]
SCHEDULES = ["concurrent", "overlap"]
CHANNELS = [2]
SSTEP_S = 4
TOL = 1e-5
MAXITER = 200
"""

DRY_HEADER = """
LATTICES = [4]
C = 4
SOLVERS = ["cg", "pipelined", "sstep"]
PRECONDS = ["none", "eo"]
SCHEDULES = ["concurrent"]
CHANNELS = [2]
SSTEP_S = 4
TOL = 1e-5
MAXITER = 100
"""


def run(dry: bool = False) -> str:
    header = DRY_HEADER if dry else SWEEP_HEADER
    return run_on_devices(TIMER_SNIPPET + header + _BODY)


if __name__ == "__main__":
    out = run(dry="--dry" in sys.argv)
    print(out)
    if "CG_BENCH_OK" not in out:
        sys.exit(1)

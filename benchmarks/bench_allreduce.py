"""Paper Figures 1/2/5: gradient-reduction time & bandwidth vs vector length,
original (per-tensor, unidirectional, unfused) vs optimised transports.

Workload mirrors synchronous-SGD gradient reduction: a pytree of K tensors
totalling L fp32 elements (K grows with L like a real model's parameter
list).  The ``original`` row reduces tensor-by-tensor over a one-direction
ring (the published code's behaviour); the optimised rows fuse into aligned
buckets and run the registered ``repro.comm`` transports.  On top of the
transport sweep, the ``ring_hier`` schedule is swept over ``channels`` in
{1, 2, 4} — the paper's multi-rail endpoint count as a config knob.

A second block sweeps the *wire codec* on the ``ring`` transport at fixed
length — fp32 / bf16 rail (``wire_dtype``) / int8+scales (``wire_codec``) —
printing the plan-predicted wire bytes next to the bytes actually lowered
into the HLO's collective-permutes.  ``--dry`` shrinks both blocks to a CI
smoke.
"""

from __future__ import annotations

import argparse

from benchmarks.common import TIMER_SNIPPET, run_on_devices

SCRIPT = TIMER_SNIPPET + r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator

DRY = %(dry)s
mesh = compat.make_mesh((2, 4), ("pod", "data"))
P_WORLD = 8

def workload(total_elems, rng):
    k = int(min(32, max(1, total_elems // 4096)))
    sizes = np.full(k, total_elems // k)
    sizes[0] += total_elems - sizes.sum()
    return {f"g{i}": jnp.asarray(rng.randn(int(s)).astype(np.float32))
            for i, s in enumerate(sizes)}

CONFIGS = [
    # (row label, CommConfig kwargs)
    ("original", dict(transport="ring", chunks=1, bidirectional=False,
                      bucket_bytes=1)),
    ("ring", dict(transport="ring", chunks=2, bucket_bytes=32*2**20)),
    ("ring_hier/ch1", dict(transport="ring_hier", chunks=2, channels=1,
                           bucket_bytes=32*2**20)),
    ("ring_hier/ch2", dict(transport="ring_hier", chunks=2, channels=2,
                           bucket_bytes=32*2**20)),
    ("ring_hier/ch4", dict(transport="ring_hier", chunks=2, channels=4,
                           bucket_bytes=32*2**20)),
    ("ring_hier_int8", dict(transport="ring_hier", chunks=2,
                            wire_codec="int8", bucket_bytes=32*2**20)),
    ("psum", dict(transport="psum", fuse=False)),
    ("psum_fused", dict(transport="psum", bucket_bytes=32*2**20)),
]

rng = np.random.RandomState(0)
print("transport,channels,elements,us_per_call,alg_bw_mb_s,pct_vs_original")
base = {}
for total in ([1<<12] if DRY else [1<<12, 1<<16, 1<<20, 1<<22]):
    tree = workload(total, rng)
    specs = {k: P() for k in tree}
    for name, kw in CONFIGS:
        comm = Communicator(mesh, CommConfig(data_axes=("pod","data"), **kw))
        fn = jax.jit(lambda g: comm.reduce(g, specs)[0])
        sec = time_call(fn, tree)
        # ring algorithm bytes: 2 (p-1)/p * payload, both directions counted once
        alg_bytes = 2 * (P_WORLD - 1) / P_WORLD * total * 4
        bw = alg_bytes / sec / 1e6
        if name == "original":
            base[total] = sec
        pct = 100.0 * base[total] / sec
        ch = kw.get("channels", 0)
        print(f"{name},{ch},{total},{sec*1e6:.1f},{bw:.1f},{pct:.0f}")

# -- wire codec block: what actually crosses the wire per codec -------------
# Single reduce axis (the inner 4-ring): the int8 ring re-encodes per chunk,
# so flat buffers must hold whole codec blocks per chunk and the divisor
# grows as world*chunks*2*block per axis.  bf16 hlo bytes read fp32 on this
# backend (XLA CPU float normalization upcasts bf16 collectives); pred_*
# columns carry the wire format.
from repro.launch.roofline import collective_wire_bytes

CODECS = [
    # (row label, CommConfig wire kwargs)
    ("fp32", dict()),
    ("bf16", dict(wire_dtype="bfloat16")),
    ("int8", dict(wire_codec="int8")),
]
total = 1 << 14 if DRY else 1 << 20
tree = workload(total, rng)
specs = {k: P() for k in tree}
print()
print("# wire codec (ring, fixed length): plan-predicted vs lowered HLO bytes")
print("codec,elements,us_per_call,pred_wire_bytes,hlo_wire_bytes,pred_ratio_vs_fp32")
base_bytes = None
for name, wire_kw in CODECS:
    comm = Communicator(mesh, CommConfig(
        transport="ring", chunks=2, bucket_bytes=32*2**20,
        data_axes=("data",), **wire_kw))
    fn = jax.jit(lambda g: comm.reduce(g, specs)[0])
    hlo = fn.lower(tree).compile().as_text()
    meas = sum(collective_wire_bytes(hlo).op_bytes.values())
    pred = comm.plan(tree).bytes_per_device
    sec = time_call(fn, tree)
    if name == "fp32":
        base_bytes = pred
    ratio = base_bytes / pred if pred else 0.0
    print(f"{name},{total},{sec*1e6:.1f},{pred:.0f},{meas:.0f},{ratio:.2f}")
"""


def run(dry: bool = False) -> str:
    return run_on_devices(SCRIPT % {"dry": dry})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="tiny lengths, single size per block (CI smoke)")
    args = ap.parse_args()
    print(run(dry=args.dry))

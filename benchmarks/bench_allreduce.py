"""Paper Figures 1/2/5: gradient-reduction time & bandwidth vs vector length,
original (per-tensor, unidirectional, unfused) vs optimised policies.

Workload mirrors synchronous-SGD gradient reduction: a pytree of K tensors
totalling L fp32 elements (K grows with L like a real model's parameter
list).  ``baidu_original`` reduces tensor-by-tensor over a one-direction
ring (the published code's behaviour); the optimised policies fuse into
aligned buckets and run bidirectional chunked / hierarchical / compressed
rings; ``native_psum`` is the vendor-collective reference.
"""

from __future__ import annotations

from benchmarks.common import TIMER_SNIPPET, run_on_devices

SCRIPT = TIMER_SNIPPET + r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, AxisType
from repro.core.reducer import GradientReducer, ReduceConfig

mesh = jax.make_mesh((2, 4), ("pod", "data"), axis_types=(AxisType.Auto,)*2)
P_WORLD = 8

def workload(total_elems, rng):
    k = int(min(32, max(1, total_elems // 4096)))
    sizes = np.full(k, total_elems // k)
    sizes[0] += total_elems - sizes.sum()
    return {f"g{i}": jnp.asarray(rng.randn(int(s)).astype(np.float32))
            for i, s in enumerate(sizes)}

POLICIES = [
    ("baidu_original", dict(policy="baidu_original", bucket_bytes=1)),
    ("fused_ring", dict(policy="fused_ring", chunks=2, bucket_bytes=32*2**20)),
    ("fused_ring_hierarchical", dict(policy="fused_ring_hierarchical",
                                     chunks=2, bucket_bytes=32*2**20)),
    ("fused_ring_compressed", dict(policy="fused_ring_compressed",
                                   chunks=2, bucket_bytes=32*2**20)),
    ("native_psum", dict(policy="native_psum")),
    ("native_psum_fused", dict(policy="native_psum_fused",
                               bucket_bytes=32*2**20)),
]

rng = np.random.RandomState(0)
print("policy,elements,us_per_call,alg_bw_mb_s,pct_vs_original")
base = {}
for total in [1<<12, 1<<16, 1<<20, 1<<22]:
    tree = workload(total, rng)
    specs = {k: P() for k in tree}
    for name, kw in POLICIES:
        red = GradientReducer(mesh, ReduceConfig(data_axes=("pod","data"), **kw))
        fn = jax.jit(lambda g: red.reduce(g, specs)[0])
        sec = time_call(fn, tree)
        # ring algorithm bytes: 2 (p-1)/p * payload, both directions counted once
        alg_bytes = 2 * (P_WORLD - 1) / P_WORLD * total * 4
        bw = alg_bytes / sec / 1e6
        if name == "baidu_original":
            base[total] = sec
        pct = 100.0 * base[total] / sec
        print(f"{name},{total},{sec*1e6:.1f},{bw:.1f},{pct:.0f}")
"""


def run() -> str:
    return run_on_devices(SCRIPT)


if __name__ == "__main__":
    print(run())

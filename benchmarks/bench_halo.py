"""Paper Tables I-III: Cartesian halo-exchange bandwidth, sequential vs
concurrent vs chunked (multi-channel) schedules, across face sizes."""

from __future__ import annotations

from benchmarks.common import TIMER_SNIPPET, run_on_devices

SCRIPT = TIMER_SNIPPET + r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator
from repro.core.halo import HaloSpec, halo_bytes

# 3-D Cartesian communicator on 8 ranks (2x2x2), like the paper's 2^4 grid
mesh = compat.make_mesh((2, 2, 2), ("x", "y", "z"))
SPECS = [HaloSpec("x", 0), HaloSpec("y", 1), HaloSpec("z", 2)]
comm = Communicator(mesh, CommConfig(data_axes=("x", "y", "z"), channels=4))

print("schedule,local_vol,bytes_per_rank,us_per_exchange,mb_s")
for L in [8, 16, 24]:
    shape = (2*L, 2*L, 2*L, 16)   # global lattice, 16 'spin' components
    x = jnp.ones(shape, jnp.float32)
    spec_in = P("x", "y", "z", None)
    nbytes = halo_bytes((L, L, L, 16), SPECS, 4)
    for sched in ["sequential", "concurrent", "chunked"]:
        def fn(xl, s=sched):
            h = comm.halo_exchange(xl, SPECS, schedule=s)
            # consume all faces so nothing is dead-code eliminated
            return sum(v.sum() for v in h.values())
        g = jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=spec_in,
                                     out_specs=P(), check_vma=False))
        sec = time_call(g, x)
        print(f"{sched},{L}^3,{nbytes},{sec*1e6:.1f},{nbytes/sec/1e6:.1f}")
"""


def run() -> str:
    return run_on_devices(SCRIPT)


if __name__ == "__main__":
    print(run())

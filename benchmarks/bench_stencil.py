"""Paper Tables V/VI: end-application stencil (Wilson-like sparse matrix)
throughput vs local volume — the halo exchange feeding a real computation."""

from __future__ import annotations

from benchmarks.common import TIMER_SNIPPET, run_on_devices

SCRIPT = TIMER_SNIPPET + r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator
from repro.core.halo import HaloSpec

mesh = compat.make_mesh((2, 2, 2), ("x", "y", "z"))
SPECS = [HaloSpec("x", 0), HaloSpec("y", 1), HaloSpec("z", 2)]
C = 12  # components (su3 spinor-ish)
comm = Communicator(mesh, CommConfig(data_axes=("x", "y", "z"), channels=2))

def stencil(xl, schedule):
    h = comm.halo_exchange(xl, SPECS, schedule=schedule)
    y = 6.0 * xl
    for d, (ax, dim) in enumerate([("x",0),("y",1),("z",2)]):
        lo = h[(ax, "-")]; hi = h[(ax, "+")]
        up = jnp.concatenate([lo, xl], axis=dim)
        dn = jnp.concatenate([xl, hi], axis=dim)
        n = xl.shape[dim]
        y = y - jax.lax.slice_in_dim(up, 0, n, axis=dim) \
              - jax.lax.slice_in_dim(dn, 1, n+1, axis=dim)
    return y

print("schedule,local_vol,gflop_s_per_rank")
for L in [8, 16, 24]:
    x = jnp.ones((2*L, 2*L, 2*L, C), jnp.float32)
    flops_per_rank = 7 * 2 * (L**3) * C   # 6 neighbour adds + scale, fused mul-add
    for sched in ["sequential", "concurrent"]:
        g = jax.jit(compat.shard_map(lambda v, s=sched: stencil(v, s), mesh=mesh,
                                     in_specs=P("x","y","z",None),
                                     out_specs=P("x","y","z",None),
                                     check_vma=False))
        sec = time_call(g, x)
        print(f"{sched},{L}^3,{flops_per_rank/sec/1e9:.3f}")
"""


def run() -> str:
    return run_on_devices(SCRIPT)


if __name__ == "__main__":
    print(run())

"""Continuous batching A/B + paged-decode throughput sweep (repro.serve).

Part 1 — the acceptance-bar A/B: the mixed-length synthetic trace
(``repro.serve.scheduler.mixed_trace``) under the ``continuous`` vs
``static`` batching policies on one engine (no recompiles between runs).
Rows print as::

    policy,steps,generated,tok_per_step,tok_per_s,mean_live

followed by the two throughput ratios; ``ratio_tok_per_s`` is the paper's
claim (≥ 2x on the mixed trace — a long sequence no longer holds every
other slot hostage).

Part 2 — tokens/sec vs batch (slots) x page_tokens, with the serving
prediction layer's per-token collective count/wire bytes as columns
(asserted against lowered HLO at zero tolerance in the dry-run's
``--suite serve``; here they annotate measured throughput)::

    slots,page_tokens,model_parallel,coll_per_tok,wire_B_per_tok,kv_bytes,kv_pages,tok_per_s

On shared-memory host devices this measures the *mechanism* (one compiled
step, in-flight admit/retire, page recycling) — wire-level effects live in
the dry-run roofline (EXPERIMENTS.md explains the split).

``--dry`` runs a tiny trace + one sweep combo as a CI smoke.
"""

from __future__ import annotations

import argparse

from benchmarks.common import TIMER_SNIPPET, run_on_devices

SCRIPT = TIMER_SNIPPET + r"""
import time
import numpy as np
import jax, jax.numpy as jnp
from repro import compat
from repro.configs import reduced_config
from repro.models import build_model
from repro.serve.engine import (PagedDecodeEngine,
                                predicted_collectives_per_token,
                                predicted_wire_bytes_per_token)
from repro.serve.kv import plan_kv_arena
from repro.serve.scheduler import ServeScheduler, mixed_trace

DRY = %(dry)s
ARCH = "llama3.2-1b"
cfg = reduced_config(ARCH)
model = build_model(cfg)
params = model.init(jax.random.key(0))

def make_engine(slots, page_tokens, r, max_seq_len):
    mesh = compat.make_mesh((1, r), ("data", "model"),
                            devices=jax.devices()[:r])
    plan = plan_kv_arena(cfg, mesh, page_tokens=page_tokens,
                         page_bytes=4096, max_seqs=slots,
                         max_seq_len=max_seq_len)
    return PagedDecodeEngine(model, mesh, plan, attn_impl="ref"), plan

# --- part 1: continuous vs static on the mixed-length trace ---------------
groups, slots, long_len, short_len = (3, 3, 8, 2) if DRY else (4, 4, 64, 4)
eng, plan = make_engine(slots, 8, 1, long_len + 1)
# compile the (one) step before timing either policy — fixed traced shapes
# mean both runs then reuse it.  Two warmup steps: the first compiles for
# the fresh arena buffer, the second for the steady state where the donated
# pages output (now carrying the mesh sharding) threads back in.
eng.admit(0)
for _ in range(2):
    jax.block_until_ready(eng.decode(params, np.zeros(slots, np.int32)))
eng.retire(0)
print("policy,steps,generated,tok_per_step,tok_per_s,mean_live")
res = {}
for policy in ("continuous", "static"):
    trace = mixed_trace(groups=groups, slots=slots, long_len=long_len,
                        short_len=short_len)
    sched = ServeScheduler(eng, policy)
    t0 = time.perf_counter()
    r = sched.run(params, trace)
    jax.block_until_ready(eng.pages)      # drain the async dispatch queue
    wall = time.perf_counter() - t0
    r["tok_per_s"] = r["generated_tokens"] / wall
    res[policy] = r
    print(f"{policy},{r['steps']},{r['generated_tokens']},"
          f"{r['tokens_per_step']:.3f},{r['tok_per_s']:.1f},"
          f"{r['mean_live_slots']:.2f}")
print(f"ratio_tok_per_s,{res['continuous']['tok_per_s'] / res['static']['tok_per_s']:.2f}")
print(f"ratio_tok_per_step,{res['continuous']['tokens_per_step'] / res['static']['tokens_per_step']:.2f}")

# --- part 2: tokens/sec vs slots x page_tokens (+ a model-parallel row) ---
combos = [(2, 8, 1)] if DRY else [(2, 8, 1), (2, 16, 1), (4, 8, 1),
                                  (4, 16, 1), (4, 16, 2)]
n_steps = 4 if DRY else 16
print("slots,page_tokens,model_parallel,coll_per_tok,wire_B_per_tok,"
      "kv_bytes,kv_pages,tok_per_s")
for slots, pt, r in combos:
    eng, plan = make_engine(slots, pt, r, n_steps + 2)
    for s in range(slots):
        eng.admit(s)
    token = np.arange(slots, dtype=np.int32)
    for _ in range(2):                # fresh-arena + steady-state compiles
        jax.block_until_ready(eng.decode(params, token))
    t0 = time.perf_counter()
    for _ in range(n_steps - 1):
        jax.block_until_ready(eng.decode(params, token))
    wall = time.perf_counter() - t0
    tps = slots * (n_steps - 1) / wall
    print(f"{slots},{pt},{r},{predicted_collectives_per_token(plan)},"
          f"{predicted_wire_bytes_per_token(plan, cfg, slots):.0f},"
          f"{plan.total_bytes},{plan.n_arena_pages},{tps:.1f}")
"""


def run(dry: bool = False) -> str:
    return run_on_devices(SCRIPT % {"dry": dry})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="tiny trace + one sweep combo (CI smoke)")
    args = ap.parse_args()
    print(run(dry=args.dry))

"""Expert-parallel dispatch A/B: first-class ``all_to_all`` vs the
replicated-psum fallback (repro.comm + repro.models.moe).

One MoE layer's forward + backward under expert parallelism, swept over
the exchange transport x channel rails on a ``(1, R)`` model mesh.  The
``psum`` row *is* the old replicated path (zero-pad the capacity buffer
across the axis, all-reduce, slice), so the A/B is a column away::

    transport,channels,model_parallel,us_per_call,dispatch_B,total_B,msgs,vs_replicated

``dispatch_B`` / ``total_B`` / ``msgs`` come from
:meth:`repro.comm.api.Communicator.a2a_plan` — the same predictions the
dry-run's ``--suite moe`` asserts against lowered HLO at <1% tolerance;
here they annotate measured step times.  ``vs_replicated`` is the
per-device dispatch-bytes ratio against the psum fallback's prediction —
the PR's acceptance bound is <= 1/R for every real transport.

On shared-memory host devices this measures the *mechanism* (exchange
count, rail striping, fallback padding); wire-level effects live in the
dry-run roofline (EXPERIMENTS.md explains the split).

``--dry`` runs one tiny combo per transport as a CI smoke.
"""

from __future__ import annotations

import argparse

from benchmarks.common import TIMER_SNIPPET, run_on_devices

SCRIPT = TIMER_SNIPPET + r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod
from repro.runtime.train_step import TrainStepConfig, build_moe_comm, make_ctx

DRY = %(dry)s
if DRY:
    d, E, k, B, S, ff = 32, 4, 2, 4, 16, 64
    grid = [("a2a", 0, 2), ("psum", 0, 2)]
else:
    d, E, k, B, S, ff = 128, 8, 2, 8, 64, 256
    grid = [(t, c, r) for r in (2, 4) for t in ("a2a", "ring", "psum")
            for c in (0, 2)]

cfg = MoEConfig(num_experts=E, top_k=k, expert_ff=ff, capacity_factor=1.5,
                parallelism="ep")
params = moe_mod.moe_init(jax.random.key(0), cfg, d)
x = jnp.asarray(np.random.RandomState(0).randn(B, S, d).astype(np.float32))
pspecs = {"router": {"w": P()}, "w_gate": P("model"), "w_up": P("model"),
          "w_down": P("model")}
cap = moe_mod.capacity(S, cfg)

print("transport,channels,model_parallel,us_per_call,dispatch_B,total_B,"
      "msgs,vs_replicated")
rows = {}
for transport, channels, r in grid:
    mesh = compat.make_mesh((1, r), ("data", "model"),
                            devices=jax.devices()[:r])
    tcfg = TrainStepConfig(moe_transport=transport, moe_channels=channels)
    ctx = make_ctx(mesh, tcfg)
    comm = build_moe_comm(mesh, tcfg)
    plan = comm.a2a_plan((B // r, E, cap, d), dtype=jnp.float32)
    rep = build_moe_comm(mesh, TrainStepConfig(moe_transport="psum")) \
        .a2a_plan((B // r, E, cap, d), dtype=jnp.float32)

    def loss(p, xx):
        y, aux, _ = moe_mod.moe_apply(p, xx, cfg, "silu", ctx=ctx,
                                      compute_dtype=jnp.float32)
        return jnp.sum(y * y) + aux

    step = jax.jit(compat.shard_map(jax.grad(loss), mesh=mesh,
                                    in_specs=(pspecs, P()),
                                    out_specs=pspecs, check_vma=False))
    t = time_call(step, params, x, warmup=2, iters=5)
    ratio = plan.dispatch_bytes_per_device / rep.dispatch_bytes_per_device
    rows[(transport, channels, r)] = t
    print(f"{transport},{channels},{r},{t*1e6:.1f},"
          f"{plan.dispatch_bytes_per_device:.0f},"
          f"{plan.bytes_per_device:.0f},{plan.messages_per_device:.0f},"
          f"{ratio:.3f}")
    assert transport == "psum" or ratio <= 1.0 / r + 1e-9, \
        f"{transport} dispatch bytes exceed 1/R of the replicated cost"

for r in sorted({g[2] for g in grid}):
    a, b = rows.get(("a2a", 0, r)), rows.get(("psum", 0, r))
    if a and b:
        print(f"ratio_us_psum_over_a2a_r{r},{b / a:.2f}")
"""


def run(dry: bool = False) -> str:
    return run_on_devices(SCRIPT % {"dry": dry})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="one tiny combo per transport (CI smoke)")
    args = ap.parse_args()
    print(run(dry=args.dry))

"""The paper's "Seq vs Concurrent vs Threaded" table, for gradient reduction.

The halo benchmarks already sweep the paper's three endpoint-concurrency
columns; this is the same sweep for the SGD path: issue-schedule policy
{accumulate_then_reduce, stream, scheduled} x virtual channels {1, 2, 4},
executed by ``Communicator.reduce_scheduled`` on a microbatched step whose
grad_fn carries real matmul compute — so streamed schedules have backward
work to hide their collectives under (XLA's latency-hiding scheduler does
the overlapping; the schedule provides the independence).

Rows print as ``policy,channels,microbatches,us_per_step,pct_vs_accum``
(pct > 100 means faster than accumulate_then_reduce at the same channels).

On shared-memory host devices the streamed rows measure the *cost* side of
the paper's trade (more collective launches, finer buckets); the *benefit*
side — reductions hidden under backward compute — needs links that progress
independently of the cores, so it lives in the dry-run roofline's
``t_exposed_collective`` (EXPERIMENTS.md explains the split).
"""

from __future__ import annotations

from benchmarks.common import TIMER_SNIPPET, run_on_devices

SCRIPT = TIMER_SNIPPET + r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator

mesh = compat.make_mesh((8,), ("data",))
MICRO = 4
D, H, LAYERS = 256, 1024, 4       # enough matmul work to overlap against

rng = np.random.RandomState(0)
params = {f"layer{i}": {"wi": jnp.asarray(rng.randn(D, H).astype(np.float32) * 0.02),
                        "wo": jnp.asarray(rng.randn(H, D).astype(np.float32) * 0.02)}
          for i in range(LAYERS)}
batch = jnp.asarray(rng.randn(32 * MICRO, D).astype(np.float32))

def loss_fn(p, x):
    h = x
    for i in range(LAYERS):
        h = jnp.tanh(h @ p[f"layer{i}"]["wi"]) @ p[f"layer{i}"]["wo"]
    return jnp.mean(h ** 2)

def grad_fn(p, mb):
    return jax.value_and_grad(loss_fn)(p, mb)

print("policy,channels,microbatches,us_per_step,pct_vs_accum")
base = {}
for channels in (1, 2, 4):
    comm = Communicator(mesh, CommConfig(transport="ring_hier", chunks=2,
                                         channels=channels,
                                         bucket_bytes=D * H * 4,
                                         data_axes=("data",)))
    for policy in ("accumulate_then_reduce", "stream", "scheduled"):
        sched = comm.schedule(params, policy, MICRO)
        def inner(p, b):
            return comm.reduce_scheduled(grad_fn, p, b, sched,
                                         op="all_reduce")
        fn = jax.jit(compat.shard_map(
            inner, mesh=mesh, in_specs=(P(), P("data")),
            out_specs=(P(), P()), check_vma=False))
        sec = time_call(fn, params, batch)
        if policy == "accumulate_then_reduce":
            base[channels] = sec
        pct = 100.0 * base[channels] / sec
        print(f"{policy},{channels},{MICRO},{sec*1e6:.1f},{pct:.0f}")
"""


def run() -> str:
    return run_on_devices(SCRIPT)


if __name__ == "__main__":
    print(run())

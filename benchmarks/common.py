"""Benchmark harness helpers.

Measured benchmarks run in fresh subprocesses with 8 XLA host devices: the
paper's *algorithmic* effects (per-tensor call overhead, fusion, chunking,
schedule) are real and measurable on shared-memory devices even though the
wire is a memcpy; wire-level effects live in the dry-run roofline instead
(EXPERIMENTS.md explains the split).
"""

from __future__ import annotations

import inspect
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_on_devices(script: str, n_devices: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"benchmark subprocess failed:\n{proc.stderr[-4000:]}")
    return proc.stdout


class Timing(float):
    """Median seconds that still *is* a float (every bench call site keeps
    working), carrying the dispersion the tuner's fitter weights by."""

    t_min: float
    t_max: float
    samples: tuple

    def __new__(cls, samples):
        ts = sorted(float(t) for t in samples)
        mid = len(ts) // 2
        # true median: mean of the middle pair for even sample counts
        # (ts[len//2] alone is the *upper* median — biased high).  Parity
        # via & 1, not modulo: this source is embedded verbatim in bench
        # scripts that then go through printf-style substitution, where a
        # bare percent sign is a format character
        med = ts[mid] if len(ts) & 1 else 0.5 * (ts[mid - 1] + ts[mid])
        self = super().__new__(cls, med)
        self.t_min = ts[0]
        self.t_max = ts[-1]
        self.samples = tuple(ts)
        return self

    @property
    def spread(self):
        return self.t_max - self.t_min


def time_call(fn, *args, warmup=1, iters=3):
    """Median wall-seconds of ``iters`` blocked calls, as a :class:`Timing`."""
    import time

    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return Timing(ts)


# the same implementation, embedded verbatim in bench subprocess scripts —
# one source of truth for module importers and TIMER_SNIPPET consumers
TIMER_SNIPPET = "\n" + inspect.getsource(Timing) + "\n" + \
    inspect.getsource(time_call) + "\n"


def _obs_schema():
    # the harness may run without PYTHONPATH=src (python benchmarks/run.py)
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    from repro.obs import schema

    return schema


def bench_rows(stdout: str) -> list[dict]:
    """Parse a bench's CSV stdout into schema row dicts (repro.obs.schema)."""
    return _obs_schema().rows_from_csv(stdout)


def write_bench_json(out_dir: str, name: str, stdout: str,
                     meta: dict | None = None) -> str:
    """Write one ``BENCH_<name>.json`` under ``out_dir`` from a bench's CSV
    stdout, through the shared ``repro.obs.bench/v1`` schema; returns the
    path."""
    schema = _obs_schema()
    return schema.write_bench_record(out_dir, name, bench_rows(stdout),
                                     meta=meta)

"""Benchmark harness helpers.

Measured benchmarks run in fresh subprocesses with 8 XLA host devices: the
paper's *algorithmic* effects (per-tensor call overhead, fusion, chunking,
schedule) are real and measurable on shared-memory devices even though the
wire is a memcpy; wire-level effects live in the dry-run roofline instead
(EXPERIMENTS.md explains the split).
"""

from __future__ import annotations

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_on_devices(script: str, n_devices: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"benchmark subprocess failed:\n{proc.stderr[-4000:]}")
    return proc.stdout


TIMER_SNIPPET = r"""
import time
import jax

def time_call(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts)//2]  # median seconds
"""

"""Paper Figures 3/4: non-communication overhead (alloc/copy/local-sum) and
the fraction of time spent communicating, before/after optimisation.

Decomposition: ``collective_only`` times the ring on a pre-fused buffer
(pure comm); the full reducer adds bucketise/debucketise (the paper's
alloc+copy analogue).  The 'original' path pays per-tensor overhead."""

from __future__ import annotations

from benchmarks.common import TIMER_SNIPPET, run_on_devices

SCRIPT = TIMER_SNIPPET + r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator
from repro.core import ring
from repro.core.ring import RingConfig

mesh = compat.make_mesh((2, 4), ("pod", "data"))
rng = np.random.RandomState(0)

def workload(total, k=32):
    sizes = np.full(k, total // k)
    sizes[0] += total - sizes.sum()
    return {f"g{i}": jnp.asarray(rng.randn(int(s)).astype(np.float32))
            for i, s in enumerate(sizes)}

print("variant,elements,us_total,us_comm,pct_comm")
for total in [1<<14, 1<<20]:
    tree = workload(total)
    specs = {k: P() for k in tree}

    # pure-comm reference: one pre-fused aligned buffer
    cfg = RingConfig(chunks=2, bidirectional=True)
    pad = cfg.flat_divisor([4, 2])
    L = (total + pad - 1) // pad * pad
    flat = jnp.zeros((L,), jnp.float32)
    comm_only = jax.jit(compat.shard_map(
        lambda x: ring.hierarchical_all_reduce(x, ("data", "pod"), cfg),
        mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False))
    t_comm = time_call(comm_only, flat)

    for name, kw in [("original", dict(transport="ring", chunks=1,
                                       bidirectional=False, bucket_bytes=1)),
                     ("optimised", dict(transport="ring_hier",
                                        chunks=2, bucket_bytes=32*2**20))]:
        comm = Communicator(mesh, CommConfig(data_axes=("pod","data"), **kw))
        fn = jax.jit(lambda g: comm.reduce(g, specs)[0])
        t_total = time_call(fn, tree)
        pct = 100.0 * min(t_comm / t_total, 1.0)
        print(f"{name},{total},{t_total*1e6:.1f},{t_comm*1e6:.1f},{pct:.0f}")
"""


def run() -> str:
    return run_on_devices(SCRIPT)


if __name__ == "__main__":
    print(run())

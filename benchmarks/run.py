"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV blocks (measured on 8 XLA host
devices in subprocesses; see benchmarks/common.py for why measured numbers
live here and wire-level numbers live in the dry-run roofline).  With
``--json DIR`` every section's rows are additionally written as
``DIR/BENCH_<name>.json`` through the shared ``repro.obs.bench/v1`` schema,
so the perf trajectory is machine-diffable run-over-run.

    PYTHONPATH=src python -m benchmarks.run [--only allreduce,halo,...] \
        [--json out/] [--dry]
"""

from __future__ import annotations

import argparse
import inspect
import sys
import time

from benchmarks import bench_allreduce, bench_arena, bench_cg, bench_halo, \
    bench_moe, bench_overhead, bench_overlap, bench_serve, bench_stencil
from benchmarks.common import write_bench_json

SECTIONS = [
    ("fig1_2_5_allreduce", bench_allreduce.run,
     "Figs 1/2/5: reduction time & bandwidth vs vector length"),
    ("fig3_4_overhead", bench_overhead.run,
     "Figs 3/4: non-comm overhead and %time in communication"),
    ("tab_overlap_sgd", bench_overlap.run,
     "Seq vs Concurrent vs Threaded, for gradient reduction: "
     "schedule policy x channels"),
    ("tab_mem_arena", bench_arena.run,
     "Huge-page arena vs per-bucket reduction: "
     "page_bytes x channels (repro.mem)"),
    ("tab_serve_batching", bench_serve.run,
     "Continuous vs static batching + paged-decode throughput: "
     "slots x page_tokens (repro.serve)"),
    ("tab_moe_ep", bench_moe.run,
     "EP dispatch/combine A/B: all_to_all transport x channels vs the "
     "replicated-psum fallback (repro.comm + repro.models.moe)"),
    ("tab1_3_halo", bench_halo.run,
     "Tables I-III: halo exchange schedules"),
    ("tab5_6_stencil", bench_stencil.run,
     "Tables V/VI: stencil application throughput"),
    ("tab5_6_cg_solver", bench_cg.run,
     "CG on the Wilson-like operator to convergence: "
     "halo schedule x channels"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="also write each section's rows as "
                         "DIR/BENCH_<name>.json (repro.obs.bench/v1)")
    ap.add_argument("--dry", action="store_true",
                    help="reduced shapes/iters where a bench supports it "
                         "(CI smoke)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failures = 0
    for name, fn, desc in SECTIONS:
        if only and not any(o in name for o in only):
            continue
        print(f"\n## {name} — {desc}", flush=True)
        t0 = time.time()
        kw = ({"dry": True} if args.dry
              and "dry" in inspect.signature(fn).parameters else {})
        try:
            out = fn(**kw)
            sys.stdout.write(out)
            dt = time.time() - t0
            print(f"## {name} done in {dt:.0f}s", flush=True)
            if args.json:
                path = write_bench_json(
                    args.json, name, out,
                    meta={"desc": desc, "seconds": round(dt, 3),
                          "dry": bool(kw)})
                print(f"## {name} rows -> {path}", flush=True)
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"## {name} FAILED: {e}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""The paper's huge-page/fused-buffer table, for gradient reduction: the
:mod:`repro.mem` CommArena (pack -> fused-span reduce -> unpack, persistent
donated buffer) vs the per-bucket baseline at the same bucket config.

Sweeps page_bytes {4 KiB small-page baseline, 2 MiB huge page} x virtual
channels {1, 2, 4}.  Rows print as::

    page_bytes,channels,n_buckets,n_spans,pad_pct,us_arena,us_buckets,pct

``pct`` > 100 means the arena path is faster.  On shared-memory host
devices this measures the *mechanism* (fewer collective launches, aligned
flat copies, in-place donated buffer) — wire-level byte/page accounting
lives in the dry-run's ``--suite mem`` roofline (EXPERIMENTS.md explains
the split).

A second block sweeps the wire codec on the arena path at page 4096 —
fp32 / bf16 rail / int8+scales (the fused Pallas pack+quantize arena) —
printing predicted vs HLO-lowered collective wire bytes per codec.

``--dry`` runs one tiny combo per page size (plus the codec block) as a
CI smoke.
"""

from __future__ import annotations

import argparse

from benchmarks.common import TIMER_SNIPPET, run_on_devices

SCRIPT = TIMER_SNIPPET + r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator

DRY = %(dry)s
mesh = compat.make_mesh((8,), ("data",))
rng = np.random.RandomState(0)
N_LEAVES, LEAF = (6, 4096) if DRY else (24, 65536)
params = {f"g{i}": jnp.asarray(rng.randn(LEAF + 128 * i).astype(np.float32))
          for i in range(N_LEAVES)}
batch = jnp.asarray(rng.randn(16, 8).astype(np.float32))

def loss_fn(p, x):
    return sum(jnp.sum(v) for v in p.values()) * 1e-3 + jnp.mean(x) * 0.0

def grad_fn(p, mb):
    return jax.value_and_grad(loss_fn)(p, mb)

print("page_bytes,channels,n_buckets,n_spans,pad_pct,us_arena,us_buckets,pct")
pages = [4096, 2 * 2**20]
chans = [1] if DRY else [1, 2, 4]
for page_bytes in pages:
    for channels in chans:
        comm = Communicator(mesh, CommConfig(
            transport="ring_hier", chunks=2, channels=channels,
            bucket_bytes=4 * LEAF, page_bytes=page_bytes,
            data_axes=("data",)))
        sched = comm.schedule(params, "scheduled", 1)
        asched = comm.arena_schedule(params, "scheduled", 1)
        arena = comm.arena(params)
        lay = arena.layout

        def bucket_run(p, b):
            return comm.reduce_scheduled(grad_fn, p, b, sched,
                                         op="all_reduce")

        def arena_run(p, b, buf):
            loss, (tree, out) = comm.reduce_scheduled(
                grad_fn, p, b, asched, op="all_reduce", arena=arena,
                arena_buf=buf)
            return loss, tree, out

        fb = jax.jit(compat.shard_map(
            bucket_run, mesh=mesh, in_specs=(P(), P("data")),
            out_specs=(P(), P()), check_vma=False))
        fa = jax.jit(compat.shard_map(
            arena_run, mesh=mesh, in_specs=(P(), P("data"), P(("data",))),
            out_specs=(P(), P(), P(("data",))), check_vma=False),
            donate_argnums=(2,))
        t_bucket = time_call(fb, params, batch)
        # the train-step contract: the returned (donated) arena threads
        # straight back in, so no per-step allocation is paid or timed
        state = {"buf": jnp.zeros((8 * lay.total_elems,), jnp.float32)}
        def arena_call(p, b):
            loss, tree, out = fa(p, b, state["buf"])
            state["buf"] = out
            return loss
        t_arena = time_call(arena_call, params, batch)
        pct = 100.0 * t_bucket / t_arena
        print(f"{page_bytes},{channels},{lay.n_segments},{lay.n_spans},"
              f"{100.0 * lay.padding_fraction:.2f},"
              f"{t_arena*1e6:.1f},{t_bucket*1e6:.1f},{pct:.0f}")

# -- wire codec block: the quantized arena vs the fp32/bf16 wire ------------
# The int8 ring re-encodes per chunk, so flat buffers must hold whole codec
# blocks per chunk: leaves here are multiples of world*chunks*2*block.
# bf16 hlo bytes read fp32 on this backend (XLA CPU float normalization
# upcasts bf16 collectives); pred_* columns carry the wire format.
from repro.launch.roofline import collective_wire_bytes

CODECS = [
    ("fp32", dict()),
    ("bf16", dict(wire_dtype="bfloat16")),
    ("int8", dict(wire_codec="int8")),
]
Q_LEAF = 65536
params_q = {f"q{i}": jnp.asarray(rng.randn(Q_LEAF).astype(np.float32))
            for i in range(4 if DRY else 16)}
N_ELEMS = sum(int(v.size) for v in params_q.values())
print()
print("# wire codec on the arena path (ring, page 4096, ch1): "
      "predicted vs lowered HLO bytes")
print("codec,elements,us_arena,pred_wire_bytes,hlo_wire_bytes,pred_ratio_vs_fp32")
base_bytes = None
for name, wire_kw in CODECS:
    comm = Communicator(mesh, CommConfig(
        transport="ring", chunks=2, channels=1, bucket_bytes=4 * Q_LEAF,
        page_bytes=4096, data_axes=("data",), **wire_kw))
    asched = comm.arena_schedule(params_q, "scheduled", 1)
    arena = comm.arena(params_q)
    lay = arena.layout
    quant = comm.codec is not None
    if quant:
        def arena_run(p, b, buf, ef):
            loss, (tree, out, ef2) = comm.reduce_scheduled(
                grad_fn, p, b, asched, op="all_reduce", arena=arena,
                arena_buf=buf, ef_buf=ef)
            return loss, tree, out, ef2
        donate, flat = (2, 3), P(("data",))
        in_specs = (P(), P("data"), flat, flat)
        out_specs = (P(), P(), flat, flat)
    else:
        def arena_run(p, b, buf):
            loss, (tree, out) = comm.reduce_scheduled(
                grad_fn, p, b, asched, op="all_reduce", arena=arena,
                arena_buf=buf)
            return loss, tree, out
        donate, flat = (2,), P(("data",))
        in_specs = (P(), P("data"), flat)
        out_specs = (P(), P(), flat)
    fa = jax.jit(compat.shard_map(arena_run, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, check_vma=False),
                 donate_argnums=donate)
    bufs = [jnp.zeros((8 * lay.total_elems,), jnp.dtype(lay.dtype))]
    if quant:
        bufs.append(jnp.zeros((8 * lay.payload_elems,), jnp.float32))
    hlo = fa.lower(params_q, batch, *bufs).compile().as_text()
    meas = sum(collective_wire_bytes(hlo).op_bytes.values())
    pred = comm.plan(params_q).arena_bytes_per_device
    state = {"bufs": bufs}
    def arena_call(p, b):
        out = fa(p, b, *state["bufs"])
        state["bufs"] = list(out[2:])
        return out[0]
    t = time_call(arena_call, params_q, batch)
    if name == "fp32":
        base_bytes = pred
    ratio = base_bytes / pred if pred else 0.0
    print(f"{name},{N_ELEMS},{t*1e6:.1f},{pred:.0f},{meas:.0f},{ratio:.2f}")
"""


def run(dry: bool = False) -> str:
    return run_on_devices(SCRIPT % {"dry": dry})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="tiny single-channel combo per page size (CI smoke)")
    args = ap.parse_args()
    print(run(dry=args.dry))

"""The paper's huge-page/fused-buffer table, for gradient reduction: the
:mod:`repro.mem` CommArena (pack -> fused-span reduce -> unpack, persistent
donated buffer) vs the per-bucket baseline at the same bucket config.

Sweeps page_bytes {4 KiB small-page baseline, 2 MiB huge page} x virtual
channels {1, 2, 4}.  Rows print as::

    page_bytes,channels,n_buckets,n_spans,pad_pct,us_arena,us_buckets,pct

``pct`` > 100 means the arena path is faster.  On shared-memory host
devices this measures the *mechanism* (fewer collective launches, aligned
flat copies, in-place donated buffer) — wire-level byte/page accounting
lives in the dry-run's ``--suite mem`` roofline (EXPERIMENTS.md explains
the split).

``--dry`` runs one tiny combo per page size as a CI smoke.
"""

from __future__ import annotations

import argparse

from benchmarks.common import TIMER_SNIPPET, run_on_devices

SCRIPT = TIMER_SNIPPET + r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator

DRY = %(dry)s
mesh = compat.make_mesh((8,), ("data",))
rng = np.random.RandomState(0)
N_LEAVES, LEAF = (6, 4096) if DRY else (24, 65536)
params = {f"g{i}": jnp.asarray(rng.randn(LEAF + 128 * i).astype(np.float32))
          for i in range(N_LEAVES)}
batch = jnp.asarray(rng.randn(16, 8).astype(np.float32))

def loss_fn(p, x):
    return sum(jnp.sum(v) for v in p.values()) * 1e-3 + jnp.mean(x) * 0.0

def grad_fn(p, mb):
    return jax.value_and_grad(loss_fn)(p, mb)

print("page_bytes,channels,n_buckets,n_spans,pad_pct,us_arena,us_buckets,pct")
pages = [4096, 2 * 2**20]
chans = [1] if DRY else [1, 2, 4]
for page_bytes in pages:
    for channels in chans:
        comm = Communicator(mesh, CommConfig(
            transport="ring_hier", chunks=2, channels=channels,
            bucket_bytes=4 * LEAF, page_bytes=page_bytes,
            data_axes=("data",)))
        sched = comm.schedule(params, "scheduled", 1)
        asched = comm.arena_schedule(params, "scheduled", 1)
        arena = comm.arena(params)
        lay = arena.layout

        def bucket_run(p, b):
            return comm.reduce_scheduled(grad_fn, p, b, sched,
                                         op="all_reduce")

        def arena_run(p, b, buf):
            loss, (tree, out) = comm.reduce_scheduled(
                grad_fn, p, b, asched, op="all_reduce", arena=arena,
                arena_buf=buf)
            return loss, tree, out

        fb = jax.jit(compat.shard_map(
            bucket_run, mesh=mesh, in_specs=(P(), P("data")),
            out_specs=(P(), P()), check_vma=False))
        fa = jax.jit(compat.shard_map(
            arena_run, mesh=mesh, in_specs=(P(), P("data"), P(("data",))),
            out_specs=(P(), P(), P(("data",))), check_vma=False),
            donate_argnums=(2,))
        t_bucket = time_call(fb, params, batch)
        # the train-step contract: the returned (donated) arena threads
        # straight back in, so no per-step allocation is paid or timed
        state = {"buf": jnp.zeros((8 * lay.total_elems,), jnp.float32)}
        def arena_call(p, b):
            loss, tree, out = fa(p, b, state["buf"])
            state["buf"] = out
            return loss
        t_arena = time_call(arena_call, params, batch)
        pct = 100.0 * t_bucket / t_arena
        print(f"{page_bytes},{channels},{lay.n_segments},{lay.n_spans},"
              f"{100.0 * lay.padding_fraction:.2f},"
              f"{t_arena*1e6:.1f},{t_bucket*1e6:.1f},{pct:.0f}")
"""


def run(dry: bool = False) -> str:
    return run_on_devices(SCRIPT % {"dry": dry})


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="tiny single-channel combo per page size (CI smoke)")
    args = ap.parse_args()
    print(run(dry=args.dry))

"""Batched serving example: prefill a batch of prompts, then decode tokens
step-by-step with donated KV caches.

    PYTHONPATH=src python examples/serve_lm.py --tokens 32
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.transformer import init_decode_state
from repro.runtime.serve_step import build_decode_step
from repro.sharding import shardings_of


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--cache", type=int, default=1024)
    args = ap.parse_args()

    model = build_model(reduced_config("llama3.2-1b").with_(
        num_layers=4, d_model=128, d_ff=512))
    mesh = make_host_mesh()
    shape = ShapeConfig("serve", args.cache, args.batch, "decode")
    step, pspecs, sspecs = build_decode_step(model, mesh, shape)

    params = model.init(jax.random.key(0))
    with mesh:
        params = jax.jit(lambda p: p,
                         out_shardings=shardings_of(pspecs, mesh))(params)
        state = init_decode_state(model.cfg, args.batch, args.cache)
        state = jax.jit(lambda s: s,
                        out_shardings=shardings_of(sspecs, mesh))(state)

    rng = np.random.RandomState(0)
    token = jnp.asarray(rng.randint(0, 100, (args.batch,)), jnp.int32)
    out_tokens = []
    t0 = time.time()
    for pos in range(args.tokens):
        with mesh:
            logits, state = step(params, token, state, jnp.asarray(pos))
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # local-vocab logits: argmax index is within this shard's range when
        # model_parallel == 1 (host demo); production combines via psum-argmax
        token = jnp.clip(token, 0, model.cfg.vocab_size - 1)
        out_tokens.append(np.asarray(token))
    dt = time.time() - t0
    toks = args.tokens * args.batch
    print(f"decoded {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on {len(jax.devices())} host devices)")
    print("sample stream:", [int(t[0]) for t in out_tokens[:16]])


if __name__ == "__main__":
    main()

"""Quickstart: train a tiny llama on synthetic data with the paper's
optimised gradient reduction, on however many devices this host has.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.comm import CommConfig
from repro.configs import reduced_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim import OptimConfig
from repro.runtime.train_loop import Trainer, TrainerConfig
from repro.runtime.train_step import TrainStepConfig


def main() -> None:
    model = build_model(reduced_config("llama3.2-1b").with_(
        num_layers=4, d_model=128, d_ff=512))
    mesh = make_host_mesh()
    print(f"devices: {len(jax.devices())}, mesh: "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")

    shape = ShapeConfig("quickstart", seq_len=128, global_batch=8, kind="train")
    data = SyntheticTokens(DataConfig(vocab_size=model.cfg.vocab_size,
                                      seq_len=128, global_batch=8))
    step_cfg = TrainStepConfig(
        dp_mode="replicated",
        comm=CommConfig(transport="ring_hier", chunks=2),
        optim=OptimConfig(base_lr=3e-3, warmup=10, total_steps=60),
        microbatches=1)
    trainer = Trainer(model, mesh, step_cfg, data, shape,
                      TrainerConfig(steps=60, log_every=10, ckpt_dir=None))
    out = trainer.run()
    first = out["history"][0]["loss"]
    last = out["history"][-1]["loss"]
    print(f"\nloss {first:.4f} -> {last:.4f} over {len(out['history'])} steps "
          f"({out['wall']:.1f}s)")


if __name__ == "__main__":
    main()

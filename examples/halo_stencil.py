"""The paper's first workload end-to-end: a Wilson-like stencil operator
driven to convergence by the comm-avoiding CG family — ``solver ∈ {cg,
pipelined, sstep} × precond ∈ {none, eo}`` — with the halo exchange on the
``overlap`` schedule.  The ``reductions`` column counts the latency-bound
inner-product all-reduces each variant pays: classic CG's ``2·iters+1``
drops to ``iters`` (pipelined, reduction hidden under the matvec) to
``ceil(iters/s)`` (s-step, one fused reduction per block), and even-odd
preconditioning roughly halves ``iters`` on top.

    PYTHONPATH=src python examples/halo_stencil.py

Run with more fake devices to see the schedules and variants diverge:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/halo_stencil.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm import CommConfig, Communicator
from repro.core.halo import HaloSpec
from repro.stencil import (PRECONDS, SOLVERS, StencilOp,
                           predicted_reduction_collectives, solve)


def main() -> None:
    n = len(jax.devices())
    mesh = compat.make_mesh((n,), ("x",))
    L, C = 24, 12                        # local extent, spinor-ish components
    specs = (HaloSpec("x", 0),)
    op = StencilOp(specs=specs, mass=0.2)
    comm = Communicator(mesh, CommConfig(transport="psum", data_axes=("x",),
                                         channels=2))
    rng = np.random.RandomState(0)
    b = jnp.asarray(rng.randn(n * L, L, C).astype(np.float32))

    hplan = comm.halo_plan((L, L, C), specs, schedule="overlap")
    print(f"devices={n}  local={L}x{L}x{C}  halo bytes/exchange="
          f"{hplan.bytes_per_device:.0f}  "
          f"overlap_frac={hplan.overlap_fraction:.2f}\n")
    print(f"{'solver':10s} {'precond':8s} {'iters':>5s} {'reductions':>10s} "
          f"{'rel_resid':>10s} {'ms/solve':>9s}")

    sols = {}
    for solver in SOLVERS:
        for precond in PRECONDS:
            def run(bl, sv=solver, pc=precond):
                r = solve(op, bl, comm, solver=sv, precond=pc, s=4, tol=1e-5,
                          maxiter=300, schedule="overlap", chunks=2,
                          channels=2)
                return r.x, r.iters, r.rel_residual
            fn = jax.jit(compat.shard_map(
                run, mesh=mesh, in_specs=P("x", None, None),
                out_specs=(P("x", None, None), P(), P()), check_vma=False))
            x, iters, rel = jax.block_until_ready(fn(b))
            t0 = time.time()
            for _ in range(3):
                jax.block_until_ready(fn(b))
            dt = (time.time() - t0) / 3
            sols[(solver, precond)] = np.asarray(x)
            red = predicted_reduction_collectives(solver, int(iters), s=4)
            print(f"{solver:10s} {precond:8s} {int(iters):5d} {red:10d} "
                  f"{float(rel):10.2e} {dt*1e3:9.1f}")

    ref = sols[("cg", "none")]
    worst = max(float(np.abs(s - ref).max()) for s in sols.values())
    print(f"\nmax |x_variant - x_cg| across the family: {worst:.2e}")
    ax = op.apply_reference(jnp.asarray(ref))
    print(f"final check ‖A x - b‖/‖b‖ = "
          f"{float(jnp.linalg.norm(ax - b) / jnp.linalg.norm(b)):.2e}")


if __name__ == "__main__":
    main()

"""The paper's first workload: Cartesian halo exchange feeding a Wilson-like
stencil operator, comparing the three communication schedules.

    PYTHONPATH=src python examples/halo_stencil.py
"""

import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm import CommConfig, Communicator
from repro.core.halo import HaloSpec, halo_bytes


def main() -> None:
    n = len(jax.devices())
    mesh = compat.make_mesh((n,), ("x",))
    L, C = 32, 12
    specs = [HaloSpec("x", 0)]
    x = jnp.ones((n * L, L, C), jnp.float32)
    comm = Communicator(mesh, CommConfig(data_axes=("x",), channels=2))

    def stencil(xl, schedule):
        h = comm.halo_exchange(xl, specs, schedule=schedule)
        up = jnp.concatenate([h[("x", "-")], xl], axis=0)
        dn = jnp.concatenate([xl, h[("x", "+")]], axis=0)
        m = xl.shape[0]
        return (2.0 * xl - jax.lax.slice_in_dim(up, 0, m, axis=0)
                - jax.lax.slice_in_dim(dn, 1, m + 1, axis=0))

    nbytes = halo_bytes((L, L, C), specs, 4)
    for sched in ["sequential", "concurrent", "chunked"]:
        fn = jax.jit(compat.shard_map(lambda v, s=sched: stencil(v, s),
                                      mesh=mesh, in_specs=P("x"),
                                      out_specs=P("x"), check_vma=False))
        jax.block_until_ready(fn(x))
        t0 = time.time()
        for _ in range(10):
            jax.block_until_ready(fn(x))
        dt = (time.time() - t0) / 10
        print(f"{sched:12s}: {dt*1e6:8.1f} us/apply "
              f"({nbytes/dt/1e6:.1f} MB/s halo traffic per rank)")


if __name__ == "__main__":
    main()

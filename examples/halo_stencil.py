"""The paper's first workload end-to-end: a Wilson-like stencil operator
driven by CG to convergence, comparing all four halo-exchange schedules
(sequential / concurrent / chunked / overlap) on one Cartesian mesh.

    PYTHONPATH=src python examples/halo_stencil.py

Run with more fake devices to see the schedules diverge:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/halo_stencil.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm import CommConfig, Communicator, HALO_SCHEDULES
from repro.core.halo import HaloSpec
from repro.stencil import StencilOp, cg_solve


def main() -> None:
    n = len(jax.devices())
    mesh = compat.make_mesh((n,), ("x",))
    L, C = 24, 12                        # local extent, spinor-ish components
    specs = (HaloSpec("x", 0),)
    op = StencilOp(specs=specs, mass=0.5)
    comm = Communicator(mesh, CommConfig(transport="psum", data_axes=("x",),
                                         channels=2))
    rng = np.random.RandomState(0)
    b = jnp.asarray(rng.randn(n * L, L, C).astype(np.float32))

    hplan = comm.halo_plan((L, L, C), specs)
    print(f"devices={n}  local={L}x{L}x{C}  halo bytes/exchange="
          f"{hplan.bytes_per_device:.0f}\n")
    print(f"{'schedule':12s} {'iters':>5s} {'rel_resid':>10s} "
          f"{'ms/solve':>9s} {'overlap_frac':>12s}")

    sols = {}
    for sched in HALO_SCHEDULES:
        def run(bl, s=sched):
            r = cg_solve(op, bl, comm, tol=1e-6, maxiter=200, schedule=s,
                         chunks=2, channels=2)
            return r.x, r.iters, r.rel_residual
        fn = jax.jit(compat.shard_map(run, mesh=mesh,
                                      in_specs=P("x", None, None),
                                      out_specs=(P("x", None, None), P(), P()),
                                      check_vma=False))
        x, iters, rel = jax.block_until_ready(fn(b))
        t0 = time.time()
        for _ in range(3):
            jax.block_until_ready(fn(b))
        dt = (time.time() - t0) / 3
        sols[sched] = np.asarray(x)
        frac = comm.halo_schedule((L, L, C), specs,
                                  schedule=sched).overlap_fraction
        print(f"{sched:12s} {int(iters):5d} {float(rel):10.2e} "
              f"{dt*1e3:9.1f} {frac:12.2f}")

    worst = max(float(np.abs(sols[s] - sols["sequential"]).max())
                for s in HALO_SCHEDULES)
    print(f"\nmax |x_sched - x_sequential| across schedules: {worst:.2e}")
    ax = op.apply_reference(jnp.asarray(sols["overlap"]))
    print(f"final check ‖A x - b‖/‖b‖ = "
          f"{float(jnp.linalg.norm(ax - b) / jnp.linalg.norm(b)):.2e}")


if __name__ == "__main__":
    main()

"""The paper's headline experiment, live: reduce a gradient-sized pytree
with the original Baidu-style schedule vs the optimised one.

    PYTHONPATH=src python examples/allreduce_demo.py --elements 4194304
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.comm import CommConfig, Communicator


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--elements", type=int, default=1 << 22)
    ap.add_argument("--tensors", type=int, default=64)
    args = ap.parse_args()

    n = len(jax.devices())
    if n == 1:
        print("NOTE: only 1 device visible — rings degenerate to identity, "
              "so this measures pure bucketing overhead.  Run with\n"
              "  XLA_FLAGS=--xla_force_host_platform_device_count=8\n"
              "to see the paper's before/after (as benchmarks/run.py does).")
    mesh = compat.make_mesh((n,), ("data",))
    rng = np.random.RandomState(0)
    k = args.tensors
    sizes = np.full(k, args.elements // k)
    sizes[0] += args.elements - sizes.sum()
    tree = {f"g{i}": jnp.asarray(rng.randn(int(s)).astype(np.float32))
            for i, s in enumerate(sizes)}
    specs = {key: P() for key in tree}

    results = {}
    for name, kw in [
        ("original         (per-tensor, uni-ring)",
         dict(transport="ring", chunks=1, bidirectional=False, bucket_bytes=1)),
        ("ring             (buckets + bi + chunks)",
         dict(transport="ring", chunks=2, bucket_bytes=32 * 2**20)),
        ("ring x2 rails    (channel striping)",
         dict(transport="ring", chunks=2, channels=2, bucket_bytes=32 * 2**20)),
        ("psum             (vendor reference)",
         dict(transport="psum", fuse=False)),
    ]:
        comm = Communicator(mesh, CommConfig(data_axes=("data",), **kw))
        fn = jax.jit(lambda g: comm.reduce(g, specs)[0])
        jax.block_until_ready(fn(tree))
        t0 = time.time()
        for _ in range(5):
            jax.block_until_ready(fn(tree))
        dt = (time.time() - t0) / 5
        results[name] = dt
        print(f"{name}: {dt*1e6:10.1f} us/reduction")
    base = results[list(results)[0]]
    for name, dt in list(results.items())[1:]:
        label = name.split("(")[0].strip()
        print(f"speedup vs original — {label}: {base/dt:.1f}x")


if __name__ == "__main__":
    main()

"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps with
checkpoint/restart, straggler accounting and the paper's reducer.

    PYTHONPATH=src python examples/train_lm.py --steps 300 \
        --transport ring_hier --channels 2 --dp-mode zero1

Interrupt it and re-run: it resumes from the last committed checkpoint.
"""

import argparse

import jax

from repro.comm import CommConfig, list_transports
from repro.configs import get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim import OptimConfig
from repro.runtime.train_loop import Trainer, TrainerConfig
from repro.runtime.train_step import DP_MODES, TrainStepConfig


def build_100m():
    """~100M-param llama-style config (fits host CPU comfortably)."""
    cfg = get_config("llama3.2-1b").with_(
        num_layers=8, d_model=512, d_ff=2048, vocab_size=32000,
        dtype="float32", remat="none", sharding="tp")
    attn = cfg.attn.__class__(**{**cfg.attn.__dict__, "num_heads": 8,
                                 "num_kv_heads": 4, "head_dim": 64})
    return build_model(cfg.with_(attn=attn))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--transport", default="ring_hier",
                    choices=list_transports())
    ap.add_argument("--channels", type=int, default=0,
                    help="virtual comm rails (0 = unconstrained)")
    ap.add_argument("--dp-mode", default="zero1", choices=DP_MODES)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--use-arena", action="store_true",
                    help="reduce out of the page-aligned repro.mem arena")
    ap.add_argument("--wire-codec", default=None, choices=["int8"],
                    help="quantize the gradient wire (int8 + per-block "
                         "scales with error feedback)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    model = build_100m()
    print(f"model: {model.param_count()/1e6:.1f}M params")
    mesh = make_host_mesh()
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    data = SyntheticTokens(DataConfig(vocab_size=model.cfg.vocab_size,
                                      seq_len=args.seq,
                                      global_batch=args.batch))
    step_cfg = TrainStepConfig(
        dp_mode=args.dp_mode,
        comm=CommConfig(transport=args.transport, channels=args.channels,
                        chunks=2, bucket_bytes=32 * 2**20),
        optim=OptimConfig(base_lr=args.lr, warmup=20, schedule="wsd",
                          total_steps=args.steps),
        microbatches=args.microbatches, schedule="stream",
        use_arena=args.use_arena, wire_codec=args.wire_codec)
    trainer = Trainer(model, mesh, step_cfg, data, shape,
                      TrainerConfig(steps=args.steps, ckpt_every=50,
                                    ckpt_dir=args.ckpt_dir, log_every=20))
    out = trainer.run()
    hist = out["history"]
    if hist:
        print(f"\nfinal loss {hist[-1]['loss']:.4f}; "
              f"{len(out['straggler_events'])} straggler events; "
              f"median step {sorted(h['sec'] for h in hist)[len(hist)//2]*1e3:.0f} ms")


if __name__ == "__main__":
    main()

"""repro.mem — the page-aligned CommArena subsystem.

Layout invariants (page-quantized offsets, non-overlap, padding
accounting), the oversized-leaf warning, Pallas pack kernels vs the jnp
oracle (bitwise), span-fused schedules, the fused-collective claim in
lowered HLO, a 2-proc cross-transport regression, checkpoint round-trips
across ``use_arena`` toggles, and (slow) full train-step equivalence of the
arena path for all three DP modes."""

import numpy as np
import pytest

from conftest import run_distributed

from repro.comm import CommConfig, Communicator, build_schedule
from repro.mem import (ArenaLayout, CommArena, PAGE_BYTES, fuse_schedule,
                       plan_arena)


def _mesh1():
    from repro import compat

    return compat.make_mesh((1,), ("data",))


# ---------------------------------------------------------------------------
# layout invariants
# ---------------------------------------------------------------------------

SIZES = (512, 128, 1024, 256, 256, 64)


@pytest.mark.parametrize("page_bytes", [512, 4096, 2 * 2**20])
@pytest.mark.parametrize("channel_of", [None, [0, 1, 0, 1, 0, 1],
                                        [2, 2, 0, 1, 0, 2]])
def test_layout_invariants(page_bytes, channel_of):
    lay = plan_arena(SIZES, page_bytes=page_bytes, channel_of=channel_of,
                     pad_multiple=8)
    lay.validate()                       # offsets quantized, non-overlapping
    quantum = lay.quantum
    assert quantum % (page_bytes // 4) == 0
    end = 0
    for s in sorted(lay.segments, key=lambda s: s.offset):
        assert s.offset % quantum == 0 and s.padded % quantum == 0
        assert s.offset >= end           # non-overlapping, ordered
        end = s.offset + s.padded
    assert lay.total_elems == end
    # every bucket appears exactly once, in exactly one span
    assert sorted(s.bucket for s in lay.segments) == list(range(len(SIZES)))
    span_members = [b for sp in lay.spans for b in sp.buckets]
    assert sorted(span_members) == list(range(len(SIZES)))
    # padding fraction matches the prediction identity
    assert lay.used_elems == sum(SIZES)
    assert lay.padding_elems == lay.total_elems - sum(SIZES)
    assert lay.padding_fraction == pytest.approx(
        1.0 - sum(SIZES) / lay.total_elems)
    # whole pages, exactly
    assert lay.total_bytes == lay.n_pages * page_bytes or \
        lay.total_bytes % page_bytes == 0
    d = lay.describe()
    assert d["n_pages"] == lay.n_pages
    assert d["padding_fraction"] == lay.padding_fraction
    assert len(d["segments"]) == len(SIZES)


def test_layout_channel_grouping_is_contiguous():
    lay = plan_arena(SIZES, page_bytes=512, channel_of=[1, 0, 1, 0, 1, 0])
    assert lay.n_spans == 2
    for sp in lay.spans:
        run = sp.offset
        for b in sp.buckets:
            seg = lay.segment_of(b)
            assert seg.offset == run and seg.channel == sp.channel
            run += seg.padded
        assert run - sp.offset == sp.size


def test_plan_arena_rejects_bad_args():
    with pytest.raises(ValueError, match="page_bytes"):
        plan_arena(SIZES, page_bytes=0)
    with pytest.raises(ValueError, match="page_bytes"):
        plan_arena(SIZES, page_bytes=129)       # not an itemsize multiple
    with pytest.raises(ValueError, match="channel_of"):
        plan_arena(SIZES, channel_of=[0, 1])
    with pytest.raises(ValueError, match="pad_multiple"):
        plan_arena(SIZES, pad_multiple=0)


def test_default_page_is_the_papers_huge_page():
    assert PAGE_BYTES == 2 * 2**20
    lay = plan_arena([100])
    assert lay.total_bytes % PAGE_BYTES == 0
    assert CommConfig().page_bytes == PAGE_BYTES


# ---------------------------------------------------------------------------
# oversized-leaf buckets: dedicated page-aligned segments + one warning
# ---------------------------------------------------------------------------


def test_oversized_bucket_warns_once_and_gets_dedicated_segment():
    import warnings as w

    import jax.numpy as jnp

    from repro.core.bucketing import GradientBucketer
    from repro.mem import arena_from_bucket_plan
    from repro.mem import layout as mem_layout

    bucketer = GradientBucketer(bucket_bytes=1024, pad_multiple=128)
    tree = {"big": jnp.zeros((1000,), jnp.float32),   # > 256-elem target
            "s1": jnp.zeros((10,), jnp.float32),
            "s2": jnp.zeros((10,), jnp.float32)}
    plan = bucketer.plan(tree)
    mem_layout._warned_oversized = False
    with w.catch_warnings(record=True) as rec:
        w.simplefilter("always")
        lay = arena_from_bucket_plan(plan, page_bytes=512,
                                     bucket_bytes=1024)
    msgs = [r for r in rec if issubclass(r.category, RuntimeWarning)]
    assert len(msgs) == 1 and "oversized" in str(msgs[0].message)
    # the warning fires once per process, not once per plan
    with w.catch_warnings(record=True) as rec2:
        w.simplefilter("always")
        arena_from_bucket_plan(plan, page_bytes=512, bucket_bytes=1024)
    assert not [r for r in rec2 if issubclass(r.category, RuntimeWarning)]
    # the oversized bucket is a dedicated page-aligned segment like any other
    big_bucket = next(f.bucket for f in plan.fields if f.size == 1000)
    seg = lay.segment_of(big_bucket)
    assert seg.offset % lay.quantum == 0
    assert seg.size == plan.bucket_sizes[big_bucket]
    lay.validate()
    # no warning when every bucket meets the target
    mem_layout._warned_oversized = False
    small = bucketer.plan({"a": jnp.zeros((10,), jnp.float32)})
    with w.catch_warnings(record=True) as rec3:
        w.simplefilter("always")
        arena_from_bucket_plan(small, page_bytes=512, bucket_bytes=1024)
    assert not [r for r in rec3 if issubclass(r.category, RuntimeWarning)]
    # pure-prediction paths (Communicator.plan -> every dry-run cell) stay
    # silent even with oversized leaves; only arena construction warns
    mem_layout._warned_oversized = False
    comm = Communicator(_mesh1(), CommConfig(transport="ring_hier",
                                             data_axes=("data",),
                                             bucket_bytes=1024))
    with w.catch_warnings(record=True) as rec4:
        w.simplefilter("always")
        comm.plan(tree)
    assert not [r for r in rec4 if issubclass(r.category, RuntimeWarning)]
    with w.catch_warnings(record=True) as rec5:
        w.simplefilter("always")
        comm.arena(tree)
    assert [r for r in rec5 if issubclass(r.category, RuntimeWarning)]


# ---------------------------------------------------------------------------
# CommArena pack/unpack: jnp vs Pallas bitwise, dirty-buffer pack_into
# ---------------------------------------------------------------------------


def _random_buffers(rng, sizes):
    import jax.numpy as jnp

    return [jnp.asarray(rng.randn(n).astype(np.float32)) for n in sizes]


def test_pack_unpack_pallas_matches_ref_bitwise(rng):
    import jax.numpy as jnp

    lay = plan_arena(SIZES, page_bytes=4096, channel_of=[0, 1, 0, 1, 0, 1])
    bufs = _random_buffers(rng, SIZES)
    a_ref = CommArena(lay, impl="jnp")
    a_pal = CommArena(lay, impl="pallas")
    packed_ref = np.asarray(a_ref.pack(bufs))
    packed_pal = np.asarray(a_pal.pack(bufs))
    assert np.array_equal(packed_ref, packed_pal)          # bitwise
    for b, u_r, u_p in zip(bufs, a_ref.unpack(a_ref.pack(bufs)),
                           a_pal.unpack(a_pal.pack(bufs))):
        assert np.array_equal(np.asarray(b), np.asarray(u_r))
        assert np.array_equal(np.asarray(u_r), np.asarray(u_p))
    # pack_into a dirty persistent buffer: segments overwritten, padding
    # keeps the old bytes (never read back), round-trip exact
    dirty = jnp.full((lay.total_elems,), 7.25, jnp.float32)
    for arena in (a_ref, a_pal):
        out = arena.pack_into(dirty, bufs)
        for b, u in zip(bufs, arena.unpack(out)):
            assert np.array_equal(np.asarray(b), np.asarray(u))
        pad_mask = np.ones(lay.total_elems, bool)
        for s in lay.segments:
            pad_mask[s.offset:s.offset + s.size] = False
        assert np.all(np.asarray(out)[pad_mask] == 7.25)


def test_unpack_spans_matches_unpack(rng):
    lay = plan_arena(SIZES, page_bytes=512, channel_of=[0, 1, 0, 1, 0, 1])
    bufs = _random_buffers(rng, SIZES)
    arena = CommArena(lay)
    packed = arena.pack(bufs)
    spans = [packed[sp.offset:sp.offset + sp.size] for sp in lay.spans]
    for a, b in zip(arena.unpack(packed), arena.unpack_spans(spans)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_arena_validation_errors(rng):
    import jax.numpy as jnp

    lay = plan_arena(SIZES, page_bytes=512)
    arena = CommArena(lay)
    with pytest.raises(ValueError, match="impl"):
        CommArena(lay, impl="cuda")
    with pytest.raises(ValueError, match="segments"):
        arena.pack(_random_buffers(rng, SIZES[:-1]))
    with pytest.raises(ValueError, match="arena shape"):
        arena.pack_into(jnp.zeros((3,), jnp.float32),
                        _random_buffers(rng, SIZES))
    with pytest.raises(ValueError, match="elems"):
        arena.pack([b[:-1] if i == 0 else b for i, b in
                    enumerate(_random_buffers(rng, SIZES))])


def test_pack_kernel_fallback_is_exact(rng):
    """Offsets/sizes off the (8·128) tiling route to the jnp oracle —
    correctness is never conditional on the fast path."""
    import jax.numpy as jnp

    from repro.kernels.pack import ops

    arena = jnp.zeros((1024,), jnp.float32)
    src = jnp.asarray(rng.randn(130).astype(np.float32))   # not lane-aligned
    out = ops.write_flat(arena, src, 3)                    # odd offset
    assert np.array_equal(np.asarray(out[3:133]), np.asarray(src))
    back = ops.read_flat(out, 3, 130)
    assert np.array_equal(np.asarray(back), np.asarray(src))


# ---------------------------------------------------------------------------
# fused span schedules
# ---------------------------------------------------------------------------


def test_fuse_schedule_invariants():
    # quantum == lane multiple and lane-aligned sizes -> zero padding, so
    # the overlap comparison is apples-to-apples (fused readiness waits for
    # the span's last member)
    sizes = (512, 128, 1024, 256, 256, 128)
    chan = [0, 1, 0, 1, 0, 1]
    lay = plan_arena(sizes, page_bytes=512, channel_of=chan)
    assert lay.padding_elems == 0
    for policy in ("accumulate_then_reduce", "stream", "scheduled"):
        for m in (1, 3):
            sched = build_schedule(policy, sizes, microbatches=m, channels=2)
            fused = fuse_schedule(sched, lay)
            fused.validate()
            assert fused.n_buckets == lay.n_spans
            assert fused.policy == policy and fused.microbatches == m
            phases = m if policy != "accumulate_then_reduce" else 1
            assert fused.n_collectives == lay.n_spans * phases
            assert fused.overlap_fraction <= sched.overlap_fraction + 1e-12
    with pytest.raises(ValueError, match="segments"):
        fuse_schedule(build_schedule("stream", sizes[:-1]), lay)


def test_arena_from_halo_plan_groups_by_rail():
    from repro.core.halo import HaloSpec
    from repro.mem import arena_from_halo_plan

    comm = Communicator(_mesh1(), CommConfig(transport="psum",
                                             data_axes=("data",),
                                             channels=2))
    hplan = comm.halo_plan((6, 5), [HaloSpec("data", 0, 1)],
                           schedule="overlap")
    lay = arena_from_halo_plan(hplan, page_bytes=512, pad_multiple=8)
    lay.validate()
    assert lay.n_segments == hplan.n_units
    # bytes -> elements, per unit
    for seg in lay.segments:
        assert seg.size == -(-hplan.unit_bytes[seg.bucket] // 4)
    # one contiguous span per halo rail
    assert lay.n_spans == len(hplan.channels)
    for sp, hc in zip(lay.spans, sorted(hplan.channels,
                                        key=lambda c: c.channel)):
        assert sorted(sp.buckets) == sorted(hc.units)


def test_communicator_arena_plan_and_schedule():
    import jax

    comm = Communicator(_mesh1(), CommConfig(
        transport="ring_hier", data_axes=("data",), channels=2,
        bucket_bytes=4096, page_bytes=4096))
    tree = {f"p{i}": jax.ShapeDtypeStruct((600,), np.float32)
            for i in range(5)}
    plan = comm.plan(tree)
    lay = plan.arena_layout
    assert isinstance(lay, ArenaLayout)
    assert lay.n_spans == 2                        # one span per rail
    assert lay.n_segments == plan.n_buckets
    # fused message count: one send-chain per span instead of per bucket
    assert plan.arena_messages_per_device <= plan.messages_per_device
    pb = plan.predicted_collective_bytes()
    assert pb["arena_pages"] == lay.n_pages
    assert pb["arena_padding_fraction"] == lay.padding_fraction
    assert plan.describe()["arena"]["total_bytes"] == lay.total_bytes
    fused = comm.arena_schedule(tree, "scheduled", 2)
    assert fused.n_buckets == lay.n_spans
    # impl knob follows local_op
    assert comm.arena(tree).impl == "jnp"
    comm_p = Communicator(_mesh1(), CommConfig(
        transport="ring_hier", data_axes=("data",), local_op="pallas"))
    assert comm_p.arena(tree).impl == "pallas"


# ---------------------------------------------------------------------------
# HLO: fused spans lower to fewer collectives than per-bucket issue, and
# the donated per-device arena buffer appears at its exact predicted size
# ---------------------------------------------------------------------------

HLO_FUSE_SCRIPT = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator

mesh = compat.make_mesh((4,), ("data",))
comm = Communicator(mesh, CommConfig(transport="psum", data_axes=("data",),
                                     channels=2, bucket_bytes=4096,
                                     page_bytes=4096))
tree = {f"g{i}": jax.ShapeDtypeStruct((600,), jnp.float32) for i in range(6)}
arena = comm.arena(tree)
lay = arena.layout
sched_b = comm.schedule(tree, "scheduled", 1)
sched_a = comm.arena_schedule(tree, "scheduled", 1)
batch = {"x": jax.ShapeDtypeStruct((1,), jnp.float32)}

def gfn(p, mb):
    return jnp.zeros((), jnp.float32), p

def bucket_fn(grads, b):
    _, tree = comm.reduce_scheduled(gfn, grads, b, sched_b, op="all_reduce")
    return tree

def arena_fn(buf, grads, b):
    _, (tree, out) = comm.reduce_scheduled(gfn, grads, b, sched_a,
                                           op="all_reduce", arena=arena,
                                           arena_buf=buf)
    return out, tree

spec = {k: P() for k in tree}
fb = jax.jit(compat.shard_map(bucket_fn, mesh=mesh, in_specs=(spec, P()),
                              out_specs=spec, check_vma=False))
fa = jax.jit(compat.shard_map(arena_fn, mesh=mesh,
                              in_specs=(P(("data",)), spec, P()),
                              out_specs=(P(("data",)), spec),
                              check_vma=False), donate_argnums=(0,))
arena_abs = jax.ShapeDtypeStruct((4 * lay.total_elems,), jnp.float32)
ca = fa.lower(arena_abs, tree, batch).compile()
cb = fb.lower(tree, batch).compile()

from repro.launch.roofline import collective_wire_bytes
na = collective_wire_bytes(ca.as_text()).op_counts.get("all-reduce", 0)
nb = collective_wire_bytes(cb.as_text()).op_counts.get("all-reduce", 0)
assert nb == sched_b.n_buckets == 6, nb
assert na == lay.n_spans == 2, na
assert na < nb, (na, nb)
# the donated per-device arena appears at its exact page-quantized size
assert f"f32[{lay.total_elems}]" in ca.as_text(), lay.total_elems
# donation aliased the (per-device) arena buffer: memory_analysis is on
# the partitioned module
ma = ca.memory_analysis()
assert ma.alias_size_in_bytes >= lay.total_elems * 4, ma.alias_size_in_bytes
print("MEM_HLO_FUSE_OK")
"""


def test_fused_spans_lower_to_fewer_collectives():
    assert "MEM_HLO_FUSE_OK" in run_distributed(HLO_FUSE_SCRIPT, n_devices=4)


# ---------------------------------------------------------------------------
# cross-transport regression: arena reduction agrees between the explicit
# ring schedule and the vendor collective on 2 procs (pairwise sums commute
# -> bitwise with backend fusion disabled; see repro/stencil/op.py)
# ---------------------------------------------------------------------------

CROSS_TRANSPORT_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator

mesh = compat.make_mesh((2,), ("data",))
rng = np.random.RandomState(3)
tree = {f"g{i}": jnp.asarray(rng.randn(500 + 128 * i).astype(np.float32))
        for i in range(4)}
batch = jnp.zeros((2,), jnp.float32)

def gfn(p, mb):
    i = jax.lax.axis_index("data").astype(jnp.float32)
    return jnp.zeros((), jnp.float32), jax.tree.map(
        lambda t: t * (1.0 + i), p)

outs = {}
for transport in ("ring_hier", "psum"):
    comm = Communicator(mesh, CommConfig(transport=transport,
                                         data_axes=("data",), channels=2,
                                         bucket_bytes=2048,
                                         page_bytes=1024, chunks=1))
    arena = comm.arena(tree)
    sched = comm.arena_schedule(tree, "scheduled", 1)
    def run(grads, b, buf):
        _, (t, out) = comm.reduce_scheduled(gfn, grads, b, sched,
                                            op="all_reduce", arena=arena,
                                            arena_buf=buf)
        return t
    spec = {k: P() for k in tree}
    fn = jax.jit(compat.shard_map(run, mesh=mesh,
                                  in_specs=(spec, P("data"), P(("data",))),
                                  out_specs=spec, check_vma=False))
    buf = jnp.zeros((2 * arena.layout.total_elems,), jnp.float32)
    outs[transport] = fn(tree, batch, buf)

for k in tree:
    a = np.asarray(outs["ring_hier"][k])
    b = np.asarray(outs["psum"][k])
    assert np.array_equal(a, b), (k, np.abs(a - b).max())
print("MEM_CROSS_TRANSPORT_OK")
"""


def test_arena_cross_transport_bitwise_2proc():
    out = run_distributed(CROSS_TRANSPORT_SCRIPT, n_devices=2,
                          extra_flags="--xla_disable_hlo_passes=fusion")
    assert "MEM_CROSS_TRANSPORT_OK" in out


# ---------------------------------------------------------------------------
# checkpoint round-trip: use_arena=True state restores into a non-arena
# step and vice versa (path-matched restore drops/keeps the scratch buffer)
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_across_use_arena(tmp_path):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.checkpoint import restore, save
    from repro.configs import reduced_config
    from repro.models import build_model
    from repro.runtime.train_step import (TrainStepConfig, build_train_step,
                                          init_train_state)

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    model = build_model(reduced_config("llama3.2-1b"))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, 500, (4, 32)), jnp.int32),
             "labels": jnp.asarray(rng.randint(0, 500, (4, 32)), jnp.int32)}
    bspecs = {"tokens": P("data", None), "labels": P("data", None)}

    def cfg(use_arena):
        return TrainStepConfig(
            dp_mode="replicated",
            comm=CommConfig(transport="ring_hier", bucket_bytes=1 << 20,
                            page_bytes=1 << 12),
            use_arena=use_arena)

    def train(tcfg, state, n=2):
        with mesh:
            step = build_train_step(model, mesh, tcfg, bspecs)
            for _ in range(n):
                state, metrics = step(state, batch)
        return state, float(metrics["loss"])

    for src_arena, dst_arena in ((True, False), (False, True)):
        ckpt_dir = str(tmp_path / f"ck_{src_arena}")
        with mesh:
            state, _ = init_train_state(model, mesh, cfg(src_arena),
                                        key=jax.random.key(1))
        state, _ = train(cfg(src_arena), state)
        save(state, 2, ckpt_dir)
        # strict restore refuses the structure change...
        with mesh:
            like, _ = init_train_state(model, mesh, cfg(dst_arena),
                                       key=jax.random.key(2))
        with pytest.raises(ValueError, match="strict=False"):
            restore(like, 2, ckpt_dir)
        # ...path-matched restore carries the params across
        restored = restore(like, 2, ckpt_dir, strict=False)
        ref, ref_loss = train(cfg(src_arena), state, 1)
        got, got_loss = train(cfg(dst_arena), restored, 1)
        assert abs(ref_loss - got_loss) < 1e-5, (src_arena, ref_loss,
                                                 got_loss)


# ---------------------------------------------------------------------------
# full train-step equivalence: arena vs bucket path for all three DP modes
# on a 1xN data mesh (slow distributed subprocess)
# ---------------------------------------------------------------------------

DP_EQUIV_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig
from repro.configs import reduced_config
from repro.models import build_model
from repro.runtime.train_step import (TrainStepConfig, build_train_step,
                                      init_train_state)

mesh = compat.make_mesh((4, 1), ("data", "model"))
model = build_model(reduced_config("llama3.2-1b"))
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, 500, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rng.randint(0, 500, (8, 32)), jnp.int32)}
bspecs = {"tokens": P("data", None), "labels": P("data", None)}

def run(mode, use_arena):
    tcfg = TrainStepConfig(
        dp_mode=mode,
        comm=CommConfig(transport="ring_hier", chunks=2, channels=2,
                        bucket_bytes=1 << 20, page_bytes=1 << 12),
        microbatches=2, schedule="scheduled", use_arena=use_arena)
    with mesh:
        state, _ = init_train_state(model, mesh, tcfg, key=jax.random.key(7))
        step = build_train_step(model, mesh, tcfg, bspecs)
        for _ in range(2):
            state, metrics = step(state, batch)
    return state, metrics

def by_path(tree):
    return {jax.tree_util.keystr(p): l for p, l in
            jax.tree_util.tree_flatten_with_path(tree)[0]}

for mode in ("replicated", "zero1", "fsdp"):
    ref_state, ref_metrics = run(mode, False)
    st, mt = run(mode, True)
    assert abs(float(mt["loss"] - ref_metrics["loss"])) < 1e-5, mode
    assert abs(float(mt["grad_norm"] - ref_metrics["grad_norm"])) < 1e-4, \
        (mode, float(mt["grad_norm"]), float(ref_metrics["grad_norm"]))
    a, b = by_path(st), by_path(ref_state)
    for k in b:
        if "arena" in k:
            continue
        if mode == "zero1" and "'opt'" in k:
            continue   # optimizer shards re-laid out per fused span
        err = float(jnp.max(jnp.abs(a[k].astype(jnp.float32)
                                    - b[k].astype(jnp.float32))))
        assert err < 5e-5, (mode, k, err)
    print(mode, "arena equiv ok")
print("MEM_DP_EQUIV_OK")
"""


@pytest.mark.slow
def test_dp_mode_arena_equivalence():
    assert "MEM_DP_EQUIV_OK" in run_distributed(DP_EQUIV_SCRIPT, n_devices=4)

"""Dependency-aware CommSchedule: construction invariants, the overlap
fraction the roofline consumes, schedule-driven train steps for every DP
mode x policy (equivalence vs accumulate_then_reduce), and the independence
of the streamed collectives in lowered HLO."""

import numpy as np
import pytest

from conftest import run_distributed

from repro.comm import (CommConfig, Communicator, SCHEDULE_POLICIES,
                        build_schedule)


# ---------------------------------------------------------------------------
# construction invariants
# ---------------------------------------------------------------------------

SIZES = (512, 128, 1024, 256, 256)


@pytest.mark.parametrize("policy", SCHEDULE_POLICIES)
@pytest.mark.parametrize("channels", [0, 1, 2, 4])
@pytest.mark.parametrize("m", [1, 3])
def test_every_bucket_issued_each_phase(policy, channels, m):
    s = build_schedule(policy, SIZES, microbatches=m, channels=channels)
    assert s.n_buckets == len(SIZES)
    phases = range(m) if policy != "accumulate_then_reduce" else [m - 1]
    for phase in phases:
        seen = sorted(b for slot in s.slots_for_phase(phase)
                      for b in slot.bucket_ids)
        assert seen == list(range(len(SIZES)))
    expected = len(SIZES) * (m if policy != "accumulate_then_reduce" else 1)
    assert s.n_collectives == expected
    if channels >= 1:
        assert s.n_channels == min(channels, len(SIZES))


def test_readiness_monotone_per_channel_and_in_range():
    for policy in SCHEDULE_POLICIES:
        s = build_schedule(policy, SIZES, microbatches=4, channels=2)
        by_channel = {}
        for slot in s.slots:
            assert 0.0 < slot.ready <= 1.0
            assert slot.ready >= by_channel.get(slot.channel, 0.0)
            by_channel[slot.channel] = slot.ready


def test_scheduled_issues_last_buckets_first():
    """Backward readiness order: the last layers' gradients (highest bucket
    index) issue first within each phase."""
    s = build_schedule("scheduled", SIZES, microbatches=2, channels=0)
    for phase in (0, 1):
        order = [b for slot in s.slots_for_phase(phase)
                 for b in slot.bucket_ids]
        assert order == sorted(order, reverse=True)


def test_overlap_fraction_ordering():
    acc = build_schedule("accumulate_then_reduce", SIZES, 4, 2)
    st = build_schedule("stream", SIZES, 4, 2)
    sc = build_schedule("scheduled", SIZES, 4, 2)
    assert acc.overlap_fraction == 0.0
    assert 0.0 < st.overlap_fraction < sc.overlap_fraction < 1.0
    # single microbatch: stream cannot overlap, scheduled still can
    assert build_schedule("stream", SIZES, 1, 2).overlap_fraction == 0.0
    assert build_schedule("scheduled", SIZES, 1, 2).overlap_fraction > 0.0


def test_describe_round_trips_and_elides():
    s = build_schedule("stream", SIZES, 2, 2)
    d = s.describe()
    assert d["policy"] == "stream" and d["n_collectives"] == s.n_collectives
    assert len(d["slots"]) == len(s.slots)
    assert "slots" not in s.describe(max_slots=3)
    assert s.describe(max_slots=3)["slots_elided"] == len(s.slots)


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown schedule policy"):
        build_schedule("bogus", SIZES)


def test_train_step_config_schedule_policy():
    from repro.runtime.train_step import TrainStepConfig

    assert TrainStepConfig().schedule_policy == "accumulate_then_reduce"
    assert TrainStepConfig(schedule="stream").schedule_policy == "stream"
    assert TrainStepConfig(schedule="scheduled",
                           microbatches=3).schedule_policy == "scheduled"
    with pytest.raises(ValueError, match="unknown schedule policy"):
        TrainStepConfig(schedule="bogus").schedule_policy


def test_roofline_exposed_collective_bounds():
    from repro.launch.roofline import Roofline

    base = dict(flops_per_device=1e12, hbm_bytes_per_device=1e9,
                wire_bytes_per_device=1e9)
    for frac in (0.0, 0.3, 1.0):
        r = Roofline(**base, overlap_fraction=frac)
        assert 0.0 <= r.t_exposed_collective <= r.t_collective
        d = r.as_dict(8)
        assert d["t_exposed_collective_s"] <= d["t_collective_s"]
        assert d["overlap_fraction"] == frac
    assert Roofline(**base).t_exposed_collective == \
        Roofline(**base).t_collective


# ---------------------------------------------------------------------------
# reduce_scheduled validation (single device)
# ---------------------------------------------------------------------------


def _comm(transport="ring_hier", **kw):
    from repro import compat

    mesh = compat.make_mesh((1,), ("data",))
    return Communicator(mesh, CommConfig(transport=transport,
                                         data_axes=("data",), **kw))


def test_reduce_scheduled_rejects_bad_op():
    comm = _comm()
    sched = build_schedule("stream", (128,), 1, 0)
    with pytest.raises(ValueError, match="op must be"):
        comm.reduce_scheduled(lambda p, b: (0.0, p), {}, {}, sched,
                              op="bogus")


def test_reduce_scheduled_rejects_rs_on_psum():
    comm = _comm(transport="psum")
    sched = build_schedule("stream", (128,), 1, 0)
    with pytest.raises(ValueError, match="reduce-scatter"):
        comm.reduce_scheduled(lambda p, b: (0.0, p), {}, {}, sched,
                              op="reduce_scatter")


def test_reduce_scheduled_detects_bucket_mismatch():
    import jax.numpy as jnp

    comm = _comm(bucket_bytes=4096)                   # cap = 1024 elems
    params = {f"w{i}": jnp.zeros((600,), jnp.float32)
              for i in range(3)}                      # -> 3 buckets
    sched = build_schedule("stream", (128,), 1, 0)    # wrong layout

    def grad_fn(p, _):
        return jnp.zeros(()), p

    with pytest.raises(ValueError, match="bucketizes into"):
        comm.reduce_scheduled(grad_fn, params, {"x": jnp.zeros((1, 1))},
                              sched)


# ---------------------------------------------------------------------------
# schedule equivalence + HLO independence (distributed subprocess, 1xN mesh)
# ---------------------------------------------------------------------------

EQUIV_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.configs import reduced_config
from repro.core.reducer import ReduceConfig
from repro.models import build_model
from repro.runtime.train_step import (TrainStepConfig, build_train_step,
                                      init_train_state)

mesh = compat.make_mesh((4, 1), ("data", "model"))   # 1xN data parallel
cfg = reduced_config("llama3.2-1b")
model = build_model(cfg)
B, S = 8, 32
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, 500, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.randint(0, 500, (B, S)), jnp.int32)}
bspecs = {"tokens": P("data", None), "labels": P("data", None)}

def run(mode, policy):
    tcfg = TrainStepConfig(
        dp_mode=mode,
        reduce=ReduceConfig(policy="fused_ring_hierarchical", chunks=2),
        microbatches=2, schedule=policy)
    with mesh:
        state, _ = init_train_state(model, mesh, tcfg, key=jax.random.key(7))
        step = build_train_step(model, mesh, tcfg, bspecs)
        metrics = None
        for _ in range(2):
            state, metrics = step(state, batch)
    return state, metrics

def flat(tree):
    return jax.tree.leaves(tree)

for mode in ("replicated", "zero1", "fsdp"):
    ref_state, ref_metrics = run(mode, "accumulate_then_reduce")
    for policy in ("stream", "scheduled"):
        st, mt = run(mode, policy)
        assert abs(float(mt["loss"] - ref_metrics["loss"])) < 1e-5, \
            (mode, policy)
        assert abs(float(mt["grad_norm"] - ref_metrics["grad_norm"])) < 1e-4, \
            (mode, policy, float(mt["grad_norm"]), float(ref_metrics["grad_norm"]))
        for a, b in zip(flat(st), flat(ref_state)):
            err = float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                        - b.astype(jnp.float32))))
            assert err < 5e-5, (mode, policy, a.shape, err)
        print(mode, policy, "equiv ok")
print("SCHED_EQUIV_OK")
"""

HLO_SCRIPT = r"""
import re
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig
from repro.configs import reduced_config
from repro.models import build_model
from repro.runtime.train_step import (TrainStepConfig, build_step_schedule,
                                      build_train_step, init_train_state)

mesh = compat.make_mesh((4, 1), ("data", "model"))
cfg = reduced_config("llama3.2-1b")
model = build_model(cfg)
bspecs = {"tokens": P("data", None), "labels": P("data", None)}
batch_abs = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}

for policy in ("stream", "scheduled"):
    # psum transport: every bucket lowers to one all-reduce op; small
    # buckets force several, channels=0 leaves them independent
    tcfg = TrainStepConfig(
        dp_mode="replicated",
        comm=CommConfig(transport="psum", bucket_bytes=1 << 16, channels=0),
        microbatches=2, schedule=policy)
    with mesh:
        sched = build_step_schedule(model, mesh, tcfg)
        state_abs, _ = init_train_state(model, mesh, tcfg, abstract=True)
        step = build_train_step(model, mesh, tcfg, bspecs)
        txt = step.lower(state_abs, batch_abs).as_text()
    n_ar = len(re.findall(r"all[-_]reduce", txt))
    assert sched.n_buckets > 1, sched.n_buckets
    # the streamed schedule issues n_buckets independent collectives per
    # microbatch; all of them must survive into the lowered module
    assert n_ar >= sched.n_collectives >= sched.n_buckets, \
        (policy, n_ar, sched.n_collectives, sched.n_buckets)
    print(policy, "buckets", sched.n_buckets, "collectives in HLO", n_ar)
print("SCHED_HLO_OK")
"""


def test_schedule_collectives_survive_lowering():
    assert "SCHED_HLO_OK" in run_distributed(HLO_SCRIPT, n_devices=4)


@pytest.mark.slow
def test_dp_mode_x_policy_equivalence():
    assert "SCHED_EQUIV_OK" in run_distributed(EQUIV_SCRIPT, n_devices=4)

"""The quantized wire end-to-end (PR 7).

Layout invariants of the int8 payload + trailing scale segment, Pallas
fused pack+quantize vs the jnp oracle at the arena level, the
``wire_codec`` plumbing through :class:`~repro.comm.Communicator` /
:class:`~repro.comm.plan.CommPlan` (including the config rejections),
checkpoint round-trips across codec toggles (the ``"ef"`` leaf is scratch,
params carry), and the two slow distributed acceptance properties: int8+EF
matches the fp32 wire per DP mode after 2 steps, and the LM loss curve
under ``wire_codec='int8'`` tracks the uncompressed run over many steps.
"""

import os

import numpy as np
import pytest

from conftest import run_distributed

from repro.comm import CommConfig, Communicator
from repro.mem import QuantArenaLayout, QuantCommArena, plan_quant_arena
from repro.mem.layout import SCALE_BYTES


def _mesh1():
    from repro import compat

    return compat.make_mesh((1,), ("data",))


# ---------------------------------------------------------------------------
# quantized layout invariants
# ---------------------------------------------------------------------------

Q_SIZES = (4096, 512, 8192, 1024, 1536)


@pytest.mark.parametrize("page_bytes,block", [(512, 128), (4096, 512),
                                              (4096, 1024), (2 * 2**20, 512)])
def test_quant_layout_invariants(page_bytes, block):
    lay = plan_quant_arena(Q_SIZES, page_bytes=page_bytes, block=block)
    lay.validate()
    assert isinstance(lay, QuantArenaLayout)
    import jax.numpy as jnp

    assert jnp.dtype(lay.dtype) == jnp.int8
    # the payload is laid out exactly like an fp32 arena (elem == byte);
    # the scale segment starts page-aligned right after it
    assert lay.scale_offset == lay.payload_elems
    assert lay.scale_offset % lay.quantum == 0
    assert lay.n_scales == lay.payload_elems // block
    assert lay.scale_region_bytes % page_bytes == 0 or \
        lay.scale_region_bytes >= lay.n_scales * SCALE_BYTES
    assert lay.total_elems == lay.scale_offset + lay.scale_region_bytes
    # every segment holds whole codec blocks: offsets/padded are block
    # multiples, so no two segments ever share a scale block
    ranges = []
    for s in lay.segments:
        assert s.offset % block == 0 and s.padded % block == 0
        lo, hi = lay.scale_byte_range(s.offset, s.padded)
        assert lay.scale_offset <= lo <= hi <= lay.total_elems
        ranges.append((lo, hi))
    ranges.sort()
    for (_, hi), (lo, _) in zip(ranges, ranges[1:]):
        assert hi <= lo                      # disjoint per-segment scales
    # wire accounting: one byte per element plus the amortized block scale
    assert lay.wire_bytes_per_elem == 1.0 + SCALE_BYTES / block
    assert 4.0 / lay.wire_bytes_per_elem >= 3.5
    d = lay.describe()
    assert d["codec"] == "int8" and d["codec_block"] == block
    assert d["total_bytes"] == lay.total_elems        # int8: byte == elem


def test_quant_arena_pallas_matches_ref(rng):
    """The fused Pallas pack+quantize at the arena level vs the jnp oracle:
    int8 payload bitwise, scales to 1 ulp, decode within the scale bound."""
    import jax.numpy as jnp

    from repro.kernels.pack_quant import ref as pq_ref

    lay = plan_quant_arena([4096, 8192], page_bytes=4096, block=512,
                           channel_of=[0, 0])
    bufs = [jnp.asarray(rng.randn(s.size).astype(np.float32)) * 3.0
            for s in sorted(lay.segments, key=lambda s: s.bucket)]
    a_ref = QuantCommArena(lay, impl="jnp")
    a_pal = QuantCommArena(lay, impl="pallas")
    packed_ref, _ = a_ref.pack(bufs)
    packed_pal, _ = a_pal.pack(bufs)
    for s in lay.segments:
        np.testing.assert_array_equal(
            np.asarray(packed_ref[s.offset:s.offset + s.size]),
            np.asarray(packed_pal[s.offset:s.offset + s.size]))
        sc_r = pq_ref.read_scales_flat(packed_ref, s.offset, s.padded,
                                       lay.scale_offset, lay.block)
        sc_p = pq_ref.read_scales_flat(packed_pal, s.offset, s.padded,
                                       lay.scale_offset, lay.block)
        np.testing.assert_allclose(np.asarray(sc_r), np.asarray(sc_p),
                                   rtol=1e-7)
    for b, u_r, u_p in zip(bufs, a_ref.unpack(packed_ref),
                           a_pal.unpack(packed_pal)):
        np.testing.assert_allclose(np.asarray(u_r), np.asarray(u_p),
                                   rtol=1e-6, atol=1e-7)
        assert np.abs(np.asarray(u_r) - np.asarray(b)).max() < \
            np.abs(np.asarray(b)).max() / 127


# ---------------------------------------------------------------------------
# Communicator / CommPlan plumbing and config rejections
# ---------------------------------------------------------------------------


def test_communicator_quant_plumbing():
    import jax

    comm = Communicator(_mesh1(), CommConfig(
        transport="ring", data_axes=("data",), wire_codec="int8",
        channels=2, bucket_bytes=1 << 20, page_bytes=4096))
    assert comm.codec == "int8"
    # segments must hold whole codec blocks -> bucketer pad folds the block
    assert comm.bucketer.pad_multiple % 512 == 0
    tree = {f"g{i}": jax.ShapeDtypeStruct((65536,), np.float32)
            for i in range(4)}
    plan = comm.plan(tree)
    assert plan.wire_codec == "int8" and plan.codec_block == 512
    assert isinstance(plan.arena_layout, QuantArenaLayout)
    assert isinstance(comm.arena(tree), QuantCommArena)
    # priced wire: ~1.008 B/elem vs 4 -> >= 3.5x compression
    assert plan.wire_bytes_per_elem == pytest.approx(1.0 + 4.0 / 512)
    assert 4.0 / plan.wire_bytes_per_elem >= 3.5
    to = plan.codec_tradeoff()
    assert to["applied"] and to["codec"] == "int8"
    assert to["kernel_hbm_bytes"] > 0 and to["t_kernel_s"] > 0
    d = plan.describe()
    assert d["wire_codec"] == "int8" and d["codec"]["applied"]
    assert d["arena"]["codec"] == "int8"
    # a non-codec-capable transport stays honest: fp32 wire, ratio 1
    comm_p = Communicator(_mesh1(), CommConfig(
        transport="psum", data_axes=("data",), wire_codec="int8",
        bucket_bytes=1 << 20, page_bytes=4096))
    plan_p = comm_p.plan(tree)
    assert plan_p.wire_bytes_per_elem == pytest.approx(4.0)
    # ... while the arena still stores/decodes int8 locally
    assert isinstance(plan_p.arena_layout, QuantArenaLayout)


def test_quant_config_rejections():
    from repro.runtime.train_step import TrainStepConfig

    with pytest.raises(ValueError, match="exclusive"):
        Communicator(_mesh1(), CommConfig(
            transport="ring", data_axes=("data",), wire_codec="int8",
            wire_dtype="bfloat16"))
    with pytest.raises(ValueError, match="wire_codec"):
        Communicator(_mesh1(), CommConfig(
            transport="ring", data_axes=("data",), wire_codec="fp4"))
    # the check fires whether the codec comes from the step config...
    with pytest.raises(ValueError, match="fsdp_gather"):
        TrainStepConfig(dp_mode="fsdp", fsdp_gather="ring",
                        wire_codec="int8").comm_config(("data",))
    # ...or from the nested CommConfig
    with pytest.raises(ValueError, match="fsdp_gather"):
        TrainStepConfig(dp_mode="fsdp", fsdp_gather="ring",
                        comm=CommConfig(wire_codec="int8")
                        ).comm_config(("data",))


# ---------------------------------------------------------------------------
# checkpoint round-trips: the "ef" accumulator is a real (checkpointable)
# state leaf under the same config; across codec toggles the path-matched
# restore carries params and drops/zero-inits the scratch, while a toggle
# that re-shapes a surviving arena leaf still raises per contract
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_across_wire_codec(tmp_path):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.checkpoint import restore, save
    from repro.configs import reduced_config
    from repro.models import build_model
    from repro.runtime.train_step import (TrainStepConfig, build_train_step,
                                          init_train_state)

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    model = build_model(reduced_config("llama3.2-1b"))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, 500, (4, 32)), jnp.int32),
             "labels": jnp.asarray(rng.randint(0, 500, (4, 32)), jnp.int32)}
    bspecs = {"tokens": P("data", None), "labels": P("data", None)}

    def cfg(codec, use_arena=True):
        return TrainStepConfig(
            dp_mode="replicated",
            comm=CommConfig(transport="ring", bucket_bytes=1 << 20,
                            page_bytes=1 << 12, wire_codec=codec),
            use_arena=use_arena)

    def train(tcfg, state, n=2):
        with mesh:
            step = build_train_step(model, mesh, tcfg, bspecs)
            for _ in range(n):
                state, metrics = step(state, batch)
        return state, float(metrics["loss"])

    # 1) same config: the EF accumulator round-trips strictly, bitwise
    with mesh:
        state, _ = init_train_state(model, mesh, cfg("int8"),
                                    key=jax.random.key(1))
    assert "ef" in state and "arena" in state
    state, _ = train(cfg("int8"), state)
    assert np.abs(np.asarray(state["ef"])).max() > 0   # EF actually in use
    ck = str(tmp_path / "ck_same")
    save(state, 2, ck)
    restored = restore(jax.tree.map(jnp.zeros_like, state), 2, ck)
    np.testing.assert_array_equal(np.asarray(restored["ef"]),
                                  np.asarray(state["ef"]))
    ref, ref_loss = train(cfg("int8"), state, 1)
    got, got_loss = train(cfg("int8"), restored, 1)
    assert ref_loss == got_loss

    # 2) codec toggles across arena on/off: strict refuses the structure
    # change (ef/arena appear or vanish), path-matched restore carries
    # params and re-inits the scratch
    for src, dst in ((("int8", True), (None, False)),
                     ((None, False), ("int8", True))):
        ckpt_dir = str(tmp_path / f"ck_{src[0]}_{src[1]}")
        with mesh:
            state, _ = init_train_state(model, mesh, cfg(*src),
                                        key=jax.random.key(1))
        state, _ = train(cfg(*src), state)
        save(state, 2, ckpt_dir)
        with mesh:
            like, _ = init_train_state(model, mesh, cfg(*dst),
                                       key=jax.random.key(2))
        with pytest.raises(ValueError, match="strict=False"):
            restore(like, 2, ckpt_dir)
        restored = restore(like, 2, ckpt_dir, strict=False)
        if dst[0] is not None:      # fresh EF starts at zero
            assert np.all(np.asarray(restored["ef"]) == 0)
        ref, ref_loss = train(cfg(*src), state, 1)
        got, got_loss = train(cfg(*dst), restored, 1)
        assert abs(ref_loss - got_loss) < 5e-5, (src, dst, ref_loss,
                                                 got_loss)

    # 3) a toggle that re-shapes the surviving arena leaf (codec on/off
    # with use_arena kept on) still raises — scratch is dropped by path,
    # never silently re-shaped
    ck3 = str(tmp_path / "ck_reshape")
    with mesh:
        state, _ = init_train_state(model, mesh, cfg("int8"),
                                    key=jax.random.key(1))
    save(state, 1, ck3)
    with mesh:
        like, _ = init_train_state(model, mesh, cfg(None),
                                   key=jax.random.key(2))
    with pytest.raises(ValueError, match="arena"):
        restore(like, 1, ck3, strict=False)


# ---------------------------------------------------------------------------
# DP-mode equivalence: wire_codec='int8'+EF vs the fp32 wire, all three
# modes, 2 steps on a 4x1 data mesh (slow distributed subprocess).
# Calibrated: dloss 0.0, dgnorm <= 2.8e-4, param err <= 1e-4 (fsdp stores
# params as flat bucket shards whose padding depends on the codec, so only
# shape-matched leaves compare there; its metrics still pin the step).
# ---------------------------------------------------------------------------

QUANT_DP_EQUIV_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig
from repro.configs import reduced_config
from repro.models import build_model
from repro.runtime.train_step import (TrainStepConfig, build_train_step,
                                      init_train_state)

mesh = compat.make_mesh((4, 1), ("data", "model"))
model = build_model(reduced_config("llama3.2-1b"))
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, 500, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rng.randint(0, 500, (8, 32)), jnp.int32)}
bspecs = {"tokens": P("data", None), "labels": P("data", None)}

def run(mode, codec):
    tcfg = TrainStepConfig(
        dp_mode=mode,
        comm=CommConfig(transport="ring", chunks=2, channels=2,
                        bucket_bytes=1 << 20, page_bytes=1 << 12,
                        wire_codec=codec),
        microbatches=2, schedule="scheduled", use_arena=True)
    with mesh:
        state, _ = init_train_state(model, mesh, tcfg, key=jax.random.key(7))
        step = build_train_step(model, mesh, tcfg, bspecs)
        for _ in range(2):
            state, metrics = step(state, batch)
    return state, metrics

def by_path(tree):
    return {jax.tree_util.keystr(p): l for p, l in
            jax.tree_util.tree_flatten_with_path(tree)[0]}

for mode in ("replicated", "zero1", "fsdp"):
    ref_state, ref_metrics = run(mode, None)
    st, mt = run(mode, "int8")
    dl = abs(float(mt["loss"] - ref_metrics["loss"]))
    dg = abs(float(mt["grad_norm"] - ref_metrics["grad_norm"]))
    assert dl < 5e-5, (mode, dl)
    assert dg < 3e-3, (mode, dg)
    a, b = by_path(st), by_path(ref_state)
    assert any("'ef'" in k for k in a), sorted(a)[:5]   # EF is a state leaf
    for k in b:
        if "arena" in k or "'ef'" in k:
            continue
        if mode == "zero1" and "'opt'" in k:
            continue   # optimizer shards re-laid out per fused span
        if a[k].shape != b[k].shape:
            continue   # fsdp flat shards: codec changes bucket padding
        err = float(jnp.max(jnp.abs(a[k].astype(jnp.float32)
                                    - b[k].astype(jnp.float32))))
        assert err < 1e-3, (mode, k, err)
    print(mode, "quant wire equiv ok")
print("QUANT_DP_EQUIV_OK")
"""


@pytest.mark.slow
def test_dp_mode_quant_equivalence():
    assert "QUANT_DP_EQUIV_OK" in run_distributed(QUANT_DP_EQUIV_SCRIPT,
                                                  n_devices=4)


# ---------------------------------------------------------------------------
# convergence equivalence: the LM loss curve under the int8 wire with error
# feedback tracks the uncompressed run step for step.  Calibrated at 30
# steps: max |diff| 2.7e-5, final relative diff 4e-6.  QUANT_EQ_STEPS
# shortens the run for CI smoke.
# ---------------------------------------------------------------------------

QUANT_CONVERGENCE_SCRIPT = r"""
import os
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig
from repro.configs import reduced_config
from repro.models import build_model
from repro.runtime.train_step import (TrainStepConfig, build_train_step,
                                      init_train_state)

STEPS = int(os.environ.get("QUANT_EQ_STEPS", "30"))
mesh = compat.make_mesh((4, 1), ("data", "model"))
model = build_model(reduced_config("llama3.2-1b"))
bspecs = {"tokens": P("data", None), "labels": P("data", None)}

def batches():
    rng = np.random.RandomState(0)
    for _ in range(STEPS):
        toks = rng.randint(0, 500, (8, 32))
        yield {"tokens": jnp.asarray(toks, jnp.int32),
               "labels": jnp.asarray(toks, jnp.int32)}

def run(codec):
    tcfg = TrainStepConfig(
        dp_mode="replicated",
        comm=CommConfig(transport="ring", chunks=2, channels=2,
                        bucket_bytes=1 << 20, page_bytes=1 << 12,
                        wire_codec=codec),
        schedule="scheduled", use_arena=True)
    with mesh:
        state, _ = init_train_state(model, mesh, tcfg, key=jax.random.key(3))
        step = build_train_step(model, mesh, tcfg, bspecs)
        losses = []
        for b in batches():
            state, metrics = step(state, b)
            losses.append(float(metrics["loss"]))
    return losses

l_fp = run(None)
l_q = run("int8")
worst = max(abs(a - b) for a, b in zip(l_fp, l_q))
assert worst < 5e-4, (worst, l_fp[-1], l_q[-1])
assert l_q[-1] < l_q[0], (l_q[0], l_q[-1])            # it actually learns
rel = abs(l_fp[-1] - l_q[-1]) / l_fp[-1]
assert rel < 1e-4, (rel, l_fp[-1], l_q[-1])
print("steps", STEPS, "max |dloss|", worst, "final rel", rel)
print("QUANT_CONVERGENCE_OK")
"""


@pytest.mark.slow
def test_lm_convergence_equivalence_int8_vs_fp32():
    assert "QUANT_CONVERGENCE_OK" in run_distributed(
        QUANT_CONVERGENCE_SCRIPT, n_devices=4)

"""The comm-avoiding solver family: pipelined and s-step CG plus even-odd
preconditioning — reference-mode correctness against dense solves, residual
histories tracking classic CG, NaN-robustness past convergence, the
latency-model collective-count ladder asserted in lowered HLO (classic
``2·iters+1`` → pipelined ``iters`` → s-step ``ceil(iters/s)``), the
pipelined reduction/matvec independence structure, and distributed
cross-transport reproducibility on 2- and 4-proc meshes."""

import math

import numpy as np
import pytest

from conftest import run_distributed

from repro.core.halo import HaloSpec
from repro.stencil import (EvenOddOp, PRECONDS, SOLVERS, StencilOp,
                           leja_chebyshev_shifts, predicted_halo_exchanges,
                           predicted_reduction_collectives, solve)

# see repro/stencil/op.py: bitwise assertions need backend fusion pinned off
NOFUSE = "--xla_disable_hlo_passes=fusion"

SHAPE = (8, 6)
SPECS = tuple(HaloSpec(f"ax{d}", d, 1) for d in range(2))


def _problem(mass=0.2, seed=0, shape=SHAPE, specs=SPECS):
    import jax.numpy as jnp

    op = StencilOp(specs=specs, mass=mass)
    rng = np.random.RandomState(seed)
    b = jnp.asarray(rng.randn(*shape).astype(np.float32))
    return op, b


# ---------------------------------------------------------------------------
# reference-mode correctness: every solver x precond against the dense solve
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("solver", SOLVERS)
@pytest.mark.parametrize("precond", PRECONDS)
def test_solver_family_matches_dense_solve(solver, precond):
    op, b = _problem()
    A = np.asarray(op.dense_matrix(SHAPE)).astype(np.float64)
    xref = np.linalg.solve(A, np.asarray(b).reshape(-1).astype(np.float64))
    res = solve(op, b, None, solver=solver, precond=precond, s=4,
                tol=1e-5, maxiter=200, reference=True)
    assert float(res.rel_residual) < 1e-5
    x = np.asarray(res.x).reshape(-1).astype(np.float64)
    true_rel = (np.linalg.norm(A @ x - np.asarray(b).reshape(-1))
                / np.linalg.norm(np.asarray(b)))
    assert true_rel < 1e-5, (solver, precond, true_rel)
    assert np.abs(x - xref).max() < 1e-4


def test_eo_precond_reduces_iterations_reference():
    """The Schur spectrum is quadratically compressed, so even-odd CG needs
    materially fewer iterations (and with them, reductions)."""
    op, b = _problem(mass=0.2)
    plain = solve(op, b, None, solver="cg", tol=1e-5, maxiter=200,
                  reference=True)
    eo = solve(op, b, None, solver="cg", precond="eo", tol=1e-5,
               maxiter=200, reference=True)
    assert int(plain.iters) >= 1.5 * int(eo.iters), \
        (int(plain.iters), int(eo.iters))


# ---------------------------------------------------------------------------
# residual histories: pipelined per-iteration, s-step per-block boundaries
# ---------------------------------------------------------------------------


def test_pipelined_history_tracks_classic():
    op, b = _problem()
    rc = solve(op, b, None, solver="cg", tol=None, maxiter=14,
               reference=True)
    rp = solve(op, b, None, solver="pipelined", tol=None, maxiter=14,
               reference=True)
    hc, hp = np.asarray(rc.history), np.asarray(rp.history)
    assert hc[0] == hp[0]              # both start at ‖b‖²
    mask = hc[:14] > 1e-6 * hc[0]
    np.testing.assert_allclose(hp[:14][mask], hc[:14][mask], rtol=0.1)


@pytest.mark.parametrize("s", [2, 4])
def test_sstep_history_matches_classic_at_block_boundaries(s):
    """In exact arithmetic each s-step block equals s classic iterations;
    the Newton basis keeps that true to f32 rounding."""
    op, b = _problem()
    rc = solve(op, b, None, solver="cg", tol=None, maxiter=24,
               reference=True)
    rs = solve(op, b, None, solver="sstep", s=s, tol=None, maxiter=24,
               reference=True)
    hc, hs = np.asarray(rc.history), np.asarray(rs.history)
    nblocks = math.ceil(24 / s)
    for i in range(nblocks):
        ref = hc[i * s]
        if ref <= 1e-6 * hc[0]:
            break
        assert abs(hs[i] - ref) <= 0.05 * ref, (s, i, hs[i], ref)


def test_unrolled_past_convergence_is_finite():
    """Fixed-iteration mode far past convergence must stall, not NaN.
    Classic and s-step hold the converged solution; pipelined drifts at the
    f32 floor (the known attainable-accuracy loss of the Ghysels–Vanroose
    recurrence) but stays finite and near the solution."""
    op, b = _problem()
    A = np.asarray(op.dense_matrix(SHAPE)).astype(np.float64)
    xref = np.linalg.solve(A, np.asarray(b).reshape(-1).astype(np.float64))
    tight = {"cg": 1e-4, "sstep": 1e-4, "pipelined": 1e-2}
    for solver in SOLVERS:
        for precond in PRECONDS:
            res = solve(op, b, None, solver=solver, precond=precond,
                        tol=None, maxiter=60, reference=True)
            x = np.asarray(res.x)
            assert np.isfinite(x).all(), (solver, precond)
            err = np.abs(x.reshape(-1) - xref).max()
            assert err < tight[solver], (solver, precond, err)


# ---------------------------------------------------------------------------
# even-odd Schur operator: structure, spectrum, masks
# ---------------------------------------------------------------------------


def test_eo_schur_operator_is_spd_on_even_subspace():
    import jax

    op, _ = _problem(mass=0.4)
    eo = EvenOddOp(op, distributed=False)
    me = np.asarray(eo.parity_mask(SHAPE, even=True)).reshape(-1)
    n = int(np.prod(SHAPE))
    eye = np.eye(n, dtype=np.float32).reshape((n,) + SHAPE)
    S = np.asarray(jax.vmap(eo.apply_reference)(
        np.asarray(eye))).reshape(n, n).T
    Se = S[np.ix_(me > 0, me > 0)]
    np.testing.assert_allclose(Se, Se.T, atol=1e-5)
    assert np.linalg.eigvalsh(Se.astype(np.float64)).min() > 0.0
    lo, hi = eo.eig_bounds()
    ev = np.linalg.eigvalsh(Se.astype(np.float64))
    assert ev.min() >= lo - 1e-5 and ev.max() <= hi + 1e-5


def test_eo_support_and_masks():
    import jax.numpy as jnp

    op, b = _problem()
    eo = EvenOddOp(op, distributed=False)
    me = eo.parity_mask(SHAPE, even=True)
    mo = eo.parity_mask(SHAPE, even=False)
    np.testing.assert_array_equal(np.asarray(me) + np.asarray(mo),
                                  np.ones(SHAPE, np.float32))
    # parity flips between any two neighbouring sites along a stencil dim
    assert np.asarray(me)[0, 0] == 1.0 and np.asarray(me)[0, 1] == 0.0
    # the Schur matvec preserves even support exactly (bitwise zeros)
    rhs = eo.project_rhs_reference(b)
    assert float(jnp.abs(mo * rhs).max()) == 0.0
    out = eo.apply_reference(rhs)
    assert float(jnp.abs(mo * out).max()) == 0.0


def test_eig_bounds_enclose_dense_spectrum():
    op, _ = _problem(mass=0.3)
    A = np.asarray(op.dense_matrix(SHAPE)).astype(np.float64)
    ev = np.linalg.eigvalsh(A)
    lo, hi = op.eig_bounds()
    assert lo - 1e-6 <= ev.min() and ev.max() <= hi + 1e-6
    # halo-2 operator: bounds still enclose (they are not tight there)
    op2 = StencilOp(specs=(HaloSpec("ax0", 0, 2), HaloSpec("ax1", 1, 1)),
                    mass=0.5)
    A2 = np.asarray(op2.dense_matrix((8, 6))).astype(np.float64)
    ev2 = np.linalg.eigvalsh(A2)
    lo2, hi2 = op2.eig_bounds()
    assert lo2 - 1e-6 <= ev2.min() and ev2.max() <= hi2 + 1e-6


def test_leja_chebyshev_shifts_properties():
    lo, hi = 0.2, 1.2
    for s in (1, 2, 4, 7):
        pts = leja_chebyshev_shifts(lo, hi, s)
        assert len(pts) == s
        assert all(lo < p < hi for p in pts)
        assert len(set(pts)) == s
    # Leja ordering starts from the extreme-magnitude point
    pts = leja_chebyshev_shifts(lo, hi, 4)
    assert pts[0] == max(pts, key=abs)
    with pytest.raises(ValueError, match="s must be"):
        leja_chebyshev_shifts(lo, hi, 0)
    with pytest.raises(ValueError, match="hi > lo"):
        leja_chebyshev_shifts(1.0, 1.0, 2)


# ---------------------------------------------------------------------------
# validation and prediction helpers
# ---------------------------------------------------------------------------


def test_solver_validation_errors():
    import jax.numpy as jnp

    op, b = _problem()
    with pytest.raises(ValueError, match="unknown solver"):
        solve(op, b, None, solver="bogus", reference=True)
    with pytest.raises(ValueError, match="unknown precond"):
        solve(op, b, None, precond="bogus", reference=True)
    with pytest.raises(ValueError, match="does not support x0"):
        solve(op, b, None, solver="sstep", x0=jnp.zeros_like(b),
              reference=True)
    with pytest.raises(ValueError, match="does not support x0"):
        solve(op, b, None, precond="eo", x0=jnp.zeros_like(b),
              reference=True)
    # halo-2 coupling connects equal parities: even-odd must refuse
    op2 = StencilOp(specs=(HaloSpec("ax0", 0, 2),), mass=0.5)
    with pytest.raises(ValueError, match="halo == 1"):
        solve(op2, jnp.zeros((8, 3)), None, precond="eo", reference=True)
    # an odd periodic extent breaks the 2-colouring
    op3 = StencilOp(specs=(HaloSpec("ax0", 0, 1),), mass=0.5)
    with pytest.raises(ValueError, match="even global extent"):
        solve(op3, jnp.zeros((7, 3)), None, precond="eo", reference=True)


def test_predicted_collective_counts():
    assert predicted_reduction_collectives("cg", 10) == 21
    assert predicted_reduction_collectives("pipelined", 10) == 10
    assert predicted_reduction_collectives("sstep", 10, s=4) == 3
    assert predicted_reduction_collectives("sstep", 8, s=4) == 2
    assert predicted_halo_exchanges("cg", "none", 10) == 10
    # one residual replacement at k=6 nets three extra matvecs (see helper)
    assert predicted_halo_exchanges("pipelined", "none", 10) == 13
    assert predicted_halo_exchanges("pipelined", "none", 10,
                                    replace_every=0) == 10
    assert predicted_halo_exchanges("pipelined", "none", 6,
                                    replace_every=6) == 6
    assert predicted_halo_exchanges("sstep", "none", 10, s=4) == 12
    assert predicted_halo_exchanges("cg", "eo", 10) == 22
    with pytest.raises(ValueError, match="unknown solver"):
        predicted_reduction_collectives("bogus", 4)
    with pytest.raises(ValueError, match="unknown precond"):
        predicted_halo_exchanges("cg", "bogus", 4)


# ---------------------------------------------------------------------------
# HLO: the collective-count ladder (acceptance: s-step at s=4 lowers to
# <= ceil(iters/4) inner-product reduction collectives) and exact permute
# byte/count predictions for every solver x precond
# ---------------------------------------------------------------------------

COUNTS_SCRIPT = r"""
import math
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator
from repro.core.halo import HaloSpec
from repro.launch.roofline import collective_wire_bytes
from repro.stencil import (StencilOp, predicted_halo_exchanges,
                           predicted_reduction_collectives, solve)

mesh = compat.make_mesh((2, 2, 2), ("x", "y", "z"))
SPECS = (HaloSpec("x", 0), HaloSpec("y", 1), HaloSpec("z", 2))
op = StencilOp(specs=SPECS, mass=0.8)
comm = Communicator(mesh, CommConfig(transport="psum",
                                     data_axes=("x", "y", "z"), channels=2))
local = (6, 6, 6, 4)
gshape = (12, 12, 12, 4)
hplan = comm.halo_plan(local, SPECS, schedule="concurrent")
ITERS, S = 8, 4

for solver in ("cg", "pipelined", "sstep"):
    for precond in ("none", "eo"):
        def run(b, sv=solver, pc=precond):
            r = solve(op, b, comm, solver=sv, precond=pc, s=S, tol=None,
                      maxiter=ITERS, schedule="concurrent",
                      chunks=comm.halo_chunks, channels=2)
            return r.x, r.rel_residual
        fn = jax.jit(compat.shard_map(run, mesh=mesh,
                                      in_specs=P("x", "y", "z", None),
                                      out_specs=(P("x", "y", "z", None), P()),
                                      check_vma=False))
        txt = fn.lower(jax.ShapeDtypeStruct(gshape, jnp.float32)) \
                .compile().as_text()
        stats = collective_wire_bytes(txt)
        ar = stats.op_counts.get("all-reduce", 0)
        cp = stats.op_counts.get("collective-permute", 0)
        pred_red = predicted_reduction_collectives(solver, ITERS, s=S)
        pred_ex = predicted_halo_exchanges(solver, precond, ITERS, s=S)
        assert ar == pred_red, (solver, precond, ar, pred_red)
        assert cp == pred_ex * hplan.n_units, (solver, precond, cp)
        pb = pred_ex * hplan.bytes_per_device
        mb = stats.op_bytes.get("collective-permute", 0.0)
        assert abs(mb - pb) / pb < 0.01, (solver, precond, mb, pb)
        print(solver, precond, "ar", ar, "cp", cp)
        if solver == "sstep":
            # the acceptance bound, verbatim
            assert ar <= math.ceil(ITERS / S), (ar, ITERS, S)

# the ladder itself: each variant strictly cheaper in reductions
assert predicted_reduction_collectives("sstep", ITERS, s=S) \
    < predicted_reduction_collectives("pipelined", ITERS) \
    < predicted_reduction_collectives("cg", ITERS)
print("SOLVER_COUNTS_OK")
"""


def test_solver_reduction_count_ladder_in_hlo():
    out = run_distributed(COUNTS_SCRIPT, n_devices=8)
    assert "SOLVER_COUNTS_OK" in out


# ---------------------------------------------------------------------------
# HLO: pipelined CG's reduction is mutually independent of the same
# iteration's matvec; classic CG's collectives form a chain (modulo the
# initial ‖b‖² batch, which only depends on b)
# ---------------------------------------------------------------------------

OVERLAP_SCRIPT = r"""
import re
import sys
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator
from repro.core.halo import HaloSpec
from repro.stencil import StencilOp, solve

mesh = compat.make_mesh((2, 2), ("x", "y"))
SPECS = (HaloSpec("x", 0), HaloSpec("y", 1))
op = StencilOp(specs=SPECS, mass=0.5)
comm = Communicator(mesh, CommConfig(transport="psum", data_axes=("x", "y"),
                                     channels=0))
gshape = (12, 12, 3)
ITERS = 4
PERMUTES_PER_EXCHANGE = 4        # 2 dims x 2 directions

def compiled_text(solver):
    def run(b):
        r = solve(op, b, comm, solver=solver, tol=None, maxiter=ITERS,
                  schedule="concurrent", chunks=2, channels=0)
        return r.x, r.rel_residual
    fn = jax.jit(compat.shard_map(run, mesh=mesh, in_specs=P("x", "y", None),
                                  out_specs=(P("x", "y", None), P()),
                                  check_vma=False))
    return fn.lower(jax.ShapeDtypeStruct(gshape, jnp.float32)) \
             .compile().as_text()

VAR = re.compile(r"%[\w.\-]+")
OP = re.compile(r"=\s*(?:\([^)]*\)|\S+)\s+(all-reduce|collective-permute)"
                r"(-start|-done)?\(")

def collective_order(text):
    '''(n_ar, n_cp, mutually-unordered (ar, cp) pairs) in the ENTRY graph.'''
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    defs, ar, cp = {}, [], []
    for line in lines[start:]:
        s = line.strip()
        if not s.startswith("%") or "=" not in s:
            continue
        vs = VAR.findall(s)
        defs[vs[0]] = set(vs[1:])
        m = OP.search(s)
        if m and m.group(2) != "-done":
            (ar if m.group(1) == "all-reduce" else cp).append(vs[0])
    sys.setrecursionlimit(100000)
    reach = {}
    def reachable(v):
        if v in reach:
            return reach[v]
        out = set(); reach[v] = out
        for u in defs.get(v, ()):
            out.add(u); out |= reachable(u)
        return out
    r = {v: reachable(v) for v in ar + cp}
    unordered = [(a, c) for a in ar for c in cp
                 if a not in r[c] and c not in r[a]]
    return len(ar), len(cp), len(unordered)

na, nc, un = collective_order(compiled_text("cg"))
assert na == 2 * ITERS + 1, na
assert nc == ITERS * PERMUTES_PER_EXCHANGE, nc
# classic: a chain — only the initial (rs, bs) batch floats free of the
# first matvec (both consume just b)
assert un == PERMUTES_PER_EXCHANGE, un

na, nc, un = collective_order(compiled_text("pipelined"))
assert na == ITERS, na
# iteration i's reduction is independent of iteration i's matvec: the last
# iteration's matvec is dead in unrolled HLO, so (ITERS-1) iterations
# contribute a full exchange of mutually-unordered permutes each
assert un == (ITERS - 1) * PERMUTES_PER_EXCHANGE, un

na, nc, un = collective_order(compiled_text("sstep"))
assert na == 1 and un == 0, (na, un)   # one reduction, after all matvecs
print("SOLVER_OVERLAP_OK")
"""


def test_pipelined_reduction_independent_of_matvec_in_hlo():
    out = run_distributed(OVERLAP_SCRIPT, n_devices=4)
    assert "SOLVER_OVERLAP_OK" in out


# ---------------------------------------------------------------------------
# distributed: residual histories match classic CG within tolerance; bitwise
# identical across transports on 2 procs (pairwise sums commute), tolerance
# across transports on 4 procs (association differs); fusion pinned off
# ---------------------------------------------------------------------------

HISTORY_SCRIPT = r"""
import math
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator
from repro.core.halo import HaloSpec
from repro.stencil import StencilOp, solve

MAXITER, S = 16, 4

for mesh_shape, names in [((2,), ("x",)), ((2, 2), ("x", "y"))]:
    nproc = 1
    for p in mesh_shape:
        nproc *= p
    mesh = compat.make_mesh(mesh_shape, names,
                            devices=jax.devices()[:nproc])
    specs = tuple(HaloSpec(a, d, 1) for d, a in enumerate(names))
    op = StencilOp(specs=specs, mass=0.3)
    gshape = tuple(6 * p for p in mesh_shape) + (3,)
    rng = np.random.RandomState(5)
    b = jnp.asarray(rng.randn(*gshape).astype(np.float32))
    pspec = P(*names, None)
    results = {}
    for transport in ("psum", "ring_hier"):
        comm = Communicator(mesh, CommConfig(transport=transport,
                                             data_axes=names, channels=2))
        for solver in ("cg", "pipelined", "sstep"):
            def run(bl, sv=solver, c=comm):
                r = solve(op, bl, c, solver=sv, s=S, tol=None,
                          maxiter=MAXITER, schedule="concurrent", chunks=2,
                          channels=2)
                return r.x, r.history
            fn = jax.jit(compat.shard_map(run, mesh=mesh, in_specs=pspec,
                                          out_specs=(pspec, P()),
                                          check_vma=False))
            x, h = fn(b)
            results[(transport, solver)] = (np.asarray(x), np.asarray(h))

    # 1) histories track classic within tolerance (per transport)
    for transport in ("psum", "ring_hier"):
        hc = results[(transport, "cg")][1]
        hp = results[(transport, "pipelined")][1]
        mask = hc[:MAXITER] > 1e-6 * hc[0]
        assert np.allclose(hp[:MAXITER][mask], hc[:MAXITER][mask],
                           rtol=0.1), (mesh_shape, transport, "pipelined")
        hs = results[(transport, "sstep")][1]
        for i in range(math.ceil(MAXITER / S)):
            ref = hc[i * S]
            if ref <= 1e-6 * hc[0]:
                break
            assert abs(hs[i] - ref) <= 0.05 * ref, \
                (mesh_shape, transport, "sstep", i)

    # 2) cross-transport: bitwise on 2 procs, tolerance on 4
    for solver in ("cg", "pipelined", "sstep"):
        xp, hp = results[("psum", solver)]
        xr, hr = results[("ring_hier", solver)]
        if nproc == 2:
            assert np.array_equal(xp, xr), (mesh_shape, solver, "x")
            assert np.array_equal(hp, hr), (mesh_shape, solver, "hist")
        else:
            assert np.allclose(xp, xr, rtol=1e-3, atol=1e-5), \
                (mesh_shape, solver)
            mask = hp > 1e-6 * hp[0]
            assert np.allclose(hp[mask], hr[mask], rtol=0.1), \
                (mesh_shape, solver)
    print(mesh_shape, "ok")

# 3) halo schedules move exact ppermute data: bitwise-identical iterates
#    for the new solvers too (fusion off, psum, 4-proc mesh)
mesh = compat.make_mesh((2, 2), ("x", "y"))
specs = (HaloSpec("x", 0, 1), HaloSpec("y", 1, 1))
op = StencilOp(specs=specs, mass=0.3)
rng = np.random.RandomState(7)
b = jnp.asarray(rng.randn(12, 12, 3).astype(np.float32))
comm = Communicator(mesh, CommConfig(transport="psum", data_axes=("x", "y"),
                                     channels=2))
for solver in ("pipelined", "sstep"):
    sols = {}
    for sched in ("sequential", "concurrent", "overlap"):
        def run(bl, sv=solver, sc=sched):
            r = solve(op, bl, comm, solver=sv, s=S, tol=None,
                      maxiter=MAXITER, schedule=sc, chunks=2, channels=2)
            return r.x
        fn = jax.jit(compat.shard_map(run, mesh=mesh,
                                      in_specs=P("x", "y", None),
                                      out_specs=P("x", "y", None),
                                      check_vma=False))
        sols[sched] = np.asarray(fn(b))
    for sched in ("concurrent", "overlap"):
        assert np.array_equal(sols["sequential"], sols[sched]), \
            (solver, sched)
print("SOLVER_HISTORY_OK")
"""


def test_solver_histories_distributed_and_cross_transport():
    out = run_distributed(HISTORY_SCRIPT, n_devices=4, extra_flags=NOFUSE)
    assert "SOLVER_HISTORY_OK" in out


# ---------------------------------------------------------------------------
# slow: even-odd preconditioning on the reference distributed problem —
# >= 1.5x fewer CG iterations, every solver x precond converging below 1e-5
# with the solution verified against the global operator
# ---------------------------------------------------------------------------

EO_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator
from repro.core.halo import HaloSpec
from repro.stencil import StencilOp, solve

mesh = compat.make_mesh((2, 2, 2), ("x", "y", "z"))
SPECS = (HaloSpec("x", 0), HaloSpec("y", 1), HaloSpec("z", 2))
op = StencilOp(specs=SPECS, mass=0.2)
rng = np.random.RandomState(3)
b = jnp.asarray(rng.randn(12, 12, 12, 3).astype(np.float32))
comm = Communicator(mesh, CommConfig(transport="psum",
                                     data_axes=("x", "y", "z"), channels=2))

def run_solver(solver, precond):
    def run(bl):
        r = solve(op, bl, comm, solver=solver, precond=precond, s=4,
                  tol=1e-5, maxiter=300, schedule="overlap", chunks=2,
                  channels=2)
        return r.x, r.iters, r.rel_residual
    fn = jax.jit(compat.shard_map(
        run, mesh=mesh, in_specs=P("x", "y", "z", None),
        out_specs=(P("x", "y", "z", None), P(), P()), check_vma=False))
    x, iters, rel = fn(b)
    assert float(rel) < 1e-5, (solver, precond, float(rel))
    # verify against the global operator, not just the recurrence residual
    ax = np.asarray(op.apply_reference(jnp.asarray(np.asarray(x))))
    true_rel = np.linalg.norm(ax - np.asarray(b)) / np.linalg.norm(np.asarray(b))
    assert true_rel < 1e-4, (solver, precond, true_rel)
    return int(iters)

iters = {}
for solver in ("cg", "pipelined", "sstep"):
    for precond in ("none", "eo"):
        iters[(solver, precond)] = run_solver(solver, precond)
        print(solver, precond, "iters", iters[(solver, precond)])

# the acceptance bar: even-odd cuts classic CG's iterations >= 1.5x
assert iters[("cg", "none")] >= 1.5 * iters[("cg", "eo")], iters
assert iters[("pipelined", "none")] >= 1.5 * iters[("pipelined", "eo")], iters
print("SOLVER_EO_OK")
"""


@pytest.mark.slow
def test_eo_reduces_iterations_distributed():
    out = run_distributed(EO_SCRIPT, n_devices=8, extra_flags=NOFUSE)
    assert "SOLVER_EO_OK" in out

"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import ops as fa_ops
from repro.kernels.flash_attn import ref as fa_ref
from repro.kernels.pack_quant import ops as pq_ops
from repro.kernels.pack_quant import ref as pq_ref
from repro.kernels.quant import ops as q_ops
from repro.kernels.quant import ref as q_ref
from repro.kernels.reduce_add import ops as ra_ops
from repro.kernels.reduce_add import ref as ra_ref


@pytest.mark.parametrize("n", [8 * 128, 64 * 128, 8 * 128 * 7])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_reduce_add_matches_ref(n, dtype, rng):
    a = jnp.asarray(rng.randn(n), dtype)
    b = jnp.asarray(rng.randn(n), dtype)
    out = ra_ops.add_accum(a, b, interpret=True)
    want = ra_ref.add_accum(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=0, atol=0)
    assert out.dtype == jnp.float32


def test_reduce_add_odd_shape_falls_back(rng):
    a = jnp.asarray(rng.randn(100), jnp.float32)   # not lane-aligned
    out = ra_ops.add_accum(a, a)
    np.testing.assert_allclose(np.asarray(out), 2 * np.asarray(a), rtol=1e-6)


@pytest.mark.parametrize("n,block", [(4096, 512), (2048, 128), (8192, 1024),
                                     (512, 256)])
def test_quant_matches_ref(n, block, rng):
    x = jnp.asarray(rng.randn(n).astype(np.float32) * 3.0)
    q, s = q_ops.quantize(x, block, interpret=True)
    q2, s2 = q_ref.quantize_blocks(np.asarray(x).reshape(-1, block))
    np.testing.assert_array_equal(np.asarray(q).reshape(-1, block), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2).reshape(-1), rtol=1e-7)
    back = q_ops.dequantize(q, s, block, interpret=True)
    # absmax block quantisation error bound: scale/2 per element
    bound = np.repeat(np.asarray(s), block) * 0.5 + 1e-8
    assert np.all(np.abs(np.asarray(back) - np.asarray(x)) <= bound)


@pytest.mark.parametrize("n,block", [
    (960, 96),      # block not a multiple of 128 lanes
    (192, 96),      # ... and a tiny block count
    (640, 320),     # lane-misaligned block, several blocks
])
def test_quant_misaligned_is_the_oracle(n, block, rng):
    """Shapes off the (32, 128) int8 tiling take the fallback, which IS the
    jnp oracle — q/scales/decode are bitwise identical, never approximate
    (``kernels.pack``'s fallback-is-the-oracle contract)."""
    x = jnp.asarray(rng.randn(n).astype(np.float32) * 3.0)
    q, s = q_ops.quantize(x, block)
    q2, s2 = q_ref.quantize_blocks(np.asarray(x).reshape(-1, block))
    np.testing.assert_array_equal(np.asarray(q).reshape(-1, block),
                                  np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2).reshape(-1))
    back = q_ops.dequantize(q, s, block)
    wback = q_ref.dequantize_blocks(np.asarray(q).reshape(-1, block),
                                    np.asarray(s).reshape(-1, 1))
    np.testing.assert_array_equal(np.asarray(back),
                                  np.asarray(wback).reshape(-1))


def test_quant_zero_block_safe():
    x = jnp.zeros((1024,), jnp.float32)
    q, s = q_ops.quantize(x, 256, interpret=True)
    assert np.all(np.asarray(q) == 0)
    back = q_ops.dequantize(q, s, 256, interpret=True)
    assert np.all(np.asarray(back) == 0)


@pytest.mark.parametrize("n,offset,block", [(2048, 512, 512), (4096, 0, 512),
                                            (1024, 2048, 256),
                                            (3072, 1024, 1024)])
def test_pack_quant_matches_ref(n, offset, block, rng):
    """Fused pack+quantize (aligned fast path) vs the jnp oracle: int8
    payload exact, scales to 1 ulp, fused dequant recovers the oracle's
    decode."""
    payload, total = 8192, 8192 + 128
    arena = jnp.zeros((total,), jnp.int8)
    x = jnp.asarray(rng.randn(n).astype(np.float32) * 3.0)
    x = x.at[:block].set(0.0)                     # zero block stays safe
    out, res = pq_ops.write_quant_flat(arena, x, offset, payload, block,
                                       interpret=True)
    want, wres = pq_ref.write_quant_flat(arena, x, offset, payload, block)
    np.testing.assert_array_equal(
        np.asarray(out[offset:offset + n]), np.asarray(want[offset:offset + n]))
    s = pq_ref.read_scales_flat(out, offset, n, payload, block)
    s2 = pq_ref.read_scales_flat(want, offset, n, payload, block)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-7)
    np.testing.assert_allclose(np.asarray(res), np.asarray(wres),
                               rtol=1e-5, atol=1e-6)
    back = pq_ops.read_dequant_flat(out, offset, n, payload, block,
                                    interpret=True)
    wback = pq_ref.read_dequant_flat(want, offset, n, payload, block)
    np.testing.assert_allclose(np.asarray(back), np.asarray(wback),
                               rtol=1e-6, atol=1e-7)
    # absmax block quantisation error bound: scale/2 per element
    bound = np.repeat(np.asarray(s), block) * 0.5 + 1e-8
    assert np.all(np.abs(np.asarray(back) - np.asarray(x)) <= bound)


@pytest.mark.parametrize("n,offset,block", [
    (1024, 256, 512),     # offset not a block multiple
    (960, 0, 96),         # block not lane-aligned
    (512, 0, 512),        # arena length not lane-aligned (total=4196+...)
])
def test_pack_quant_misaligned_is_the_oracle(n, offset, block, rng):
    """Shapes off the (32, 128) int8 tiling take the fallback, which IS the
    jnp oracle — outputs are bitwise identical, never approximately so."""
    payload = 4096
    total = payload + 100 if n == 512 else payload + 128
    arena = jnp.zeros((total,), jnp.int8)
    x = jnp.asarray(rng.randn(n).astype(np.float32) * 3.0)
    out, res = pq_ops.write_quant_flat(arena, x, offset, payload, block)
    want, wres = pq_ref.write_quant_flat(arena, x, offset, payload, block)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(res), np.asarray(wres))
    back = pq_ops.read_dequant_flat(out, offset, n, payload, block)
    wback = pq_ref.read_dequant_flat(want, offset, n, payload, block)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(wback))


@pytest.mark.parametrize("sq,sk,hq,hkv,d", [
    (256, 256, 4, 2, 64), (128, 128, 2, 2, 32), (256, 256, 8, 1, 64),
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_matches_ref(sq, sk, hq, hkv, d, causal, window, rng):
    q = jnp.asarray(rng.randn(2, hq, sq, d).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.randn(2, hkv, sk, d).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.randn(2, hkv, sk, d).astype(np.float32))
    out = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 block_q=128, block_k=128, interpret=True)
    want = fa_ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    v = jnp.asarray(rng.randn(1, 2, 128, 64), jnp.bfloat16)
    out = fa_ops.flash_attention(q, k, v, interpret=True)
    want = fa_ref.attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)

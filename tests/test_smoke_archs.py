"""Per-architecture smoke tests: reduced same-family config, one forward +
train grad + one decode step on CPU; output shapes and finiteness asserted.
The FULL configs are exercised only via the AOT dry-run (no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, applicable_shapes, get_config, list_archs, \
    reduced_config, sub_quadratic
from repro.models import build_model

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_and_decode(arch, rng):
    cfg = reduced_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    B, S = 2, 32
    batch = {"tokens": jnp.asarray(rng.randint(0, 500, (B, S)), jnp.int32),
             "labels": jnp.asarray(rng.randint(0, 500, (B, S)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.enc_seq, cfg.d_model).astype(np.float32)) * 0.1
    if cfg.frontend == "vision_stub":
        batch["extra_embeds"] = jnp.asarray(
            rng.randn(B, cfg.frontend_seq, cfg.d_model).astype(np.float32)) * 0.1

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: m.loss_fn(p, b)))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch}: bad grads"

    state = m.init_decode_state(B, 64, params=params,
                                frames=batch.get("frames"))
    logits, state2 = jax.jit(
        lambda p, t, s, pos: m.decode_step(p, t, s, pos, seq_len=64))(
        params, jnp.ones((B,), jnp.int32), state, jnp.asarray(3))
    assert logits.shape == (B, m.cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_registered(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.num_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 0
    shapes = applicable_shapes(cfg)
    assert "train_4k" in shapes and "decode_32k" in shapes
    # long_500k only for sub-quadratic archs (DESIGN.md §Arch-applicability)
    assert ("long_500k" in shapes) == sub_quadratic(cfg)


def test_assigned_cell_count():
    """40 assigned cells = 34 runnable + 6 documented long_500k skips."""
    total = sum(4 for _ in ARCHS)
    runnable = sum(len(applicable_shapes(get_config(a))) for a in ARCHS)
    assert total == 40
    assert runnable == 34


def test_arch_exact_hyperparams():
    spot = {
        "llava-next-34b": dict(num_layers=60, d_model=7168, d_ff=20480),
        "phi3-medium-14b": dict(num_layers=40, d_model=5120, d_ff=17920),
        "mixtral-8x7b": dict(num_layers=32, d_model=4096),
        "falcon-mamba-7b": dict(num_layers=64, d_model=4096, d_ff=0),
        "whisper-base": dict(num_layers=6, d_model=512, d_ff=2048),
    }
    for arch, want in spot.items():
        cfg = get_config(arch)
        for k, v in want.items():
            assert getattr(cfg, k) == v, (arch, k)
    assert get_config("mixtral-8x7b").moe.num_experts == 8
    assert get_config("llama4-maverick-400b-a17b").moe.num_experts == 128
    assert get_config("hymba-1.5b").attn.num_kv_heads == 5
    assert get_config("qwen2-7b").attn.qkv_bias is True


def test_param_counts_in_range():
    """Total params should land near the published sizes (padding included)."""
    expect = {
        "llama3.2-1b": (1.0e9, 1.8e9),
        "qwen2-7b": (7.0e9, 8.5e9),
        "phi3-medium-14b": (13e9, 15.5e9),
        "mixtral-8x7b": (45e9, 50e9),
        "falcon-mamba-7b": (6.5e9, 8.5e9),
        "llava-next-34b": (33e9, 37e9),
        "llama4-maverick-400b-a17b": (370e9, 430e9),
        "minicpm-2b": (2.2e9, 3.3e9),
        "hymba-1.5b": (1.3e9, 2.1e9),
        "whisper-base": (0.05e9, 0.15e9),
    }
    for arch, (lo, hi) in expect.items():
        n = build_model(get_config(arch)).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"

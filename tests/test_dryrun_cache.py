"""Dry-run cell caching: the output-JSON key must include an override
fingerprint, so re-running with a different ``--accum-policy`` / schedule /
solver override can never be served a stale cached cell (regression: the
key used to be ``tag|arch|shape|mesh`` only).

Runs in a subprocess because importing ``repro.launch.dryrun`` sets the
512-device ``XLA_FLAGS`` override, which must never leak into the main
pytest process (see tests/conftest.py)."""

from conftest import run_distributed

CACHE_KEY_SCRIPT = r"""
from repro.launch.dryrun import cell_key, overrides_fingerprint

# no overrides: the bare legacy-shaped key
assert cell_key("t", "arch", "shape", "single") == "t|arch|shape|single"
assert cell_key("t", "arch", "shape", "single", {}) == "t|arch|shape|single"

# overrides fold into the key ...
base = {"accum_policy": "accumulate_then_reduce", "accum_microbatches": 1}
k1 = cell_key("t", "arch", "shape", "single", base)
assert k1 != "t|arch|shape|single"

# ... so changing ONLY an override changes the key (the regression)
k2 = cell_key("t", "arch", "shape", "single",
              {**base, "accum_policy": "scheduled"})
assert k2 != k1, (k1, k2)
k3 = cell_key("t", "arch", "shape", "single",
              {**base, "accum_microbatches": 4})
assert k3 != k1 and k3 != k2

# solver-grid knobs distinguish stencil cells the same way
s1 = cell_key("t", "stencil", "L8h1", "single",
              {"solver": "cg", "precond": "none", "sstep_s": 4})
s2 = cell_key("t", "stencil", "L8h1", "single",
              {"solver": "sstep", "precond": "none", "sstep_s": 4})
s3 = cell_key("t", "stencil", "L8h1", "single",
              {"solver": "sstep", "precond": "eo", "sstep_s": 4})
assert len({s1, s2, s3}) == 3

# deterministic and order-insensitive: same dict -> same key
a = {"x": 1, "y": "z", "nested": {"b": 2, "a": 1}}
b = {"nested": {"a": 1, "b": 2}, "y": "z", "x": 1}
assert overrides_fingerprint(a) == overrides_fingerprint(b)
assert cell_key("t", "m", "s", "multi", a) == cell_key("t", "m", "s", "multi", b)

# distinct values never collide in the fingerprint
assert overrides_fingerprint({"p": "ab"}) != overrides_fingerprint({"p": "a"})
assert overrides_fingerprint(None) == "" == overrides_fingerprint({})
print("DRYRUN_CACHE_KEY_OK")
"""


def test_cell_key_includes_override_fingerprint():
    out = run_distributed(CACHE_KEY_SCRIPT, n_devices=1)
    assert "DRYRUN_CACHE_KEY_OK" in out

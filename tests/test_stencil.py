"""Stencil subsystem: halo-schedule construction invariants, the uneven
chunk split, operator/CG correctness against references, bitwise
cross-schedule equivalence on 1-D/2-D/3-D meshes, HLO-level schedule
structure (overlap independence vs sequential chaining), and predicted vs
lowered halo wire bytes for indivisible shapes."""

import numpy as np
import pytest

from conftest import run_distributed

from repro.comm import (CommConfig, Communicator, HALO_SCHEDULES,
                        build_halo_schedule, halo_interior_fraction)
from repro.core.halo import (HaloSpec, _split_chunks, chunk_sizes,
                             halo_bytes)

# backend fusion heuristics may contract FMAs differently per module; the
# bitwise cross-schedule assertions pin the fusion pass off (see
# repro/stencil/op.py docstring), tolerance assertions run under defaults
NOFUSE = "--xla_disable_hlo_passes=fusion"


# ---------------------------------------------------------------------------
# build_halo_schedule invariants (plain-pytest mirror of the hypothesis
# versions in test_properties.py, so they run without the dev extra)
# ---------------------------------------------------------------------------

SHAPE = (6, 7, 5, 3)


@pytest.mark.parametrize("schedule", HALO_SCHEDULES)
@pytest.mark.parametrize("channels", [0, 1, 2, 4])
@pytest.mark.parametrize("halo", [1, 2])
def test_halo_schedule_invariants(schedule, channels, halo):
    specs = [HaloSpec("x", 0, halo), HaloSpec("y", 1, halo),
             HaloSpec("z", 2, halo)]
    s = build_halo_schedule(specs, SHAPE, schedule=schedule,
                            channels=channels, chunks=3)
    # every unit issued exactly once, all in the single phase
    seen = sorted(b for slot in s.slots for b in slot.bucket_ids)
    assert seen == list(range(s.n_buckets))
    assert all(slot.phase == 0 for slot in s.slots)
    # channel assignments within range per schedule semantics
    if schedule == "sequential":
        assert {slot.channel for slot in s.slots} == {0}
    elif schedule == "overlap" and channels >= 1:
        assert all(0 <= slot.channel < channels for slot in s.slots)
    else:
        assert all(0 <= slot.channel < s.n_buckets for slot in s.slots)
    assert 0.0 <= s.overlap_fraction <= 1.0
    # payload bytes conserved: chunk splitting never changes the total
    assert sum(s.bucket_sizes) == halo_bytes(SHAPE, specs, 4)
    if schedule == "overlap":
        assert s.overlap_fraction == pytest.approx(
            halo_interior_fraction(SHAPE, specs))
        assert s.overlap_fraction > 0.0
    else:
        assert s.overlap_fraction == 0.0


def test_chunked_schedule_counts_uneven_pieces():
    specs = [HaloSpec("x", 0)]
    s = build_halo_schedule(specs, (6, 7, 3), schedule="chunked", chunks=3)
    # face (1, 7, 3) splits along the 7-dim into 3+2+2 rows
    assert s.n_buckets == 6
    assert sorted(s.bucket_sizes, reverse=True) == [3 * 3 * 4] * 2 + \
        [2 * 3 * 4] * 4


def test_unknown_halo_schedule_raises():
    import jax.numpy as jnp

    from repro.core.halo import halo_exchange

    with pytest.raises(ValueError, match="unknown halo schedule"):
        build_halo_schedule([HaloSpec("x", 0)], (4, 4), schedule="bogus")
    with pytest.raises(ValueError, match="schedule must be one of"):
        halo_exchange(jnp.zeros((4, 4)), [HaloSpec("x", 0)],
                      schedule="bogus")


# ---------------------------------------------------------------------------
# uneven chunk split (regression: used to silently degrade to 1 chunk)
# ---------------------------------------------------------------------------


def test_chunk_sizes_cover_and_balance():
    for n, k in [(7, 3), (5, 2), (1, 4), (12, 5), (6, 2), (8, 8)]:
        cs = chunk_sizes(n, k)
        assert sum(cs) == n
        assert len(cs) == min(k, n)
        assert max(cs) - min(cs) <= 1


def test_split_chunks_uneven_roundtrip():
    import jax.numpy as jnp

    face = jnp.arange(1 * 7 * 5, dtype=jnp.float32).reshape(1, 7, 5)
    parts = _split_chunks(face, 3, 0)
    assert len(parts) == 3          # regression: was 1 (silent degrade)
    assert [p.shape[1] for p in parts] == [3, 2, 2]
    back = jnp.concatenate(parts, axis=1)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(face))


# ---------------------------------------------------------------------------
# operator + CG against references (single process)
# ---------------------------------------------------------------------------


def test_operator_matches_periodic_reference_all_schedules():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.stencil import StencilOp

    op = StencilOp(specs=(HaloSpec("x", 0), HaloSpec("y", 1)), mass=0.7)
    x = jnp.asarray(np.random.RandomState(0).randn(6, 5).astype(np.float32))
    ref = np.asarray(op.apply_reference(x))
    mesh = compat.make_mesh((1, 1), ("x", "y"))
    outs = {}
    for sched in HALO_SCHEDULES:
        fn = jax.jit(compat.shard_map(
            lambda v, s=sched: op.apply(v, schedule=s, channels=2),
            mesh=mesh, in_specs=P("x", "y"), out_specs=P("x", "y"),
            check_vma=False))
        outs[sched] = np.asarray(fn(x))
        assert np.abs(outs[sched] - ref).max() < 1e-5, sched
    for sched in HALO_SCHEDULES[1:]:
        np.testing.assert_array_equal(outs["sequential"], outs[sched])


def test_operator_spd_and_cg_matches_dense_solve():
    import jax.numpy as jnp

    from repro.stencil import StencilOp, cg_solve

    op = StencilOp(specs=(HaloSpec("x", 0), HaloSpec("y", 1, 2)), mass=0.4)
    A = np.asarray(op.dense_matrix((6, 5)))
    np.testing.assert_allclose(A, A.T, atol=1e-6)
    assert np.linalg.eigvalsh(A).min() > 0.0
    b = jnp.asarray(np.random.RandomState(1).randn(6, 5).astype(np.float32))
    res = cg_solve(op, b, None, tol=1e-7, maxiter=300,
                   matvec=op.apply_reference)
    xref = np.linalg.solve(A, np.asarray(b).reshape(-1)).reshape(6, 5)
    assert float(res.rel_residual) < 1e-6
    assert np.abs(np.asarray(res.x) - xref).max() < 1e-4


def test_cg_fixed_iteration_mode_is_nan_free_past_convergence():
    import jax.numpy as jnp

    from repro.stencil import StencilOp, cg_solve

    op = StencilOp(specs=(HaloSpec("x", 0),), mass=1.0)
    b = jnp.asarray(np.random.RandomState(2).randn(8, 3).astype(np.float32))
    res = cg_solve(op, b, None, tol=None, maxiter=50,
                   matvec=op.apply_reference)
    assert np.isfinite(np.asarray(res.x)).all()
    assert float(res.rel_residual) < 1e-6


def test_halo_plan_bytes_and_describe():
    from repro import compat

    mesh = compat.make_mesh((1,), ("x",))
    comm = Communicator(mesh, CommConfig(data_axes=("x",), channels=2))
    specs = [HaloSpec("x", 0, 2)]
    plan = comm.halo_plan((6, 5), specs, schedule="concurrent")
    assert plan.bytes_per_device == halo_bytes((6, 5), specs, 4)
    assert plan.n_units == 2 and plan.unit_keys == ("x-", "x+")
    d = plan.describe()
    assert d["schedule"] == "concurrent"
    assert d["bytes_per_device"] == plan.bytes_per_device
    assert d["overlap_fraction"] == 0.0
    # overlap records the interior fraction the roofline can hide under
    ov = comm.halo_plan((6, 5), specs, schedule="overlap")
    assert ov.overlap_fraction == pytest.approx(
        halo_interior_fraction((6, 5), specs))


# ---------------------------------------------------------------------------
# distributed: all four schedules on 1-D / 2-D / 3-D meshes, halo 1-2,
# bitwise-identical operator output (fusion pass pinned off)
# ---------------------------------------------------------------------------

MESH_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import HALO_SCHEDULES
from repro.core.halo import HaloSpec
from repro.stencil import StencilOp

rng = np.random.RandomState(3)
CASES = [((8,), ("x",)), ((4, 2), ("x", "y")), ((2, 2, 2), ("x", "y", "z"))]
for mesh_shape, names in CASES:
    mesh = compat.make_mesh(mesh_shape, names)
    nd = len(names)
    for halo in (1, 2):
        specs = tuple(HaloSpec(a, d, halo) for d, a in enumerate(names))
        op = StencilOp(specs=specs, mass=0.8)
        gshape = tuple(6 * p for p in mesh_shape) + (3,)
        xg = jnp.asarray(rng.randn(*gshape).astype(np.float32))
        ref = np.asarray(op.apply_reference(xg))
        pspec = P(*names, None)
        outs = {}
        for sched in HALO_SCHEDULES:
            fn = jax.jit(compat.shard_map(
                lambda v, s=sched: op.apply(v, schedule=s, chunks=2,
                                            channels=2),
                mesh=mesh, in_specs=pspec, out_specs=pspec,
                check_vma=False))
            outs[sched] = np.asarray(fn(xg))
            err = np.abs(outs[sched] - ref).max()
            assert err < 1e-5, (mesh_shape, halo, sched, err)
        for sched in HALO_SCHEDULES[1:]:
            assert np.array_equal(outs["sequential"], outs[sched]), \
                (mesh_shape, halo, sched)
        print(mesh_shape, "halo", halo, "ok")
print("STENCIL_MESHES_OK")
"""


def test_operator_bitwise_identical_across_schedules_and_meshes():
    out = run_distributed(MESH_SCRIPT, n_devices=8, extra_flags=NOFUSE)
    assert "STENCIL_MESHES_OK" in out


# ---------------------------------------------------------------------------
# HLO-level schedule structure: the overlap schedule lowers to >= 2*n_dims
# mutually independent collective-permutes; sequential to a data-dependent
# chain (each transfer transitively consumes the previous one's result)
# ---------------------------------------------------------------------------

HLO_SCRIPT = r"""
import re
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.halo import HaloSpec, halo_exchange

mesh = compat.make_mesh((2, 2), ("x", "y"))
SPECS = (HaloSpec("x", 0), HaloSpec("y", 1))
N_DIMS = 2

def lowered(sched, channels=0):
    def hx(xl):
        h = halo_exchange(xl, SPECS, schedule=sched, chunks=2,
                          channels=channels)
        return sum(v.sum() for v in h.values())
    g = jax.jit(compat.shard_map(hx, mesh=mesh, in_specs=P("x", "y"),
                                 out_specs=P(), check_vma=False))
    return g.lower(jnp.zeros((8, 8), jnp.float32)).as_text()

VAR = re.compile(r"%[\w.#]+")

def cp_dependencies(text):
    '''[(cp_def_var, transitively_reachable_earlier_cp_defs)], in order.'''
    defs = {}          # var -> set of operand vars
    cp_vars = []
    for line in text.splitlines():
        if "=" not in line:
            continue
        vs = VAR.findall(line)
        if not vs or not line.lstrip().startswith("%"):
            continue
        head, deps = vs[0], set(vs[1:])
        defs[head] = deps
        if "collective_permute" in line:
            cp_vars.append(head)
    out = []
    for v in cp_vars:
        seen, stack, hits = set(), list(defs.get(v, ())), set()
        while stack:
            u = stack.pop()
            if u in seen:
                continue
            seen.add(u)
            if u in cp_vars and u != v:
                hits.add(u)
            stack.extend(defs.get(u, ()))
        out.append((v, hits))
    return out

seq = cp_dependencies(lowered("sequential"))
assert len(seq) >= 2 * N_DIMS, len(seq)
# a chain: every transfer after the first consumes an earlier one's result
dependent = [v for v, hits in seq if hits]
assert len(dependent) == len(seq) - 1, (len(dependent), len(seq))

ov = cp_dependencies(lowered("overlap", channels=0))
assert len(ov) >= 2 * N_DIMS, len(ov)
# fully independent: no transfer consumes any other transfer's result
assert all(not hits for _, hits in ov), ov

# channels=2 stripes the faces over exactly 2 rails: 2 independent roots,
# everything else chained behind its rail head
ov2 = cp_dependencies(lowered("overlap", channels=2))
roots = [v for v, hits in ov2 if not hits]
assert len(roots) == 2, (len(roots), len(ov2))
print("STENCIL_HLO_OK")
"""


def test_overlap_lowers_independent_permutes_sequential_chains():
    out = run_distributed(HLO_SCRIPT, n_devices=4)
    assert "STENCIL_HLO_OK" in out


# ---------------------------------------------------------------------------
# predicted vs lowered halo wire bytes for odd (chunk-indivisible) shapes
# (regression for the silent 1-chunk degrade)
# ---------------------------------------------------------------------------

BYTES_SCRIPT = r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator
from repro.core.halo import HaloSpec, halo_exchange
from repro.launch.roofline import collective_wire_bytes

mesh = compat.make_mesh((4, 2), ("x", "y"))
SPECS = (HaloSpec("x", 0), HaloSpec("y", 1))
comm = Communicator(mesh, CommConfig(data_axes=("x", "y"), channels=3))
local = (5, 7, 3)                  # odd everywhere: every face splits unevenly
gshape = (4 * 5, 2 * 7, 3)

for sched in ("chunked", "concurrent", "overlap", "sequential"):
    def hx(xl, s=sched):
        h = comm.halo_exchange(xl, SPECS, schedule=s)
        return sum(v.sum() for v in h.values())
    g = jax.jit(compat.shard_map(hx, mesh=mesh, in_specs=P("x", "y", None),
                                 out_specs=P(), check_vma=False))
    txt = g.lower(jnp.zeros(gshape, jnp.float32)).compile().as_text()
    stats = collective_wire_bytes(txt)
    plan = comm.halo_plan(local, SPECS, schedule=sched)
    measured = stats.op_bytes.get("collective-permute", 0.0)
    assert plan.bytes_per_device > 0
    rel = abs(measured - plan.bytes_per_device) / plan.bytes_per_device
    assert rel < 0.01, (sched, measured, plan.bytes_per_device)
    n_cp = stats.op_counts.get("collective-permute", 0)
    assert n_cp == plan.n_units, (sched, n_cp, plan.n_units)
    print(sched, "bytes", measured, "units", n_cp)
print("STENCIL_BYTES_OK")
"""


def test_predicted_halo_bytes_match_lowered_hlo_odd_shapes():
    out = run_distributed(BYTES_SCRIPT, n_devices=8)
    assert "STENCIL_BYTES_OK" in out


# ---------------------------------------------------------------------------
# CG end-to-end: converges under every schedule with identical iterates
# (2x2x2 mesh; inner products on the channelized ring and psum transports)
# ---------------------------------------------------------------------------

CG_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator, HALO_SCHEDULES
from repro.core.halo import HaloSpec
from repro.stencil import StencilOp, cg_solve

mesh = compat.make_mesh((2, 2, 2), ("x", "y", "z"))
SPECS = (HaloSpec("x", 0), HaloSpec("y", 1), HaloSpec("z", 2))
op = StencilOp(specs=SPECS, mass=0.5)
rng = np.random.RandomState(3)
b = jnp.asarray(rng.randn(12, 12, 12, 3).astype(np.float32))

for transport in ("psum", "ring_hier"):
    comm = Communicator(mesh, CommConfig(transport=transport,
                                         data_axes=("x", "y", "z"),
                                         channels=2))
    sols = {}
    for sched in HALO_SCHEDULES:
        def run(bl, s=sched):
            r = cg_solve(op, bl, comm, tol=1e-6, maxiter=200, schedule=s,
                         chunks=2, channels=2)
            return r.x, r.iters, r.rel_residual
        fn = jax.jit(compat.shard_map(
            run, mesh=mesh, in_specs=P("x", "y", "z", None),
            out_specs=(P("x", "y", "z", None), P(), P()), check_vma=False))
        x, iters, rel = fn(b)
        assert float(rel) < 1e-5, (transport, sched, float(rel))
        sols[sched] = np.asarray(x)
        print(transport, sched, "iters", int(iters), "rel", float(rel))
    for sched in HALO_SCHEDULES[1:]:
        assert np.array_equal(sols["sequential"], sols[sched]), \
            (transport, sched)
    # solution actually solves the global system
    ax = np.asarray(op.apply_reference(jnp.asarray(sols["overlap"])))
    rel = np.linalg.norm(ax - np.asarray(b)) / np.linalg.norm(np.asarray(b))
    assert rel < 1e-4, rel
print("STENCIL_CG_OK")
"""


@pytest.mark.slow
def test_cg_converges_identically_under_all_schedules():
    out = run_distributed(CG_SCRIPT, n_devices=8, extra_flags=NOFUSE)
    assert "STENCIL_CG_OK" in out

"""Distributed correctness on 8 fake host devices (fresh subprocesses so the
main pytest process keeps its single real device)."""

import pytest

from conftest import run_distributed

RING_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import ring
from repro.core.ring import RingConfig

mesh = compat.make_mesh((2, 4), ("pod", "data"))
L = 2*4*2*4*512*2
x = np.random.RandomState(0).randn(8, L).astype(np.float32)
want = x.sum(0)

def run(fn, cfg, axes):
    g = jax.jit(compat.shard_map(lambda xl: fn(xl.reshape(-1), axes, cfg),
        mesh=mesh, in_specs=P(("pod","data")), out_specs=P(), check_vma=False))
    return np.asarray(g(x.reshape(-1)))

for cfg in [RingConfig(chunks=1, bidirectional=False),
            RingConfig(chunks=2, bidirectional=True),
            RingConfig(chunks=4, bidirectional=True)]:
    out = run(ring.hierarchical_all_reduce, cfg, ("data","pod"))
    assert np.abs(out - want).max() < 1e-4, cfg
    out = run(ring.flat_all_reduce, cfg, ("data","pod"))
    assert np.abs(out - want).max() < 1e-4, cfg

# lossy wire configs: bounded relative error
for cfg, tol in [(RingConfig(chunks=2, bidirectional=True, wire_dtype="bfloat16"), 0.03),
                 (RingConfig(chunks=2, bidirectional=True, codec="int8", codec_block=256), 0.05)]:
    out = run(ring.hierarchical_all_reduce, cfg, ("data","pod"))
    rel = np.abs(out - want).max() / np.abs(want).max()
    assert rel < tol, (cfg, rel)

# RS/AG roundtrip == AR
cfg = RingConfig(chunks=2, bidirectional=True)
def rsag(xl):
    s = ring.ring_reduce_scatter(xl.reshape(-1), "data", cfg)
    return ring.ring_all_gather(s, "data", cfg)
g = jax.jit(compat.shard_map(rsag, mesh=mesh, in_specs=P(("pod","data")),
    out_specs=P(("pod","data")), check_vma=False))
out = np.asarray(g(x.reshape(-1))).reshape(2, 4, L)
per_pod = x.reshape(2,4,L).sum(1)
for p in range(2):
    for d in range(4):
        assert np.abs(out[p,d] - per_pod[p]).max() < 1e-4
print("RING_OK")
"""

REDUCER_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro import compat
from repro.core.reducer import GradientReducer, ReduceConfig

mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.RandomState(1)
grads = {"w": jnp.asarray(rng.randn(16, 256).astype(np.float32)),
         "b": jnp.asarray(rng.randn(256).astype(np.float32)),
         "emb": jnp.asarray(rng.randn(1000, 64).astype(np.float32))}
specs = {"w": P(None, "model"), "b": P(), "emb": P("model", None)}
sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                  is_leaf=lambda x: isinstance(x, P))
grads = jax.tree.map(lambda g, s: jax.device_put(g, s), grads, sh)

for policy in ["fused_ring_hierarchical", "fused_ring", "native_psum",
               "native_psum_fused", "baidu_original"]:
    red = GradientReducer(mesh, ReduceConfig(policy=policy, data_axes=("pod","data"), chunks=2))
    def mk(x):
        i = jax.lax.axis_index("pod")*2 + jax.lax.axis_index("data")
        return jax.tree.map(lambda t: t*(1.0+i), x)
    gv = jax.jit(compat.shard_map(mk, mesh=mesh, in_specs=(specs,),
                                  out_specs=specs, check_vma=False))(grads)
    out = jax.jit(lambda g: red.reduce(g, specs)[0])(gv)
    scale = np.mean([1.0+i for i in range(4)])
    for k in grads:
        err = float(jnp.max(jnp.abs(out[k] - grads[k]*scale)))
        assert err < 1e-4, (policy, k, err)
print("REDUCER_OK")
"""

HALO_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.halo import HaloSpec, halo_exchange

mesh = compat.make_mesh((8,), ("data",))
Y = jnp.arange(64, dtype=jnp.float32).reshape(64, 1)
for sched in ["concurrent", "sequential", "chunked"]:
    def hx(xl, s=sched):
        h = halo_exchange(xl, [HaloSpec("data", 0)], schedule=s, chunks=1)
        return jnp.concatenate([h[("data","-")], xl, h[("data","+")]], 0)
    g = jax.jit(compat.shard_map(hx, mesh=mesh, in_specs=P("data"),
                                 out_specs=P("data"), check_vma=False))
    out = np.asarray(g(Y)).reshape(8, 10)
    ys = np.asarray(Y).reshape(8, 8)
    for r in range(8):
        exp = np.concatenate([[ys[(r-1)%8,-1]], ys[r], [ys[(r+1)%8,0]]])
        assert np.array_equal(out[r], exp), (sched, r)
print("HALO_OK")
"""

DPMODES_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.configs import reduced_config
from repro.models import build_model
from repro.runtime.train_step import TrainStepConfig, build_train_step, init_train_state
from repro.core.reducer import ReduceConfig
from repro.optim import adamw_tree_update, init_opt_state, OptimConfig, make_schedule
from repro.optim.adamw import clip_factor

mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduced_config("llama3.2-1b")
m = build_model(cfg)
B, S = 8, 32
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, 500, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.randint(0, 500, (B, S)), jnp.int32)}
bspecs = {"tokens": P(("pod","data"), None), "labels": P(("pod","data"), None)}

ocfg = OptimConfig()
params = m.init(jax.random.key(7))
opt = init_opt_state(params)
sched = make_schedule(ocfg.schedule, base_lr=ocfg.base_lr, warmup=ocfg.warmup,
                      total=ocfg.total_steps)
@jax.jit
def ref_step(params, opt, step):
    loss, g = jax.value_and_grad(lambda p: m.loss_fn(p, batch))(params)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(g)))
    g = jax.tree.map(lambda x: x * clip_factor(gn, ocfg.clip_norm), g)
    p2, opt2 = adamw_tree_update(params, g, opt, step, sched(step), ocfg)
    return p2, opt2, loss
ref = []
st = jnp.zeros((), jnp.int32)
for i in range(3):
    params, opt, loss = ref_step(params, opt, st); st = st + 1
    ref.append(float(loss))

for mode, tol in [("replicated", 5e-5), ("zero1", 5e-5), ("fsdp", 5e-4)]:
    tcfg = TrainStepConfig(dp_mode=mode,
                           reduce=ReduceConfig(policy="fused_ring_hierarchical", chunks=2),
                           microbatches=2)
    with mesh:
        state, _ = init_train_state(m, mesh, tcfg, key=jax.random.key(7))
        step = build_train_step(m, mesh, tcfg, bspecs)
        losses = []
        for i in range(3):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
    err = max(abs(a-b) for a, b in zip(ref, losses))
    assert err < tol, (mode, ref, losses)
    print(mode, "OK", err)
print("DPMODES_OK")
"""

SERVE_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro import compat
from repro.configs import reduced_config, base
from repro.models import build_model
from repro.runtime.serve_step import build_decode_step, build_prefill
from repro.sharding import shardings_of

mesh = compat.make_mesh((4, 2), ("data", "model"))
cfg = reduced_config("llama3.2-1b")
m = build_model(cfg)
params = m.init(jax.random.key(0))
B, S = 8, 16384  # long cache -> seq-sharded kv path
shape = base.ShapeConfig("t", S, B, "decode")
step, pspecs, sspecs = build_decode_step(m, mesh, shape)
with mesh:
    psh = shardings_of(pspecs, mesh)
    params_d = jax.jit(lambda p: p, out_shardings=psh)(params)
    state = jax.jit(lambda: m.abstract_decode_state(B, S) and None)  # noqa
    import repro.models.transformer as T
    state = T.init_decode_state(m.cfg, B, S)
    state = jax.jit(lambda s: s, out_shardings=shardings_of(sspecs, mesh))(state)
    # single-device reference via plain decode
    tok = jnp.arange(B, dtype=jnp.int32) % 100
    ref_state = T.init_decode_state(m.cfg, B, S)
    logits_ref, _ = m.decode_step(params, tok, ref_state, jnp.asarray(0), seq_len=S)
    logits, state = step(params_d, tok, state, jnp.asarray(0))
    err = float(jnp.max(jnp.abs(logits.astype(jnp.float32) - logits_ref.astype(jnp.float32))))
    assert err < 2e-2, err
print("SERVE_OK", err)
"""


@pytest.mark.slow
def test_ring_collectives_distributed():
    assert "RING_OK" in run_distributed(RING_SCRIPT)


@pytest.mark.slow
def test_reducer_policies_distributed():
    assert "REDUCER_OK" in run_distributed(REDUCER_SCRIPT)


@pytest.mark.slow
def test_halo_exchange_distributed():
    assert "HALO_OK" in run_distributed(HALO_SCRIPT)


@pytest.mark.slow
def test_dp_modes_match_single_device():
    assert "DPMODES_OK" in run_distributed(DPMODES_SCRIPT)


@pytest.mark.slow
def test_serve_decode_seq_sharded_kv():
    assert "SERVE_OK" in run_distributed(SERVE_SCRIPT)


EP_BITWISE_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod
from repro.models.parallel import SINGLE
from repro.runtime.train_step import TrainStepConfig, make_ctx

mesh = compat.make_mesh((2,), ("model",))
cfg = MoEConfig(num_experts=4, top_k=2, expert_ff=32, capacity_factor=2.0,
                parallelism="ep")
d, B, S = 16, 4, 8
p = moe_mod.moe_init(jax.random.key(0), cfg, d)
x = jnp.asarray(np.random.RandomState(1).randn(B, S, d).astype(np.float32))
w = jnp.asarray(np.random.RandomState(2).randn(B, S, d).astype(np.float32))

pspecs = {"router": {"w": P()}, "w_gate": P("model"), "w_up": P("model"),
          "w_down": P("model")}


def loss(pp, xx, ctx):
    y, aux, drop = moe_mod.moe_apply(pp, xx, cfg, "silu", ctx=ctx,
                                     compute_dtype=jnp.float32)
    return jnp.sum(y * w) + aux, (y, drop)


ref_fn = jax.jit(jax.value_and_grad(lambda pp, xx: loss(pp, xx, SINGLE),
                                    argnums=(0, 1), has_aux=True))
(ref_l, (ref_y, ref_drop)), (ref_gp, ref_gx) = ref_fn(p, x)

for transport in ("a2a", "ring", "psum"):
    ctx = make_ctx(mesh, TrainStepConfig(moe_transport=transport))

    def sharded(pp, xx):
        (l, (y, drop)), (gp, gx) = jax.value_and_grad(
            lambda a, b: loss(a, b, ctx), argnums=(0, 1), has_aux=True)(pp, xx)
        # expert-shard cotangents are local; replicated leaves need no psum
        # (fan_out's backward already summed the rank-partials)
        return l, y, drop, gp, gx

    fn = jax.jit(compat.shard_map(
        sharded, mesh=mesh, in_specs=(pspecs, P()),
        out_specs=(P(), P(), P(), pspecs, P()), check_vma=False))
    l, y, drop, gp, gx = fn(p, x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref_y))
    np.testing.assert_array_equal(np.asarray(l), np.asarray(ref_l))
    np.testing.assert_array_equal(np.asarray(drop), np.asarray(ref_drop))
    np.testing.assert_array_equal(np.asarray(gx), np.asarray(ref_gx))
    for k2 in ("w_gate", "w_up", "w_down"):
        np.testing.assert_array_equal(np.asarray(gp[k2]),
                                      np.asarray(ref_gp[k2]))
    np.testing.assert_array_equal(np.asarray(gp["router"]["w"]),
                                  np.asarray(ref_gp["router"]["w"]))
    print(transport, "bitwise ok")
print("EP_BITWISE_OK")
"""

EP_TOL_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.configs.base import MoEConfig
from repro.models import moe as moe_mod
from repro.models.parallel import SINGLE
from repro.runtime.train_step import TrainStepConfig, make_ctx

mesh = compat.make_mesh((4,), ("model",))
d = 32

cases = [
    # (cfg, B, S)  — B=6 does not divide the axis: replicated-psum fallback
    (MoEConfig(num_experts=8, top_k=2, expert_ff=64, capacity_factor=1.5,
               parallelism="ep"), 8, 16),
    (MoEConfig(num_experts=8, top_k=1, expert_ff=64, capacity_factor=2.0,
               shared_expert_ff=64, parallelism="ep"), 8, 16),
    (MoEConfig(num_experts=8, top_k=2, expert_ff=64, capacity_factor=1.5,
               parallelism="ep"), 6, 16),
]

for ci, (cfg, B, S) in enumerate(cases):
    p = moe_mod.moe_init(jax.random.key(ci), cfg, d)
    x = jnp.asarray(np.random.RandomState(ci).randn(B, S, d)
                    .astype(np.float32)) * 0.5
    w = jnp.asarray(np.random.RandomState(100 + ci).randn(B, S, d)
                    .astype(np.float32))
    pspecs = {"router": {"w": P()}, "w_gate": P("model"),
              "w_up": P("model"), "w_down": P("model")}
    if cfg.shared_expert_ff:
        pspecs["shared"] = jax.tree.map(
            lambda _: P(), p["shared"],
            is_leaf=lambda l: hasattr(l, "shape"))

    def loss(pp, xx, ctx):
        y, aux, _ = moe_mod.moe_apply(pp, xx, cfg, "silu", ctx=ctx,
                                      compute_dtype=jnp.bfloat16)
        return jnp.sum(y.astype(jnp.float32) * w) + aux

    (ref_l, ref_gx) = jax.jit(jax.value_and_grad(
        lambda pp, xx: loss(pp, xx, SINGLE), argnums=1))(p, x)

    ctx = make_ctx(mesh, TrainStepConfig(moe_transport="a2a"))
    fn = jax.jit(compat.shard_map(
        lambda pp, xx: jax.value_and_grad(
            lambda a, b: loss(a, b, ctx), argnums=1)(pp, xx),
        mesh=mesh, in_specs=(pspecs, P()), out_specs=(P(), P()),
        check_vma=False))
    l, gx = fn(p, x)
    np.testing.assert_allclose(float(l), float(ref_l), rtol=2e-2)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ref_gx),
                               rtol=5e-2, atol=5e-2)
    print("case", ci, "ok", float(l), float(ref_l))
print("EP_TOL_OK")
"""


def test_moe_ep_bitwise_matches_dense_replica():
    """2 ranks, fusion pinned off: the EP all-to-all path (every transport)
    reproduces the single-rank dense-replica MoE forward AND backward
    bitwise — same arithmetic, only the placement moved."""
    assert "EP_BITWISE_OK" in run_distributed(
        EP_BITWISE_SCRIPT, n_devices=2,
        extra_flags="--xla_disable_hlo_passes=fusion")


@pytest.mark.slow
def test_moe_ep_tolerance_4rank():
    """4 ranks, bf16 compute, fusion on: EP == dense replica to bf16
    tolerance, including the shared-expert arch and the b %% r != 0
    replicated-psum fallback."""
    assert "EP_TOL_OK" in run_distributed(EP_TOL_SCRIPT, n_devices=4)

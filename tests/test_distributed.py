"""Distributed correctness on 8 fake host devices (fresh subprocesses so the
main pytest process keeps its single real device)."""

import pytest

from conftest import run_distributed

RING_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import ring
from repro.core.ring import RingConfig

mesh = compat.make_mesh((2, 4), ("pod", "data"))
L = 2*4*2*4*512*2
x = np.random.RandomState(0).randn(8, L).astype(np.float32)
want = x.sum(0)

def run(fn, cfg, axes):
    g = jax.jit(compat.shard_map(lambda xl: fn(xl.reshape(-1), axes, cfg),
        mesh=mesh, in_specs=P(("pod","data")), out_specs=P(), check_vma=False))
    return np.asarray(g(x.reshape(-1)))

for cfg in [RingConfig(chunks=1, bidirectional=False),
            RingConfig(chunks=2, bidirectional=True),
            RingConfig(chunks=4, bidirectional=True)]:
    out = run(ring.hierarchical_all_reduce, cfg, ("data","pod"))
    assert np.abs(out - want).max() < 1e-4, cfg
    out = run(ring.flat_all_reduce, cfg, ("data","pod"))
    assert np.abs(out - want).max() < 1e-4, cfg

# lossy wire configs: bounded relative error
for cfg, tol in [(RingConfig(chunks=2, bidirectional=True, wire_dtype="bfloat16"), 0.03),
                 (RingConfig(chunks=2, bidirectional=True, codec="int8", codec_block=256), 0.05)]:
    out = run(ring.hierarchical_all_reduce, cfg, ("data","pod"))
    rel = np.abs(out - want).max() / np.abs(want).max()
    assert rel < tol, (cfg, rel)

# RS/AG roundtrip == AR
cfg = RingConfig(chunks=2, bidirectional=True)
def rsag(xl):
    s = ring.ring_reduce_scatter(xl.reshape(-1), "data", cfg)
    return ring.ring_all_gather(s, "data", cfg)
g = jax.jit(compat.shard_map(rsag, mesh=mesh, in_specs=P(("pod","data")),
    out_specs=P(("pod","data")), check_vma=False))
out = np.asarray(g(x.reshape(-1))).reshape(2, 4, L)
per_pod = x.reshape(2,4,L).sum(1)
for p in range(2):
    for d in range(4):
        assert np.abs(out[p,d] - per_pod[p]).max() < 1e-4
print("RING_OK")
"""

REDUCER_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro import compat
from repro.core.reducer import GradientReducer, ReduceConfig

mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.RandomState(1)
grads = {"w": jnp.asarray(rng.randn(16, 256).astype(np.float32)),
         "b": jnp.asarray(rng.randn(256).astype(np.float32)),
         "emb": jnp.asarray(rng.randn(1000, 64).astype(np.float32))}
specs = {"w": P(None, "model"), "b": P(), "emb": P("model", None)}
sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                  is_leaf=lambda x: isinstance(x, P))
grads = jax.tree.map(lambda g, s: jax.device_put(g, s), grads, sh)

for policy in ["fused_ring_hierarchical", "fused_ring", "native_psum",
               "native_psum_fused", "baidu_original"]:
    red = GradientReducer(mesh, ReduceConfig(policy=policy, data_axes=("pod","data"), chunks=2))
    def mk(x):
        i = jax.lax.axis_index("pod")*2 + jax.lax.axis_index("data")
        return jax.tree.map(lambda t: t*(1.0+i), x)
    gv = jax.jit(compat.shard_map(mk, mesh=mesh, in_specs=(specs,),
                                  out_specs=specs, check_vma=False))(grads)
    out = jax.jit(lambda g: red.reduce(g, specs)[0])(gv)
    scale = np.mean([1.0+i for i in range(4)])
    for k in grads:
        err = float(jnp.max(jnp.abs(out[k] - grads[k]*scale)))
        assert err < 1e-4, (policy, k, err)
print("REDUCER_OK")
"""

HALO_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.halo import HaloSpec, halo_exchange

mesh = compat.make_mesh((8,), ("data",))
Y = jnp.arange(64, dtype=jnp.float32).reshape(64, 1)
for sched in ["concurrent", "sequential", "chunked"]:
    def hx(xl, s=sched):
        h = halo_exchange(xl, [HaloSpec("data", 0)], schedule=s, chunks=1)
        return jnp.concatenate([h[("data","-")], xl, h[("data","+")]], 0)
    g = jax.jit(compat.shard_map(hx, mesh=mesh, in_specs=P("data"),
                                 out_specs=P("data"), check_vma=False))
    out = np.asarray(g(Y)).reshape(8, 10)
    ys = np.asarray(Y).reshape(8, 8)
    for r in range(8):
        exp = np.concatenate([[ys[(r-1)%8,-1]], ys[r], [ys[(r+1)%8,0]]])
        assert np.array_equal(out[r], exp), (sched, r)
print("HALO_OK")
"""

DPMODES_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.configs import reduced_config
from repro.models import build_model
from repro.runtime.train_step import TrainStepConfig, build_train_step, init_train_state
from repro.core.reducer import ReduceConfig
from repro.optim import adamw_tree_update, init_opt_state, OptimConfig, make_schedule
from repro.optim.adamw import clip_factor

mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduced_config("llama3.2-1b")
m = build_model(cfg)
B, S = 8, 32
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, 500, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.randint(0, 500, (B, S)), jnp.int32)}
bspecs = {"tokens": P(("pod","data"), None), "labels": P(("pod","data"), None)}

ocfg = OptimConfig()
params = m.init(jax.random.key(7))
opt = init_opt_state(params)
sched = make_schedule(ocfg.schedule, base_lr=ocfg.base_lr, warmup=ocfg.warmup,
                      total=ocfg.total_steps)
@jax.jit
def ref_step(params, opt, step):
    loss, g = jax.value_and_grad(lambda p: m.loss_fn(p, batch))(params)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(g)))
    g = jax.tree.map(lambda x: x * clip_factor(gn, ocfg.clip_norm), g)
    p2, opt2 = adamw_tree_update(params, g, opt, step, sched(step), ocfg)
    return p2, opt2, loss
ref = []
st = jnp.zeros((), jnp.int32)
for i in range(3):
    params, opt, loss = ref_step(params, opt, st); st = st + 1
    ref.append(float(loss))

for mode, tol in [("replicated", 5e-5), ("zero1", 5e-5), ("fsdp", 5e-4)]:
    tcfg = TrainStepConfig(dp_mode=mode,
                           reduce=ReduceConfig(policy="fused_ring_hierarchical", chunks=2),
                           microbatches=2)
    with mesh:
        state, _ = init_train_state(m, mesh, tcfg, key=jax.random.key(7))
        step = build_train_step(m, mesh, tcfg, bspecs)
        losses = []
        for i in range(3):
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
    err = max(abs(a-b) for a, b in zip(ref, losses))
    assert err < tol, (mode, ref, losses)
    print(mode, "OK", err)
print("DPMODES_OK")
"""

SERVE_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro import compat
from repro.configs import reduced_config, base
from repro.models import build_model
from repro.runtime.serve_step import build_decode_step, build_prefill
from repro.sharding import shardings_of

mesh = compat.make_mesh((4, 2), ("data", "model"))
cfg = reduced_config("llama3.2-1b")
m = build_model(cfg)
params = m.init(jax.random.key(0))
B, S = 8, 16384  # long cache -> seq-sharded kv path
shape = base.ShapeConfig("t", S, B, "decode")
step, pspecs, sspecs = build_decode_step(m, mesh, shape)
with mesh:
    psh = shardings_of(pspecs, mesh)
    params_d = jax.jit(lambda p: p, out_shardings=psh)(params)
    state = jax.jit(lambda: m.abstract_decode_state(B, S) and None)  # noqa
    import repro.models.transformer as T
    state = T.init_decode_state(m.cfg, B, S)
    state = jax.jit(lambda s: s, out_shardings=shardings_of(sspecs, mesh))(state)
    # single-device reference via plain decode
    tok = jnp.arange(B, dtype=jnp.int32) % 100
    ref_state = T.init_decode_state(m.cfg, B, S)
    logits_ref, _ = m.decode_step(params, tok, ref_state, jnp.asarray(0), seq_len=S)
    logits, state = step(params_d, tok, state, jnp.asarray(0))
    err = float(jnp.max(jnp.abs(logits.astype(jnp.float32) - logits_ref.astype(jnp.float32))))
    assert err < 2e-2, err
print("SERVE_OK", err)
"""


@pytest.mark.slow
def test_ring_collectives_distributed():
    assert "RING_OK" in run_distributed(RING_SCRIPT)


@pytest.mark.slow
def test_reducer_policies_distributed():
    assert "REDUCER_OK" in run_distributed(REDUCER_SCRIPT)


@pytest.mark.slow
def test_halo_exchange_distributed():
    assert "HALO_OK" in run_distributed(HALO_SCRIPT)


@pytest.mark.slow
def test_dp_modes_match_single_device():
    assert "DPMODES_OK" in run_distributed(DPMODES_SCRIPT)


@pytest.mark.slow
def test_serve_decode_seq_sharded_kv():
    assert "SERVE_OK" in run_distributed(SERVE_SCRIPT)

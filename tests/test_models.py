"""Model-layer unit tests: attention paths, SSM, MoE vs dense references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttnConfig, MoEConfig, SSMConfig
from repro.kernels.flash_attn import ref as fa_ref
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.parallel import SINGLE


def test_blockwise_attention_matches_exact(rng):
    q = jnp.asarray(rng.randn(2, 4, 256, 32).astype(np.float32)) * 0.4
    k = jnp.asarray(rng.randn(2, 2, 256, 32).astype(np.float32)) * 0.4
    v = jnp.asarray(rng.randn(2, 2, 256, 32).astype(np.float32))
    for kw in [dict(causal=True), dict(causal=True, window=96),
               dict(causal=True, chunk=64), dict(causal=False)]:
        out = attn_mod.blockwise_attention(q, k, v, block_q=64, block_k=64, **kw)
        want = fa_ref.attention(q, k, v, causal=kw.get("causal", True),
                                window=kw.get("window"))
        if "chunk" in kw:
            continue  # ref has no chunk mode; covered by skip-equivalence below
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_causal_skip_is_exact(rng):
    """Static skipping of masked blocks must not change the result."""
    q = jnp.asarray(rng.randn(1, 2, 256, 32).astype(np.float32))
    k = jnp.asarray(rng.randn(1, 2, 256, 32).astype(np.float32))
    v = jnp.asarray(rng.randn(1, 2, 256, 32).astype(np.float32))
    for kw in [dict(causal=True), dict(causal=True, window=64),
               dict(causal=True, chunk=64)]:
        a = attn_mod.blockwise_attention(q, k, v, block_q=64, block_k=64,
                                         causal_skip=False, **kw)
        b = attn_mod.blockwise_attention(q, k, v, block_q=64, block_k=64,
                                         causal_skip=True, **kw)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("window", [None, 8])
def test_attn_decode_matches_forward(window, rng):
    """Sequential decode with KV cache == full causal forward, step by step."""
    cfg = AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16, window=window)
    key = jax.random.key(0)
    p = attn_mod.attn_init(key, cfg, 64, pad_to=1)
    S, B = 24, 2
    x = jnp.asarray(rng.randn(B, S, 64).astype(np.float32)) * 0.3
    full = attn_mod.attn_apply(p, x, cfg, is_global=False, ctx=SINGLE,
                               compute_dtype=jnp.float32)
    cache = attn_mod.init_cache(cfg, B, S, is_global=False, dtype=jnp.float32)
    outs = []
    for t in range(S):
        y, cache = attn_mod.attn_decode(p, x[:, t:t + 1], cfg, cache,
                                        is_global=False, ctx=SINGLE,
                                        pos=jnp.asarray(t),
                                        compute_dtype=jnp.float32,
                                        cache_len_global=cache["k"].shape[2])
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_ssm_decode_matches_full_scan(rng):
    cfg = SSMConfig(state_dim=4, conv_width=4, expand=2, dt_rank=8)
    key = jax.random.key(1)
    d = 32
    p = ssm_mod.ssm_init(key, cfg, d)
    B, S = 2, 16
    x = jnp.asarray(rng.randn(B, S, d).astype(np.float32)) * 0.3
    full = ssm_mod.ssm_apply(p, x, cfg, ctx=SINGLE, compute_dtype=jnp.float32,
                             d_model=d)
    state = ssm_mod.init_ssm_state(cfg, d, B)
    outs = []
    for t in range(S):
        y, state = ssm_mod.ssm_decode(p, x[:, t:t + 1], cfg, state, ctx=SINGLE,
                                      compute_dtype=jnp.float32, d_model=d)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=1e-3, atol=1e-3)


def test_moe_matches_dense_reference(rng):
    """With generous capacity (no drops), sort-based dispatch == explicit
    per-token expert evaluation."""
    cfg = MoEConfig(num_experts=4, top_k=2, expert_ff=32,
                    capacity_factor=4.0, parallelism="tp")
    key = jax.random.key(2)
    d = 16
    p = moe_mod.moe_init(key, cfg, d)
    B, S = 2, 32
    x = jnp.asarray(rng.randn(B, S, d).astype(np.float32)) * 0.5
    y, aux, drop = moe_mod.moe_apply(p, x, cfg, "silu", ctx=SINGLE,
                                     compute_dtype=jnp.float32)
    assert float(drop) == 0.0          # generous capacity: nothing dropped

    # dense reference
    logits = np.asarray(x) @ np.asarray(p["router"]["w"])
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    top_v, top_i = jax.lax.top_k(jnp.asarray(logits), 2)
    gates = jax.nn.softmax(top_v, axis=-1)
    want = np.zeros((B, S, d), np.float32)
    for b in range(B):
        for s in range(S):
            for j in range(2):
                e = int(top_i[b, s, j])
                w1 = np.asarray(p["w_gate"][e]); w3 = np.asarray(p["w_up"][e])
                w2 = np.asarray(p["w_down"][e])
                h = np.asarray(jax.nn.silu(jnp.asarray(x[b, s] @ w1))) * \
                    (np.asarray(x[b, s]) @ w3)
                want[b, s] += float(gates[b, s, j]) * (h @ w2)
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """Tiny capacity must drop overflow tokens, not crash."""
    cfg = MoEConfig(num_experts=2, top_k=1, expert_ff=16, capacity_factor=0.1)
    p = moe_mod.moe_init(jax.random.key(3), cfg, 8)
    x = jnp.ones((1, 64, 8), jnp.float32)  # all tokens -> same expert
    y, _, drop = moe_mod.moe_apply(p, x, cfg, "silu", ctx=SINGLE,
                                   compute_dtype=jnp.float32)
    assert np.isfinite(np.asarray(y)).all()
    # most tokens dropped -> most outputs zero
    nonzero = np.abs(np.asarray(y)).sum(-1) > 1e-6
    cap = moe_mod.capacity(64, cfg)
    assert nonzero.sum() <= cap * cfg.num_experts
    # the drop metric reports exactly the overflow: 64 tokens -> one expert
    assert float(drop) == pytest.approx((64 - cap) / 64)


def test_gqa_head_gather_mapping():
    cfg = AttnConfig(num_heads=6, num_kv_heads=2, head_dim=8)
    k = jnp.arange(2 * 2 * 4 * 8, dtype=jnp.float32).reshape(2, 2, 4, 8)
    v = k + 100
    kk, vv = attn_mod._gather_kv_for_local_q(k, v, cfg, 8, SINGLE)
    # true group = 3: q heads 0-2 -> kv0, 3-5 -> kv1, padded 6,7 -> kv1 (clip)
    expect = [0, 0, 0, 1, 1, 1, 1, 1]
    for h, e in enumerate(expect):
        np.testing.assert_array_equal(np.asarray(kk[:, h]), np.asarray(k[:, e]))


def test_moe_aux_loss_uniform_router_is_one_for_every_k():
    """A uniform router (zero logits) must sit at the balanced fixed point
    1.0 regardless of top_k.  The pre-fix form collapsed top-k multiplicity
    through ``> 0`` and skipped the 1/k, so it returned k instead — mixtral
    (k=2) and llama4 (k=1) aux losses were not comparable."""
    d = 16
    for k in (1, 2, 4):
        cfg = MoEConfig(num_experts=8, top_k=k, expert_ff=32,
                        capacity_factor=8.0)
        p = moe_mod.moe_init(jax.random.key(0), cfg, d)
        p = dict(p, router={"w": jnp.zeros_like(p["router"]["w"])})
        x = jnp.asarray(np.random.RandomState(0).randn(2, 16, d)
                        .astype(np.float32))
        _, aux, _ = moe_mod.moe_apply(p, x, cfg, "silu", ctx=SINGLE,
                                      compute_dtype=jnp.float32)
        assert float(aux) == pytest.approx(1.0, abs=1e-6), k


def test_moe_aux_loss_balanced_assignment_is_one():
    """Direct fixed-point check: uniform gates + perfectly balanced top-k
    assignment -> exactly 1.0 for every k."""
    e = 8
    for k in (1, 2, 4):
        b, s = 2, e
        gates = jnp.full((b, s, e), 1.0 / e)
        # token t takes experts (t*k, t*k+1, ..) mod e: each expert used
        # exactly s*k/e times per row
        ids = (jnp.arange(s)[:, None] * k + jnp.arange(k)[None, :]) % e
        ids = jnp.broadcast_to(ids[None], (b, s, k))
        aux = moe_mod.load_balance_aux(gates, ids, e, k)
        assert float(aux) == pytest.approx(1.0, abs=1e-6), k


def test_moe_drop_fraction_concentrated_routing():
    """All tokens on one expert: drop_fraction == (T - cap) / T exactly."""
    e, k, s = 4, 1, 64
    ids = jnp.zeros((2, s, k), jnp.int32)
    cap = 16
    frac = moe_mod.dropped_fraction(ids, e, cap)
    assert float(frac) == pytest.approx((s - cap) / s)
    # balanced routing under the same capacity: nothing dropped
    bal = jnp.broadcast_to((jnp.arange(s) % e)[None, :, None], (2, s, k))
    assert float(moe_mod.dropped_fraction(bal, e, cap)) == 0.0

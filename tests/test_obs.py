"""repro.obs: bus/tracer/drift units, the instrumented-train acceptance
run, and the obs-off HLO-identity pin."""

import json

import numpy as np
import pytest

from repro import compat
from repro.configs import reduced_config
from repro.configs.base import ShapeConfig
from repro.core.reducer import ReduceConfig
from repro.data import DataConfig, SyntheticTokens
from repro.models import build_model
from repro.obs import (DriftDetector, MetricsBus, NULL_OBS, ObsConfig,
                       Tracer, make_obs)
from repro.obs import report as obs_report
from repro.obs import schema as obs_schema
from repro.optim import OptimConfig
from repro.runtime.train_loop import Trainer, TrainerConfig
from repro.runtime.train_step import TrainStepConfig


# ---------------------------------------------------------------------------
# bus
# ---------------------------------------------------------------------------


def test_bus_aggregates_and_reads():
    bus = MetricsBus()
    assert bus.counter("steps") == 1.0
    assert bus.counter("steps", 2.0) == 3.0
    bus.counter("stall", reason="a")
    bus.counter("stall", reason="b")
    assert bus.counter_value("stall", reason="a") == 1.0
    assert bus.counter_value("stall") == 0.0       # labels are part of the key
    assert bus.counter_total("stall") == 2.0
    bus.gauge("loss", 3.5)
    bus.gauge("loss", 2.5)
    assert bus.gauge_value("loss") == 2.5          # last value wins
    assert bus.has_gauge("loss") and not bus.has_gauge("nope")
    for v in (1.0, 2.0, 3.0, 4.0):
        bus.observe("lat", v)
    h = bus.hist_summary("lat")
    assert h["count"] == 4 and h["min"] == 1.0 and h["max"] == 4.0
    s = bus.summary()
    assert s["counters"]["stall{reason=a}"] == 1.0
    assert s["n_records"] == bus.n_records > 0


def test_bus_jsonl_sink_and_numpy_coercion(tmp_path):
    d = str(tmp_path / "run")
    bus = MetricsBus(d, flush_every=2)
    bus.gauge("g", np.float32(1.5))                 # numpy scalar must encode
    bus.event("ev", arr=np.int64(7), s="x")
    bus.counter("c")
    bus.close()
    lines = [json.loads(l) for l in open(bus.path) if l.strip()]
    assert [r["kind"] for r in lines] == ["gauge", "event", "counter"]
    assert lines[0]["value"] == 1.5
    assert lines[1]["fields"]["arr"] == 7
    assert all(isinstance(r["ts"], float) for r in lines)


def test_null_bus_is_inert(tmp_path):
    obs = make_obs(None)
    assert obs is NULL_OBS and not obs.enabled
    obs.counter("x")
    obs.gauge("y", 1.0)
    with obs.span("phase") as sp:
        sp.fence([1, 2])
    assert obs.bus.counter_total("x") == 0.0
    assert obs.drift_detector(1.0) is None
    assert obs.finish() == {"events": None, "trace": None}
    assert make_obs(ObsConfig.off()) is NULL_OBS


# ---------------------------------------------------------------------------
# tracer / chrome export
# ---------------------------------------------------------------------------


def test_tracer_spans_mirror_to_bus_and_export_chrome(tmp_path):
    bus = MetricsBus()
    clock = iter(np.arange(0.0, 10.0, 0.5))
    tr = Tracer(bus, clock=lambda: float(next(clock)), pid=7, tid=1)
    with tr.span("step", step=0):
        with tr.span("wait"):
            pass
    assert [e[0] for e in tr.events] == ["wait", "step"]
    assert bus.spans["step"][0] == pytest.approx(1.5)   # 3 clock reads inside
    path = tr.export_chrome(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    evs = doc["traceEvents"]
    assert len(evs) == 2
    for e in evs:
        assert e["ph"] == "X" and e["dur"] > 0 and e["pid"] == 7
        assert set(e) >= {"name", "ts", "dur", "pid", "tid"}
    assert {e["name"] for e in evs} == {"step", "wait"}
    assert evs[1]["args"] == {"step": 0}


def test_disabled_tracer_hands_out_the_shared_null_span():
    tr = Tracer(enabled=False)
    s1, s2 = tr.span("a"), tr.span("b", x=1)
    assert s1 is s2
    with s1:
        pass
    assert tr.events == []


def test_span_fence_blocks_on_device_work():
    import jax.numpy as jnp

    bus = MetricsBus()
    tr = Tracer(bus)
    with tr.span("wait") as sp:
        y = sp.fence(jnp.ones((8, 8)) @ jnp.ones((8, 8)))
    assert float(y[0, 0]) == 8.0
    assert bus.spans["wait"][0] > 0


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------


def test_drift_detector_warmup_window_and_alarm_transition():
    bus = MetricsBus()
    det = DriftDetector(0.1, bus=bus, threshold=0.5, window=4, warmup=1,
                        min_samples=2)
    s0 = det.update(0, 10.0)       # compile step: gauged, excluded
    assert s0.warmup and not s0.drifting and s0.median_rel_err is None
    assert bus.gauge_value("model_error", metric="step_time_s") \
        == pytest.approx(99.0)
    assert not det.update(1, 0.11).drifting     # window not full yet
    s2 = det.update(2, 0.12)
    assert s2.median_rel_err == pytest.approx(0.15) and not s2.drifting
    # sustained 2x steps: the rolling median crosses, alarm fires ONCE
    for step in (3, 4, 5):
        det.update(step, 0.2)
    assert det.drifting and det.alarms == 1
    assert bus.counter_total("drift_alarms") == 1.0
    det.update(6, 0.2)             # still drifting: no second alarm
    assert det.alarms == 1
    # recovery: back near the prediction clears the state...
    for step in (7, 8, 9, 10):
        det.update(step, 0.1)
    assert not det.drifting
    # ...and a relapse alarms again (transition counting)
    for step in (11, 12, 13, 14):
        det.update(step, 0.25)
    assert det.alarms == 2


def test_drift_detector_rejects_nonpositive_prediction():
    with pytest.raises(ValueError, match="predicted_s"):
        DriftDetector(0.0)


def test_one_straggler_step_cannot_fire_the_alarm():
    det = DriftDetector(0.1, threshold=0.5, window=5, warmup=0,
                        min_samples=3)
    for step in range(4):
        det.update(step, 0.1)
    det.update(4, 5.0)             # one GC pause / straggler
    assert not det.drifting and det.alarms == 0


# ---------------------------------------------------------------------------
# bench schema
# ---------------------------------------------------------------------------


def test_rows_from_csv_headers_blocks_and_degradation():
    text = """# commentary
a,b,c
1,2.5,x

name,us
ring,12.0
ring,13.5
9,9,9,9
"""
    rows = obs_schema.rows_from_csv(text)
    assert rows[0] == {"a": 1, "b": 2.5, "c": "x"}
    assert rows[1] == {"name": "ring", "us": 12.0}
    assert rows[2] == {"name": "ring", "us": 13.5}
    # shape change without a new header degrades to positional keys
    assert rows[3] == {"col0": 9, "col1": 9, "col2": 9, "col3": 9}


def test_bench_record_roundtrip_and_validation(tmp_path):
    rows = [{"transport": "ring", "us": 10.5}]
    path = obs_schema.write_bench_record(str(tmp_path), "allreduce", rows,
                                         meta={"dry": True})
    assert path.endswith("BENCH_allreduce.json")
    rec = obs_schema.load_bench_record(path)
    assert rec["schema"] == obs_schema.SCHEMA
    assert rec["rows"] == rows and rec["n_rows"] == 1
    with pytest.raises(ValueError, match="schema"):
        obs_schema.validate_record({"schema": "nope"})
    with pytest.raises(ValueError, match="scalar"):
        obs_schema.bench_record("x", [{"bad": [1, 2]}])


# ---------------------------------------------------------------------------
# the acceptance run: 2 instrumented steps -> events + trace + report
# ---------------------------------------------------------------------------


def _mesh():
    return compat.make_mesh((1, 1), ("data", "model"))


def _tiny(steps, obs_cfg):
    cfg = reduced_config("llama3.2-1b")
    model = build_model(cfg)
    shape = ShapeConfig("tiny", 64, 4, "train")
    data = SyntheticTokens(DataConfig(vocab_size=model.cfg.vocab_size,
                                      seq_len=64, global_batch=4, seed=1),
                           model_cfg=cfg)
    scfg = TrainStepConfig(
        dp_mode="replicated",
        reduce=ReduceConfig(policy="fused_ring_hierarchical"),
        optim=OptimConfig(base_lr=3e-3, warmup=5, total_steps=steps),
        microbatches=1)
    tcfg = TrainerConfig(steps=steps, ckpt_every=1000, log_every=100,
                         obs=obs_cfg)
    return Trainer(model, _mesh(), scfg, data, shape, tcfg,
                   log=lambda s: None)


def test_instrumented_train_produces_events_trace_and_drift(tmp_path,
                                                            capsys):
    run_dir = str(tmp_path / "run")
    # predicted_step_s far below reality => guaranteed drift within 2 steps
    obs_cfg = ObsConfig(run_dir=run_dir, predicted_step_s=1e-7,
                        drift_warmup=0, drift_min_samples=1, drift_window=4)
    tr = _tiny(2, obs_cfg)
    out = tr.run()
    assert out["obs"]["events"] and out["obs"]["trace"]

    records = obs_report.read_events(run_dir)
    kinds = {r["kind"] for r in records}
    assert {"span", "gauge", "counter", "event"} <= kinds
    span_names = {r["name"] for r in records if r["kind"] == "span"}
    assert {"data", "step", "dispatch", "wait"} <= span_names
    gauge_names = {r["name"] for r in records if r["kind"] == "gauge"}
    assert {"step_time_s", "loss", "grad_norm", "lr",
            "model_error"} <= gauge_names
    assert any(r["name"] == "drift_alarm" for r in records
               if r["kind"] == "event")

    # Perfetto-loadable: valid JSON, >= 1 complete ("X") event
    doc = json.load(open(out["obs"]["trace"]))
    assert doc["traceEvents"] and all(e["ph"] == "X"
                                      for e in doc["traceEvents"])
    assert sum(1 for e in doc["traceEvents"] if e["name"] == "step") == 2

    # the report renders from the files alone
    assert obs_report.main([run_dir]) == 0
    text = capsys.readouterr().out
    assert "per-phase time breakdown" in text
    assert "predicted vs measured (drift)" in text
    summary = obs_report.summarize(run_dir)
    assert summary["counters"]["steps"] == 2.0
    assert len(summary["drift"]["samples"]) == 2
    assert summary["trace"]["n_events"] == len(doc["traceEvents"])


def test_obs_off_lowers_to_identical_hlo(tmp_path):
    """The acceptance pin: ObsConfig(enabled=False) — and obs entirely —
    must not perturb the compiled step program."""
    tr_off = _tiny(2, ObsConfig.off())
    tr_none = _tiny(2, None)
    tr_on = _tiny(2, ObsConfig(run_dir=str(tmp_path / "r"),
                               predicted_step_s=1.0))
    batch = tr_on.data.batch_at(0)
    texts = []
    for tr in (tr_off, tr_none, tr_on):
        with tr.mesh:
            texts.append(tr.step_fn.lower(tr.state, batch).as_text())
    assert texts[0] == texts[1] == texts[2]


def test_predict_step_time_prices_the_live_step():
    from repro.obs.predict import predict_step_time
    from repro.runtime.train_step import build_step_schedule

    tr = _tiny(2, None)
    sched = build_step_schedule(tr.model, tr.mesh, tr.step_cfg)
    pred = predict_step_time(tr.step_fn, (tr.state, tr.data.batch_at(0)),
                             mesh=tr.mesh,
                             overlap_fraction=sched.overlap_fraction)
    assert pred["t_step_s"] > 0 and pred["source"] == "roofline"
    assert pred["bottleneck"] in ("compute", "memory", "collective")
    assert pred["t_step_s"] >= pred["t_exposed_collective_s"]


# ---------------------------------------------------------------------------
# report CLI edges
# ---------------------------------------------------------------------------


def test_report_missing_run_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="events.jsonl"):
        obs_report.read_events(str(tmp_path))


def test_report_json_mode(tmp_path, capsys):
    d = str(tmp_path / "r")
    obs = make_obs(ObsConfig(run_dir=d, flush_every=1))
    obs.counter("steps")
    obs.gauge("loss", 1.25)
    obs.finish()
    assert obs_report.main([d, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["counters"]["steps"] == 1.0 and doc["gauges"]["loss"] == 1.25

"""Optimizer, schedules, data pipeline, checkpointing, fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokens
from repro.models.parallel import SINGLE
from repro.optim import (OptimConfig, adamw_flat_update, adamw_tree_update,
                         init_opt_state, make_schedule)
from repro.optim.adamw import clip_factor, global_grad_norm
from repro.runtime.ft import Heartbeat, StragglerMonitor, elastic_shape


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_matches_manual(rng):
    cfg = OptimConfig(base_lr=1e-2, weight_decay=0.1)
    p = {"w": jnp.asarray(rng.randn(8, 4).astype(np.float32))}
    g = {"w": jnp.asarray(rng.randn(8, 4).astype(np.float32))}
    opt = init_opt_state(p)
    p2, opt2 = adamw_tree_update(p, g, opt, jnp.asarray(0), 1e-2, cfg)
    # manual
    gw = np.asarray(g["w"])
    mu = 0.1 * gw
    nu = 0.05 * gw * gw
    mu_hat = mu / (1 - 0.9)
    nu_hat = nu / (1 - 0.95)
    want = np.asarray(p["w"]) * (1 - 1e-2 * 0.1) - 1e-2 * mu_hat / (np.sqrt(nu_hat) + cfg.eps)
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(opt2["mu"]["w"]), mu, rtol=1e-6)


def test_adamw_flat_matches_tree(rng):
    """ZeRO flat update == tree update on the same values."""
    cfg = OptimConfig(base_lr=3e-3, weight_decay=0.0)
    w = jnp.asarray(rng.randn(256).astype(np.float32))
    g = jnp.asarray(rng.randn(256).astype(np.float32))
    tree_p, tree_opt = adamw_tree_update({"w": w}, {"w": g},
                                         init_opt_state({"w": w}),
                                         jnp.asarray(0), 3e-3, cfg)
    deltas, flat_opt = adamw_flat_update(
        [g], {"mu": [jnp.zeros_like(g)], "nu": [jnp.zeros_like(g)]},
        jnp.asarray(0), 3e-3, cfg)
    np.testing.assert_allclose(np.asarray(w + deltas[0]),
                               np.asarray(tree_p["w"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(flat_opt["mu"][0]),
                               np.asarray(tree_opt["mu"]["w"]), rtol=1e-6)


def test_clip_factor():
    assert float(clip_factor(jnp.asarray(0.5), 1.0)) == 1.0
    assert abs(float(clip_factor(jnp.asarray(4.0), 1.0)) - 0.25) < 1e-6


def test_wsd_schedule_phases():
    f = make_schedule("wsd", base_lr=1.0, warmup=10, total=100,
                      stable_frac=0.5)
    assert float(f(jnp.asarray(0))) < 0.2          # warming
    assert abs(float(f(jnp.asarray(30))) - 1.0) < 1e-6   # stable
    assert float(f(jnp.asarray(99))) < 0.5          # decaying


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_deterministic_and_shifted():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=4, seed=3)
    ds = SyntheticTokens(cfg)
    b1 = ds.batch_at(17)
    b2 = ds.batch_at(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = ds.batch_at(18)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < 1000
    assert b1["tokens"].shape == (4, 64)


def test_data_modality_stubs():
    mc = get_config("whisper-base")
    ds = SyntheticTokens(DataConfig(vocab_size=500, seq_len=32, global_batch=2),
                         model_cfg=mc)
    b = ds.batch_at(0)
    assert b["frames"].shape == (2, mc.enc_seq, mc.d_model)
    mc2 = get_config("llava-next-34b")
    ds2 = SyntheticTokens(DataConfig(vocab_size=500, seq_len=1024, global_batch=2),
                          model_cfg=mc2)
    b2 = ds2.batch_at(0)
    assert b2["extra_embeds"].shape == (2, mc2.frontend_seq, mc2.d_model)
    assert b2["tokens"].shape == (2, 1024 - mc2.frontend_seq)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _state(rng):
    return {"params": {"w": jnp.asarray(rng.randn(8, 8).astype(np.float32))},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path, rng):
    st = _state(rng)
    save(st, 7, str(tmp_path))
    assert latest_step(str(tmp_path)) == 7
    back = restore(jax.eval_shape(lambda: st), 7, str(tmp_path))
    np.testing.assert_array_equal(np.asarray(back["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
    assert int(back["step"]) == 7


def test_checkpoint_detects_corruption(tmp_path, rng):
    st = _state(rng)
    d = save(st, 1, str(tmp_path))
    # flip bytes in the first array file
    target = os.path.join(d, "arr_00000.npy")
    raw = bytearray(open(target, "rb").read())
    raw[-1] ^= 0xFF
    open(target, "wb").write(raw)
    with pytest.raises(IOError, match="checksum"):
        restore(jax.eval_shape(lambda: st), 1, str(tmp_path))


def test_checkpoint_uncommitted_ignored(tmp_path, rng):
    st = _state(rng)
    save(st, 5, str(tmp_path))
    # simulate crash: a later dir without COMMITTED
    os.makedirs(tmp_path / "step_00000009")
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_manager_gc_and_async(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=True)
    st = _state(rng)
    for s in [1, 2, 3, 4]:
        mgr.save(st, s)
    mgr.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]
    back, step = mgr.restore_latest(jax.eval_shape(lambda: st))
    assert step == 4 and back is not None


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0, warmup_steps=2)
    flagged = [mon.record(i, 0.1) for i in range(8)]
    assert not any(flagged)
    ev = mon.record(8, 0.5)                    # 5x the EWMA
    assert ev and ev.ratio == pytest.approx(5.0)
    assert not mon.record(9, 0.1)              # estimate unpoisoned
    assert len(mon.events) == 1


def test_heartbeat_dead_host_detection(tmp_path):
    hb_a = Heartbeat(str(tmp_path), "host_a", timeout=10.0)
    hb_b = Heartbeat(str(tmp_path), "host_b", timeout=10.0)
    hb_a.beat(now=1000.0)
    hb_b.beat(now=1000.0)
    assert hb_a.dead_hosts(now=1005.0) == []
    hb_a.beat(now=1020.0)
    assert hb_a.dead_hosts(now=1021.0) == ["host_b"]


def test_elastic_shape_shrinks_data_axis():
    shape, names = elastic_shape(8, model_parallel=2, want_pods=1)
    assert dict(zip(names, shape)) == {"data": 4, "model": 2}
    # odd device loss: model axis halves until it divides
    shape2, names2 = elastic_shape(6, model_parallel=4, want_pods=1)
    sizes = dict(zip(names2, shape2))
    assert sizes["data"] * sizes["model"] == 6
    # 448 survivors of a 512-chip twin pod
    shape3, names3 = elastic_shape(448, model_parallel=16, want_pods=2)
    sizes3 = dict(zip(names3, shape3))
    assert sizes3["model"] == 16 and sizes3["pod"] * sizes3["data"] * 16 == 448

"""The unified repro.comm Communicator API: registry semantics, channel
striping, capability validation, and numerical equivalence of every
registered transport against ``lax.psum`` on a 1-D mesh."""

import numpy as np
import pytest

from conftest import run_distributed

from repro.comm import (CommConfig, Communicator, POLICY_TO_TRANSPORT,
                        assign_channels, comm_config_from_policy,
                        get_transport, list_transports, transport_specs)
from repro.core.reducer import POLICIES


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_builtin_transports_registered():
    names = list_transports()
    for expected in ("ring", "ring_hier", "ring_compressed", "psum"):
        assert expected in names


def test_get_transport_unknown_raises_with_menu():
    with pytest.raises(ValueError, match="unknown transport"):
        get_transport("definitely_not_a_transport")
    with pytest.raises(ValueError, match="ring_hier"):
        get_transport("definitely_not_a_transport")


def test_transport_specs_capabilities():
    specs = transport_specs()
    assert specs["ring"].supports_rs
    assert specs["ring_hier"].supports_rs
    assert not specs["psum"].supports_rs
    assert specs["ring_compressed"].supports_codec
    assert specs["ring_compressed"].codec == "int8"
    assert specs["ring_hier"].hierarchical
    assert not specs["ring"].hierarchical


def test_every_legacy_policy_maps_to_registered_transport():
    assert set(POLICY_TO_TRANSPORT) == set(POLICIES)
    for policy, (transport, _) in POLICY_TO_TRANSPORT.items():
        get_transport(transport)  # must not raise
        ccfg = comm_config_from_policy(policy)
        assert ccfg.transport == transport


def test_comm_config_from_policy_forced_overrides():
    ccfg = comm_config_from_policy("baidu_original", chunks=8,
                                   bidirectional=True)
    assert ccfg.chunks == 1 and ccfg.bidirectional is False
    assert comm_config_from_policy("native_psum").fuse is False
    with pytest.raises(ValueError, match="unknown policy"):
        comm_config_from_policy("nope")


# ---------------------------------------------------------------------------
# construction-time capability validation
# ---------------------------------------------------------------------------


def _mesh1():
    from repro import compat

    return compat.make_mesh((1,), ("data",))


def test_unknown_transport_fails_at_construction():
    with pytest.raises(ValueError, match="unknown transport"):
        Communicator(_mesh1(), CommConfig(transport="bogus",
                                          data_axes=("data",)))


def test_invalid_wire_dtype_fails_at_construction():
    with pytest.raises(ValueError, match="wire_dtype"):
        Communicator(_mesh1(), CommConfig(transport="psum",
                                          wire_dtype="bfloat16",
                                          data_axes=("data",)))


def test_unfused_ring_fails_at_construction():
    with pytest.raises(ValueError, match="fuse"):
        Communicator(_mesh1(), CommConfig(transport="ring", fuse=False,
                                          data_axes=("data",)))


def test_psum_reduce_scatter_rejected():
    comm = Communicator(_mesh1(), CommConfig(transport="psum",
                                             data_axes=("data",)))
    import jax.numpy as jnp

    with pytest.raises(ValueError, match="reduce-scatter"):
        comm.reduce_scatter([jnp.zeros((8,), jnp.float32)])


# ---------------------------------------------------------------------------
# channel striping
# ---------------------------------------------------------------------------


def test_stripe_partitions_every_bucket_exactly_once():
    sizes = [512, 128, 1024, 256, 256, 64, 2048]
    for n_channels in (1, 2, 3, 4, 7, 9):
        assignments = assign_channels(sizes, n_channels)
        assert len(assignments) == n_channels
        seen = [i for a in assignments for i in a.buckets]
        assert sorted(seen) == list(range(len(sizes)))   # round-trip
        for a in assignments:
            assert a.elems == sum(sizes[i] for i in a.buckets)
            assert list(a.buckets) == sorted(a.buckets)


def test_stripe_is_deterministic_and_balanced():
    sizes = [100] * 8
    a1 = assign_channels(sizes, 4)
    a2 = assign_channels(sizes, 4)
    assert a1 == a2
    assert all(len(a.buckets) == 2 and a.elems == 200 for a in a1)


def test_communicator_stripe_and_plan():
    comm = Communicator(_mesh1(), CommConfig(transport="ring_hier",
                                             data_axes=("data",), channels=2,
                                             bucket_bytes=4096))
    import jax

    tree = {f"p{i}": jax.ShapeDtypeStruct((600,), np.float32)
            for i in range(5)}
    plan = comm.plan(tree)
    assert plan.n_channels == 2
    assert plan.transport == "ring_hier"
    covered = sorted(i for a in plan.channels for i in a.buckets)
    assert covered == list(range(plan.n_buckets))
    pb = plan.predicted_collective_bytes()
    assert pb["grad_bytes"] == 5 * 600 * 4
    assert pb["bytes_per_device"] == 0.0          # world == 1: no wire bytes
    desc = plan.describe()
    assert desc["world"] == 1 and desc["n_buckets"] == plan.n_buckets
    # channels=0 -> every bucket is its own independent channel
    comm0 = Communicator(_mesh1(), CommConfig(transport="ring_hier",
                                              data_axes=("data",),
                                              bucket_bytes=4096))
    assert comm0.plan(tree).n_channels == comm0.plan(tree).n_buckets


# ---------------------------------------------------------------------------
# numerical equivalence vs lax.psum (1-D mesh, 4 fake devices)
# ---------------------------------------------------------------------------

EQUIV_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator, list_transports

mesh = compat.make_mesh((4,), ("data",))
rng = np.random.RandomState(0)
tree = {f"g{i}": jnp.asarray(rng.randn(3000 + 256*i).astype(np.float32))
        for i in range(4)}
specs = {k: P() for k in tree}

def per_device(g):
    i = jax.lax.axis_index("data")
    return jax.tree.map(lambda t: t * (1.0 + i), g)

gv = jax.jit(compat.shard_map(per_device, mesh=mesh, in_specs=(specs,),
                              out_specs=specs, check_vma=False))(tree)
ref = jax.jit(compat.shard_map(
    lambda g: jax.tree.map(lambda x: jax.lax.pmean(x, "data"), g),
    mesh=mesh, in_specs=(specs,), out_specs=specs, check_vma=False))(gv)

cases = [(t, 0) for t in list_transports()] + [("ring_hier", 2), ("ring", 4)]
for transport, channels in cases:
    comm = Communicator(mesh, CommConfig(transport=transport, chunks=2,
                                         channels=channels,
                                         data_axes=("data",)))
    out, _ = comm.reduce(gv, specs)
    err = max(float(jnp.abs(out[k] - ref[k]).max()) for k in tree)
    tol = 0.08 if transport == "ring_compressed" else 1e-4
    assert err < tol, (transport, channels, err)
    print(transport, channels, "ok", err)

# legacy shim delegates to the same machinery (all six policies get full
# coverage in the slow distributed suite; one per transport family here)
import warnings
from repro.core.reducer import GradientReducer, ReduceConfig
with warnings.catch_warnings():
    warnings.simplefilter("ignore", DeprecationWarning)
    for policy in ["baidu_original", "fused_ring_hierarchical",
                   "native_psum_fused"]:
        kw = dict(bucket_bytes=1) if policy == "baidu_original" else {}
        red = GradientReducer(mesh, ReduceConfig(policy=policy,
                                                 data_axes=("data",),
                                                 chunks=2, **kw))
        out, _ = red.reduce(gv, specs)
        err = max(float(jnp.abs(out[k] - ref[k]).max()) for k in tree)
        tol = 0.08 if policy == "fused_ring_compressed" else 1e-4
        assert err < tol, (policy, err)
print("COMM_EQUIV_OK")
"""


def test_transports_match_psum_on_1d_mesh():
    assert "COMM_EQUIV_OK" in run_distributed(EQUIV_SCRIPT, n_devices=4)

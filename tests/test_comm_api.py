"""The unified repro.comm Communicator API: registry semantics, channel
striping, capability validation, and numerical equivalence of every
registered transport against ``lax.psum`` on a 1-D mesh."""

import numpy as np
import pytest

from conftest import run_distributed

from repro.comm import (CommConfig, Communicator, POLICY_TO_TRANSPORT,
                        assign_channels, comm_config_from_policy,
                        get_transport, list_transports, transport_specs)
from repro.core.reducer import POLICIES


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_builtin_transports_registered():
    names = list_transports()
    for expected in ("a2a", "ring", "ring_hier", "psum"):
        assert expected in names
    assert "ring_compressed" not in names


def test_removed_ring_compressed_tombstone():
    with pytest.raises(ValueError, match="wire_codec='int8'"):
        get_transport("ring_compressed")


def test_get_transport_unknown_raises_with_menu():
    with pytest.raises(ValueError, match="unknown transport"):
        get_transport("definitely_not_a_transport")
    with pytest.raises(ValueError, match="ring_hier"):
        get_transport("definitely_not_a_transport")


def test_transport_specs_capabilities():
    specs = transport_specs()
    assert specs["ring"].supports_rs
    assert specs["ring_hier"].supports_rs
    assert not specs["psum"].supports_rs
    assert specs["ring_hier"].hierarchical
    assert not specs["ring"].hierarchical
    # all-to-all capability: native + rings + the honest psum fallback
    assert specs["a2a"].supports_a2a
    assert specs["ring"].supports_a2a
    assert specs["psum"].supports_a2a
    assert not specs["a2a"].supports_rs


def test_every_legacy_policy_maps_to_registered_transport():
    assert set(POLICY_TO_TRANSPORT) == set(POLICIES)
    for policy, (transport, _) in POLICY_TO_TRANSPORT.items():
        get_transport(transport)  # must not raise
        ccfg = comm_config_from_policy(policy)
        assert ccfg.transport == transport


def test_comm_config_from_policy_forced_overrides():
    ccfg = comm_config_from_policy("baidu_original", chunks=8,
                                   bidirectional=True)
    assert ccfg.chunks == 1 and ccfg.bidirectional is False
    assert comm_config_from_policy("native_psum").fuse is False
    with pytest.raises(ValueError, match="unknown policy"):
        comm_config_from_policy("nope")


# ---------------------------------------------------------------------------
# construction-time capability validation
# ---------------------------------------------------------------------------


def _mesh1():
    from repro import compat

    return compat.make_mesh((1,), ("data",))


def test_unknown_transport_fails_at_construction():
    with pytest.raises(ValueError, match="unknown transport"):
        Communicator(_mesh1(), CommConfig(transport="bogus",
                                          data_axes=("data",)))


def test_invalid_wire_dtype_fails_at_construction():
    with pytest.raises(ValueError, match="wire_dtype"):
        Communicator(_mesh1(), CommConfig(transport="psum",
                                          wire_dtype="bfloat16",
                                          data_axes=("data",)))


def test_unfused_ring_fails_at_construction():
    with pytest.raises(ValueError, match="fuse"):
        Communicator(_mesh1(), CommConfig(transport="ring", fuse=False,
                                          data_axes=("data",)))


def test_psum_reduce_scatter_rejected():
    comm = Communicator(_mesh1(), CommConfig(transport="psum",
                                             data_axes=("data",)))
    import jax.numpy as jnp

    with pytest.raises(ValueError, match="reduce-scatter"):
        comm.reduce_scatter([jnp.zeros((8,), jnp.float32)])


# ---------------------------------------------------------------------------
# channel striping
# ---------------------------------------------------------------------------


def test_stripe_partitions_every_bucket_exactly_once():
    sizes = [512, 128, 1024, 256, 256, 64, 2048]
    for n_channels in (1, 2, 3, 4, 7, 9):
        assignments = assign_channels(sizes, n_channels)
        assert len(assignments) == n_channels
        seen = [i for a in assignments for i in a.buckets]
        assert sorted(seen) == list(range(len(sizes)))   # round-trip
        for a in assignments:
            assert a.elems == sum(sizes[i] for i in a.buckets)
            assert list(a.buckets) == sorted(a.buckets)


def test_stripe_is_deterministic_and_balanced():
    sizes = [100] * 8
    a1 = assign_channels(sizes, 4)
    a2 = assign_channels(sizes, 4)
    assert a1 == a2
    assert all(len(a.buckets) == 2 and a.elems == 200 for a in a1)


def test_communicator_stripe_and_plan():
    comm = Communicator(_mesh1(), CommConfig(transport="ring_hier",
                                             data_axes=("data",), channels=2,
                                             bucket_bytes=4096))
    import jax

    tree = {f"p{i}": jax.ShapeDtypeStruct((600,), np.float32)
            for i in range(5)}
    plan = comm.plan(tree)
    assert plan.n_channels == 2
    assert plan.transport == "ring_hier"
    covered = sorted(i for a in plan.channels for i in a.buckets)
    assert covered == list(range(plan.n_buckets))
    pb = plan.predicted_collective_bytes()
    assert pb["grad_bytes"] == 5 * 600 * 4
    assert pb["bytes_per_device"] == 0.0          # world == 1: no wire bytes
    desc = plan.describe()
    assert desc["world"] == 1 and desc["n_buckets"] == plan.n_buckets
    # channels=0 -> every bucket is its own independent channel
    comm0 = Communicator(_mesh1(), CommConfig(transport="ring_hier",
                                              data_axes=("data",),
                                              bucket_bytes=4096))
    assert comm0.plan(tree).n_channels == comm0.plan(tree).n_buckets


# ---------------------------------------------------------------------------
# numerical equivalence vs lax.psum (1-D mesh, 4 fake devices)
# ---------------------------------------------------------------------------

EQUIV_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator, list_transports

mesh = compat.make_mesh((4,), ("data",))
rng = np.random.RandomState(0)
tree = {f"g{i}": jnp.asarray(rng.randn(3000 + 256*i).astype(np.float32))
        for i in range(4)}
specs = {k: P() for k in tree}

def per_device(g):
    i = jax.lax.axis_index("data")
    return jax.tree.map(lambda t: t * (1.0 + i), g)

gv = jax.jit(compat.shard_map(per_device, mesh=mesh, in_specs=(specs,),
                              out_specs=specs, check_vma=False))(tree)
ref = jax.jit(compat.shard_map(
    lambda g: jax.tree.map(lambda x: jax.lax.pmean(x, "data"), g),
    mesh=mesh, in_specs=(specs,), out_specs=specs, check_vma=False))(gv)

cases = [(t, 0) for t in list_transports()] + [("ring_hier", 2), ("ring", 4)]
for transport, channels in cases:
    comm = Communicator(mesh, CommConfig(transport=transport, chunks=2,
                                         channels=channels,
                                         data_axes=("data",)))
    out, _ = comm.reduce(gv, specs)
    err = max(float(jnp.abs(out[k] - ref[k]).max()) for k in tree)
    assert err < 1e-4, (transport, channels, err)
    print(transport, channels, "ok", err)

# quantized wire rides any ring transport via wire_codec (the removed
# ring_compressed transport's replacement spelling)
comm_q = Communicator(mesh, CommConfig(transport="ring_hier", chunks=2,
                                       wire_codec="int8",
                                       data_axes=("data",)))
out, _ = comm_q.reduce(gv, specs)
err = max(float(jnp.abs(out[k] - ref[k]).max()) for k in tree)
assert err < 0.08, ("ring_hier+int8", err)

# legacy shim delegates to the same machinery (all six policies get full
# coverage in the slow distributed suite; one per transport family here)
import warnings
from repro.core.reducer import GradientReducer, ReduceConfig
with warnings.catch_warnings():
    warnings.simplefilter("ignore", DeprecationWarning)
    for policy in ["baidu_original", "fused_ring_hierarchical",
                   "native_psum_fused"]:
        kw = dict(bucket_bytes=1) if policy == "baidu_original" else {}
        red = GradientReducer(mesh, ReduceConfig(policy=policy,
                                                 data_axes=("data",),
                                                 chunks=2, **kw))
        out, _ = red.reduce(gv, specs)
        err = max(float(jnp.abs(out[k] - ref[k]).max()) for k in tree)
        assert err < 1e-4, (policy, err)
print("COMM_EQUIV_OK")
"""


def test_transports_match_psum_on_1d_mesh():
    assert "COMM_EQUIV_OK" in run_distributed(EQUIV_SCRIPT, n_devices=4)


# ---------------------------------------------------------------------------
# GradientBucketer: the oversized-leaf invariant (a leaf larger than
# bucket_bytes becomes a singleton bucket, never split) and its corollaries
# ---------------------------------------------------------------------------


def _plan_of(tree, bucket_bytes=1024, pad=128):
    from repro.core.bucketing import GradientBucketer

    b = GradientBucketer(bucket_bytes=bucket_bytes, pad_multiple=pad)
    return b, b.plan(tree)


def _bucket_of_leaf(plan):
    return {f.leaf: f.bucket for f in plan.fields}


def test_oversized_leaf_is_singleton_bucket():
    import jax.numpy as jnp

    # cap = 1024 B / 4 = 256 elements; the 1000-element leaf overflows it
    big = jnp.zeros((1000,), jnp.float32)
    small = jnp.zeros((10,), jnp.float32)
    for order in (["a_big", "b_s1", "c_s2"],      # oversized first
                  ["a_s1", "b_big", "c_s2"],      # oversized in the middle
                  ["a_s1", "b_s2", "c_big"]):     # oversized last
        tree = {k: (big if "big" in k else small) for k in order}
        _, plan = _plan_of(tree)
        by_leaf = _bucket_of_leaf(plan)
        leaves = sorted(tree)                     # dict flatten order
        big_leaf = next(i for i, k in enumerate(leaves) if "big" in k)
        big_bucket = by_leaf[big_leaf]
        # nothing shares the oversized leaf's bucket
        assert [l for l, bk in by_leaf.items() if bk == big_bucket] == \
            [big_leaf], order
        # and the leaf was not split: its field spans its full size, and
        # the bucket is exactly its padded size
        f = next(f for f in plan.fields if f.leaf == big_leaf)
        assert f.size == 1000 and f.offset == 0
        assert plan.bucket_sizes[big_bucket] == 1024  # 1000 padded to 128s


def test_adjacent_oversized_leaves_stay_separate():
    import jax.numpy as jnp

    tree = {"a": jnp.zeros((500,), jnp.float32),
            "b": jnp.zeros((700,), jnp.float32)}
    _, plan = _plan_of(tree)
    by_leaf = _bucket_of_leaf(plan)
    assert by_leaf[0] != by_leaf[1]
    assert plan.n_buckets == 2


def test_small_leaves_after_oversized_open_fresh_bucket():
    import jax.numpy as jnp

    tree = {"a": jnp.zeros((300,), jnp.float32),   # > 256-elem cap
            "b": jnp.zeros((10,), jnp.float32),
            "c": jnp.zeros((10,), jnp.float32)}
    _, plan = _plan_of(tree)
    by_leaf = _bucket_of_leaf(plan)
    assert by_leaf[0] == 0
    assert by_leaf[1] == by_leaf[2] == 1           # both fit bucket 1
    assert plan.n_buckets == 2


def test_oversized_roundtrip_and_padding_accounting():
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.RandomState(0)
    tree = {"a": jnp.asarray(rng.randn(333).astype(np.float32)),
            "b": jnp.asarray(rng.randn(7).astype(np.float32))}
    b, plan = _plan_of(tree)
    buckets, _ = b.bucketize(tree)
    assert [int(x.shape[0]) for x in buckets] == list(plan.bucket_sizes)
    back = b.debucketize(buckets, plan)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
    assert plan.used_elems == 340
    assert plan.total_elems == sum(plan.bucket_sizes)


# ---------------------------------------------------------------------------
# latency model: t_collective = alpha * messages + bytes / bw
# ---------------------------------------------------------------------------


def test_latency_model_alpha_beta_split():
    from repro.comm import ALPHA_S, LatencyModel

    m = LatencyModel()
    assert m.collective_seconds(0, 0) == 0.0
    # pure-latency regime: tiny payload, many messages
    assert m.collective_seconds(100, 8) == pytest.approx(
        100 * ALPHA_S + 8 / m.bandwidth)
    # alpha dominates small messages, beta dominates bulk
    small = m.collective_seconds(10, 1024)
    bulk = m.collective_seconds(10, 10 * 2**30)
    assert small == pytest.approx(10 * ALPHA_S, rel=2e-2)
    assert bulk == pytest.approx(10 * 2**30 / m.bandwidth, rel=2e-2)


def test_transport_message_counts():
    from repro.core.ring import RingConfig

    def transport_for(name, **ring_kw):
        _, cls = get_transport(name)
        return cls(("data",), RingConfig(**ring_kw))

    # psum: one ring over the joint world = 2*(p-1) hops
    assert transport_for("psum").predicted_messages_per_device([4]) == 6.0
    assert transport_for("psum").predicted_messages_per_device(
        [2, 4]) == 14.0
    assert transport_for("psum").predicted_messages_per_device([1]) == 0.0
    # explicit bidirectional 2-chunk ring: 4 parallel chains, same hop count
    ring = transport_for("ring", chunks=2, bidirectional=True)
    assert ring.predicted_messages_per_device([4]) == 6.0 * 4
    uni = transport_for("ring", chunks=1, bidirectional=False)
    assert uni.predicted_messages_per_device([4]) == 6.0
    # message count scales with buckets through CommPlan (axis size 1 mesh:
    # no wire, so just check the field and describe key are wired through)
    import jax.numpy as jnp

    from repro import compat

    mesh = compat.make_mesh((1,), ("data",))
    comm = Communicator(mesh, CommConfig(transport="psum",
                                         data_axes=("data",)))
    plan = comm.plan({"w": jnp.zeros((512,), jnp.float32)})
    assert plan.messages_per_device == 0.0
    assert "messages_per_device" in plan.describe()
    assert plan.predicted_collective_seconds() >= 0.0


def test_halo_plan_message_count_is_unit_count():
    from repro.core.halo import HaloSpec

    from repro import compat

    mesh = compat.make_mesh((1,), ("x",))
    comm = Communicator(mesh, CommConfig(data_axes=("x",), channels=2))
    specs = [HaloSpec("x", 0, 1)]
    plan = comm.halo_plan((6, 5), specs, schedule="concurrent")
    assert plan.messages_per_device == plan.n_units == 2
    assert plan.describe()["messages_per_device"] == 2
    assert plan.predicted_collective_seconds() == pytest.approx(
        2 * 1.5e-6 + plan.bytes_per_device / 50e9)


# ---------------------------------------------------------------------------
# all-to-all: capability gating, predicted pricing, schedule, equivalence
# ---------------------------------------------------------------------------


def _a2a_comm(transport="a2a", channels=0):
    from repro import compat

    mesh = compat.make_mesh((1,), ("model",))
    return Communicator(mesh, CommConfig(transport=transport,
                                         data_axes=("model",),
                                         channels=channels))


def test_a2a_needs_single_axis_and_capability():
    from repro import compat

    mesh2 = compat.make_mesh((1, 1), ("pod", "data"))
    comm2 = Communicator(mesh2, CommConfig(transport="a2a",
                                           data_axes=("pod", "data")))
    import jax.numpy as jnp

    with pytest.raises(ValueError, match="exactly one comm axis"):
        comm2.all_to_all(jnp.zeros((4, 4)), split_axis=0, concat_axis=1)


def test_a2a_predicted_messages_and_bytes():
    _, cls = get_transport("a2a")
    t = cls(("model",), None)
    # ring-style pricing: p-1 pairwise hops, (p-1)/p of the buffer crosses
    assert t.predicted_a2a_messages_per_device(4) == 3.0
    assert t.predicted_a2a_messages_per_device(1) == 0.0
    assert t.predicted_a2a_bytes_per_device(1024, 4) == 1024 * 4 * 3 / 4
    # psum fallback prices the honest replicated cost: 2(p-1) full copies
    _, pcls = get_transport("psum")
    from repro.core.ring import RingConfig

    p = pcls(("model",), RingConfig())
    assert p.predicted_a2a_messages_per_device(4) == 6.0
    assert p.predicted_a2a_bytes_per_device(1024, 4) == 2 * 3 * 1024 * 4
    # the acceptance bound: dispatch bytes <= 1/R of the replicated cost
    for r in (2, 4, 8):
        assert (t.predicted_a2a_bytes_per_device(1 << 20, r)
                <= p.predicted_a2a_bytes_per_device(1 << 20, r) / r)


def test_a2a_plan_and_moe_schedule():
    comm = _a2a_comm(channels=2)
    shape = (4, 8, 16, 64)           # last dim divisible by channels=2
    plan = comm.a2a_plan(shape)
    assert plan.n_units == 4                        # dispatch+combine x rails
    assert sorted(k.split("#")[0] for k in plan.unit_keys) == \
        ["combine", "combine", "dispatch", "dispatch"]
    assert plan.bytes_per_device == 0.0             # axis size 1: no wire
    assert plan.dispatch_bytes_per_device == 0.0
    assert plan.describe()["transport"] == "a2a"
    assert plan.predicted_collective_seconds() >= 0.0
    sched = comm.moe_schedule(shape)
    sched.validate()
    assert sched.policy == "moe" and sched.channels == 2
    assert sched.n_buckets == 4
    # rails fall back to 1 when the feature dim doesn't divide
    assert comm.a2a_rails((4, 8, 16, 63)) == 1
    assert comm.a2a_rails(shape) == 2


def test_a2a_axis_size_one_is_identity():
    import jax.numpy as jnp

    for transport in ("a2a", "ring", "ring_hier", "psum"):
        comm = _a2a_comm(transport=transport)
        x = jnp.arange(8.0).reshape(2, 4)
        out = comm.all_to_all(x, split_axis=0, concat_axis=1)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


A2A_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator

mesh = compat.make_mesh((4,), ("model",))
rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(4, 8, 3, 12).astype(np.float32))

def native(v):
    return jax.lax.all_to_all(v, "model", 1, 0, tiled=True)

ref = jax.jit(compat.shard_map(native, mesh=mesh, in_specs=P(),
                               out_specs=P("model"), check_vma=False))(x)

for transport in ("a2a", "ring", "ring_hier", "psum"):
    for channels in (0, 2, 3):
        comm = Communicator(mesh, CommConfig(transport=transport,
                                             data_axes=("model",),
                                             channels=channels))

        def fwd(v):
            return comm.all_to_all(v, split_axis=1, concat_axis=0)

        out = jax.jit(compat.shard_map(fwd, mesh=mesh, in_specs=P(),
                                       out_specs=P("model"),
                                       check_vma=False))(x)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        print(transport, channels, "fwd ok")

# gradient check once per transport (native reference transpose)
def loss_ref(v, w_local):
    return jnp.sum(native(v) * w_local)

w = jnp.asarray(rng.randn(64, 2, 3, 12).astype(np.float32))
gref = jax.jit(compat.shard_map(
    jax.grad(loss_ref), mesh=mesh, in_specs=(P(), P("model")),
    out_specs=P(), check_vma=False))(x, w)
for transport in ("a2a", "ring", "psum"):
    comm = Communicator(mesh, CommConfig(transport=transport,
                                         data_axes=("model",)))

    def loss_t(v, w_local):
        return jnp.sum(comm.all_to_all(v, split_axis=1, concat_axis=0)
                       * w_local)

    g = jax.jit(compat.shard_map(
        jax.grad(loss_t), mesh=mesh, in_specs=(P(), P("model")),
        out_specs=P(), check_vma=False))(x, w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gref),
                               rtol=1e-6, atol=1e-6)
    print(transport, "grad ok")

# ragged: counts travel with the payload
comm = Communicator(mesh, CommConfig(transport="a2a",
                                     data_axes=("model",)))

def ragged(v):
    i = jax.lax.axis_index("model")
    counts = jnp.arange(4, dtype=jnp.int32) + 10 * i   # count j for dest j
    recv, rc = comm.all_to_all_ragged(v, counts, split_axis=1,
                                      concat_axis=0)
    return recv, rc

_, rc = jax.jit(compat.shard_map(ragged, mesh=mesh, in_specs=P(),
                                 out_specs=(P("model"), P("model")),
                                 check_vma=False))(x)
rc = np.asarray(rc).reshape(4, 4)
for i in range(4):
    for j in range(4):
        assert rc[i, j] == i + 10 * j, (i, j, rc[i, j])   # from src j: j's count for dest i
print("A2A_EQUIV_OK")
"""


def test_all_to_all_matches_native_on_1d_mesh():
    assert "A2A_EQUIV_OK" in run_distributed(A2A_SCRIPT, n_devices=4)


def test_roofline_alpha_term():
    from repro.launch.roofline import ICI_BW, Roofline

    base = Roofline(flops_per_device=1e12, hbm_bytes_per_device=1e9,
                    wire_bytes_per_device=1e6)
    with_alpha = Roofline(flops_per_device=1e12, hbm_bytes_per_device=1e9,
                          wire_bytes_per_device=1e6,
                          messages_per_device=1000)
    # default (no count) keeps the pure-bandwidth behaviour
    assert base.t_collective == pytest.approx(1e6 / ICI_BW)
    assert with_alpha.t_collective == pytest.approx(
        1e6 / ICI_BW + 1000 * with_alpha.alpha_s)
    assert with_alpha.t_exposed_collective <= with_alpha.t_collective
    assert with_alpha.as_dict(8)["messages_per_device"] == 1000

"""repro.tune: the measured auto-tuner — fit recovery, DB keying, "auto"
resolution — plus regression tests for the three bugfixes that shipped with
it (``settings_for`` error, StragglerMonitor warmup seeding, ``time_call``
median/dispersion)."""

import json
import math
import subprocess
import sys
import warnings

import pytest

from repro.comm.plan import ALPHA_S, LINK_BANDWIDTH, LatencyModel
from repro.launch.settings import ArchSettings, settings_for
from repro.tune import (FitResult, TuningDB, fit_cells, fit_latency,
                        overrides_fingerprint, resolve_settings,
                        synthesize_cells, tune_key)
from repro.tune.fit import dispersion_weight
from repro.tune.probe import ProbeCell, group_cells, parse_cells

PLANT_ALPHA = 3.2e-6
PLANT_BW = 37.5e9


# ---------------------------------------------------------------------------
# fitter
# ---------------------------------------------------------------------------


def test_fit_recovers_planted_constants_under_one_percent():
    """The acceptance criterion: synthetic timings with known α/bandwidth
    come back to <1% relative error."""
    cells = synthesize_cells(
        transports=("ring_hier", "psum"), channels=(1, 2),
        pages=(4096, 2 * 2**20), sizes=(1 << 12, 1 << 16, 1 << 20),
        alpha_s=PLANT_ALPHA, bandwidth=PLANT_BW)
    groups = group_cells(cells)
    assert len(groups) == 2 * 2 * 2
    for key, group in groups.items():
        fit = fit_cells(group)
        assert abs(fit.alpha_s - PLANT_ALPHA) / PLANT_ALPHA < 0.01, key
        assert abs(fit.bandwidth - PLANT_BW) / PLANT_BW < 0.01, key
        assert fit.max_rel_err < 0.01, key


def test_fit_latency_varying_messages():
    """With message counts varying across samples (multi-bucket probes),
    both coefficients are identifiable from noise-free data."""
    samples = [(m, b, PLANT_ALPHA * m + b / PLANT_BW, 1.0)
               for m, b in [(14, 1e6), (28, 2e6), (56, 8e6), (112, 3.2e7)]]
    fit = fit_latency(samples)
    assert abs(fit.alpha_s - PLANT_ALPHA) / PLANT_ALPHA < 1e-6
    assert abs(fit.bandwidth - PLANT_BW) / PLANT_BW < 1e-6
    assert fit.rms_residual_s < 1e-12


def test_fit_weights_down_noisy_cells():
    """A wildly dispersed outlier cell must not drag the constants: its
    1/σ² weight collapses."""
    good = [(14.0, float(b), PLANT_ALPHA * 14 + b / PLANT_BW, 1e12)
            for b in (1e6, 4e6, 1.6e7, 6.4e7)]
    # outlier measured 100x too slow, but with spread as large as itself
    b_out = 2.56e8
    t_true = PLANT_ALPHA * 14 + b_out / PLANT_BW
    noisy_w = dispersion_weight(100 * t_true, 0.5 * t_true, 200 * t_true)
    fit = fit_latency(good + [(14.0, b_out, 100 * t_true, noisy_w)])
    assert abs(fit.bandwidth - PLANT_BW) / PLANT_BW < 0.05
    # equal weights for comparison: the outlier wins and wrecks the fit
    fit_flat = fit_latency([(m, b, t, 1.0) for m, b, t, _ in good]
                           + [(14.0, b_out, 100 * t_true, 1.0)])
    assert abs(fit_flat.bandwidth - PLANT_BW) / PLANT_BW > 0.5


def test_fit_clamps_to_physical_octant():
    # pure-bandwidth data pulls α negative-ish under noise; clamp holds 0
    samples = [(1.0, b, b / PLANT_BW, 1.0) for b in (1e6, 2e6, 4e6)]
    fit = fit_latency(samples)
    assert fit.alpha_s >= 0.0
    assert fit.bandwidth > 0.0 and math.isfinite(fit.bandwidth)


def test_fit_result_round_trips_through_json():
    fit = fit_cells(synthesize_cells(alpha_s=PLANT_ALPHA, bandwidth=PLANT_BW))
    back = FitResult.from_dict(json.loads(json.dumps(fit.as_dict())))
    assert back == fit


# ---------------------------------------------------------------------------
# tuning DB
# ---------------------------------------------------------------------------


def test_tune_key_stable_under_override_reordering():
    a = tune_key("llama3.2-1b", "2x4", "ring_hier", 2, 4096,
                 {"x": 1, "y": "z"})
    b = tune_key("llama3.2-1b", "2x4", "ring_hier", 2, 4096,
                 {"y": "z", "x": 1})
    assert a == b
    assert overrides_fingerprint({"x": 1, "y": "z"}) == \
        overrides_fingerprint({"y": "z", "x": 1})
    # and the fingerprint is shared with the dry-run cache keying
    assert tune_key("a", "m", "t", 1, 4096) == "tune|a|m|t|ch1|p4096"


def test_db_round_trip_and_lookup(tmp_path):
    cells = synthesize_cells(transports=("psum", "ring_hier"),
                             alpha_s=PLANT_ALPHA, bandwidth=PLANT_BW)
    db = TuningDB()
    for (tr, ch, page), group in group_cells(cells).items():
        db.put_fit(arch="generic", mesh="2x4", transport=tr, channels=ch,
                   page_bytes=page, fit=fit_cells(group), cells=group)
    path = str(tmp_path / "tuning.json")
    db.save(path)
    back = TuningDB.load(path)
    assert back.records == db.records
    # save -> load -> save is byte-stable (sorted keys, fixed layout)
    back.save(str(tmp_path / "tuning2.json"))
    assert (tmp_path / "tuning.json").read_text() == \
        (tmp_path / "tuning2.json").read_text()

    # transport is a hard lookup requirement; soft dims degrade gracefully
    hit = back.lookup(transport="psum", arch="other-arch", mesh="16x16")
    assert hit is not None and hit[1]["transport"] == "psum"
    assert back.lookup(transport="no_such_transport") is None
    # rebuild measured constants from the stored record
    lm = LatencyModel.from_record(hit[1])
    assert abs(lm.alpha_s - PLANT_ALPHA) / PLANT_ALPHA < 0.01
    assert abs(lm.bandwidth - PLANT_BW) / PLANT_BW < 0.01


def test_db_best_config_prefers_cheaper_fit():
    slow = fit_latency([(14, b, 100e-6 * 14 + b / 1e9, 1.0)
                        for b in (1e6, 4e6)])
    fast = fit_latency([(14, b, 1e-6 * 14 + b / 100e9, 1.0)
                        for b in (1e6, 4e6)])
    mk = lambda tr, ch, elems: [ProbeCell(       # noqa: E731
        bench="synthetic", arch="generic", mesh="2x4", transport=tr,
        channels=ch, page_bytes=4096, elems=elems, messages=14.0,
        nbytes=elems * 4.0, seconds=1.0, t_min=1.0, t_max=1.0)]
    db = TuningDB()
    db.put_fit(arch="generic", mesh="2x4", transport="ring", channels=1,
               page_bytes=4096, fit=slow, cells=mk("ring", 1, 1 << 16))
    db.put_fit(arch="generic", mesh="2x4", transport="ring_hier", channels=4,
               page_bytes=4096, fit=fast, cells=mk("ring_hier", 4, 1 << 16))
    best = db.best_config(arch="generic", mesh="2x4")
    assert best["transport"] == "ring_hier" and best["channels"] == 4
    # pinning the transport restricts the candidates
    pinned = db.best_config(arch="generic", mesh="2x4", transport="ring")
    assert pinned["transport"] == "ring"


# ---------------------------------------------------------------------------
# "auto" resolution
# ---------------------------------------------------------------------------


def _db_with_record(transport="psum", channels=2, page_bytes=4096):
    cells = synthesize_cells(transports=(transport,), channels=(channels,),
                             pages=(page_bytes,), alpha_s=PLANT_ALPHA,
                             bandwidth=PLANT_BW)
    db = TuningDB()
    db.put_fit(arch="generic", mesh="2x4", transport=transport,
               channels=channels, page_bytes=page_bytes,
               fit=fit_cells(cells), cells=cells)
    return db


def test_resolve_auto_from_db():
    st = ArchSettings("replicated", 1, "resident", transport="auto",
                      page_bytes="auto")
    resolved, info = resolve_settings(st, "llama3.2-1b", mesh_label="2x4",
                                      db=_db_with_record())
    assert info["source"] == "db"
    assert resolved.transport == "psum"
    assert resolved.channels == 2          # channels=0 upgraded (soft)
    assert resolved.page_bytes == 4096
    # non-sentinel settings pass through untouched
    pinned = ArchSettings("replicated", 1, "resident", transport="ring",
                          channels=1)
    same, info2 = resolve_settings(pinned, "x", db=_db_with_record())
    assert same == pinned and info2["source"] == "unchanged"


def test_resolve_falls_back_with_warning_on_empty_db():
    st = ArchSettings("replicated", 1, "resident", transport="auto",
                      page_bytes="auto")
    with pytest.warns(UserWarning, match="no tuning-DB record"):
        resolved, info = resolve_settings(st, "llama3.2-1b", db=TuningDB())
    assert info["source"] == "fallback"
    assert resolved.transport == "ring_hier"       # today's default
    assert resolved.page_bytes == 2 * 2**20
    assert resolved.channels == 0                  # soft sentinel: stays


def test_resolve_soft_channels_stays_silent_without_db():
    # channels=0 alone must not warn (it is a valid production setting)
    st = ArchSettings("replicated", 1, "resident")   # channels=0 default
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        resolved, info = resolve_settings(st, "llama3.2-1b", db=TuningDB())
    assert resolved.channels == 0 and info["source"] == "fallback"


def test_comm_config_warns_and_defaults_on_unresolved_auto():
    st = ArchSettings("replicated", 1, "resident", transport="auto",
                      page_bytes="auto")
    with pytest.warns(UserWarning, match="unresolved 'auto'"):
        ccfg = st.comm_config()
    assert ccfg.transport == "ring_hier" and ccfg.page_bytes == 2 * 2**20
    # resolved settings build without noise
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ccfg2 = ArchSettings("replicated", 1, "resident",
                             page_bytes=4096).comm_config()
    assert ccfg2.page_bytes == 4096


# ---------------------------------------------------------------------------
# probe plumbing
# ---------------------------------------------------------------------------


def test_probe_cell_round_trip_and_parse():
    cells = synthesize_cells()
    line = "CELL " + json.dumps(cells[0].as_dict())
    parsed = parse_cells("noise\n" + line + "\nmore noise\n")
    assert parsed == [cells[0]]


def test_probe_dry_cli_writes_consumable_db(tmp_path):
    """The CI smoke in miniature: probe --dry -> 2 cells -> DB file whose
    record carries the planted constants."""
    out = str(tmp_path / "tuning.json")
    r = subprocess.run(
        [sys.executable, "-m", "repro.tune.probe", "--dry", "--out", out,
         "--plant-alpha", str(PLANT_ALPHA), "--plant-bandwidth",
         str(PLANT_BW)],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "probed 2 cells -> 1 fit group(s)" in r.stdout
    db = TuningDB.load(out)
    assert len(db) == 1
    (key,) = db.records
    fit = db.fit_for(key)
    assert abs(fit.alpha_s - PLANT_ALPHA) / PLANT_ALPHA < 0.01
    assert abs(fit.bandwidth - PLANT_BW) / PLANT_BW < 0.01
    assert fit.max_rel_err < 0.01


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------


def test_settings_for_unknown_arch_raises_value_error_with_menu():
    with pytest.raises(ValueError, match="unknown arch 'not-an-arch'"):
        settings_for("not-an-arch")
    with pytest.raises(ValueError, match="llama3.2-1b"):
        settings_for("not-an-arch")
    # no bare KeyError escapes
    try:
        settings_for("nope")
    except ValueError:
        pass


def test_straggler_monitor_seeds_from_warmup_median():
    """Regression: the EWMA used to seed from step 0 — the compile step —
    inflating the baseline so early stragglers passed unflagged."""
    from repro.runtime.ft import StragglerMonitor

    mon = StragglerMonitor(threshold=2.0, warmup_steps=3)
    # compile step is 500x a steady step; old code seeded the EWMA with it
    assert not mon.record(0, 50.0)
    assert not mon.record(1, 0.1)
    assert not mon.record(2, 0.1)
    assert mon._ewma == pytest.approx(0.1)   # median of [50, 0.1, 0.1]
    # an early 5x straggler is now caught (old code: 0.5 < 2*50 passed)
    ev = mon.record(3, 0.5)
    assert ev.flagged and bool(ev)
    assert mon.events == [ev]
    assert (ev.step, ev.seconds, ev.ewma) == (3, 0.5, pytest.approx(0.1))


def test_straggler_monitor_warmup_emits_no_events():
    from repro.runtime.ft import StragglerMonitor

    mon = StragglerMonitor(threshold=2.0, warmup_steps=4)
    for step, sec in enumerate([10.0, 0.1, 30.0, 0.1]):
        assert not mon.record(step, sec)
    assert mon.events == []
    assert mon._ewma == pytest.approx((0.1 + 10.0) / 2)  # even-count median


def test_straggler_monitor_zero_warmup_still_works():
    from repro.runtime.ft import StragglerMonitor

    mon = StragglerMonitor(threshold=2.0, warmup_steps=0)
    assert not mon.record(0, 0.1)          # seeds from first sample
    assert mon.record(1, 0.5).flagged


def test_time_call_true_median_and_dispersion(monkeypatch):
    """Regression: ``ts[len(ts)//2]`` is the *upper* median for even iters
    — a biased input to the tuner's fits.  The fixed version interpolates
    and carries min/max for dispersion weighting."""
    from benchmarks import common

    # perf_counter deltas of 1, 2, 3, 10 seconds over 4 timed iters
    ticks = iter([0.0, 1.0,  10.0, 12.0,  20.0, 23.0,  30.0, 40.0])
    import time as _time
    monkeypatch.setattr(_time, "perf_counter", lambda: next(ticks))

    t = common.time_call(lambda: None, warmup=0, iters=4)
    assert isinstance(t, float)            # call sites keep working
    assert float(t) == pytest.approx(2.5)  # true median of [1,2,3,10]
    assert t.t_min == pytest.approx(1.0)
    assert t.t_max == pytest.approx(10.0)
    assert t.spread == pytest.approx(9.0)
    assert t.samples == (1.0, 2.0, 3.0, 10.0)


def test_timer_snippet_matches_module_implementation():
    """The subprocess-embedded snippet is built from the module source —
    the two can never drift apart."""
    from benchmarks import common

    ns = {}
    exec(common.TIMER_SNIPPET, ns)
    t = ns["Timing"]([4.0, 2.0])
    assert float(t) == pytest.approx(3.0)      # interpolated, not upper
    assert (t.t_min, t.t_max) == (2.0, 4.0)
    assert ns["time_call"].__doc__ == common.time_call.__doc__


def test_dispersion_weight_floors():
    # zero-spread cells still get a finite weight (1% rel floor)
    w = dispersion_weight(1.0, 1.0, 1.0)
    assert w == pytest.approx(1.0 / 0.01**2)
    # spread dominates when larger than the floor
    assert dispersion_weight(1.0, 0.5, 1.5) == pytest.approx(1.0 / 0.5**2)

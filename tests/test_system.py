"""End-to-end behaviour: the Trainer trains, checkpoints, and resumes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import reduced_config
from repro.core.reducer import ReduceConfig
from repro.data import DataConfig, SyntheticTokens
from repro.models import build_model
from repro.optim import OptimConfig
from repro.runtime.train_loop import Trainer, TrainerConfig
from repro.runtime.train_step import TrainStepConfig


def _mesh():
    # feature-detects AxisType / axis_types support for the installed jax
    return compat.make_mesh((1, 1), ("data", "model"))


def _setup(tmp_path, steps=24, ckpt_every=8):
    cfg = reduced_config("llama3.2-1b")
    model = build_model(cfg)
    from repro.configs.base import ShapeConfig

    shape = ShapeConfig("tiny", 64, 4, "train")
    data = SyntheticTokens(DataConfig(vocab_size=model.cfg.vocab_size,
                                      seq_len=64, global_batch=4, seed=1),
                           model_cfg=cfg)
    scfg = TrainStepConfig(
        dp_mode="replicated",
        reduce=ReduceConfig(policy="fused_ring_hierarchical"),
        optim=OptimConfig(base_lr=3e-3, warmup=5, total_steps=steps),
        microbatches=1)
    tcfg = TrainerConfig(steps=steps, ckpt_every=ckpt_every,
                         ckpt_dir=str(tmp_path / "ckpt"), log_every=100)
    return model, shape, data, scfg, tcfg


def test_training_reduces_loss(tmp_path):
    model, shape, data, scfg, tcfg = _setup(tmp_path)
    tr = Trainer(model, _mesh(), scfg, data, shape, tcfg,
                 log=lambda s: None)
    out = tr.run()
    hist = out["history"]
    first = np.mean([h["loss"] for h in hist[:4]])
    last = np.mean([h["loss"] for h in hist[-4:]])
    assert np.isfinite(first) and np.isfinite(last)
    assert last < first - 0.05, f"no learning: {first:.4f} -> {last:.4f}"


def test_checkpoint_restart_is_seamless(tmp_path):
    """Kill after N steps; a fresh Trainer resumes and matches an unbroken
    run exactly (deterministic data + state restore)."""
    model, shape, data, scfg, tcfg = _setup(tmp_path, steps=12, ckpt_every=4)

    # unbroken reference
    import dataclasses

    ref_dir = tmp_path / "ref"
    tcfg_ref = dataclasses.replace(tcfg, ckpt_dir=str(ref_dir))
    ref = Trainer(model, _mesh(), scfg, data, shape, tcfg_ref,
                  log=lambda s: None).run()

    # crashed run: stop at step 8 (simulated failure after a commit)
    tcfg_a = dataclasses.replace(tcfg, steps=8)
    Trainer(model, _mesh(), scfg, data, shape, tcfg_a, log=lambda s: None).run()
    # resume to completion
    tr_b = Trainer(model, _mesh(), scfg, data, shape, tcfg, log=lambda s: None)
    assert tr_b.start_step == 8, "did not resume from the committed step"
    out_b = tr_b.run()

    ref_tail = {h["step"]: h["loss"] for h in ref["history"]}
    for h in out_b["history"]:
        assert abs(h["loss"] - ref_tail[h["step"]]) < 1e-4, \
            f"divergence at step {h['step']}"


def test_straggler_events_surface(tmp_path):
    model, shape, data, scfg, tcfg = _setup(tmp_path, steps=6)
    tr = Trainer(model, _mesh(), scfg, data, shape, tcfg, log=lambda s: None)
    for i in range(5):
        assert not tr.monitor.record(i, 0.1)
    ev = tr.monitor.record(5, 1.0)
    # bool-compat: the event is truthy exactly when flagged
    assert ev and ev.flagged and bool(ev) is True
    assert ev.step == 5 and ev.seconds == 1.0
    assert ev.ewma == pytest.approx(0.1) and ev.ratio == pytest.approx(10.0)
    assert len(tr.monitor.events) == 1
    assert tr.monitor.events[0] is ev


def test_straggler_event_structure_and_warmup():
    from repro.runtime.ft import StragglerEvent, StragglerMonitor

    m = StragglerMonitor(warmup_steps=2)
    w = m.record(0, 5.0)   # compile step: collected, never flagged
    assert isinstance(w, StragglerEvent)
    assert not w and w.ewma == 0.0 and w.ratio == float("inf")
    m.record(1, 0.1)       # ewma seeds from median(5.0, 0.1)
    assert not m.record(2, 0.2)
    assert m.events == []


def test_heartbeat_dead_hosts_boundary_and_self_exclusion(tmp_path):
    from repro.runtime.ft import Heartbeat

    d = str(tmp_path / "beats")
    a = Heartbeat(d, "a", timeout=10.0)
    b = Heartbeat(d, "b", timeout=10.0)
    a.beat(now=100.0)
    b.beat(now=100.0)
    # exactly at the timeout is still alive (strict >)
    assert a.dead_hosts(now=110.0) == []
    # one tick past: dead — but only as seen by the *other* host; a host
    # never reports itself dead off its own stale file
    assert a.dead_hosts(now=110.1) == ["b"]
    assert b.dead_hosts(now=110.1) == ["a"]
    b.beat(now=111.0)
    assert a.dead_hosts(now=112.0) == []


def test_heartbeat_prune_stale_cleans_beat_files(tmp_path):
    from repro.runtime.ft import Heartbeat

    d = str(tmp_path / "beats")
    a = Heartbeat(d, "a", timeout=1.0)
    b = Heartbeat(d, "b", timeout=1.0)
    a.beat(now=0.0)
    b.beat(now=0.0)
    # within grace: dead but not pruned
    assert a.prune_stale(now=5.0) == []
    assert a.dead_hosts(now=5.0) == ["b"]
    # past grace (default 10x timeout): the stale file is removed...
    assert a.prune_stale(now=11.0) == ["b"]
    assert a.dead_hosts(now=11.0) == []
    # ...but never the reporter's own file
    assert a.prune_stale(now=1e9) == []
    assert (tmp_path / "beats" / "a.beat").exists()
    assert not (tmp_path / "beats" / "b.beat").exists()

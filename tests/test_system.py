"""End-to-end behaviour: the Trainer trains, checkpoints, and resumes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import reduced_config
from repro.core.reducer import ReduceConfig
from repro.data import DataConfig, SyntheticTokens
from repro.models import build_model
from repro.optim import OptimConfig
from repro.runtime.train_loop import Trainer, TrainerConfig
from repro.runtime.train_step import TrainStepConfig


def _mesh():
    # feature-detects AxisType / axis_types support for the installed jax
    return compat.make_mesh((1, 1), ("data", "model"))


def _setup(tmp_path, steps=24, ckpt_every=8):
    cfg = reduced_config("llama3.2-1b")
    model = build_model(cfg)
    from repro.configs.base import ShapeConfig

    shape = ShapeConfig("tiny", 64, 4, "train")
    data = SyntheticTokens(DataConfig(vocab_size=model.cfg.vocab_size,
                                      seq_len=64, global_batch=4, seed=1),
                           model_cfg=cfg)
    scfg = TrainStepConfig(
        dp_mode="replicated",
        reduce=ReduceConfig(policy="fused_ring_hierarchical"),
        optim=OptimConfig(base_lr=3e-3, warmup=5, total_steps=steps),
        microbatches=1)
    tcfg = TrainerConfig(steps=steps, ckpt_every=ckpt_every,
                         ckpt_dir=str(tmp_path / "ckpt"), log_every=100)
    return model, shape, data, scfg, tcfg


def test_training_reduces_loss(tmp_path):
    model, shape, data, scfg, tcfg = _setup(tmp_path)
    tr = Trainer(model, _mesh(), scfg, data, shape, tcfg,
                 log=lambda s: None)
    out = tr.run()
    hist = out["history"]
    first = np.mean([h["loss"] for h in hist[:4]])
    last = np.mean([h["loss"] for h in hist[-4:]])
    assert np.isfinite(first) and np.isfinite(last)
    assert last < first - 0.05, f"no learning: {first:.4f} -> {last:.4f}"


def test_checkpoint_restart_is_seamless(tmp_path):
    """Kill after N steps; a fresh Trainer resumes and matches an unbroken
    run exactly (deterministic data + state restore)."""
    model, shape, data, scfg, tcfg = _setup(tmp_path, steps=12, ckpt_every=4)

    # unbroken reference
    import dataclasses

    ref_dir = tmp_path / "ref"
    tcfg_ref = dataclasses.replace(tcfg, ckpt_dir=str(ref_dir))
    ref = Trainer(model, _mesh(), scfg, data, shape, tcfg_ref,
                  log=lambda s: None).run()

    # crashed run: stop at step 8 (simulated failure after a commit)
    tcfg_a = dataclasses.replace(tcfg, steps=8)
    Trainer(model, _mesh(), scfg, data, shape, tcfg_a, log=lambda s: None).run()
    # resume to completion
    tr_b = Trainer(model, _mesh(), scfg, data, shape, tcfg, log=lambda s: None)
    assert tr_b.start_step == 8, "did not resume from the committed step"
    out_b = tr_b.run()

    ref_tail = {h["step"]: h["loss"] for h in ref["history"]}
    for h in out_b["history"]:
        assert abs(h["loss"] - ref_tail[h["step"]]) < 1e-4, \
            f"divergence at step {h['step']}"


def test_straggler_events_surface(tmp_path):
    model, shape, data, scfg, tcfg = _setup(tmp_path, steps=6)
    tr = Trainer(model, _mesh(), scfg, data, shape, tcfg, log=lambda s: None)
    for i in range(5):
        tr.monitor.record(i, 0.1)
    assert tr.monitor.record(5, 1.0) is True
    assert len(tr.monitor.events) == 1

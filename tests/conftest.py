"""Shared fixtures.  NOTE: no XLA_FLAGS here — unit/smoke tests must see the
real single CPU device (the 512-device override is exclusively the dry-run's;
distributed tests spawn subprocesses that set their own flag)."""

import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture(scope="session")
def rng():
    import numpy as np

    return np.random.RandomState(0)


def run_distributed(script: str, n_devices: int = 8, timeout: int = 560,
                    extra_flags: str = "") -> str:
    """Run ``script`` in a fresh interpreter with N host devices; returns
    stdout.  Raises on non-zero exit.  ``extra_flags`` appends to XLA_FLAGS
    (e.g. ``--xla_disable_hlo_passes=fusion`` for the bitwise cross-schedule
    stencil tests, which must exclude backend fusion heuristics)."""
    import subprocess

    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices}"
                        + (f" {extra_flags}" if extra_flags else ""))
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"distributed script failed (rc={proc.returncode}):\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout

"""Property tests over the system's core invariants.

With ``hypothesis`` installed (the CI dev extra) each invariant is explored
by randomised strategies; without it, the same invariants run as a
deterministic parametrized grid over hand-picked representative cases, so a
bare local checkout still gets tier-1 property coverage instead of a silent
self-skip.  Every test body is shared between the two paths via
:func:`given_or_grid` — keep the ``cases`` list in the same argument order
as the strategy dict.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:               # deterministic grid fallback
    HAVE_HYPOTHESIS = False

from repro.core.bucketing import GradientBucketer
from repro.comm.wire_codec import Int8BlockCodec, IdentityCodec
from repro.core.halo import halo_bytes, HaloSpec
from repro.core.ring import RingConfig
from repro.core.topology import padded_size, ring_perm
from repro.optim.schedules import make_schedule


def given_or_grid(argnames, cases, strategies):
    """Hypothesis ``@given`` when available, else a pytest parametrize grid.

    ``argnames`` is the comma-joined parameter list, ``cases`` the explicit
    fallback tuples (same order), ``strategies`` a zero-arg callable
    returning the ``@given`` kwargs — callable so strategy construction
    never runs when hypothesis is absent."""
    def wrap(fn):
        if HAVE_HYPOTHESIS:
            return settings(max_examples=30, deadline=None)(
                given(**strategies())(fn))
        return pytest.mark.parametrize(argnames, cases)(fn)
    return wrap


_SHAPES_CASES = [
    [(1, 3, 2)],
    [(2, 64, 8), (1, 1, 1), (4, 5, 6)],
    [(1, 17, 3)] * 7,
    [(3, 33, 2), (1, 1, 8), (2, 2, 2), (4, 64, 1)],
]


@given_or_grid(
    "shapes,bucket_kb,pad",
    [(s, kb, pad) for s, (kb, pad) in zip(
        _SHAPES_CASES, [(1, 128), (4, 256), (64, 512), (4, 128)])],
    lambda: dict(
        shapes=st.lists(st.tuples(st.integers(1, 4), st.integers(1, 64),
                                  st.integers(1, 8)),
                        min_size=1, max_size=12),
        bucket_kb=st.sampled_from([1, 4, 64]),
        pad=st.sampled_from([128, 256, 512])))
def test_bucketize_roundtrip(shapes, bucket_kb, pad):
    """flatten -> buckets -> unflatten is the identity for any pytree."""
    rng = np.random.RandomState(42)
    tree = {f"p{i}": jnp.asarray(rng.randn(*s).astype(np.float32))
            for i, s in enumerate(shapes)}
    b = GradientBucketer(bucket_bytes=bucket_kb * 1024, pad_multiple=pad)
    buckets, plan = b.bucketize(tree)
    # every bucket is pad-aligned
    assert all(bk.shape[0] % b.pad_multiple == 0 for bk in buckets)
    back = b.debucketize(buckets, plan)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(tree[k]))
    # plan is cached: same structure returns the identical object
    assert b.plan(tree) is plan


@given_or_grid(
    "n_blocks,block,scale",
    [(1, 128, 1.0), (4, 256, 1e-3), (16, 512, 1e3), (3, 128, 42.0)],
    lambda: dict(n_blocks=st.integers(1, 16),
                 block=st.sampled_from([128, 256, 512]),
                 scale=st.floats(1e-3, 1e3)))
def test_int8_codec_error_bound(n_blocks, block, scale):
    """|decode(encode(x)) - x| <= blockwise absmax / 254 elementwise."""
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(n_blocks * block).astype(np.float32) * scale)
    codec = Int8BlockCodec(block=block)
    back = codec.decode(codec.encode(x))
    absmax = np.abs(np.asarray(x).reshape(n_blocks, block)).max(1)
    bound = np.repeat(absmax / 254.0 + 1e-7, block)
    assert np.all(np.abs(np.asarray(back) - np.asarray(x)) <= bound)


@given_or_grid(
    "n,mult",
    [(1, 1), (1, 8), (7, 8), (128, 128), (129, 128), (9999, 384), (384, 384)],
    lambda: dict(n=st.integers(1, 10_000),
                 mult=st.sampled_from([1, 8, 128, 384])))
def test_padded_size(n, mult):
    p = padded_size(n, mult)
    assert p >= n and p % mult == 0 and p - n < mult


@given_or_grid(
    "size,direction",
    [(2, 1), (2, -1), (5, 1), (8, -1), (64, 1)],
    lambda: dict(size=st.integers(2, 64), direction=st.sampled_from([1, -1])))
def test_ring_perm_is_permutation(size, direction):
    perm = ring_perm(size, direction)
    srcs = [a for a, _ in perm]
    dsts = [b for _, b in perm]
    assert sorted(srcs) == list(range(size))
    assert sorted(dsts) == list(range(size))
    # a ring: applying size times returns home
    nxt = dict(perm)
    cur = 0
    for _ in range(size):
        cur = nxt[cur]
    assert cur == 0


@given_or_grid(
    "name,base,warmup",
    [("constant", 1e-3, 1), ("linear", 1e-4, 10), ("cosine", 1e-2, 50),
     ("wsd", 1e-5, 25)],
    lambda: dict(name=st.sampled_from(["constant", "linear", "cosine", "wsd"]),
                 base=st.floats(1e-5, 1e-2), warmup=st.integers(1, 50)))
def test_schedules_warmup_and_bounds(name, base, warmup):
    f = make_schedule(name, base_lr=base, warmup=warmup, total=200)
    lrs = np.array([float(f(jnp.asarray(s))) for s in range(0, 200, 10)])
    assert np.all(lrs >= 0) and np.all(lrs <= base * (1 + 1e-6))
    # warmup reaches (close to) base by the warmup step
    assert float(f(jnp.asarray(warmup))) >= 0.99 * float(f(jnp.asarray(warmup + 1))) * 0.5


@given_or_grid(
    "shape,halo",
    [((2, 2), 1), ((32, 7), 2), ((5, 32), 1), ((16, 16), 2)],
    lambda: dict(shape=st.tuples(st.integers(2, 32), st.integers(2, 32)),
                 halo=st.integers(1, 2)))
def test_halo_bytes_formula(shape, halo):
    specs = [HaloSpec("data", 0, halo)]
    b = halo_bytes(shape, specs, 4)
    assert b == 2 * halo * shape[1] * 4


_HALO_DIM_CASES = [
    (((4,), (1,)), "sequential", 0, 1, 2),
    (((2, 8), (1, 2)), "concurrent", 2, 3, 1),
    (((8, 3, 5), (2, 1, 1)), "chunked", 0, 4, 6),
    (((6, 7, 5), (1, 1, 2)), "overlap", 3, 2, 3),
    (((5, 5), (2, 2)), "overlap", 0, 1, 4),
    (((3, 4), (1, 2)), "chunked", 4, 3, 2),
]


@given_or_grid(
    "dims,schedule,channels,chunks,extra",
    _HALO_DIM_CASES,
    lambda: dict(
        dims=st.integers(1, 3).flatmap(
            lambda nd: st.tuples(
                st.tuples(*[st.integers(2, 8) for _ in range(nd)]),
                st.tuples(*[st.integers(1, 2) for _ in range(nd)]))),
        schedule=st.sampled_from(["sequential", "concurrent", "chunked",
                                  "overlap"]),
        channels=st.integers(0, 4), chunks=st.integers(1, 4),
        extra=st.integers(1, 6)))
def test_build_halo_schedule_invariants(dims, schedule, channels, chunks,
                                        extra):
    """Every direction's payload issues exactly once, channels stay in
    range, overlap_fraction in [0, 1], and chunking conserves bytes."""
    from repro.comm import build_halo_schedule
    from repro.core.halo import HaloSpec, halo_bytes

    shape, halos = dims
    shape = shape + (extra,)                      # one unsharded dim
    specs = [HaloSpec(f"ax{d}", d, h) for d, h in enumerate(halos)]
    s = build_halo_schedule(specs, shape, schedule=schedule,
                            channels=channels, chunks=chunks)
    seen = sorted(b for slot in s.slots for b in slot.bucket_ids)
    assert seen == list(range(s.n_buckets))
    assert all(slot.phase == 0 for slot in s.slots)
    limit = (1 if schedule == "sequential"
             else channels if (schedule == "overlap" and channels >= 1)
             else s.n_buckets)
    assert all(0 <= slot.channel < limit for slot in s.slots)
    assert 0.0 <= s.overlap_fraction <= 1.0
    assert (s.overlap_fraction > 0.0) == (
        schedule == "overlap"
        and all(n > 2 * sp.halo for n, sp in zip(shape, specs)))
    assert sum(s.bucket_sizes) == halo_bytes(shape, specs, 4)


@given_or_grid(
    "shape,mass,seed,halo",
    [((3, 3), 0.1, 0, 1), ((4, 5), 0.5, 1, 2), ((6, 4), 1.5, 2, 1),
     ((5, 6), 2.0, 12345, 2)],
    lambda: dict(shape=st.tuples(st.integers(3, 6), st.integers(3, 6)),
                 mass=st.floats(0.1, 2.0), seed=st.integers(0, 2**16),
                 halo=st.integers(1, 2)))
def test_cg_converges_to_linalg_solution(shape, mass, seed, halo):
    """CG on any SPD Wilson-like operator reaches the dense
    ``jnp.linalg.solve`` solution of the same periodic system."""
    from repro.stencil import StencilOp, cg_solve

    specs = tuple(HaloSpec(f"ax{d}", d, halo) for d in range(len(shape)))
    op = StencilOp(specs=specs, mass=mass)
    A = np.asarray(op.dense_matrix(shape))
    assert np.linalg.eigvalsh(A).min() > 0.0        # SPD by construction
    rng = np.random.RandomState(seed)
    b = jnp.asarray(rng.randn(*shape).astype(np.float32))
    res = cg_solve(op, b, None, tol=1e-7, maxiter=500,
                   matvec=op.apply_reference)
    xref = np.asarray(jnp.linalg.solve(jnp.asarray(A), b.reshape(-1)))
    assert float(res.rel_residual) < 1e-6
    assert np.abs(np.asarray(res.x).reshape(-1) - xref).max() < 1e-3


@given_or_grid(
    "shape,mass,seed,solver",
    [((4, 6), 0.2, 0, "pipelined"), ((6, 4), 0.5, 1, "sstep"),
     ((4, 4), 1.0, 2, "pipelined"), ((6, 6), 0.3, 3, "sstep")],
    lambda: dict(shape=st.sampled_from([(4, 4), (4, 6), (6, 4), (6, 6)]),
                 mass=st.floats(0.1, 2.0), seed=st.integers(0, 2**16),
                 solver=st.sampled_from(["pipelined", "sstep"])))
def test_comm_avoiding_solvers_converge_with_eo(shape, mass, seed, solver):
    """Any comm-avoiding solver x even-odd combination on any SPD
    even-extent Wilson-like operator reaches the dense solution."""
    from repro.stencil import StencilOp, solve

    specs = tuple(HaloSpec(f"ax{d}", d, 1) for d in range(len(shape)))
    op = StencilOp(specs=specs, mass=mass)
    rng = np.random.RandomState(seed)
    b = jnp.asarray(rng.randn(*shape).astype(np.float32))
    res = solve(op, b, None, solver=solver, precond="eo", s=4, tol=1e-5,
                maxiter=400, reference=True)
    A = np.asarray(op.dense_matrix(shape)).astype(np.float64)
    xref = np.linalg.solve(A, np.asarray(b).reshape(-1).astype(np.float64))
    assert float(res.rel_residual) < 1e-5
    assert np.abs(np.asarray(res.x).reshape(-1) - xref).max() < 1e-3


@given_or_grid(
    "chunks,bidi,codec",
    [(1, False, None), (2, True, None), (4, True, "int8"), (3, False, "int8")],
    lambda: dict(chunks=st.integers(1, 4), bidi=st.booleans(),
                 codec=st.sampled_from([None, "int8"])))
def test_ring_config_divisor_consistency(chunks, bidi, codec):
    cfg = RingConfig(chunks=chunks, bidirectional=bidi, codec=codec)
    d = cfg.channel_divisor
    assert d % chunks == 0
    if bidi:
        assert d % 2 == 0
    if codec == "int8":
        assert d % cfg.codec_block == 0
    assert cfg.flat_divisor([4, 2]) % (8 * d * d) == 0 or True  # composes
    assert cfg.flat_divisor([4]) == 4 * d


# ---------------------------------------------------------------------------
# repro.serve: KV arena layout + page allocator (PR 6)
# ---------------------------------------------------------------------------


@given_or_grid(
    "page_tokens,page_bytes,max_seqs,max_seq_len",
    [(8, 4096, 4, 64), (1, 512, 1, 1), (16, 4096, 6, 100),
     (32, 2 * 2**20, 2, 31), (5, 512, 3, 17)],
    lambda: dict(page_tokens=st.integers(1, 32),
                 page_bytes=st.sampled_from([512, 4096, 2 * 2**20]),
                 max_seqs=st.integers(1, 6),
                 max_seq_len=st.integers(1, 128)))
def test_kv_arena_layout_invariants(page_tokens, page_bytes, max_seqs,
                                    max_seq_len):
    """Any (page_tokens, page_bytes, capacity) cell: page-quantized,
    non-overlapping, and the waste accounting closes exactly."""
    from repro.configs import reduced_config
    from repro.serve import plan_kv_arena

    cfg = reduced_config("llama3.2-1b")
    plan = plan_kv_arena(cfg, page_tokens=page_tokens, page_bytes=page_bytes,
                         max_seqs=max_seqs, max_seq_len=max_seq_len)
    plan.layout.validate()
    isz = jnp.dtype(plan.layout.dtype).itemsize
    # page offsets start on huge-page boundaries and never overlap
    assert (plan.page_stride * isz) % page_bytes == 0
    assert plan.page_stride >= plan.payload_elems
    for pid in (0, 1, plan.n_kv_pages - 1):
        assert plan.page_offset(pid) == pid * plan.page_stride
    # capacity: every (slot, block, layer) cell has a page
    assert plan.n_kv_pages == plan.max_seqs * plan.max_blocks * plan.n_layers
    assert plan.max_blocks * page_tokens >= max_seq_len
    # V lives in the strict upper half of the payload: no K/V overlap
    assert plan.v_offset == plan.payload_elems // 2
    assert plan.k_offset + plan.v_offset <= plan.page_stride
    # waste accounting closes: used + padding == total, fraction matches
    used = plan.n_kv_pages * plan.payload_elems
    assert plan.total_elems == plan.n_kv_pages * plan.page_stride
    assert plan.layout.padding_elems == plan.total_elems - used
    assert plan.padding_fraction == pytest.approx(
        1.0 - used / plan.total_elems)
    assert plan.total_bytes == plan.n_arena_pages * page_bytes


@given_or_grid(
    "n_pages,seed,rounds",
    [(1, 0, 4), (7, 1, 20), (32, 2, 60), (5, 3, 12)],
    lambda: dict(n_pages=st.integers(1, 48), seed=st.integers(0, 2**16),
                 rounds=st.integers(1, 80)))
def test_kv_allocator_conservation(n_pages, seed, rounds):
    """Random alloc/free interleavings: pages are conserved (free +
    allocated == total), never double-issued, and all recyclable."""
    from repro.serve import KVPageAllocator

    rng = np.random.RandomState(seed)
    a = KVPageAllocator(n_pages)
    held = []
    for _ in range(rounds):
        if rng.rand() < 0.6 and a.n_free:
            n = int(rng.randint(1, a.n_free + 1))
            got = a.alloc(n)
            assert len(got) == n
            assert not set(got) & set(held)          # never double-issued
            assert all(0 <= p < n_pages for p in got)
            held += got
        elif held:
            n = int(rng.randint(1, len(held) + 1))
            rng.shuffle(held)
            back, held = held[:n], held[n:]
            a.free(back)
        assert a.n_free + a.n_allocated == a.n_total == n_pages
        assert a.n_allocated == len(held)
    if held:
        a.free(held)
    assert a.n_free == n_pages
    # over-allocation and double-free stay hard errors at every state
    with pytest.raises(MemoryError):
        a.alloc(n_pages + 1)
    got = a.alloc(1)
    with pytest.raises(ValueError):
        a.free(got + got)


# ---------------------------------------------------------------------------
# quantized wire: fused arena pack+quantize + error feedback (PR 7)
# ---------------------------------------------------------------------------


@given_or_grid(
    "n_blocks,block,mag,seed",
    [(1, 512, 1.0, 0), (3, 512, 1e-3, 1), (8, 1024, 1e3, 2),
     (2, 256, 37.0, 3)],
    lambda: dict(n_blocks=st.integers(1, 8),
                 block=st.sampled_from([256, 512, 1024]),
                 mag=st.floats(1e-3, 1e3), seed=st.integers(0, 2**16)))
def test_quant_arena_blockwise_error_bound(n_blocks, block, mag, seed):
    """pack -> unpack error is elementwise <= scale/2 with the scale of the
    element's *own* block (scale = max(blockwise absmax / 127, tiny))."""
    from repro.mem import QuantCommArena, plan_quant_arena

    n = n_blocks * block
    lay = plan_quant_arena([n], page_bytes=4096, block=block)
    arena = QuantCommArena(lay)
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(n).astype(np.float32) * mag)
    buf, _ = arena.pack([x])
    back = np.asarray(arena.unpack(buf)[0])
    absmax = np.abs(np.asarray(x)).reshape(n_blocks, block).max(1)
    scale = np.maximum(absmax / 127.0, np.finfo(np.float32).tiny)
    bound = np.repeat(scale / 2.0 * (1 + 1e-5), block)
    assert np.all(np.abs(back - np.asarray(x)) <= bound)


@given_or_grid(
    "n_blocks,mag,seed,steps",
    [(2, 1.0, 0, 1), (4, 1e2, 1, 3), (1, 1e-2, 2, 2), (6, 5.0, 3, 4)],
    lambda: dict(n_blocks=st.integers(1, 6), mag=st.floats(1e-2, 1e2),
                 seed=st.integers(0, 2**16), steps=st.integers(1, 4)))
def test_quant_arena_ef_residual_conservation(n_blocks, mag, seed, steps):
    """The error-feedback accumulator is *exactly* the unrepresented part:
    after every pack, ``new_ef == (x + old_ef) - decode(arena)`` bitwise
    (decode returns the very fp32 product the residual was computed from),
    so no gradient mass is silently dropped across steps."""
    from repro.mem import QuantCommArena, plan_quant_arena

    block = 512
    n = n_blocks * block
    lay = plan_quant_arena([n], page_bytes=4096, block=block)
    arena = QuantCommArena(lay)
    rng = np.random.RandomState(seed)
    buf, ef = arena.zeros(), arena.ef_zeros()
    for _ in range(steps):
        x = jnp.asarray(rng.randn(n).astype(np.float32) * mag)
        comp = np.asarray(x + ef[:n])           # what pack_into encodes
        buf, ef = arena.pack_into(buf, [x], ef)
        decoded = np.asarray(arena.unpack(buf)[0])
        np.testing.assert_array_equal(
            np.asarray(ef)[:n], (comp - decoded).astype(np.float32))
        # conservation: decoded + residual recovers the compensated
        # gradient to one rounding of the subtraction
        np.testing.assert_allclose(decoded + np.asarray(ef)[:n], comp,
                                   rtol=1e-6, atol=1e-6 * mag)


@given_or_grid(
    "n_leaves,base_blocks,spread,seed",
    [(2, 2, 1e6, 0), (3, 1, 1e4, 1), (4, 3, 1e2, 2), (2, 4, 1e8, 3)],
    lambda: dict(n_leaves=st.integers(2, 4), base_blocks=st.integers(1, 4),
                 spread=st.sampled_from([1e2, 1e4, 1e6]),
                 seed=st.integers(0, 2**16)))
def test_quant_arena_oversized_leaves_keep_own_scales(n_leaves, base_blocks,
                                                      spread, seed):
    """Oversized leaves (bigger than the bucket target) get dedicated
    block-aligned segments, so a huge-magnitude neighbour never inflates a
    tiny leaf's quantization scales: each leaf's error stays bounded by its
    *own* blockwise absmax."""
    from repro.core.bucketing import GradientBucketer
    from repro.mem import QuantCommArena, quant_arena_from_bucket_plan

    block = 512
    sizes = [(base_blocks + i) * block for i in range(n_leaves)]
    mags = [spread if i % 2 == 0 else 1.0 for i in range(n_leaves)]
    rng = np.random.RandomState(seed)
    tree = {f"p{i}": jnp.asarray(rng.randn(n).astype(np.float32) * m)
            for i, (n, m) in enumerate(zip(sizes, mags))}
    bucket_bytes = 2 * block                     # every leaf is oversized
    b = GradientBucketer(bucket_bytes=bucket_bytes, pad_multiple=block)
    buckets, plan = b.bucketize(tree)
    assert plan.n_buckets == n_leaves            # never split, never merged
    lay = quant_arena_from_bucket_plan(plan, page_bytes=4096, block=block,
                                       bucket_bytes=bucket_bytes,
                                       warn_oversized=False)
    # dedicated segments start on block boundaries: scale blocks disjoint
    assert all(seg.offset % block == 0 for seg in lay.segments)
    ranges = sorted((seg.offset, seg.offset + seg.padded)
                    for seg in lay.segments)
    assert all(a_end <= b_start
               for (_, a_end), (b_start, _) in zip(ranges, ranges[1:]))
    arena = QuantCommArena(lay)
    buf, _ = arena.pack(buckets)
    back = b.debucketize(arena.unpack(buf), plan)
    for i, k in enumerate(tree):
        x = np.asarray(tree[k])
        nb = -(-x.size // block)
        xb = np.pad(x, (0, nb * block - x.size)).reshape(nb, block)
        scale = np.maximum(np.abs(xb).max(1) / 127.0,
                           np.finfo(np.float32).tiny)
        bound = np.repeat(scale / 2.0 * (1 + 1e-5), block)[:x.size]
        assert np.all(np.abs(np.asarray(back[k]) - x) <= bound), k


# ---------------------------------------------------------------------------
# MoE capacity: a capacity_factor >= num_experts can never drop (even fully
# concentrated routing fits), and dropped_fraction is exact under overflow
# ---------------------------------------------------------------------------


@given_or_grid(
    "e,k,s,seed",
    [(4, 2, 16, 0), (8, 1, 32, 1), (2, 2, 8, 2), (16, 4, 24, 3)],
    lambda: dict(e=st.sampled_from([2, 4, 8, 16]),
                 k=st.sampled_from([1, 2, 4]),
                 s=st.integers(4, 48),
                 seed=st.integers(0, 2**16)))
def test_moe_sufficient_capacity_never_drops(e, k, s, seed):
    from repro.configs.base import MoEConfig
    from repro.models import moe as moe_mod

    k = min(k, e)
    rng = np.random.RandomState(seed)
    ids = jnp.asarray(rng.randint(0, e, size=(2, s, k)).astype(np.int32))
    # capacity_factor == num_experts -> cap >= s*k even if every token
    # routes to one expert
    cfg = MoEConfig(num_experts=e, top_k=k, expert_ff=8,
                    capacity_factor=float(e))
    cap = moe_mod.capacity(s, cfg)
    assert cap >= s * k
    assert float(moe_mod.dropped_fraction(ids, e, cap)) == 0.0
    # exactness under overflow: brute-force count vs the one-hot sum
    small_cap = max(1, (s * k) // (2 * e))
    want = 0
    for b in range(2):
        flat = np.asarray(ids[b]).reshape(-1)
        for ex in range(e):
            want += max(int((flat == ex).sum()) - small_cap, 0)
    got = float(moe_mod.dropped_fraction(ids, e, small_cap)) * (2 * s * k)
    assert got == pytest.approx(want)

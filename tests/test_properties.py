"""Hypothesis property tests over the system's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -e '.[dev]')")
from hypothesis import given, settings, strategies as st

from repro.core.bucketing import GradientBucketer
from repro.core.compression import Int8BlockCodec, IdentityCodec
from repro.core.halo import halo_bytes, HaloSpec
from repro.core.ring import RingConfig
from repro.core.topology import padded_size, ring_perm
from repro.optim.schedules import make_schedule

SHAPES = st.lists(
    st.tuples(st.integers(1, 4), st.integers(1, 64), st.integers(1, 8)),
    min_size=1, max_size=12)


@settings(max_examples=30, deadline=None)
@given(shapes=SHAPES, bucket_kb=st.sampled_from([1, 4, 64]),
       pad=st.sampled_from([128, 256, 512]))
def test_bucketize_roundtrip(shapes, bucket_kb, pad):
    """flatten -> buckets -> unflatten is the identity for any pytree."""
    rng = np.random.RandomState(42)
    tree = {f"p{i}": jnp.asarray(rng.randn(*s).astype(np.float32))
            for i, s in enumerate(shapes)}
    b = GradientBucketer(bucket_bytes=bucket_kb * 1024, pad_multiple=pad)
    buckets, plan = b.bucketize(tree)
    # every bucket is pad-aligned
    assert all(bk.shape[0] % b.pad_multiple == 0 for bk in buckets)
    back = b.debucketize(buckets, plan)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(tree[k]))
    # plan is cached: same structure returns the identical object
    assert b.plan(tree) is plan


@settings(max_examples=30, deadline=None)
@given(n_blocks=st.integers(1, 16), block=st.sampled_from([128, 256, 512]),
       scale=st.floats(1e-3, 1e3))
def test_int8_codec_error_bound(n_blocks, block, scale):
    """|decode(encode(x)) - x| <= blockwise absmax / 254 elementwise."""
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(n_blocks * block).astype(np.float32) * scale)
    codec = Int8BlockCodec(block=block)
    back = codec.decode(codec.encode(x))
    absmax = np.abs(np.asarray(x).reshape(n_blocks, block)).max(1)
    bound = np.repeat(absmax / 254.0 + 1e-7, block)
    assert np.all(np.abs(np.asarray(back) - np.asarray(x)) <= bound)


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 10_000), mult=st.sampled_from([1, 8, 128, 384]))
def test_padded_size(n, mult):
    p = padded_size(n, mult)
    assert p >= n and p % mult == 0 and p - n < mult


@settings(max_examples=20, deadline=None)
@given(size=st.integers(2, 64), direction=st.sampled_from([1, -1]))
def test_ring_perm_is_permutation(size, direction):
    perm = ring_perm(size, direction)
    srcs = [a for a, _ in perm]
    dsts = [b for _, b in perm]
    assert sorted(srcs) == list(range(size))
    assert sorted(dsts) == list(range(size))
    # a ring: applying size times returns home
    nxt = dict(perm)
    cur = 0
    for _ in range(size):
        cur = nxt[cur]
    assert cur == 0


@settings(max_examples=20, deadline=None)
@given(name=st.sampled_from(["constant", "linear", "cosine", "wsd"]),
       base=st.floats(1e-5, 1e-2), warmup=st.integers(1, 50))
def test_schedules_warmup_and_bounds(name, base, warmup):
    f = make_schedule(name, base_lr=base, warmup=warmup, total=200)
    lrs = np.array([float(f(jnp.asarray(s))) for s in range(0, 200, 10)])
    assert np.all(lrs >= 0) and np.all(lrs <= base * (1 + 1e-6))
    # warmup reaches (close to) base by the warmup step
    assert float(f(jnp.asarray(warmup))) >= 0.99 * float(f(jnp.asarray(warmup + 1))) * 0.5


@settings(max_examples=20, deadline=None)
@given(shape=st.tuples(st.integers(2, 32), st.integers(2, 32)),
       halo=st.integers(1, 2))
def test_halo_bytes_formula(shape, halo):
    specs = [HaloSpec("data", 0, halo)]
    b = halo_bytes(shape, specs, 4)
    assert b == 2 * halo * shape[1] * 4


_HALO_DIMS = st.integers(1, 3).flatmap(
    lambda nd: st.tuples(
        st.tuples(*[st.integers(2, 8) for _ in range(nd)]),
        st.tuples(*[st.integers(1, 2) for _ in range(nd)])))


@settings(max_examples=40, deadline=None)
@given(dims=_HALO_DIMS,
       schedule=st.sampled_from(["sequential", "concurrent", "chunked",
                                 "overlap"]),
       channels=st.integers(0, 4), chunks=st.integers(1, 4),
       extra=st.integers(1, 6))
def test_build_halo_schedule_invariants(dims, schedule, channels, chunks,
                                        extra):
    """Every direction's payload issues exactly once, channels stay in
    range, overlap_fraction in [0, 1], and chunking conserves bytes."""
    from repro.comm import build_halo_schedule
    from repro.core.halo import HaloSpec, halo_bytes

    shape, halos = dims
    shape = shape + (extra,)                      # one unsharded dim
    specs = [HaloSpec(f"ax{d}", d, h) for d, h in enumerate(halos)]
    s = build_halo_schedule(specs, shape, schedule=schedule,
                            channels=channels, chunks=chunks)
    seen = sorted(b for slot in s.slots for b in slot.bucket_ids)
    assert seen == list(range(s.n_buckets))
    assert all(slot.phase == 0 for slot in s.slots)
    limit = (1 if schedule == "sequential"
             else channels if (schedule == "overlap" and channels >= 1)
             else s.n_buckets)
    assert all(0 <= slot.channel < limit for slot in s.slots)
    assert 0.0 <= s.overlap_fraction <= 1.0
    assert (s.overlap_fraction > 0.0) == (
        schedule == "overlap"
        and all(n > 2 * sp.halo for n, sp in zip(shape, specs)))
    assert sum(s.bucket_sizes) == halo_bytes(shape, specs, 4)


@settings(max_examples=15, deadline=None)
@given(shape=st.tuples(st.integers(3, 6), st.integers(3, 6)),
       mass=st.floats(0.1, 2.0), seed=st.integers(0, 2**16),
       halo=st.integers(1, 2))
def test_cg_converges_to_linalg_solution(shape, mass, seed, halo):
    """CG on any SPD Wilson-like operator reaches the dense
    ``jnp.linalg.solve`` solution of the same periodic system."""
    from repro.stencil import StencilOp, cg_solve

    specs = tuple(HaloSpec(f"ax{d}", d, halo) for d in range(len(shape)))
    op = StencilOp(specs=specs, mass=mass)
    A = np.asarray(op.dense_matrix(shape))
    assert np.linalg.eigvalsh(A).min() > 0.0        # SPD by construction
    rng = np.random.RandomState(seed)
    b = jnp.asarray(rng.randn(*shape).astype(np.float32))
    res = cg_solve(op, b, None, tol=1e-7, maxiter=500,
                   matvec=op.apply_reference)
    xref = np.asarray(jnp.linalg.solve(jnp.asarray(A), b.reshape(-1)))
    assert float(res.rel_residual) < 1e-6
    assert np.abs(np.asarray(res.x).reshape(-1) - xref).max() < 1e-3


@settings(max_examples=20, deadline=None)
@given(chunks=st.integers(1, 4), bidi=st.booleans(),
       codec=st.sampled_from([None, "int8"]))
def test_ring_config_divisor_consistency(chunks, bidi, codec):
    cfg = RingConfig(chunks=chunks, bidirectional=bidi, codec=codec)
    d = cfg.channel_divisor
    assert d % chunks == 0
    if bidi:
        assert d % 2 == 0
    if codec == "int8":
        assert d % cfg.codec_block == 0
    assert cfg.flat_divisor([4, 2]) % (8 * d * d) == 0 or True  # composes
    assert cfg.flat_divisor([4]) == 4 * d

"""repro.serve — paged KV arena, flash-decode kernel, continuous batching.

Layers, bottom-up: arena plan arithmetic and the page allocator; the
flash-decode kernel against its op-for-op blockwise mirror (lockstep
tolerance) and its own determinism (bitwise); the split/combine LSE
identity; the paged engine against the contiguous ``decode_step`` oracle
(allclose); the lowered-HLO collective pins the dry-run asserts (0
collectives at R=1 in-process, ``2·n_layers`` at R=2 in a subprocess);
the gathered-serving decoder-only guard; and the continuous-vs-static
scheduler, both on a step-exact fake engine (throughput ratio ≥ 2×) and
end-to-end on the real one (identical logits under both policies).
"""

import numpy as np
import pytest

from conftest import run_distributed


def _jnp():
    import jax.numpy as jnp

    return jnp


# ---------------------------------------------------------------------------
# KV arena plan + allocator
# ---------------------------------------------------------------------------


def _plan(**kw):
    from repro.configs import reduced_config
    from repro.serve import plan_kv_arena

    cfg = reduced_config(kw.pop("arch", "llama3.2-1b"))
    kw.setdefault("page_bytes", 4096)
    return cfg, plan_kv_arena(cfg, **kw)


def test_kv_plan_arithmetic():
    import jax.numpy as jnp

    cfg, plan = _plan(page_tokens=8, max_seqs=4, max_seq_len=64)
    hkv, d = cfg.attn.num_kv_heads, cfg.attn.head_dim
    assert plan.payload_elems == 2 * hkv * 8 * d          # K and V halves
    assert plan.v_offset == hkv * 8 * d and plan.k_offset == 0
    assert plan.max_blocks == -(-64 // 8)
    assert plan.n_kv_pages == 4 * plan.max_blocks * plan.n_layers
    # equal payloads -> one stride; offsets are exactly id * stride
    assert plan.page_stride == plan.layout.segments[0].padded
    for pid in (0, 1, plan.n_kv_pages - 1):
        assert plan.page_offset(pid) == pid * plan.page_stride
    assert plan.total_elems == plan.n_kv_pages * plan.page_stride
    assert plan.total_bytes == plan.n_arena_pages * 4096
    assert 0.0 <= plan.padding_fraction < 1.0
    assert plan.zeros().shape == (plan.total_elems,)
    assert plan.zeros().dtype == jnp.bfloat16
    d_ = plan.describe()
    assert d_["n_kv_pages"] == plan.n_kv_pages
    assert d_["total_bytes"] == plan.total_bytes


def test_kv_plan_pads_blocks_to_model_axis():
    from types import SimpleNamespace

    from repro import compat

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    _, p1 = _plan(page_tokens=8, max_seqs=2, max_seq_len=24, mesh=mesh)
    assert p1.model_parallel == 1 and p1.max_blocks == 3
    # a 4-wide model axis forces max_blocks up to a multiple of 4 so every
    # rank owns the same static chunk of page-table columns (the plan only
    # reads the mesh's axis sizes, so a stand-in suffices here)
    fake = SimpleNamespace(axis_names=("data", "model"),
                           devices=np.zeros((1, 4)))
    _, p4 = _plan(page_tokens=8, max_seqs=2, max_seq_len=24, mesh=fake)
    assert p4.model_parallel == 4
    assert p4.max_blocks == 4 and p4.blocks_per_rank == 1


def test_kv_plan_rejects_non_pageable_archs():
    from repro.configs import reduced_config
    from repro.serve import plan_kv_arena

    for arch in ("falcon-mamba-7b", "whisper-base"):
        with pytest.raises(NotImplementedError):
            plan_kv_arena(reduced_config(arch), page_tokens=8)


def test_page_allocator_free_list():
    from repro.serve import KVPageAllocator

    a = KVPageAllocator(6)
    assert a.n_free == 6 and a.n_allocated == 0
    got = a.alloc(4)
    assert len(got) == 4 and len(set(got)) == 4
    assert a.n_free == 2
    with pytest.raises(MemoryError):
        a.alloc(3)
    a.free(got[:2])
    assert a.n_free == 4
    with pytest.raises(ValueError):      # double free
        a.free(got[:1] + got[:1])
    # LIFO recycling: the most recently freed page comes back first
    a2 = KVPageAllocator(3)
    p = a2.alloc(3)
    a2.free([p[1]])
    assert a2.alloc(1) == [p[1]]


# ---------------------------------------------------------------------------
# flash-decode kernel vs references
# ---------------------------------------------------------------------------


def _qkv(rng, b=2, hq=4, hkv=2, l=256, d=16, valid_p=0.7):
    jnp = _jnp()
    q = jnp.asarray(rng.randn(b, hq, 1, d).astype(np.float32))
    k = jnp.asarray(rng.randn(b, hkv, l, d).astype(np.float32))
    v = jnp.asarray(rng.randn(b, hkv, l, d).astype(np.float32))
    valid = jnp.asarray((rng.rand(b, l) < valid_p).astype(np.int32))
    return q, k, v, valid


def test_flash_decode_deterministic_bitwise(rng):
    """Same input → same bits, twice.  This is the determinism split-KV
    serving relies on (pages are rescored every step)."""
    from repro.kernels.flash_decode.flash_decode import flash_decode_stats_fwd

    q, k, v, valid = _qkv(rng)
    a = flash_decode_stats_fwd(q, k, v, valid, block_k=128, interpret=True)
    b = flash_decode_stats_fwd(q, k, v, valid, block_k=128, interpret=True)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_flash_decode_matches_blockwise_mirror(rng):
    """Kernel vs the op-for-op mirror: identical accumulation order, so
    only XLA-fusion reassociation (~1 ulp/op) separates them.  The bound
    here is ~100x tighter than any algorithmic drift would produce."""
    from repro.kernels.flash_decode import ref
    from repro.kernels.flash_decode.flash_decode import flash_decode_stats_fwd

    q, k, v, valid = _qkv(rng)
    jnp = _jnp()
    ke = jnp.repeat(k, 2, axis=1)
    ve = jnp.repeat(v, 2, axis=1)
    got = flash_decode_stats_fwd(q, k, v, valid, block_k=128, interpret=True)
    want = ref.decode_stats_blockwise(q, ke, ve, valid, block_k=128)
    for g, w, name in zip(got, want, ("acc", "m", "l")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5, err_msg=name)


def test_blockwise_mirror_matches_oracle(rng):
    from repro.kernels.flash_decode import ref

    q, k, v, valid = _qkv(rng, hq=2, hkv=2)
    bw = ref.decode_stats_blockwise(q, k, v, valid, block_k=64)
    one = ref.decode_stats(q, k, v, valid != 0)
    # combine() of each must give the same normalised output
    np.testing.assert_allclose(np.asarray(ref.combine([bw])),
                               np.asarray(ref.combine([one])),
                               rtol=1e-5, atol=1e-6)


def test_split_combine_is_the_full_softmax(rng):
    """The LSE identity: stats over KV splits + combine == one shot —
    through the kernel as well as the oracle."""
    from repro.kernels.flash_decode import flash_decode_stats, combine, ref

    q, k, v, valid = _qkv(rng, l=256)
    full = ref.decode_attention(q, _jnp().repeat(k, 2, 1),
                                _jnp().repeat(v, 2, 1), valid, splits=1)
    parts = []
    for i in range(4):
        sl = slice(i * 64, (i + 1) * 64)
        parts.append(flash_decode_stats(q, k[:, :, sl], v[:, :, sl],
                                        valid[:, sl], block_k=64,
                                        interpret=True))
    np.testing.assert_allclose(np.asarray(combine(parts)),
                               np.asarray(full), rtol=2e-5, atol=2e-6)
    # combine is order-invariant up to float reassociation
    np.testing.assert_allclose(np.asarray(combine(parts[::-1])),
                               np.asarray(combine(parts)),
                               rtol=2e-5, atol=2e-6)


def test_flash_decode_fallback_is_the_oracle(rng):
    """Non-tiling L routes to the one-shot oracle — bitwise, because it IS
    the oracle call."""
    from repro.kernels.flash_decode import flash_decode_stats, ref

    q, k, v, valid = _qkv(rng, l=100)          # 100 % 64 != 0 -> fallback
    jnp = _jnp()
    got = flash_decode_stats(q, k, v, valid, block_k=64)
    want = ref.decode_stats(q, jnp.repeat(k, 2, 1), jnp.repeat(v, 2, 1),
                            valid != 0)
    for g, w in zip(got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w))


def test_flash_decode_output_wrapper(rng):
    from repro.kernels.flash_decode import flash_decode, ref

    q, k, v, valid = _qkv(rng, l=128)
    jnp = _jnp()
    out = flash_decode(q, k, v, valid, interpret=True)
    want = ref.decode_attention(q, jnp.repeat(k, 2, 1), jnp.repeat(v, 2, 1),
                                valid, splits=1)
    assert out.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# paged engine vs the contiguous decode oracle
# ---------------------------------------------------------------------------


def _engine(attn_impl="ref", **plan_kw):
    import jax

    from repro import compat
    from repro.configs import reduced_config
    from repro.models import build_model
    from repro.serve import PagedDecodeEngine, plan_kv_arena

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    model = build_model(reduced_config("llama3.2-1b"))
    params = model.init(jax.random.PRNGKey(0))
    plan_kw.setdefault("page_tokens", 8)
    plan_kw.setdefault("page_bytes", 4096)
    plan_kw.setdefault("max_seqs", 4)
    plan_kw.setdefault("max_seq_len", 64)
    plan = plan_kv_arena(model.cfg, mesh, **plan_kw)
    eng = PagedDecodeEngine(model, mesh, plan, attn_impl=attn_impl,
                            interpret=True)
    return model, params, eng


@pytest.mark.parametrize("attn_impl", ["ref", "kernel"])
def test_paged_matches_contiguous_decode(rng, attn_impl):
    """The tentpole numeric claim: paged flash-decode == the model's own
    contiguous decode_step, token for token, across page boundaries."""
    import jax.numpy as jnp

    model, params, eng = _engine(attn_impl=attn_impl)
    b, steps = eng.plan.max_seqs, 10           # crosses the 8-token page
    state = model.init_decode_state(b, 32)
    for s in range(b):
        eng.admit(s)
    toks = rng.randint(0, model.cfg.vocab_size, (steps, b)).astype(np.int32)
    for t in range(steps):
        tok = jnp.asarray(toks[t])
        got = eng.decode(params, toks[t])
        want, state = model.decode_step(params, tok, state, t, seq_len=32)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=2e-2, atol=2e-3,
            err_msg=f"step {t} ({attn_impl})")


def test_engine_slot_lifecycle_and_page_recycling(rng):
    _, params, eng = _engine()
    total = eng.allocator.n_total
    eng.admit(0)
    eng.admit(2)
    assert eng.free_slots() == [1, 3]
    assert eng.allocator.n_allocated == 2 * eng.plan.n_layers
    with pytest.raises(ValueError):
        eng.admit(0)                            # already live
    for _ in range(9):                          # cross the 8-token page
        eng.decode(params, np.zeros((4,), np.int32))
    assert eng.allocator.n_allocated == 2 * 2 * eng.plan.n_layers
    eng.retire(0)
    eng.retire(2)
    assert eng.allocator.n_free == total        # every page came back
    assert not eng.slot_valid.any()
    # retired pages are immediately reusable by a new sequence
    eng.admit(1)
    assert eng.can_admit(16)


def test_decode_state_specs_replicate_paged_state():
    """The paged names must dodge the shape[0]==global_batch fallback —
    otherwise slot_len/page_table get scattered over data ranks."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.configs import reduced_config
    from repro.sharding import rules

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    cfg = reduced_config("llama3.2-1b")
    state = {
        "pages": jax.ShapeDtypeStruct((1024,), jnp.bfloat16),
        "page_table": jax.ShapeDtypeStruct((4, 8, 2), jnp.int32),
        "slot_len": jax.ShapeDtypeStruct((4,), jnp.int32),
        "slot_valid": jax.ShapeDtypeStruct((4,), jnp.bool_),
    }
    specs = rules.decode_state_specs(state, cfg, mesh, global_batch=4)
    assert all(specs[k] == P() for k in state)


# ---------------------------------------------------------------------------
# lowered HLO: the collective count the dry-run prices
# ---------------------------------------------------------------------------


def test_single_rank_step_lowers_to_zero_collectives():
    import jax

    from repro.launch.roofline import collective_wire_bytes
    from repro.serve.engine import (predicted_collectives_per_token,
                                    predicted_wire_bytes_per_token)

    model, _, eng = _engine()
    assert predicted_collectives_per_token(eng.plan) == 0
    assert predicted_wire_bytes_per_token(eng.plan, model.cfg, 4) == 0.0
    import jax.numpy as jnp

    args = (eng.pages, jax.tree.map(lambda s: s, model.abstract_params()),
            jnp.asarray(eng.table.table), jnp.zeros((4,), jnp.int32),
            jnp.asarray(eng.slot_len), jnp.asarray(eng.slot_valid))
    with eng.mesh:
        txt = eng.step.lower(*args).compile().as_text()
    stats = collective_wire_bytes(txt)
    assert stats.op_counts.get("all-reduce", 0) == 0
    assert sum(stats.op_counts.values()) == 0


SERVE_HLO_R2_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs import reduced_config
from repro.models import build_model
from repro.launch.roofline import collective_wire_bytes
from repro.serve import plan_kv_arena
from repro.serve.engine import (build_paged_decode_step,
                                predicted_collectives_per_token,
                                predicted_wire_bytes_per_token)

mesh = compat.make_mesh((1, 2), ("data", "model"))
model = build_model(reduced_config("llama3.2-1b"))
plan = plan_kv_arena(model.cfg, mesh, page_tokens=8, page_bytes=4096,
                     max_seqs=4, max_seq_len=64)
step, pspecs, _ = build_paged_decode_step(model, mesh, plan, attn_impl="ref")
args = (jax.ShapeDtypeStruct((plan.total_elems,), plan.layout.dtype),
        model.abstract_params(),
        jax.ShapeDtypeStruct((plan.max_seqs, plan.max_blocks, plan.n_layers),
                             jnp.int32),
        jax.ShapeDtypeStruct((plan.max_seqs,), jnp.int32),
        jax.ShapeDtypeStruct((plan.max_seqs,), jnp.int32),
        jax.ShapeDtypeStruct((plan.max_seqs,), jnp.bool_))
with mesh:
    txt = step.lower(*args).compile().as_text()
stats = collective_wire_bytes(txt)
n = stats.op_counts.get("all-reduce", 0)
want = predicted_collectives_per_token(plan)
assert want == 2 * plan.n_layers, want
assert n == want, (n, want)                       # zero tolerance
got_b = stats.op_bytes.get("all-reduce", 0.0)
want_b = predicted_wire_bytes_per_token(plan, model.cfg, plan.max_seqs)
assert got_b == want_b, (got_b, want_b)           # zero tolerance

# numeric equivalence R=2 vs R=1: same params, same tokens, same logits
mesh1 = compat.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
plan1 = plan_kv_arena(model.cfg, mesh1, page_tokens=8, page_bytes=4096,
                      max_seqs=4, max_seq_len=64)
from repro.serve import PagedDecodeEngine
params = model.init(jax.random.PRNGKey(0))
e2 = PagedDecodeEngine(model, mesh, plan, attn_impl="ref")
e1 = PagedDecodeEngine(model, mesh1, plan1, attn_impl="ref")
rng = np.random.RandomState(0)
for s in range(4):
    e1.admit(s); e2.admit(s)
for t in range(5):
    tok = rng.randint(0, model.cfg.vocab_size, (4,)).astype(np.int32)
    l1 = np.asarray(e1.decode(params, tok), np.float32)
    l2 = np.asarray(e2.decode(params, tok), np.float32)
    assert np.allclose(l1, l2, rtol=2e-2, atol=2e-3), np.abs(l1 - l2).max()
print("SERVE_HLO_R2_OK")
"""


def test_model_parallel_collective_count_and_equivalence():
    out = run_distributed(SERVE_HLO_R2_SCRIPT, n_devices=2)
    assert "SERVE_HLO_R2_OK" in out


# ---------------------------------------------------------------------------
# gathered serving guard (satellite: family check covered every family)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "hymba-1.5b",
                                  "whisper-base"])
def test_gathered_serving_rejects_non_decoder_only(arch):
    """ssm / hybrid / audio-frontend families must refuse gathered serving
    at BUILD time (the old check only caught encdec, only in prefill)."""
    from repro import compat
    from repro.configs import reduced_config
    from repro.models import build_model
    from repro.runtime.serve_step import build_decode_step, build_prefill
    from repro.configs.base import ShapeConfig

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    model = build_model(reduced_config(arch))
    shp = ShapeConfig("serve_test", 16, 2, "decode")
    with pytest.raises(NotImplementedError, match="decoder-only"):
        build_prefill(model, mesh, shp, weight_mode="gathered")
    with pytest.raises(NotImplementedError, match="decoder-only"):
        build_decode_step(model, mesh, shp, weight_mode="gathered")


def test_gathered_serving_still_builds_for_decoder_only():
    from repro import compat
    from repro.configs import reduced_config
    from repro.models import build_model
    from repro.runtime.serve_step import build_decode_step
    from repro.configs.base import ShapeConfig

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    model = build_model(reduced_config("llama3.2-1b"))
    shp = ShapeConfig("serve_test", 16, 2, "decode")
    step, pspecs, sspecs = build_decode_step(model, mesh, shp,
                                             weight_mode="gathered")
    assert "groups" in pspecs


# ---------------------------------------------------------------------------
# scheduler: continuous vs static batching
# ---------------------------------------------------------------------------


class _FakeEngine:
    """Step-exact stand-in: same slot/page accounting as the real engine,
    no device work.  Lets the ≥2× throughput claim be asserted in
    milliseconds; bench_serve measures it on the real engine."""

    class _Cfg:
        vocab_size = 512

    class _Model:
        cfg = None

    def __init__(self, max_seqs=4, page_tokens=8, max_seq_len=96,
                 n_layers=2):
        from repro.serve import KVPageAllocator

        class Plan:
            pass

        self.plan = Plan()
        self.plan.max_seqs = max_seqs
        self.plan.page_tokens = page_tokens
        self.plan.n_layers = n_layers
        self.model = self._Model()
        self.model.cfg = self._Cfg()
        n_blocks = -(-max_seq_len // page_tokens)
        self.allocator = KVPageAllocator(max_seqs * n_blocks * n_layers)
        self.slot_valid = np.zeros((max_seqs,), bool)
        self.slot_len = np.zeros((max_seqs,), np.int32)
        self._pages = {}

    def free_slots(self):
        return [i for i in range(self.plan.max_seqs)
                if not self.slot_valid[i]]

    def pages_for(self, n_tokens):
        return -(-n_tokens // self.plan.page_tokens) * self.plan.n_layers

    def can_admit(self, n_tokens):
        return (bool(self.free_slots())
                and self.allocator.n_free >= self.pages_for(n_tokens))

    def admit(self, slot):
        self.slot_valid[slot] = True
        self.slot_len[slot] = 0
        self._pages[slot] = self.allocator.alloc(self.plan.n_layers)

    def retire(self, slot):
        self.allocator.free(self._pages.pop(slot))
        self.slot_valid[slot] = False
        self.slot_len[slot] = 0

    def decode(self, params, token):
        for s in np.nonzero(self.slot_valid)[0]:
            if self.slot_len[s] % self.plan.page_tokens == 0 \
                    and self.slot_len[s] > 0:
                self._pages[int(s)] += self.allocator.alloc(
                    self.plan.n_layers)
        self.slot_len[self.slot_valid] += 1
        return np.zeros((self.plan.max_seqs, 512), np.float32)


def test_continuous_batching_beats_static_2x():
    """The acceptance ratio on the mixed-length trace: shorts turn their
    slots around while longs keep decoding, so continuous ≥ 2× static."""
    from repro.serve import ServeScheduler, mixed_trace

    reqs = mixed_trace(groups=4, slots=4, long_len=64, short_len=4)
    res = {}
    for policy in ("continuous", "static"):
        sched = ServeScheduler(_FakeEngine(), policy=policy)
        res[policy] = sched.run(None, reqs)
    assert res["continuous"]["generated_tokens"] == \
        res["static"]["generated_tokens"] == sum(r.decode_len for r in reqs)
    ratio = (res["continuous"]["tokens_per_step"]
             / res["static"]["tokens_per_step"])
    assert ratio >= 2.0, res
    # static pays exactly groups * the long request's step count
    assert res["static"]["steps"] == 4 * 64
    assert res["continuous"]["mean_live_slots"] > \
        res["static"]["mean_live_slots"]


def test_scheduler_rejects_bad_policy_and_stalls():
    from repro.serve import Request, ServeScheduler

    with pytest.raises(ValueError, match="policy"):
        ServeScheduler(_FakeEngine(), policy="dynamic")
    with pytest.raises(ValueError):
        Request(0, prompt_len=0, decode_len=4)
    # a request that can never fit must raise, not spin — and the guard
    # trip must be visible on the bus as a serve_stall counter
    from repro.obs import ObsConfig, make_obs

    obs = make_obs(ObsConfig(run_dir=None))
    sched = ServeScheduler(_FakeEngine(max_seqs=2, max_seq_len=16), obs=obs)
    with pytest.raises(RuntimeError, match="stalled"):
        sched.run(None, [Request(0, 1, 1000)])
    assert obs.bus.counter_total("serve_stall") == 1
    assert obs.bus.counter_value("serve_stall",
                                 reason="arena_too_small") == 1

    # the max_steps guard trips the same counter under its own label
    obs2 = make_obs(ObsConfig(run_dir=None))
    sched2 = ServeScheduler(_FakeEngine(), obs=obs2)
    with pytest.raises(RuntimeError, match="max_steps"):
        sched2.run(None, [Request(0, 1, 64)], max_steps=3)
    assert obs2.bus.counter_value("serve_stall", reason="max_steps") == 1


def test_scheduler_policies_agree_on_the_real_engine(rng):
    """End-to-end with the real jitted step: both policies finish the
    trace, recycle every page, and never recompile mid-run."""
    from repro.serve import ServeScheduler, mixed_trace

    reqs = mixed_trace(groups=2, slots=3, long_len=10, short_len=3)
    for policy in ("continuous", "static"):
        _, params, eng = _engine(max_seqs=3, max_seq_len=16)
        out = ServeScheduler(eng, policy=policy).run(params, reqs)
        assert out["generated_tokens"] == sum(r.decode_len for r in reqs)
        assert eng.allocator.n_free == eng.allocator.n_total
        assert not eng.slot_valid.any()

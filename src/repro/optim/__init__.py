from repro.optim.adamw import (OptimConfig, adamw_flat_update, adamw_tree_update,
                               global_grad_norm, init_opt_state,
                               init_opt_state_flat)
from repro.optim.schedules import make_schedule

__all__ = ["OptimConfig", "adamw_flat_update", "adamw_tree_update",
           "global_grad_norm", "init_opt_state", "init_opt_state_flat",
           "make_schedule"]

"""AdamW for tree-form and flat-bucket-shard (ZeRO) states.

Two entry points used by the train-step builders:

* ``adamw_tree_update``   — classic replicated-DP update over param pytrees.
* ``adamw_flat_update``   — operates on 1-D bucket *shards* (the reducer's
  reduce-scatter output); returns the parameter *delta* so ZeRO modes can
  all-gather the delta and apply it to full params (decoupled weight decay
  is applied outside on the params directly).

Global-norm clipping must know which leaves are TP-sharded (their sum-sq is
psum'd over the model axis; replicated leaves are counted once) — pass the
param PartitionSpecs.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.sharding.rules import MODEL_AXIS


@dataclass(frozen=True)
class OptimConfig:
    base_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "cosine"          # constant | linear | cosine | wsd
    warmup: int = 100
    total_steps: int = 1000


def init_opt_state(params):
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)
    return {"mu": zeros(params), "nu": zeros(params)}


def init_opt_state_flat(shards: list):
    return {"mu": [jnp.zeros_like(s, dtype=jnp.float32) for s in shards],
            "nu": [jnp.zeros_like(s, dtype=jnp.float32) for s in shards]}


def global_grad_norm(grads, specs, ctx):
    """Global L2 norm with model-axis-aware accounting."""
    flat_g, _ = jax.tree_util.tree_flatten(grads)
    flat_s, _ = jax.tree_util.tree_flatten(specs,
                                           is_leaf=lambda x: isinstance(
                                               x, jax.sharding.PartitionSpec))
    sharded_sq = jnp.zeros((), jnp.float32)
    local_sq = jnp.zeros((), jnp.float32)
    for g, s in zip(flat_g, flat_s):
        ss = jnp.sum(jnp.square(g.astype(jnp.float32)))
        if any(MODEL_AXIS in (ax if isinstance(ax, tuple) else (ax,))
               for ax in s if ax is not None):
            sharded_sq = sharded_sq + ss
        else:
            local_sq = local_sq + ss
    return jnp.sqrt(ctx.psum(sharded_sq) + local_sq)


def clip_factor(gnorm, max_norm: float):
    return jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))


def _adamw_moments(g, mu, nu, step, cfg: OptimConfig):
    g = g.astype(jnp.float32)
    mu = cfg.b1 * mu + (1 - cfg.b1) * g
    nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
    t = step.astype(jnp.float32) + 1.0
    mu_hat = mu / (1 - cfg.b1 ** t)
    nu_hat = nu / (1 - cfg.b2 ** t)
    upd = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
    return upd, mu, nu


def adamw_tree_update(params, grads, opt_state, step, lr, cfg: OptimConfig):
    """Replicated update: params' = (1 - lr*wd) * params - lr * adam(grads)."""
    lp, treedef = jax.tree_util.tree_flatten(params)
    lg = treedef.flatten_up_to(grads)
    lmu = treedef.flatten_up_to(opt_state["mu"])
    lnu = treedef.flatten_up_to(opt_state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(lp, lg, lmu, lnu):
        upd, mu2, nu2 = _adamw_moments(g, mu, nu, step, cfg)
        p2 = p.astype(jnp.float32) * (1 - lr * cfg.weight_decay) - lr * upd
        new_p.append(p2.astype(p.dtype))
        new_mu.append(mu2)
        new_nu.append(nu2)
    unf = treedef.unflatten
    return unf(new_p), {"mu": unf(new_mu), "nu": unf(new_nu)}


def adamw_flat_update(grad_shards: list, opt_state: dict, step, lr,
                      cfg: OptimConfig):
    """ZeRO update on flat bucket shards.  Returns (deltas, new_opt_state);
    delta = -lr * adam_update (weight decay applied to params outside)."""
    deltas, mus, nus = [], [], []
    for g, mu, nu in zip(grad_shards, opt_state["mu"], opt_state["nu"]):
        upd, mu2, nu2 = _adamw_moments(g, mu, nu, step, cfg)
        deltas.append(-lr * upd)
        mus.append(mu2)
        nus.append(nu2)
    return deltas, {"mu": mus, "nu": nus}

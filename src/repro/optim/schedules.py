"""LR schedules: warmup-cosine (default), WSD (minicpm), constant, linear."""

from __future__ import annotations

import jax.numpy as jnp


def make_schedule(name: str, *, base_lr: float, warmup: int = 100,
                  total: int = 1000, stable_frac: float = 0.8,
                  min_frac: float = 0.1):
    """Returns ``fn(step) -> lr`` (jnp-traceable)."""
    w = max(warmup, 1)

    def warm(step):
        return jnp.minimum(step / w, 1.0)

    if name == "constant":
        return lambda step: base_lr * warm(step)

    if name == "linear":
        def lin(step):
            t = jnp.clip((step - w) / max(total - w, 1), 0.0, 1.0)
            return base_lr * warm(step) * (1 - (1 - min_frac) * t)
        return lin

    if name == "cosine":
        def cos(step):
            t = jnp.clip((step - w) / max(total - w, 1), 0.0, 1.0)
            return base_lr * warm(step) * (min_frac + (1 - min_frac) * 0.5 *
                                           (1 + jnp.cos(jnp.pi * t)))
        return cos

    if name == "wsd":
        # Warmup -> Stable (constant) -> Decay (1-sqrt, per minicpm)
        stable_end = w + int((total - w) * stable_frac)

        def wsd(step):
            decay_t = jnp.clip((step - stable_end) / max(total - stable_end, 1),
                               0.0, 1.0)
            decay = 1.0 - (1.0 - min_frac) * jnp.sqrt(decay_t)
            return base_lr * warm(step) * jnp.where(step < stable_end, 1.0, decay)
        return wsd

    raise ValueError(f"unknown schedule {name!r}")

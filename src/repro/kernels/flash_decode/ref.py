"""Pure-jnp oracles for split-KV decode attention.

Decode attention factors into **partial softmax statistics** over any
partition of the key positions::

    stats(q, K, V)     = (acc, m, l)       # unnormalised numerator, running
                                           # max, denominator
    out                = combine(parts) = Σ acc_i·e^{m_i−m} / Σ l_i·e^{m_i−m}

so splitting KV over pages, devices or both and merging with a
log-sum-exp combine is *exactly* the full softmax — the invariance the
flash-decode kernel, the paged engine's cross-rank reduction and the
dry-run's collective-count prediction all rest on.

Two oracles: :func:`decode_stats` is the one-shot reference;
:func:`decode_stats_blockwise` mirrors the Pallas kernel's online-softmax
loop op-for-op (same primitives, same accumulation order), which pins the
kernel's *algorithm*: agreement is tied to the shared reduction order, so
any drift in tiling or update maths shows up far above the ~1-ulp noise
floor XLA fusion is allowed to introduce.  The kernel's *numerics* are
pinned separately as run-to-run **bitwise** determinism (same input → same
bits), which is what split-KV serving actually relies on.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_stats(q: jax.Array, k: jax.Array, v: jax.Array,
                 valid: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Partial attention statistics over one KV shard.

    q: (B, H, 1, D); k/v: (B, H, L, D); valid: (B, L) bool.
    Returns fp32 ``(acc (B,H,1,D), m (B,H,1,1), l (B,H,1,1))``.
    """
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk",
                   q.astype(jnp.float32) / math.sqrt(d),
                   k.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    acc = jnp.einsum("bhqk,bhkd->bhqd", e, v.astype(jnp.float32))
    l = jnp.sum(e, axis=-1, keepdims=True)
    return acc, m, l


def decode_stats_blockwise(q: jax.Array, k: jax.Array, v: jax.Array,
                           valid: jax.Array, *, block_k: int = 128
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Online-softmax mirror of the Pallas kernel.

    Same tiling (``block_k``), same primitive ops in the same order as
    ``flash_decode._decode_kernel``; L must tile by ``block_k``.  Matches
    the kernel to reordering-free float error (~1 ulp per op — XLA fuses
    the two call sites differently, so exact bitwise equality across the
    two execution paths is not defined; run-to-run determinism of each
    path individually is).
    """
    b, h, _, d = q.shape
    sk = k.shape[2]
    if sk % block_k:
        raise ValueError(f"L={sk} must tile by block_k={block_k}")
    scale = 1.0 / (d ** 0.5)
    m = jnp.full((b, h, 1, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((b, h, 1, 1), jnp.float32)
    acc = jnp.zeros((b, h, 1, d), jnp.float32)
    qf = q.astype(jnp.float32)
    for j in range(sk // block_k):
        k0 = j * block_k
        kj = k[:, :, k0:k0 + block_k].astype(jnp.float32)
        vj = v[:, :, k0:k0 + block_k].astype(jnp.float32)
        s = jax.lax.dot_general(
            qf, kj, (((3,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32) * scale      # (B,H,1,bk)
        ok = valid[:, None, None, k0:k0 + block_k] != 0
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p, vj, (((3,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)
        m = m_new
    return acc, m, l


def combine(parts) -> jax.Array:
    """Merge split-KV partial stats into the normalised output.

    ``parts``: sequence of ``(acc, m, l)``.  Algebraically identical to the
    full softmax over the concatenated key positions.
    """
    m = parts[0][1]
    for _, mi, _ in parts[1:]:
        m = jnp.maximum(m, mi)
    num = jnp.zeros_like(parts[0][0])
    den = jnp.zeros_like(parts[0][2])
    for acc, mi, li in parts:
        w = jnp.exp(mi - m)
        num = num + acc * w
        den = den + li * w
    return num / jnp.maximum(den, 1e-30)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid: jax.Array, *, splits: int = 1) -> jax.Array:
    """Full decode attention via ``splits`` KV shards + LSE combine —
    the end-to-end oracle the paged engine is checked against."""
    sk = k.shape[2]
    if sk % splits:
        raise ValueError(f"L={sk} must tile by splits={splits}")
    c = sk // splits
    parts = [decode_stats(q, k[:, :, i * c:(i + 1) * c],
                          v[:, :, i * c:(i + 1) * c],
                          valid[:, i * c:(i + 1) * c])
             for i in range(splits)]
    return combine(parts).astype(q.dtype)

"""Split-KV decode attention statistics — Pallas TPU kernel.

Decode is the α-bound regime: one query token against a long KV history.
The kernel tiles the key positions (grid ``(batch, q_heads, k_blocks)``,
trailing dim sequential) and emits **unnormalised** partial statistics
``(acc, m, l)`` instead of the finished output, so callers can merge
shards — per-device KV pages, per-page splits — with a log-sum-exp
combine (:func:`repro.kernels.flash_decode.ref.combine`).  That combine is
what the paged engine turns into a single fused ``Communicator.all_reduce``
across the model axis.

GQA is folded into the index maps (q head ``h`` reads kv head
``h // group``), same as the prefill flash kernel.  A ``valid`` mask (not
causality) gates key positions: paged KV holds many sequences at different
lengths in one fixed-shape buffer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, acc_o, m_o, l_o,
                   m_s, l_s, acc_s, *, scale: float):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # Op-for-op the loop body of ref.decode_stats_blockwise — keep the two
    # implementations in lockstep; the lockstep test depends on it.
    q = q_ref[0, 0].astype(jnp.float32)              # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    ok = valid_ref[...] != 0                         # (1, bk)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_s[...]                                # (1, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_s[...] = alpha * l_s[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        acc_o[0, 0] = acc_s[...]
        m_o[0, 0] = m_s[...]
        l_o[0, 0] = l_s[...]


def flash_decode_stats_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                           valid: jax.Array, *, block_k: int = 128,
                           interpret: bool = False
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """q: (B, Hq, 1, D); k/v: (B, Hkv, L, D); valid: (B, L) int/bool.

    Returns fp32 ``(acc (B,Hq,1,D), m (B,Hq,1,1), l (B,Hq,1,1))`` — the
    partial softmax statistics of this KV shard.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    if sq != 1:
        raise ValueError(f"decode kernel takes a single query token, got S={sq}")
    if hq % hkv != 0:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    group = hq // hkv
    bk = min(block_k, sk)
    if sk % bk:
        raise ValueError(f"L={sk} must tile by block_k={bk}")
    scale = 1.0 / (d ** 0.5)
    grid = (b, hq, sk // bk)
    valid = valid.astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, j, g=group: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, j, g=group: (b_, h // g, j, 0)),
            pl.BlockSpec((1, bk), lambda b_, h, j: (b_, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b_, h, j: (b_, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, 1, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, 1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),    # running max m
            pltpu.VMEM((1, 1), jnp.float32),    # running denom l
            pltpu.VMEM((1, d), jnp.float32),    # output accumulator
        ],
        compiler_params=getattr(pltpu, "CompilerParams",
                                getattr(pltpu, "TPUCompilerParams", None))(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, valid)

"""Jit'd wrappers for split-KV decode attention with oracle fallback.

``flash_decode_stats`` is the building block the paged engine consumes:
partial softmax statistics over one KV shard, mergeable across shards or
ranks with :func:`ref.combine`.  ``flash_decode`` closes the loop locally
(single shard → normalised output).  Shapes that do not tile by the key
block fall back to the one-shot oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.flash_decode import ref
from repro.kernels.flash_decode.flash_decode import flash_decode_stats_fwd


def _expand_gqa(q, k, v):
    group = q.shape[1] // k.shape[1]
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    return k, v


def flash_decode_stats(q: jax.Array, k: jax.Array, v: jax.Array,
                       valid: jax.Array, *, block_k: int = 128,
                       interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Partial stats (acc, m, l) for q (B,Hq,1,D) over kv (B,Hkv,L,D)."""
    hq, d = q.shape[1], q.shape[3]
    hkv, sk = k.shape[1], k.shape[2]
    bk = min(block_k, sk)
    if sk % bk or d % 8 or hq % hkv:
        ke, ve = _expand_gqa(q, k, v)
        return ref.decode_stats(q, ke, ve, valid != 0)
    interpret = default_interpret() if interpret is None else interpret
    return flash_decode_stats_fwd(q, k, v, valid, block_k=bk,
                                  interpret=interpret)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 valid: jax.Array, *, block_k: int = 128,
                 interpret: bool | None = None) -> jax.Array:
    """Single-shard decode attention output (B, Hq, 1, D)."""
    stats = flash_decode_stats(q, k, v, valid, block_k=block_k,
                               interpret=interpret)
    return ref.combine([stats]).astype(q.dtype)

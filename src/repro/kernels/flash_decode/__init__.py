from repro.kernels.flash_decode.ops import flash_decode, flash_decode_stats
from repro.kernels.flash_decode.ref import combine

__all__ = ["flash_decode", "flash_decode_stats", "combine"]

"""Pure-jnp oracle for the reduce_add kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def add_accum(a: jax.Array, b: jax.Array, *, accum_dtype=jnp.float32,
              out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or accum_dtype
    return (a.astype(accum_dtype) + b.astype(accum_dtype)).astype(out_dtype)

from repro.kernels.reduce_add.ops import add_accum

__all__ = ["add_accum"]

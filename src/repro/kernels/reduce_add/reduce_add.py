"""Fused ring-step accumulate: ``out = cast(a) + cast(b)`` in fp32.

The paper's T4 bottleneck: once the wire runs near line rate, the local
``dst[i] += src[i]`` loop of the ring algorithm dominates unless it is
parallelised.  On TPU the analogue is a VPU kernel that streams both
operands HBM->VMEM in lane-aligned (rows, 128) tiles, upconverts the narrow
wire dtype in-register, and writes the fp32 (or requantised) sum back —
one pass, no intermediate buffers.

Flat buffers arrive padded to 128 lanes by the bucketer, so the kernel only
handles exact tilings (guaranteed, never probabilistic — the paper's ethos).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
DEFAULT_BLOCK_ROWS = 512  # (512, 128) fp32 tile = 256 KiB/operand in VMEM


def _kernel(a_ref, b_ref, o_ref, *, accum_dtype):
    a = a_ref[...].astype(accum_dtype)
    b = b_ref[...].astype(accum_dtype)
    o_ref[...] = (a + b).astype(o_ref.dtype)


def add_accum_2d(a: jax.Array, b: jax.Array, *, accum_dtype=jnp.float32,
                 out_dtype=None, block_rows: int = DEFAULT_BLOCK_ROWS,
                 interpret: bool = False) -> jax.Array:
    """``a``, ``b``: (rows, 128)-shaped views of the flat payload."""
    rows, lanes = a.shape
    if lanes != LANES:
        raise ValueError(f"expected lane dim {LANES}, got {lanes}")
    out_dtype = out_dtype or accum_dtype
    br = min(block_rows, rows)
    if rows % br != 0:
        # rows is a multiple of 8 by construction; fall back to one tile
        br = rows
    grid = (rows // br,)

    import functools
    return pl.pallas_call(
        functools.partial(_kernel, accum_dtype=accum_dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((br, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((br, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), out_dtype),
        interpret=interpret,
    )(a, b)

"""Jit'd wrapper: flat-payload accumulate with automatic tiling/fallback.

``add_accum`` is the ``local_op='pallas'`` hook of ``core.ring``: it accepts
the ring hop's 1-D payloads, views them as (rows, 128) tiles, and runs the
Pallas kernel (interpret mode off-TPU).  Shapes not meeting the lane
alignment fall back to the jnp oracle — correctness is never conditional on
the fast path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.reduce_add import ref
from repro.kernels.reduce_add.reduce_add import LANES, add_accum_2d


def add_accum(a: jax.Array, b: jax.Array, *, accum_dtype=jnp.float32,
              out_dtype=None, interpret: bool | None = None) -> jax.Array:
    out_dtype = out_dtype or accum_dtype
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch {a.shape} vs {b.shape}")
    if a.ndim != 1 or a.shape[0] % (8 * LANES) != 0:
        return ref.add_accum(a, b, accum_dtype=accum_dtype, out_dtype=out_dtype)
    interpret = default_interpret() if interpret is None else interpret
    rows = a.shape[0] // LANES
    out = add_accum_2d(a.reshape(rows, LANES), b.reshape(rows, LANES),
                       accum_dtype=accum_dtype, out_dtype=out_dtype,
                       interpret=interpret)
    return out.reshape(-1)

"""Blockwise (flash) causal attention forward — Pallas TPU kernel.

Serving-prefill hot-spot: materialising a 32k x 32k score matrix is
HBM-roofline suicide; the blockwise online-softmax form keeps a (bq, bk)
tile resident in VMEM and accumulates rescaled partial outputs.  MXU-aligned
tiles (bq, bk multiples of 128; head_dim lanes) with fp32 accumulators.

Grid: (batch, q_heads, q_blocks, k_blocks); the trailing k dimension is
sequential ('arbitrary') so the m/l/acc scratch carries across k steps.
GQA is handled in the index maps (q head h reads kv head ``h // group``).
Supports causal masking and an optional sliding window (SWA archs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, block_q: int, block_k: int, causal: bool,
                 window: int | None):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Skip fully-masked tiles: strictly-future keys (causal) or beyond window.
    run = jnp.asarray(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                          # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) with Hq % Hkv == 0."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    if hq % hkv != 0:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    group = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    if sq % bq or sk % bk:
        raise ValueError(f"seq lens ({sq},{sk}) must tile by ({bq},{bk})")
    scale = 1.0 / (d ** 0.5)
    grid = (b, hq, sq // bq, sk // bk)

    kernel = functools.partial(_attn_kernel, scale=scale, block_q=bq,
                               block_k=bk, causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom l
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        compiler_params=getattr(pltpu, "CompilerParams",
                                getattr(pltpu, "TPUCompilerParams", None))(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)

"""Jit'd wrapper for flash attention with oracle fallback.

Used by the serving prefill path; training uses the differentiable
blockwise-jnp implementation in ``models.attention`` (same math, has a VJP).
Sequences that do not tile by the block size fall back to the oracle.
"""

from __future__ import annotations

import jax

from repro.kernels import default_interpret
from repro.kernels.flash_attn import ref
from repro.kernels.flash_attn.flash_attn import flash_attention_fwd


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    sq, sk = q.shape[2], k.shape[2]
    bq, bk = min(block_q, sq), min(block_k, sk)
    if sq % bq or sk % bk or q.shape[3] % 8:
        return ref.attention(q, k, v, causal=causal, window=window)
    interpret = default_interpret() if interpret is None else interpret
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=interpret)

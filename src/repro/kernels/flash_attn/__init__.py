from repro.kernels.flash_attn.ops import flash_attention

__all__ = ["flash_attention"]

"""Pure-jnp oracle: exact (non-blockwise) masked attention with GQA."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)   # align ends (prefill/decode)
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)

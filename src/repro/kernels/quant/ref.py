"""Pure-jnp oracle for the quant kernels (mirrors comm.wire_codec)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_blocks(x: jax.Array):
    x = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_blocks(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale

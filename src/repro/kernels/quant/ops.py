"""Jit'd wrappers for the int8 block codec kernels.

``quantize``/``dequantize`` take flat payloads + a block size, reshape to
(n_blocks, block), and dispatch to Pallas (interpret off-TPU) or the jnp
oracle when the layout is not tileable.  The fallback is *bitwise* the
kernel's arithmetic (same ops in the same order), so misaligned shapes —
block not a multiple of 128 lanes, or a block count with no (32, 128)
int8-legal tile — are a performance cliff, never a numerics change
(``kernels.pack``'s fallback-is-the-oracle contract).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.quant import ref
from repro.kernels.quant.quant import (dequantize_blocks, quantize_blocks,
                                       rows_per_tile)

LANES = 128


def _tileable(n_blocks: int, block: int) -> bool:
    return block % LANES == 0 and rows_per_tile(n_blocks) > 0


def quantize(x: jax.Array, block: int = 512, *, interpret: bool | None = None):
    """Flat fp32 (n,) -> (int8 (n,), fp32 scales (n/block,))."""
    n = x.shape[0]
    if n % block != 0:
        raise ValueError(f"size {n} not divisible by block {block}")
    xb = x.reshape(-1, block)
    if not _tileable(n // block, block):
        q, s = ref.quantize_blocks(xb)
    else:
        interpret = default_interpret() if interpret is None else interpret
        q, s = quantize_blocks(xb, interpret=interpret)
    return q.reshape(-1), s.reshape(-1)


def dequantize(q: jax.Array, scales: jax.Array, block: int = 512, *,
               interpret: bool | None = None) -> jax.Array:
    n = q.shape[0]
    if n % block != 0:
        raise ValueError(f"size {n} not divisible by block {block}")
    qb = q.reshape(-1, block)
    sb = scales.reshape(-1, 1)
    if not _tileable(n // block, block):
        out = ref.dequantize_blocks(qb, sb)
    else:
        interpret = default_interpret() if interpret is None else interpret
        out = dequantize_blocks(qb, sb, interpret=interpret)
    return out.reshape(-1)

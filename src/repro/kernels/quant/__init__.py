from repro.kernels.quant.ops import dequantize, quantize

__all__ = ["quantize", "dequantize"]

"""Block-absmax int8 quantise / dequantise Pallas kernels.

Wire codec hot-spot: every ring hop under ``codec='int8'`` encodes the
running partial sum and decodes the received payload.  The kernels fuse the
absmax reduction, scale computation, rounding and cast in one VMEM pass.

Layout: the flat payload is viewed as (n_blocks, block) with ``block`` a
multiple of 128 lanes; one grid step processes ``rows_per_tile`` quant
blocks.  Scales are fp32, one per block (row).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROWS_PER_TILE = 256
SUBLANES_I8 = 32           # int8 min tile is (32, 128)


def rows_per_tile(n_blocks: int,
                  max_rows: int = DEFAULT_ROWS_PER_TILE) -> int:
    """Largest tile height that divides ``n_blocks`` and meets the int8
    (32, 128) min tile; 0 when none exists (caller falls back to the jnp
    oracle in ``ref.py`` — same contract as ``kernels.pack._block_rows``)."""
    rpt = math.gcd(n_blocks, max_rows)
    return rpt if rpt % SUBLANES_I8 == 0 else 0


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = q * s_ref[...]


def quantize_blocks(x: jax.Array, *, max_rows: int = DEFAULT_ROWS_PER_TILE,
                    interpret: bool = False):
    """``x``: (n_blocks, block) fp32 -> (int8 q of same shape, fp32 (n_blocks, 1))."""
    n_blocks, block = x.shape
    rpt = rows_per_tile(n_blocks, max_rows)
    if rpt <= 0:
        raise ValueError(f"no (32, 128)-aligned tiling for {n_blocks} quant "
                         f"blocks; use the ops.py fallback")
    grid = (n_blocks // rpt,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rpt, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rpt, block), lambda i: (i, 0)),
                   pl.BlockSpec((rpt, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_blocks, block), jnp.int8),
                   jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32)],
        interpret=interpret,
    )(x)


def dequantize_blocks(q: jax.Array, scale: jax.Array, *,
                      max_rows: int = DEFAULT_ROWS_PER_TILE,
                      interpret: bool = False) -> jax.Array:
    n_blocks, block = q.shape
    rpt = rows_per_tile(n_blocks, max_rows)
    if rpt <= 0:
        raise ValueError(f"no (32, 128)-aligned tiling for {n_blocks} quant "
                         f"blocks; use the ops.py fallback")
    grid = (n_blocks // rpt,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rpt, block), lambda i: (i, 0)),
                  pl.BlockSpec((rpt, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rpt, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block), jnp.float32),
        interpret=interpret,
    )(q, scale)

"""Jit'd wrappers: fused quantizing arena writes / dequantizing reads.

``write_quant_flat`` / ``read_dequant_flat`` are the ``impl='pallas'``
hooks of :class:`repro.mem.arena.QuantCommArena`: they view the flat int8
arena and the fp32 segment payload as (rows, 128) lane tiles and run the
fused pack+quantize / dequant+unpack kernels (interpret mode off-TPU).
Shapes or offsets not meeting the int8 (32·128) + whole-quant-block
alignment fall back to the jnp oracle in ``ref.py``, which is *bitwise*
the kernel arithmetic — correctness is never conditional on the fast
path.

Scale bytes ride the trailing scale segment of the same arena; they are
written through :func:`repro.kernels.pack.write_flat` (a few bytes per
span — almost always the dynamic-update-slice fallback) so the whole
encode stays a single aliased in-place update chain on the donated
buffer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import default_interpret
from repro.kernels.pack import ops as pack_ops
from repro.kernels.pack_quant import ref
from repro.kernels.pack_quant.pack_quant import (LANES, _block_rows,
                                                 read_dequant_rows_2d,
                                                 write_quant_rows_2d)


def _tileable(size: int, offset: int, total: int, block: int) -> bool:
    if block % LANES or size % block or offset % block:
        return False
    if size % LANES or offset % LANES or total % LANES:
        return False
    return _block_rows(size // LANES, offset // LANES, block // LANES) > 0


def write_quant_flat(arena: jax.Array, src: jax.Array, offset: int,
                     scale_offset: int, block: int, *,
                     interpret: bool | None = None):
    """Quantize flat ``src`` into ``arena[offset : offset + n]`` + trailing
    scales; returns ``(arena, residual)`` (see the ref oracle)."""
    if arena.ndim != 1 or src.ndim != 1:
        raise ValueError(f"flat buffers expected, got {arena.shape} / "
                         f"{src.shape}")
    if arena.dtype != jnp.int8:
        raise ValueError(f"int8 arena expected, got {arena.dtype}")
    n = src.shape[0]
    if n % block != 0:
        raise ValueError(f"size {n} not divisible by block {block}")
    if not _tileable(n, offset, arena.shape[0], block):
        return ref.write_quant_flat(arena, src, offset, scale_offset, block)
    interpret = default_interpret() if interpret is None else interpret
    out, scales, residual = write_quant_rows_2d(
        arena.reshape(-1, LANES), src.reshape(-1, LANES), offset // LANES,
        block, interpret=interpret)
    sbytes = lax.bitcast_convert_type(scales.reshape(-1),
                                      jnp.int8).reshape(-1)
    out = pack_ops.write_flat(
        out.reshape(-1), sbytes,
        ref.scale_byte_offset(scale_offset, offset, block),
        interpret=interpret)
    return out, residual.reshape(-1)


def read_dequant_flat(arena: jax.Array, offset: int, size: int,
                      scale_offset: int, block: int, *,
                      interpret: bool | None = None) -> jax.Array:
    """Fused dequant+unpack of ``arena[offset : offset + size]`` to flat
    fp32."""
    if arena.ndim != 1:
        raise ValueError(f"flat arena expected, got {arena.shape}")
    if size % block != 0:
        raise ValueError(f"size {size} not divisible by block {block}")
    if not _tileable(size, offset, arena.shape[0], block):
        return ref.read_dequant_flat(arena, offset, size, scale_offset,
                                     block)
    interpret = default_interpret() if interpret is None else interpret
    scales = ref.read_scales_flat(arena, offset, size, scale_offset, block)
    out = read_dequant_rows_2d(arena.reshape(-1, LANES),
                               scales.reshape(-1, 1), offset // LANES,
                               size // LANES, block, interpret=interpret)
    return out.reshape(-1)

from repro.kernels.pack_quant.ops import read_dequant_flat, write_quant_flat

__all__ = ["read_dequant_flat", "write_quant_flat"]

"""Pure-jnp oracle for the fused pack+quantize arena kernels.

Arithmetic is exactly :mod:`repro.kernels.quant.ref` (block-absmax int8);
the scale of every quant block is bitcast fp32 -> 4 int8 bytes and stored
in the trailing scale segment of the same flat int8 arena, so one donated
buffer carries payload *and* scales across the step boundary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.quant import ref as quant_ref

SCALE_BYTES = 4  # one fp32 scale per quant block


def scale_byte_offset(scale_offset: int, offset: int, block: int) -> int:
    """Arena byte index of the scale for the quant block starting at
    payload element ``offset`` (offsets are block multiples by layout)."""
    return scale_offset + (offset // block) * SCALE_BYTES


def write_quant_flat(arena: jax.Array, src: jax.Array, offset: int,
                     scale_offset: int, block: int):
    """Quantize flat ``src`` into ``arena[offset : offset + n]`` (int8
    payload) + bitcast fp32 scales into the trailing scale segment; returns
    ``(arena, residual)`` with ``residual = src - dequant(quant(src))`` for
    error feedback."""
    x = src.astype(jnp.float32).reshape(-1, block)
    q, s = quant_ref.quantize_blocks(x)
    residual = (x - quant_ref.dequantize_blocks(q, s)).reshape(-1)
    arena = lax.dynamic_update_slice_in_dim(arena, q.reshape(-1), offset,
                                            axis=0)
    sbytes = lax.bitcast_convert_type(s.reshape(-1), jnp.int8).reshape(-1)
    arena = lax.dynamic_update_slice_in_dim(
        arena, sbytes, scale_byte_offset(scale_offset, offset, block), axis=0)
    return arena, residual


def read_scales_flat(arena: jax.Array, offset: int, size: int,
                     scale_offset: int, block: int) -> jax.Array:
    """The fp32 scales of ``arena[offset : offset + size]`` — the trailing
    scale bytes sliced out and bitcast back, shape ``(size // block,)``."""
    lo = scale_byte_offset(scale_offset, offset, block)
    hi = scale_byte_offset(scale_offset, offset + size, block)
    sbytes = lax.slice_in_dim(arena, lo, hi, axis=0)
    return lax.bitcast_convert_type(sbytes.reshape(-1, SCALE_BYTES),
                                    jnp.float32)


def read_dequant_flat(arena: jax.Array, offset: int, size: int,
                      scale_offset: int, block: int) -> jax.Array:
    """Fused dequant+unpack: ``arena[offset : offset + size]`` decoded to
    flat fp32 using the trailing scales."""
    q = lax.slice_in_dim(arena, offset, offset + size, axis=0)
    s = read_scales_flat(arena, offset, size, scale_offset, block)
    return quant_ref.dequantize_blocks(q.reshape(-1, block),
                                       s.reshape(-1, 1)).reshape(-1)

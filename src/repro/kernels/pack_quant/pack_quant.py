"""Fused pack+quantize kernels for the quantized communication arena.

The fp32 arena pack (:mod:`repro.kernels.pack`) is a pure copy; under a
wire codec the same pass can also *encode*.  One VMEM trip per tile: load
the bucket rows, reduce the per-block absmax, scale/round/clip to int8,
write the payload **in place** into the aliased arena rows, and emit the
fp32 scales plus the quantization residual (the error-feedback update) —
the paper's T1/T4 copy loop and the wire codec fused into one kernel, so
compressing costs one extra read-modify-write of the bucket instead of a
separate quantize pass over a staging buffer.

Tiling: the flat int8 arena and the fp32 source are viewed as (rows, 128)
lane tiles; a tile height must satisfy the int8 (32, 128) min tile *and*
hold whole quant blocks (``block // 128`` rows each) so every scale is
computed from one tile.  Misaligned extents fall back to the bitwise jnp
oracle in ``ops.py``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES_I8 = 32           # int8 min tile is (32, 128)
MAX_BLOCK_ROWS = 1024


def _block_rows(rows: int, row_offset: int, q_rows: int) -> int:
    """Largest tile height dividing both the copy extent and its alignment
    that is int8-tile legal and holds whole quant blocks; 0 when no such
    tiling exists (caller falls back)."""
    br = math.gcd(rows, MAX_BLOCK_ROWS)
    if row_offset:
        br = math.gcd(br, row_offset)
    step = math.lcm(SUBLANES_I8, q_rows)
    return br if br % step == 0 else 0


def _pack_quant_kernel(block, _arena_ref, x_ref, q_ref, s_ref, r_ref):
    x = x_ref[...].astype(jnp.float32)
    xb = x.reshape(-1, block)
    absmax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / 127.0, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(xb / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8).reshape(x.shape)
    s_ref[...] = scale
    # int8 round-trips exactly through fp32, so q * scale is bitwise the
    # decoded wire value and xb - q * scale is the exact EF residual
    r_ref[...] = (xb - q * scale).reshape(x.shape)


def write_quant_rows_2d(arena: jax.Array, src: jax.Array, row_offset: int,
                        block: int, *, interpret: bool = False):
    """Quantize ``src`` (rows, 128) fp32 into ``arena[row_offset:...]``
    (int8, aliased in place); returns ``(arena, scales, residual)`` with
    ``scales`` (rows·128/block, 1) fp32 and ``residual`` shaped like
    ``src``."""
    rows = src.shape[0]
    q_rows = block // LANES
    br = _block_rows(rows, row_offset, q_rows)
    if br <= 0:
        raise ValueError(f"no aligned tiling for rows={rows} at "
                         f"offset={row_offset} block={block}; use the "
                         f"ops.py fallback")
    grid = (rows // br,)
    n_blocks = rows // q_rows
    sb = br // q_rows
    return pl.pallas_call(
        functools.partial(_pack_quant_kernel, block),
        grid=grid,
        in_specs=[pl.BlockSpec((br, LANES), lambda i: (row_offset // br + i, 0)),
                  pl.BlockSpec((br, LANES), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, LANES), lambda i: (row_offset // br + i, 0)),
                   pl.BlockSpec((sb, 1), lambda i: (i, 0)),
                   pl.BlockSpec((br, LANES), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(arena.shape, jnp.int8),
                   jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
                   jax.ShapeDtypeStruct((rows, LANES), jnp.float32)],
        input_output_aliases={0: 0},
        interpret=interpret,
    )(arena, src)


def _dequant_read_kernel(block, arena_ref, s_ref, o_ref):
    q = arena_ref[...].astype(jnp.float32).reshape(-1, block)
    o_ref[...] = (q * s_ref[...]).reshape(o_ref.shape)


def read_dequant_rows_2d(arena: jax.Array, scales: jax.Array,
                         row_offset: int, rows: int, block: int, *,
                         interpret: bool = False) -> jax.Array:
    """Fused dequant+unpack: decode ``arena[row_offset : row_offset+rows]``
    (int8) against ``scales`` (rows·128/block, 1) into a fresh fp32
    (rows, 128) buffer."""
    q_rows = block // LANES
    br = _block_rows(rows, row_offset, q_rows)
    if br <= 0:
        raise ValueError(f"no aligned tiling for rows={rows} at "
                         f"offset={row_offset} block={block}; use the "
                         f"ops.py fallback")
    grid = (rows // br,)
    sb = br // q_rows
    return pl.pallas_call(
        functools.partial(_dequant_read_kernel, block),
        grid=grid,
        in_specs=[pl.BlockSpec((br, LANES), lambda i: (row_offset // br + i, 0)),
                  pl.BlockSpec((sb, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=interpret,
    )(arena, scales)

"""Pallas TPU kernels for the compute hot-spots the paper optimises.

The paper's T4 is 'thread the local reduce/copy loops' — on TPU the analogue
is VPU/MXU-aligned fused kernels with explicit VMEM tiling:

* ``reduce_add``  — the ring step's local ``acc += recv`` with fp32
  accumulation over a narrow wire dtype.
* ``quant``       — int8 block quantise/dequantise for the wire codec.
* ``flash_attn``  — blockwise causal attention (serving prefill hot-spot).

Each kernel ships ``ops.py`` (jit'd wrapper; ``interpret=True`` on CPU) and
``ref.py`` (pure-jnp oracle used by the allclose test sweeps).
"""


def on_tpu() -> bool:
    import jax

    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Pallas kernels execute in interpret mode off-TPU (CPU CI)."""
    return not on_tpu()

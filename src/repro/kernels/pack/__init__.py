from repro.kernels.pack.ops import read_flat, write_flat

__all__ = ["read_flat", "write_flat"]

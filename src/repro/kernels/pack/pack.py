"""Flat-copy kernels for the communication arena (pack/unpack).

The paper's T1/T2 memory techniques culminate in *one* stable, page-aligned
buffer per step that every collective reduces out of.  Moving gradients in
and out of that buffer is pure data movement — exactly the kind of local
copy loop the paper threads (T4).  On TPU the analogue is a VPU-width copy
that streams lane-aligned (rows, 128) tiles between a bucket and its arena
segment:

* :func:`write_rows_2d`  — copy a source tile block into a row-offset slice
  of the arena, *in place* (``input_output_aliases``), so packing N buckets
  is N aliased copies over one persistent buffer instead of a fresh
  concatenation per step;
* :func:`read_rows_2d`   — the inverse: materialise one segment's rows out
  of the arena (unpack).

Segment offsets are page-quantized by :mod:`repro.mem.layout` (2 MiB
default = 4096 rows of 128 fp32 lanes), so the row offsets here are always
multiples of any power-of-two block size — guaranteed, never probabilistic,
the paper's ethos.  Sources whose row counts don't meet the fp32 (8, 128)
tiling fall back to the jnp oracle in ``ops.py``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8               # fp32 min tile is (8, 128)
MAX_BLOCK_ROWS = 1024      # (1024, 128) fp32 tile = 512 KiB per operand


def _block_rows(rows: int, row_offset: int) -> int:
    """Largest tile height that divides both the copy extent and its
    alignment; 0 when no (8·128)-aligned tiling exists (caller falls back)."""
    br = math.gcd(rows, MAX_BLOCK_ROWS)
    if row_offset:
        br = math.gcd(br, row_offset)
    return br if br % SUBLANES == 0 else 0


def _copy_kernel(_arena_ref, src_ref, o_ref):
    o_ref[...] = src_ref[...].astype(o_ref.dtype)


def write_rows_2d(arena: jax.Array, src: jax.Array, row_offset: int, *,
                  interpret: bool = False) -> jax.Array:
    """Return ``arena`` with ``src`` written at ``arena[row_offset:...]``.

    ``arena``: (rows_total, 128); ``src``: (rows, 128).  The arena input is
    aliased to the output, so untouched rows keep their values and XLA can
    update the (donated) buffer in place.
    """
    rows = src.shape[0]
    br = _block_rows(rows, row_offset)
    if br <= 0:
        raise ValueError(f"no aligned tiling for rows={rows} at "
                         f"offset={row_offset}; use the ops.py fallback")
    grid = (rows // br,)
    return pl.pallas_call(
        _copy_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, LANES), lambda i: (row_offset // br + i, 0)),
                  pl.BlockSpec((br, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, LANES), lambda i: (row_offset // br + i, 0)),
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(arena, src)


def _slice_kernel(arena_ref, o_ref):
    o_ref[...] = arena_ref[...]


def read_rows_2d(arena: jax.Array, row_offset: int, rows: int, *,
                 interpret: bool = False) -> jax.Array:
    """``arena[row_offset : row_offset + rows]`` as a fresh (rows, 128)
    buffer — the unpack direction of :func:`write_rows_2d`."""
    br = _block_rows(rows, row_offset)
    if br <= 0:
        raise ValueError(f"no aligned tiling for rows={rows} at "
                         f"offset={row_offset}; use the ops.py fallback")
    grid = (rows // br,)
    return pl.pallas_call(
        _slice_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, LANES), lambda i: (row_offset // br + i, 0))],
        out_specs=pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), arena.dtype),
        interpret=interpret,
    )(arena)

"""Pure-jnp oracle for the arena pack/unpack kernels."""

from __future__ import annotations

import jax
from jax import lax


def write_flat(arena: jax.Array, src: jax.Array, offset: int) -> jax.Array:
    """``arena`` with ``src`` (cast to the arena dtype) written at
    ``arena[offset : offset + src.size]``."""
    return lax.dynamic_update_slice_in_dim(
        arena, src.astype(arena.dtype), offset, axis=0)


def read_flat(arena: jax.Array, offset: int, size: int) -> jax.Array:
    """``arena[offset : offset + size]``."""
    return lax.slice_in_dim(arena, offset, offset + size, axis=0)

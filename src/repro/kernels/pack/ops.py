"""Jit'd wrappers: flat arena writes/reads with automatic tiling/fallback.

``write_flat`` / ``read_flat`` are the ``impl='pallas'`` hooks of
:class:`repro.mem.arena.CommArena`: they view the 1-D arena and segment
payloads as (rows, 128) lane tiles and run the Pallas flat-copy kernels
(interpret mode off-TPU).  Shapes or offsets not meeting the (8·128)
alignment fall back to the jnp oracle — correctness is never conditional on
the fast path.
"""

from __future__ import annotations

import jax

from repro.kernels import default_interpret
from repro.kernels.pack import ref
from repro.kernels.pack.pack import LANES, _block_rows, read_rows_2d, \
    write_rows_2d


def _tileable(size: int, offset: int, total: int) -> bool:
    if size % LANES or offset % LANES or total % LANES:
        return False
    return _block_rows(size // LANES, offset // LANES) > 0


def write_flat(arena: jax.Array, src: jax.Array, offset: int, *,
               interpret: bool | None = None) -> jax.Array:
    """``arena`` with ``src`` written at ``offset`` (element index)."""
    if arena.ndim != 1 or src.ndim != 1:
        raise ValueError(f"flat buffers expected, got {arena.shape} / "
                         f"{src.shape}")
    n = src.shape[0]
    if src.dtype != arena.dtype or not _tileable(n, offset, arena.shape[0]):
        return ref.write_flat(arena, src, offset)
    interpret = default_interpret() if interpret is None else interpret
    out = write_rows_2d(arena.reshape(-1, LANES), src.reshape(-1, LANES),
                        offset // LANES, interpret=interpret)
    return out.reshape(-1)


def read_flat(arena: jax.Array, offset: int, size: int, *,
              interpret: bool | None = None) -> jax.Array:
    """``arena[offset : offset + size]``."""
    if arena.ndim != 1:
        raise ValueError(f"flat arena expected, got {arena.shape}")
    if not _tileable(size, offset, arena.shape[0]):
        return ref.read_flat(arena, offset, size)
    interpret = default_interpret() if interpret is None else interpret
    out = read_rows_2d(arena.reshape(-1, LANES), offset // LANES,
                       size // LANES, interpret=interpret)
    return out.reshape(-1)

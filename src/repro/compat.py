"""Version-portability shims over the installed JAX.

The communication stack leans on a handful of APIs whose spelling moved
between JAX releases:

* ``jax.shard_map``      — top-level since ~0.6; previously
  ``jax.experimental.shard_map.shard_map`` with ``check_rep`` instead of
  ``check_vma``.
* ``jax.lax.axis_size``  — newer; older releases spell the (static) axis
  size as ``lax.psum(1, axis)``, which constant-folds to a Python int.
* ``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)`` —
  newer; older meshes take no axis-type argument.

Everything in ``repro`` that touches one of these goes through this module
so the repo runs unmodified on either side of the API break.
"""

from __future__ import annotations

import enum
from typing import Any, Sequence

import jax
from jax import lax

try:  # newer jax
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    HAS_AXIS_TYPE = True
except ImportError:  # pragma: no cover - depends on installed jax
    HAS_AXIS_TYPE = False

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stand-in for ``jax.sharding.AxisType`` on older releases.

        Older JAX has no explicit/auto/manual axis-type machinery; meshes
        behave like ``Auto`` everywhere, so the enum only needs to exist for
        call sites that spell ``AxisType.Auto``.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              axis_types: tuple | None = None, devices=None) -> Any:
    """``jax.make_mesh`` that tolerates the absence of ``axis_types``."""
    kwargs = {} if devices is None else {"devices": devices}
    if HAS_AXIS_TYPE:
        kinds = axis_types or (AxisType.Auto,) * len(tuple(axis_shapes))
        try:
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                                 axis_types=kinds, **kwargs)
        except TypeError:  # AxisType importable but make_mesh predates kwarg
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename papered
    over; falls back to ``jax.experimental.shard_map`` when the top-level
    entry point is missing."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy_sm

    return legacy_sm(f, mesh, in_specs, out_specs, check_rep=check_vma)


def axis_size(axis) -> int:
    """Static size of a named mesh axis inside a manual/collective context.

    ``lax.psum`` of a concrete Python scalar constant-folds to the axis size
    as a plain int, which is exactly what ``lax.axis_size`` returns on newer
    releases.
    """
    fn = getattr(lax, "axis_size", None)
    if fn is not None:
        return fn(axis)
    return lax.psum(1, axis)

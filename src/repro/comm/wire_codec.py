"""Wire codecs + error feedback for lossy collective payloads.

First-class home of the codec layer (formerly ``repro.core.compression``,
removed together with the ``ring_compressed`` transport shim).  Two ways to
put a codec on the wire:

* ``CommConfig.wire_codec="int8"`` (or ``--wire-codec int8`` on the launch
  drivers) — applies the codec to any ring-family transport; with
  ``use_arena`` the fused Pallas pack+quantize path
  (:mod:`repro.kernels.pack_quant`) writes the int8 payload + fp32 block
  scales in one pass and carries error-feedback residuals as a train-state
  leaf, priced end-to-end by ``CommPlan.codec_tradeoff``;
* ``RingConfig.codec="int8"`` — the eager per-hop form used directly by
  :mod:`repro.core.ring`.

The quantization math here is the single source of truth —
``kernels/quant/ref`` and ``kernels/pack_quant/ref`` mirror it exactly:
``scale = max(absmax/127, tiny)``; ``q = clip(round(x/scale), ±127)``.

Codecs are pytree-payload transforms used by ``core.ring``:

* reduce-scatter hops re-encode the running partial sum (per-hop rounding;
  bounded by the block scale, compensated at the source by error feedback);
* all-gather hops encode once at the source and forward verbatim (lossless
  relative to the encoded value).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Payload = dict[str, jax.Array]


class IdentityCodec:
    """No-op codec; optionally casts to a narrow wire dtype (bf16 rail)."""

    def __init__(self, wire_dtype=None):
        self.wire_dtype = jnp.dtype(wire_dtype) if wire_dtype is not None else None

    block = 1

    def encode(self, x: jax.Array) -> Payload:
        if self.wire_dtype is not None:
            x = x.astype(self.wire_dtype)
        return {"x": x}

    def decode(self, payload: Payload) -> jax.Array:
        return payload["x"]

    def wire_bytes(self, n_elems: int, accum_dtype=jnp.float32) -> int:
        dt = self.wire_dtype or jnp.dtype(accum_dtype)
        return n_elems * dt.itemsize


class Int8BlockCodec:
    """Per-block absmax int8 quantisation.

    ``encode``: view ``x`` as (n/block, block); scale each block by
    ``absmax/127`` and round-to-nearest into int8.  ``decode`` inverts.
    Requires ``x.size % block == 0`` (the bucketer's pad multiple guarantees
    this).  4 bytes of scale per ``block`` elements => wire ratio
    ``(1 + 4/block) / 4`` vs fp32 (~0.258 at block=512).
    """

    def __init__(self, block: int = 512):
        if block <= 0:
            raise ValueError("block must be positive")
        self.block = block

    def encode(self, x: jax.Array) -> Payload:
        n = x.shape[0]
        if n % self.block != 0:
            raise ValueError(f"size {n} not divisible by codec block {self.block}")
        xb = x.astype(jnp.float32).reshape(-1, self.block)
        scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
        q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
        return {"q": q.reshape(-1), "scale": scale.reshape(-1)}

    def decode(self, payload: Payload) -> jax.Array:
        q = payload["q"].astype(jnp.float32).reshape(-1, self.block)
        scale = payload["scale"].reshape(-1, 1)
        return (q * scale).reshape(-1)

    def wire_bytes(self, n_elems: int, accum_dtype=jnp.float32) -> int:
        return n_elems * 1 + (n_elems // self.block) * 4


def make_codec(name: str | None, *, wire_dtype=None, block: int = 512):
    if name in (None, "none", "identity"):
        return IdentityCodec(wire_dtype=wire_dtype)
    if name == "int8":
        return Int8BlockCodec(block=block)
    raise ValueError(f"unknown codec {name!r}")


# ---------------------------------------------------------------------------
# error feedback (EF-SGD): re-inject each device's own quantisation error
# ---------------------------------------------------------------------------


@dataclass
class ErrorFeedback:
    """Source-side error feedback for lossy wire codecs.

    ``compensate`` adds the residual carried from the previous step and
    returns the new residual (the part of the compensated gradient the codec
    cannot represent).  State is a pytree congruent with the bucket list.
    """

    codec: Any

    def init(self, buckets: list[jax.Array]) -> list[jax.Array]:
        return [jnp.zeros_like(b, dtype=jnp.float32) for b in buckets]

    def compensate(self, buckets: list[jax.Array], residuals: list[jax.Array]
                   ) -> tuple[list[jax.Array], list[jax.Array]]:
        comp, new_res = [], []
        for b, r in zip(buckets, residuals):
            y = b.astype(jnp.float32) + r
            decoded = self.codec.decode(self.codec.encode(y))
            comp.append(y)
            new_res.append(y - decoded)
        return comp, new_res

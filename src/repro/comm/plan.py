"""CommPlan: one object describing how a gradient pytree moves.

Fuses the three views that used to live in three places:

* the :class:`~repro.core.bucketing.BucketPlan` (which leaf lands where in
  which fused buffer — the paper's guaranteed-large-buffer layout);
* the **channel assignment** (which bucket rides which virtual channel —
  the paper's multi-rail PSM2 endpoints as a config knob);
* the **predicted wire bytes** (the napkin-math roofline term that
  ``GradientReducer.predicted_collective_bytes`` used to compute).

Benchmarks and the dry-run report read this one object.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.core.bucketing import BucketPlan

# Per-message launch + small-message latency cost, seconds.  The paper's
# core observation is that once the wire runs near line rate, per-message
# overhead — not bandwidth — dominates small collectives; 1.5 µs is the
# order of an Omni-Path/ICI small-message one-way latency and makes the
# α term visible exactly where the paper says it matters (CG inner
# products, tiny gradient buckets) without perturbing bulk-transfer cells.
ALPHA_S = 1.5e-6

# Per-link one-direction bandwidth, bytes/s.  Single source for both β
# terms: :class:`LatencyModel` here and ``repro.launch.roofline.ICI_BW``.
LINK_BANDWIDTH = 50e9

# Per-chip HBM bandwidth, bytes/s (v5e).  Single source for the roofline
# memory term and the codec kernel-time pricing in
# :meth:`CommPlan.codec_tradeoff` — the fused pack+quantize/dequant passes
# are pure streaming kernels, so their cost is HBM bytes over this number.
HBM_BANDWIDTH = 819e9


@dataclass(frozen=True)
class LatencyModel:
    """α/β cost model of one device's collective traffic:

        t_collective = α · messages + bytes / bandwidth

    ``messages`` counts discrete network operations whose launch latency
    cannot be amortised (ring hops, ``ppermute`` payloads); ``bytes`` is
    the per-device wire-byte total the bandwidth term amortises.  The β
    term alone is what the roofline used before solver variants made the
    message *count* a first-class design axis (2 vs 1 vs 1/s reductions
    per CG iteration)."""

    alpha_s: float = ALPHA_S
    bandwidth: float = LINK_BANDWIDTH

    def collective_seconds(self, messages: float, nbytes: float) -> float:
        return self.alpha_s * float(messages) + float(nbytes) / self.bandwidth

    @classmethod
    def from_record(cls, record) -> "LatencyModel":
        """Measured constants from a tuning-DB record (or a bare fit dict /
        :class:`repro.tune.fit.FitResult`): what ``dryrun --tuned`` prices
        cells with instead of the hardcoded guesses above."""
        if hasattr(record, "alpha_s"):          # FitResult (duck-typed)
            return cls(alpha_s=float(record.alpha_s),
                       bandwidth=float(record.bandwidth))
        fit = record.get("fit", record)         # DB record or raw fit dict
        return cls(alpha_s=float(fit["alpha_s"]),
                   bandwidth=float(fit["bandwidth"]))


@dataclass(frozen=True)
class ChannelAssignment:
    """Buckets carried by one virtual channel (independent collective)."""

    channel: int
    buckets: tuple[int, ...]   # indices into the bucket list, ascending
    elems: int                 # total padded elements on this channel


def assign_channels(bucket_sizes: Sequence[int], channels: int
                    ) -> tuple[ChannelAssignment, ...]:
    """Greedy least-loaded striping of buckets across ``channels`` virtual
    channels.  Deterministic: buckets are visited largest-first, ties broken
    by index, and each lands on the currently lightest channel."""
    n = max(int(channels), 1)
    loads = [0] * n
    members: list[list[int]] = [[] for _ in range(n)]
    order = sorted(range(len(bucket_sizes)),
                   key=lambda i: (-int(bucket_sizes[i]), i))
    for i in order:
        c = min(range(n), key=lambda j: (loads[j], j))
        members[c].append(i)
        loads[c] += int(bucket_sizes[i])
    return tuple(ChannelAssignment(c, tuple(sorted(members[c])), loads[c])
                 for c in range(n))


@dataclass(frozen=True)
class CommPlan:
    """Bucket layout + channel striping + predicted bytes for one pytree."""

    transport: str
    axes: tuple[str, ...]
    axis_sizes: tuple[int, ...]
    bucket_plan: BucketPlan
    channels: tuple[ChannelAssignment, ...]
    wire_bytes_per_elem: float     # codec/wire-dtype bytes per element
    bytes_per_device: float        # predicted all-reduce wire bytes/device
    messages_per_device: float = 0.0  # discrete sends/device (α latency term)
    # arena mode (repro.mem): page-quantized fused-span layout + its cost.
    # The arena byte term covers the page padding too — in arena mode the
    # padding crosses the wire, so the prediction must not pretend otherwise.
    arena_layout: "object | None" = None     # repro.mem.layout.ArenaLayout
    arena_bytes_per_device: float = 0.0      # wire bytes incl. page padding
    arena_messages_per_device: float = 0.0   # α term at one send per span
    # quantized wire (repro.kernels.pack_quant): codec identity, priced so
    # the compressed prediction is checkable against lowered HLO at 0
    # tolerance (mem-suite codec cells) and against the fp32 twin.
    wire_codec: str | None = None            # None | "int8"
    codec_block: int = 512                   # absmax block (elems per scale)

    @property
    def n_buckets(self) -> int:
        return self.bucket_plan.n_buckets

    @property
    def n_channels(self) -> int:
        return len(self.channels)

    @property
    def total_elems(self) -> int:
        return self.bucket_plan.total_elems

    @property
    def world(self) -> int:
        w = 1
        for p in self.axis_sizes:
            w *= p
        return w

    def bucket_channel(self, bucket: int) -> int:
        for a in self.channels:
            if bucket in a.buckets:
                return a.channel
        raise KeyError(bucket)

    @property
    def channel_imbalance(self) -> float:
        """max/mean channel load (1.0 = perfectly striped)."""
        loads = [a.elems for a in self.channels]
        mean = sum(loads) / max(len(loads), 1)
        return max(loads) / mean if mean else 1.0

    def predicted_collective_bytes(self) -> dict[str, float]:
        """The dict ``GradientReducer.predicted_collective_bytes`` returned,
        plus the channel-level breakdown."""
        used = self.bucket_plan.used_elems
        out = {
            "bytes_per_device": self.bytes_per_device,
            "grad_bytes": used * 4.0,
            "wire_bytes_per_elem": self.wire_bytes_per_elem,
            "n_channels": float(self.n_channels),
            "channel_imbalance": self.channel_imbalance,
            "messages_per_device": self.messages_per_device,
        }
        if self.arena_layout is not None:
            out.update({
                "arena_bytes_per_device": self.arena_bytes_per_device,
                "arena_messages_per_device": self.arena_messages_per_device,
                "arena_pages": float(self.arena_layout.n_pages),
                "arena_total_bytes": float(self.arena_layout.total_bytes),
                "arena_padding_fraction":
                    self.arena_layout.padding_fraction,
            })
        return out

    def predicted_collective_seconds(self, model: LatencyModel = LatencyModel()
                                     ) -> float:
        """α·messages + bytes/bw for one reduction of this plan."""
        return model.collective_seconds(self.messages_per_device,
                                        self.bytes_per_device)

    def codec_tradeoff(self, model: LatencyModel = LatencyModel(),
                       hbm_bandwidth: float = HBM_BANDWIDTH) -> dict:
        """Price the quantized wire end-to-end: fp32 vs int8+scales.

        Compression is not free — the fused pack+quantize and dequant
        kernels stream the payload through HBM, so the honest comparison is

            t_fp32  = α·msgs + bytes_fp32 / bw_link
            t_codec = α·msgs + bytes_codec / bw_link + hbm_bytes / bw_hbm

        with the same message count on both sides (the codec shrinks hop
        *payloads*, not hop counts).  Kernel HBM traffic per reduction,
        per payload element of ``w = 1 + 4/block`` wire bytes: encode
        reads the fp32 gradient and the error-feedback accumulator,
        writes the accumulator and the wire form (``4+4+4+w``); decode
        reads the wire form and writes fp32 (``w+4``).

        Computed for this plan's codec, or as a what-if at ``codec_block``
        when ``wire_codec`` is ``None`` (``applied`` says which).  Arena
        plans price the arena wire bytes (page padding included).
        """
        nbytes = (self.arena_bytes_per_device if self.arena_layout is not None
                  else self.bytes_per_device)
        msgs = (self.arena_messages_per_device if self.arena_layout is not None
                else self.messages_per_device)
        wpe_q = 1.0 + 4.0 / self.codec_block
        if self.wire_codec is not None:
            codec_bytes = nbytes
            fp32_bytes = nbytes * 4.0 / self.wire_bytes_per_elem
        else:
            fp32_bytes = nbytes * 4.0 / self.wire_bytes_per_elem
            codec_bytes = fp32_bytes * wpe_q / 4.0
        elems = self.total_elems
        kernel_bytes = elems * ((4.0 + 4.0 + 4.0 + wpe_q) + (wpe_q + 4.0))
        kernel_s = kernel_bytes / hbm_bandwidth
        t_fp32 = model.collective_seconds(msgs, fp32_bytes)
        t_codec = model.collective_seconds(msgs, codec_bytes) + kernel_s
        return {
            "applied": self.wire_codec is not None,
            "codec": self.wire_codec or "int8",
            "codec_block": self.codec_block,
            "wire_bytes_fp32": fp32_bytes,
            "wire_bytes_codec": codec_bytes,
            "compression_ratio": fp32_bytes / codec_bytes if codec_bytes
            else 0.0,
            "kernel_hbm_bytes": kernel_bytes,
            "t_kernel_s": kernel_s,
            "t_fp32_s": t_fp32,
            "t_codec_s": t_codec,
            "speedup": t_fp32 / t_codec if t_codec else 0.0,
        }

    def describe(self) -> dict:
        """JSON-friendly summary for the dry-run report."""
        out = {
            "transport": self.transport,
            "axes": list(self.axes),
            "axis_sizes": list(self.axis_sizes),
            "world": self.world,
            "n_buckets": self.n_buckets,
            "total_elems": self.total_elems,
            "padding_waste": self.bucket_plan.padding_waste,
            "channels": [{"channel": a.channel, "buckets": list(a.buckets),
                          "elems": a.elems} for a in self.channels],
            **self.predicted_collective_bytes(),
        }
        if self.arena_layout is not None:
            out["arena"] = self.arena_layout.describe()
        if self.wire_codec is not None:
            out["wire_codec"] = self.wire_codec
            out["codec_block"] = self.codec_block
            out["codec"] = self.codec_tradeoff()
        return out


@dataclass(frozen=True)
class HaloChannel:
    """Units carried by one halo rail, with their payload *bytes* (unlike
    :class:`ChannelAssignment`, whose loads are element counts)."""

    channel: int
    units: tuple[int, ...]     # indices into the unit list, ascending
    bytes: int


@dataclass(frozen=True)
class HaloPlan:
    """The halo-exchange analogue of :class:`CommPlan`: bytes per direction
    × channel for one Cartesian exchange, plus the predicted wire bytes.

    ``units`` are the individual ``ppermute`` payloads (one per direction,
    times the chunk split under the ``chunked`` schedule), labelled
    ``"<axis><dir>[#chunk]"``; ``unit_bytes[i]`` is unit ``i``'s payload
    size.  Each unit crosses the wire exactly once (a ``collective-permute``
    is one hop), so ``bytes_per_device`` is simply the payload total — the
    dry-run's stencil suite checks this against the bytes parsed from the
    lowered HLO.  Self-neighbour exchanges (mesh axis of size 1) still lower
    to a ``collective-permute`` and are therefore counted.
    """

    schedule: str
    axes: tuple[str, ...]          # mesh axis per exchanged direction spec
    axis_sizes: tuple[int, ...]
    local_shape: tuple[int, ...]
    halos: tuple[int, ...]         # face width per spec
    unit_keys: tuple[str, ...]
    unit_bytes: tuple[int, ...]
    channels: tuple[HaloChannel, ...]
    overlap_fraction: float

    @property
    def n_units(self) -> int:
        return len(self.unit_bytes)

    @property
    def bytes_per_device(self) -> float:
        """Predicted wire bytes per device per exchange (one hop per unit)."""
        return float(sum(self.unit_bytes))

    @property
    def messages_per_device(self) -> float:
        """α-term message count: each unit is exactly one ``ppermute``
        payload, i.e. one discrete send per device per exchange."""
        return float(self.n_units)

    def predicted_collective_seconds(self, model: LatencyModel = LatencyModel()
                                     ) -> float:
        """α·messages + bytes/bw for one halo exchange of this plan."""
        return model.collective_seconds(self.messages_per_device,
                                        self.bytes_per_device)

    @property
    def channel_imbalance(self) -> float:
        """max/mean channel load (1.0 = perfectly striped)."""
        loads = [a.bytes for a in self.channels]
        mean = sum(loads) / max(len(loads), 1)
        return max(loads) / mean if mean else 1.0

    def describe(self) -> dict:
        """JSON-friendly summary for the dry-run report."""
        return {
            "schedule": self.schedule,
            "axes": list(self.axes),
            "axis_sizes": list(self.axis_sizes),
            "local_shape": list(self.local_shape),
            "halos": list(self.halos),
            "n_units": self.n_units,
            "units": [{"key": k, "bytes": b}
                      for k, b in zip(self.unit_keys, self.unit_bytes)],
            "channels": [{"channel": a.channel, "units": list(a.units),
                          "bytes": a.bytes} for a in self.channels],
            "bytes_per_device": self.bytes_per_device,
            "messages_per_device": self.messages_per_device,
            "channel_imbalance": self.channel_imbalance,
            "overlap_fraction": self.overlap_fraction,
        }


@dataclass(frozen=True)
class A2APlan:
    """The all-to-all analogue of :class:`CommPlan`: predicted wire cost of
    one expert-parallel dispatch + combine round-trip of a local capacity
    buffer of ``elems_per_device`` elements.

    ``units`` are per-rail all-to-all payloads — ``dispatch#c`` /
    ``combine#c`` per channel rail — and ``unit_bytes[i]`` is the *wire*
    bytes that rail puts in flight per exchange (already scaled by the
    transport: ``(R-1)/R`` of the payload for ring/native all-to-all,
    ``2(R-1)×`` for the honest replicated-psum fallback).  The dry-run's
    moe suite checks ``bytes_per_device`` against the bytes parsed from
    lowered HLO.
    """

    transport: str
    axis: str
    axis_size: int
    elems_per_device: int          # local capacity-buffer elements, one phase
    itemsize: int
    unit_keys: tuple[str, ...]     # "dispatch#c" / "combine#c"
    unit_bytes: tuple[int, ...]
    messages_per_unit: float       # hops per rail exchange (R-1 or 2(R-1))
    channels: tuple[HaloChannel, ...]
    overlap_fraction: float

    @property
    def n_units(self) -> int:
        return len(self.unit_bytes)

    @property
    def bytes_per_device(self) -> float:
        """Predicted wire bytes per device per dispatch+combine round-trip."""
        return float(sum(self.unit_bytes))

    @property
    def messages_per_device(self) -> float:
        """α-term sends per device: hop count per rail, summed over units."""
        return self.messages_per_unit * self.n_units

    @property
    def dispatch_bytes_per_device(self) -> float:
        """Wire bytes of the dispatch half alone (the A/B headline number)."""
        return float(sum(b for k, b in zip(self.unit_keys, self.unit_bytes)
                         if k.startswith("dispatch")))

    def predicted_collective_seconds(self, model: LatencyModel = LatencyModel()
                                     ) -> float:
        """α·messages + bytes/bw for one dispatch+combine round-trip."""
        return model.collective_seconds(self.messages_per_device,
                                        self.bytes_per_device)

    @property
    def channel_imbalance(self) -> float:
        """max/mean channel load (1.0 = perfectly striped)."""
        loads = [a.bytes for a in self.channels]
        mean = sum(loads) / max(len(loads), 1)
        return max(loads) / mean if mean else 1.0

    def describe(self) -> dict:
        """JSON-friendly summary for the dry-run report."""
        return {
            "transport": self.transport,
            "axis": self.axis,
            "axis_size": self.axis_size,
            "elems_per_device": self.elems_per_device,
            "itemsize": self.itemsize,
            "n_units": self.n_units,
            "units": [{"key": k, "bytes": b}
                      for k, b in zip(self.unit_keys, self.unit_bytes)],
            "channels": [{"channel": a.channel, "units": list(a.units),
                          "bytes": a.bytes} for a in self.channels],
            "bytes_per_device": self.bytes_per_device,
            "dispatch_bytes_per_device": self.dispatch_bytes_per_device,
            "messages_per_device": self.messages_per_device,
            "channel_imbalance": self.channel_imbalance,
            "overlap_fraction": self.overlap_fraction,
        }

"""CommPlan: one object describing how a gradient pytree moves.

Fuses the three views that used to live in three places:

* the :class:`~repro.core.bucketing.BucketPlan` (which leaf lands where in
  which fused buffer — the paper's guaranteed-large-buffer layout);
* the **channel assignment** (which bucket rides which virtual channel —
  the paper's multi-rail PSM2 endpoints as a config knob);
* the **predicted wire bytes** (the napkin-math roofline term that
  ``GradientReducer.predicted_collective_bytes`` used to compute).

Benchmarks and the dry-run report read this one object.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.core.bucketing import BucketPlan


@dataclass(frozen=True)
class ChannelAssignment:
    """Buckets carried by one virtual channel (independent collective)."""

    channel: int
    buckets: tuple[int, ...]   # indices into the bucket list, ascending
    elems: int                 # total padded elements on this channel


def assign_channels(bucket_sizes: Sequence[int], channels: int
                    ) -> tuple[ChannelAssignment, ...]:
    """Greedy least-loaded striping of buckets across ``channels`` virtual
    channels.  Deterministic: buckets are visited largest-first, ties broken
    by index, and each lands on the currently lightest channel."""
    n = max(int(channels), 1)
    loads = [0] * n
    members: list[list[int]] = [[] for _ in range(n)]
    order = sorted(range(len(bucket_sizes)),
                   key=lambda i: (-int(bucket_sizes[i]), i))
    for i in order:
        c = min(range(n), key=lambda j: (loads[j], j))
        members[c].append(i)
        loads[c] += int(bucket_sizes[i])
    return tuple(ChannelAssignment(c, tuple(sorted(members[c])), loads[c])
                 for c in range(n))


@dataclass(frozen=True)
class CommPlan:
    """Bucket layout + channel striping + predicted bytes for one pytree."""

    transport: str
    axes: tuple[str, ...]
    axis_sizes: tuple[int, ...]
    bucket_plan: BucketPlan
    channels: tuple[ChannelAssignment, ...]
    wire_bytes_per_elem: float     # codec/wire-dtype bytes per element
    bytes_per_device: float        # predicted all-reduce wire bytes/device

    @property
    def n_buckets(self) -> int:
        return self.bucket_plan.n_buckets

    @property
    def n_channels(self) -> int:
        return len(self.channels)

    @property
    def total_elems(self) -> int:
        return self.bucket_plan.total_elems

    @property
    def world(self) -> int:
        w = 1
        for p in self.axis_sizes:
            w *= p
        return w

    def bucket_channel(self, bucket: int) -> int:
        for a in self.channels:
            if bucket in a.buckets:
                return a.channel
        raise KeyError(bucket)

    @property
    def channel_imbalance(self) -> float:
        """max/mean channel load (1.0 = perfectly striped)."""
        loads = [a.elems for a in self.channels]
        mean = sum(loads) / max(len(loads), 1)
        return max(loads) / mean if mean else 1.0

    def predicted_collective_bytes(self) -> dict[str, float]:
        """The dict ``GradientReducer.predicted_collective_bytes`` returned,
        plus the channel-level breakdown."""
        used = self.bucket_plan.used_elems
        return {
            "bytes_per_device": self.bytes_per_device,
            "grad_bytes": used * 4.0,
            "wire_bytes_per_elem": self.wire_bytes_per_elem,
            "n_channels": float(self.n_channels),
            "channel_imbalance": self.channel_imbalance,
        }

    def describe(self) -> dict:
        """JSON-friendly summary for the dry-run report."""
        return {
            "transport": self.transport,
            "axes": list(self.axes),
            "axis_sizes": list(self.axis_sizes),
            "world": self.world,
            "n_buckets": self.n_buckets,
            "total_elems": self.total_elems,
            "padding_waste": self.bucket_plan.padding_waste,
            "channels": [{"channel": a.channel, "buckets": list(a.buckets),
                          "elems": a.elems} for a in self.channels],
            **self.predicted_collective_bytes(),
        }

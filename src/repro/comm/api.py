"""The Communicator: every collective in the system behind one object.

The paper's central technique is concurrency through multiple independent
communicators (multi-rail PSM2 endpoints) over guaranteed large buffers.
:class:`Communicator` makes that a first-class object: constructed once from
``(mesh, CommConfig)``, it owns

* the **transport** — a registered collective schedule
  (:mod:`repro.comm.registry`) whose capabilities are checked here, at
  construction, so an invalid combination never reaches trace time;
* the **bucketer** — fused, alignment-guaranteed flat buffers
  (:mod:`repro.core.bucketing`);
* the **virtual channels** — ``cfg.channels`` independent rails that the
  bucket list is striped across (:func:`repro.comm.plan.assign_channels`).
  ``channels == 0`` leaves every bucket an independent collective (the
  scheduler free-for-all); ``channels == N`` guarantees exactly N rails,
  each issuing its buckets in FIFO order with no cross-rail dependencies —
  the multi-rail analogue as a config knob instead of a code path.

Collective methods (``all_reduce`` / ``reduce_scatter`` / ``all_gather`` /
``halo_exchange``) run *inside* a fully-manual ``shard_map``; ``reduce`` is
the SPMD convenience wrapper that opens one for you.  ``GradientReducer``
(:mod:`repro.core.reducer`) survives as a thin deprecated shim over this
class.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.comm.plan import (A2APlan, ChannelAssignment, CommPlan,
                             HaloChannel, HaloPlan, assign_channels)
from repro.comm.registry import Transport, get_transport
from repro.comm.schedule import (CommSchedule, build_halo_schedule,
                                 build_moe_schedule, build_schedule,
                                 halo_units)
from repro.core.bucketing import BucketPlan, GradientBucketer
from repro.comm.wire_codec import ErrorFeedback
from repro.core.halo import HaloSpec, halo_exchange as _halo_exchange
from repro.core.ring import RingConfig
from repro.core.topology import order_token, reduce_axes_of

if TYPE_CHECKING:  # repro.mem is imported lazily (it imports comm.schedule)
    from repro.mem.arena import CommArena, QuantCommArena
    from repro.mem.layout import ArenaLayout, QuantArenaLayout

# NOTE: the legacy ``POLICY_TO_TRANSPORT`` table and
# ``comm_config_from_policy`` live with the rest of the string-policy shim
# in :mod:`repro.core.reducer` (re-exported from :mod:`repro.comm` for
# compatibility).


@dataclass(frozen=True)
class CommConfig:
    """Static (compile-time) description of the communication substrate."""

    transport: str = "ring_hier"
    data_axes: tuple[str, ...] = ("pod", "data")
    bucket_bytes: int = 4 * 2**20
    page_bytes: int = 2 * 2**20    # arena quantization granule (huge page)
    channels: int = 0              # 0 = unconstrained; N = N guaranteed rails
    chunks: int = 2                # per-segment ppermute chains (ring only)
    bidirectional: bool = True
    wire_dtype: str | None = None
    wire_codec: str | None = None  # "int8": quantized wire + arena codec
    codec_block: int = 512
    local_op: str = "jnp"          # "jnp" | "pallas" (kernels/reduce_add)
    mean: bool = True
    fuse: bool = True              # False: per-tensor collectives, no buckets

    def ring_config(self, codec: str | None = None) -> RingConfig:
        return RingConfig(chunks=self.chunks, bidirectional=self.bidirectional,
                          wire_dtype=self.wire_dtype, local_op=self.local_op,
                          codec=codec, codec_block=self.codec_block)


class Communicator:
    """Channelized collectives over the data axes of ``mesh``."""

    def __init__(self, mesh: Mesh, cfg: CommConfig = CommConfig()):
        spec, cls = get_transport(cfg.transport)   # unknown -> ValueError
        if cfg.wire_dtype not in spec.wire_dtypes:
            raise ValueError(
                f"transport {cfg.transport!r} does not support "
                f"wire_dtype={cfg.wire_dtype!r} (allowed: {spec.wire_dtypes})")
        if cfg.channels < 0:
            raise ValueError(f"channels must be >= 0, got {cfg.channels}")
        if cfg.chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {cfg.chunks}")
        if not cfg.fuse and spec.supports_rs:
            # ring schedules need the bucketer's alignment guarantees;
            # unfused (per-tensor) mode is only safe on native collectives
            raise ValueError(
                f"transport {cfg.transport!r} requires fused aligned buckets "
                f"(fuse=True); only native transports support fuse=False")
        self.mesh = mesh
        self.cfg = cfg
        self.spec = spec
        self.axes = reduce_axes_of(mesh.axis_names, cfg.data_axes)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.axis_sizes = tuple(sizes[a] for a in self.axes)
        self.world = 1
        for s in self.axis_sizes:
            self.world *= s
        codec = (cfg.wire_codec if cfg.wire_codec is not None
                 else spec.codec)
        if codec not in (None, "int8"):
            raise ValueError(f"unknown wire_codec {codec!r} "
                             f"(supported: 'int8')")
        if cfg.wire_codec is not None and cfg.wire_dtype is not None:
            raise ValueError("wire_codec and wire_dtype are exclusive wire "
                             "formats; set at most one")
        self.codec = codec
        # codec-capable (ring-family) transports carry the int8 payload on
        # every hop; others (psum) reduce locally-dequantized fp32 spans,
        # so their ring config stays lossless and the wire is priced fp32
        self._ring_cfg = cfg.ring_config(
            codec=codec if spec.supports_codec else None)
        self.transport: Transport = cls(self.axes, self._ring_cfg)
        pad = self.transport.flat_divisor(self.axis_sizes)
        if codec is not None:
            # quantized segments hold whole codec blocks even when the
            # transport's own divisor (e.g. psum) does not include them
            pad = math.lcm(pad, cfg.codec_block)
        self.bucketer = GradientBucketer(bucket_bytes=cfg.bucket_bytes,
                                         pad_multiple=pad)
        self._ef = (ErrorFeedback(self._ring_cfg.make_codec())
                    if self._ring_cfg.codec is not None else None)

    # -- layout / planning ---------------------------------------------------

    @property
    def ordered_axes(self) -> tuple[str, ...]:
        """Innermost (fastest / intra-pod) axis first."""
        return self.transport.ordered_axes

    def stripe(self, bucket_sizes: Sequence[int]
               ) -> tuple[ChannelAssignment, ...]:
        """Partition a bucket list across the virtual channels.

        With ``channels == 0`` every bucket gets its own channel (fully
        independent collectives); otherwise exactly ``cfg.channels`` rails.
        """
        n = self.cfg.channels if self.cfg.channels >= 1 else max(len(bucket_sizes), 1)
        return assign_channels(bucket_sizes, n)

    def plan(self, tree) -> CommPlan:
        """Full communication plan for one gradient-shaped pytree, including
        the page-quantized :class:`~repro.mem.layout.ArenaLayout` the arena
        mode would reduce out of (pages, padding overhead, and the fused
        α/β cost where padding bytes cross the wire too)."""
        bplan = self.bucketer.plan(tree)
        chans = self.stripe(bplan.bucket_sizes)
        n = max(bplan.used_elems, 1)
        codec = self._ring_cfg.make_codec()
        wire_per_elem = codec.wire_bytes(n) / n
        bytes_dev = self.transport.predicted_bytes_per_device(
            bplan.used_elems, self.axis_sizes)
        msgs_per_unit = self.transport.predicted_messages_per_device(
            self.axis_sizes)
        # silent layout: plan() runs for every dry-run/roofline cell; the
        # oversized-leaf warning belongs to actual arena construction
        layout = self.arena_layout(tree, warn=False, _chans=chans)
        # quantized arenas move their (padded) payload elements at the
        # codec's bytes/elem; the trailing scale segment never travels as
        # a unit — scales ride each span's hop payload (priced by the
        # codec's wire_bytes) or stay local under fp32-wire transports
        wire_elems = getattr(layout, "payload_elems", layout.total_elems)
        arena_bytes = self.transport.predicted_bytes_per_device(
            wire_elems, self.axis_sizes)
        return CommPlan(transport=self.cfg.transport, axes=self.axes,
                        axis_sizes=self.axis_sizes, bucket_plan=bplan,
                        channels=chans, wire_bytes_per_elem=wire_per_elem,
                        bytes_per_device=bytes_dev,
                        messages_per_device=msgs_per_unit * bplan.n_buckets,
                        arena_layout=layout,
                        arena_bytes_per_device=arena_bytes,
                        arena_messages_per_device=(msgs_per_unit
                                                   * layout.n_spans),
                        wire_codec=self.codec,
                        codec_block=self.cfg.codec_block)

    def arena_layout(self, tree, *, warn: bool = True,
                     _chans: tuple[ChannelAssignment, ...] | None = None
                     ) -> "ArenaLayout | QuantArenaLayout":
        """The page-quantized arena placement of ``tree``'s buckets:
        segment offsets/sizes quantized to ``cfg.page_bytes`` (lcm'd with
        the transport's flat divisor so fused spans stay reduce-scatter
        legal), segments grouped into one contiguous span per virtual
        channel.  Under a wire codec this is the int8
        :class:`~repro.mem.layout.QuantArenaLayout` (payload + trailing
        scale segment).  (``bucketer.plan`` is signature-cached, so
        repeated calls on the same tree shape replan nothing; ``_chans``
        lets :meth:`plan` reuse its striping.)"""
        from repro.mem.layout import (arena_from_bucket_plan,
                                      quant_arena_from_bucket_plan)

        bplan = self.bucketer.plan(tree)
        chans = (_chans if _chans is not None
                 else self.stripe(bplan.bucket_sizes))
        chan_of = [0] * bplan.n_buckets
        for a in chans:
            for b in a.buckets:
                chan_of[b] = a.channel
        if self.codec is not None:
            return quant_arena_from_bucket_plan(
                bplan, page_bytes=self.cfg.page_bytes,
                block=self.cfg.codec_block, channel_of=chan_of,
                pad_multiple=self.bucketer.pad_multiple,
                bucket_bytes=self.cfg.bucket_bytes, warn_oversized=warn)
        return arena_from_bucket_plan(
            bplan, page_bytes=self.cfg.page_bytes, channel_of=chan_of,
            pad_multiple=self.bucketer.pad_multiple,
            bucket_bytes=self.cfg.bucket_bytes, warn_oversized=warn)

    def arena(self, tree) -> "CommArena | QuantCommArena":
        """A :class:`~repro.mem.arena.CommArena` (or
        :class:`~repro.mem.arena.QuantCommArena` under a wire codec) over
        :meth:`arena_layout`; the pack/unpack implementation follows
        ``cfg.local_op`` (the same knob that selects the Pallas ring-step
        accumulate)."""
        from repro.mem.arena import CommArena, QuantCommArena

        impl = "pallas" if self.cfg.local_op == "pallas" else "jnp"
        if self.codec is not None:
            return QuantCommArena(self.arena_layout(tree), impl=impl)
        return CommArena(self.arena_layout(tree), impl=impl)

    # -- channelized execution (inside a fully-manual shard_map) -------------

    def _run_striped(self, op, items: list) -> list:
        """Apply ``op`` to every flat buffer, honouring channel striping:
        buffers on the same rail are chained (``order_token``, so each rail
        issues FIFO), rails stay independent."""
        if self.cfg.channels < 1:
            return [op(x) for x in items]
        out: list = [None] * len(items)
        for assignment in self.stripe([int(x.shape[0]) for x in items]):
            dep = None
            for i in assignment.buckets:
                y = op(order_token(dep, items[i]))
                dep = y.reshape(-1)[0]
                out[i] = y
        return out

    def all_reduce(self, buckets: list) -> list:
        """Sum each flat bucket over the data axes (no mean)."""
        return self._run_striped(self.transport.all_reduce, buckets)

    def reduce_scatter(self, buckets: list) -> list:
        """Sum-and-shard each flat bucket (inner axis segments first)."""
        if not self.spec.supports_rs:
            raise ValueError(
                f"transport {self.cfg.transport!r} does not support "
                f"reduce-scatter (supports_rs=False)")
        return self._run_striped(self.transport.reduce_scatter, buckets)

    def all_gather(self, shards: list) -> list:
        """Inverse of :meth:`reduce_scatter` (same ownership layout)."""
        if not self.spec.supports_rs:
            raise ValueError(
                f"transport {self.cfg.transport!r} does not support "
                f"all-gather (supports_rs=False)")
        return self._run_striped(self.transport.all_gather, shards)

    def gather_flat(self, shard: jax.Array, *, native: bool = False) -> jax.Array:
        """Per-axis all-gather of one flat shard (FSDP weight path).

        ``native=True`` emits one XLA all-gather op per axis (its autodiff
        transpose is ``psum_scatter``); otherwise the transport's unrolled
        ring schedule is used (transpose == ring reduce-scatter-sum)."""
        if native:
            for ax in self.axes:               # outermost first
                shard = lax.all_gather(shard, ax, tiled=True)
            return shard
        return self.transport.all_gather(shard)

    @property
    def halo_chunks(self) -> int:
        """Pieces each face splits into under the ``chunked`` schedule:
        the channel knob when set, else 4 (the paper's threaded default).
        Single source of the fallback for the executor, the prediction
        layers, and the benchmarks."""
        return self.cfg.channels if self.cfg.channels >= 1 else 4

    def _halo_schedule_name(self, schedule: str | None) -> str:
        return schedule if schedule is not None else (
            "chunked" if self.cfg.channels >= 2 else "concurrent")

    def halo_exchange(self, x: jax.Array, specs: Sequence[HaloSpec], *,
                      schedule: str | None = None) -> dict:
        """Cartesian halo exchange sharing the communicator's channel knob:
        under ``chunked``, ``channels >= 2`` splits every face across that
        many independent rails (the paper's threaded multi-EP columns);
        under ``overlap``, whole faces are striped across the ``channels``
        guaranteed rails with per-rail FIFO order — the same rail rule as
        :meth:`reduce_scheduled` — so interior stencil compute can hide the
        transfers (see :mod:`repro.stencil.op`)."""
        return _halo_exchange(x, specs,
                              schedule=self._halo_schedule_name(schedule),
                              chunks=self.halo_chunks,
                              channels=self.cfg.channels)

    def halo_schedule(self, x_shape: Sequence[int], specs: Sequence[HaloSpec],
                      *, schedule: str | None = None,
                      itemsize: int = 4) -> CommSchedule:
        """The issue slots :meth:`halo_exchange` would execute for one local
        shard of ``x_shape`` — halo overlap as a first-class
        :class:`~repro.comm.schedule.CommSchedule`, exactly like bucket
        reduction (its ``overlap_fraction`` feeds the roofline's
        ``t_exposed_collective``)."""
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        return build_halo_schedule(specs, x_shape,
                                   schedule=self._halo_schedule_name(schedule),
                                   channels=self.cfg.channels,
                                   chunks=self.halo_chunks,
                                   itemsize=itemsize, axis_sizes=sizes)

    def halo_plan(self, x_shape: Sequence[int], specs: Sequence[HaloSpec], *,
                  schedule: str | None = None, itemsize: int = 4) -> HaloPlan:
        """Halo bytes per direction × channel for one exchange — the
        :class:`~repro.comm.plan.HaloPlan` analogue of :meth:`plan`, read by
        the dry-run's stencil suite and ``benchmarks/bench_cg.py``."""
        sched = self.halo_schedule(x_shape, specs, schedule=schedule,
                                   itemsize=itemsize)
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        keys, _ = halo_units(specs, x_shape, schedule=sched.policy,
                             chunks=self.halo_chunks,
                             itemsize=itemsize, axis_sizes=sizes)
        by_channel: dict[int, list[int]] = {}
        for slot in sched.slots:
            by_channel.setdefault(slot.channel, []).extend(slot.bucket_ids)
        chans = tuple(HaloChannel(c, tuple(sorted(u)), sum(
            sched.bucket_sizes[i] for i in u)) for c, u in
            sorted(by_channel.items()))
        return HaloPlan(
            schedule=sched.policy,
            axes=tuple(s.axis for s in specs),
            axis_sizes=tuple(sizes.get(s.axis, 1) for s in specs),
            local_shape=tuple(int(n) for n in x_shape),
            halos=tuple(s.halo for s in specs),
            unit_keys=tuple(keys),
            unit_bytes=sched.bucket_sizes,
            channels=chans,
            overlap_fraction=sched.overlap_fraction,
        )

    # -- all-to-all (expert-parallel dispatch/combine) -----------------------

    def _a2a_axis(self) -> str:
        if len(self.axes) != 1:
            raise ValueError(
                f"all_to_all needs exactly one comm axis, got {self.axes}; "
                f"construct the Communicator with data_axes=('model',) (or "
                f"the single EP axis)")
        if not self.spec.supports_a2a:
            raise ValueError(
                f"transport {self.cfg.transport!r} does not support "
                f"all-to-all (supports_a2a=False); use 'a2a', a ring "
                f"transport, or 'psum' (honest replicated fallback)")
        return self.axes[0]

    def a2a_rails(self, shape: Sequence[int]) -> int:
        """Independent channel rails one all-to-all of ``shape`` splits into.

        The payload is striped along its last (feature) dimension —
        ``cfg.channels`` rails when it divides evenly, else a single rail.
        Each rail is an independent collective (its own ppermute chain /
        HLO all-to-all op), the multi-EP concurrency knob applied to
        dispatch.
        """
        c = self.cfg.channels
        if c <= 1:
            return 1
        return c if int(shape[-1]) % c == 0 else 1

    def all_to_all(self, x: jax.Array, *, split_axis: int,
                   concat_axis: int) -> jax.Array:
        """Channelized tiled all-to-all over the single comm axis.

        Semantics of ``lax.all_to_all(..., tiled=True)``: ``x`` splits into
        ``R`` blocks along ``split_axis``, block ``j`` travels to rank
        ``j``, received blocks concatenate along ``concat_axis`` in source
        order.  With ``cfg.channels >= 2`` the payload is striped along its
        last dimension into that many independent rails.
        """
        axis = self._a2a_axis()
        if self.axis_sizes[0] == 1:
            return x                   # single rank: nothing moves (and the
                                       # axis may not even be bound here)
        rails = self.a2a_rails(x.shape)
        if rails <= 1:
            return self.transport.all_to_all(
                x, axis, split_axis=split_axis, concat_axis=concat_axis)
        w = x.shape[-1] // rails
        outs = []
        for c in range(rails):
            part = lax.slice_in_dim(x, c * w, (c + 1) * w, axis=x.ndim - 1)
            outs.append(self.transport.all_to_all(
                part, axis, split_axis=split_axis, concat_axis=concat_axis))
        return jnp.concatenate(outs, axis=-1)

    def all_to_all_ragged(self, payload: jax.Array, counts: jax.Array, *,
                          split_axis: int, concat_axis: int
                          ) -> tuple[jax.Array, jax.Array]:
        """All-to-all of capacity-padded blocks plus their valid-row counts.

        The capacity-factor overflow story: each of the ``R`` destination
        blocks along ``split_axis`` is padded to the static capacity, and
        ``counts`` (int32, shape ``(R,)``) carries how many leading rows of
        each block are real.  Both travel; the receiver gets
        ``(recv_payload, recv_counts)`` where ``recv_counts[j]`` is how many
        rows source ``j`` actually filled — positions past the count are
        pad and must be masked by the caller.  Priced as the payload
        exchange plus ``4 * R`` count bytes.
        """
        axis = self._a2a_axis()
        r = self.axis_sizes[0]
        if counts.shape[0] != r:
            raise ValueError(
                f"counts must have shape ({r},), got {counts.shape}")
        recv = self.all_to_all(payload, split_axis=split_axis,
                               concat_axis=concat_axis)
        if r == 1:
            return recv, counts.astype(jnp.int32)
        recv_counts = self.transport.all_to_all(
            counts.astype(jnp.int32), axis, split_axis=0, concat_axis=0)
        return recv, recv_counts

    def moe_schedule(self, shape: Sequence[int],
                     dtype=jnp.float32) -> CommSchedule:
        """Issue slots for one EP dispatch + combine round-trip of a local
        capacity buffer of ``shape``: per-rail dispatch slots ready early
        (they overlap the previous layer / router math) and combine slots
        ready late (they overlap the expert GEMMs)."""
        axis = self._a2a_axis()
        r = self.axis_sizes[0]
        n = 1
        for d in shape:
            n *= int(d)
        itemsize = jnp.dtype(dtype).itemsize
        rails = self.a2a_rails(shape)
        phase_bytes = self.transport.predicted_a2a_bytes_per_device(
            n, r, itemsize)
        return build_moe_schedule(phase_bytes, rails)

    def a2a_plan(self, shape: Sequence[int], dtype=jnp.float32) -> A2APlan:
        """Predicted wire cost of one EP dispatch + combine round-trip —
        the :class:`~repro.comm.plan.A2APlan` analogue of :meth:`plan`,
        read by the dry-run's moe suite and ``benchmarks/bench_moe.py``."""
        axis = self._a2a_axis()
        r = self.axis_sizes[0]
        n = 1
        for d in shape:
            n *= int(d)
        itemsize = jnp.dtype(dtype).itemsize
        rails = self.a2a_rails(shape)
        sched = self.moe_schedule(shape, dtype)
        by_channel: dict[int, list[int]] = {}
        for slot in sched.slots:
            by_channel.setdefault(slot.channel, []).extend(slot.bucket_ids)
        chans = tuple(HaloChannel(c, tuple(sorted(u)), sum(
            sched.bucket_sizes[i] for i in u)) for c, u in
            sorted(by_channel.items()))
        keys = tuple(f"{phase}#{c}" for phase in ("dispatch", "combine")
                     for c in range(rails))
        return A2APlan(
            transport=self.cfg.transport,
            axis=axis,
            axis_size=r,
            elems_per_device=n,
            itemsize=itemsize,
            unit_keys=keys,
            unit_bytes=sched.bucket_sizes,
            messages_per_unit=self.transport.predicted_a2a_messages_per_device(r),
            channels=chans,
            overlap_fraction=sched.overlap_fraction,
        )

    # -- tree-level ops (inside a fully-manual shard_map) --------------------

    def _mean_buckets(self, buckets: list) -> list:
        if not self.cfg.mean:
            return buckets
        inv = jnp.asarray(1.0 / self.world, jnp.float32)
        return [b * inv for b in buckets]

    def _mean_tree(self, tree):
        if not self.cfg.mean:
            return tree
        inv = 1.0 / self.world
        return jax.tree.map(
            lambda x: (x.astype(jnp.float32) * inv).astype(x.dtype), tree)

    def all_reduce_tree(self, grads, ef_state=None):
        """All-reduce(-mean) a local gradient pytree.  Returns
        ``(reduced, new_ef_state)``; ``ef_state`` passes through as ``None``
        unless the transport carries a lossy codec."""
        if not self.axes:
            return grads, ef_state
        if not self.cfg.fuse:
            red = jax.tree.map(lambda x: self.transport.all_reduce(x), grads)
            return self._mean_tree(red), ef_state
        buckets, bplan = self.bucketer.bucketize(grads)
        new_res = ef_state
        if self._ef is not None and ef_state is not None:
            buckets, new_res = self._ef.compensate(buckets, list(ef_state))
        reduced = self._mean_buckets(self.all_reduce(buckets))
        return self.bucketer.debucketize(reduced, bplan), new_res

    def reduce_scatter_tree(self, grads):
        """Reduce-scatter(-mean) into flat bucket shards (ZeRO path).
        Returns ``(shards, bucket_plan)``; invert with
        :meth:`all_gather_buckets`."""
        buckets, bplan = self.bucketer.bucketize(grads)
        inv = jnp.asarray(1.0 / self.world if self.cfg.mean else 1.0,
                          jnp.float32)
        shards = [s * inv for s in self.reduce_scatter(buckets)]
        return shards, bplan

    def all_gather_buckets(self, shards: list, bplan: BucketPlan | None = None):
        """Inverse of :meth:`reduce_scatter_tree`: full buckets, or the
        debucketized tree when ``bplan`` is given."""
        full = self.all_gather(shards)
        return full if bplan is None else self.bucketer.debucketize(full, bplan)

    # -- dependency-aware scheduled reduction --------------------------------

    def schedule(self, tree, policy: str, microbatches: int = 1
                 ) -> CommSchedule:
        """The :class:`~repro.comm.schedule.CommSchedule` this communicator
        would execute for one gradient-shaped pytree: bucket layout from the
        bucketer, striping from ``cfg.channels``, issue order from
        ``policy``."""
        if not self.cfg.fuse:
            # per-tensor collectives: every leaf is its own "bucket"
            sizes = [int(np.prod(l.shape)) if l.shape else 1
                     for l in jax.tree.leaves(tree)]
            return build_schedule(policy, sizes, microbatches=microbatches,
                                  channels=self.cfg.channels)
        bplan = self.bucketer.plan(tree)
        return build_schedule(policy, bplan.bucket_sizes,
                              microbatches=microbatches,
                              channels=self.cfg.channels)

    def arena_schedule(self, tree, policy: str, microbatches: int = 1
                       ) -> CommSchedule:
        """The span-level schedule the arena mode executes: the bucket
        schedule of :meth:`schedule` with each channel's contiguous arena
        span fused into a single issue
        (:func:`repro.mem.layout.fuse_schedule`)."""
        from repro.mem.layout import fuse_schedule

        return fuse_schedule(self.schedule(tree, policy, microbatches),
                             self.arena_layout(tree))

    def reduce_scheduled(self, grad_fn, params, batch,
                         schedule: CommSchedule, *, op: str = "all_reduce",
                         arena: "CommArena | QuantCommArena | None" = None,
                         arena_buf: jax.Array | None = None,
                         ef_buf: jax.Array | None = None):
        """Run ``grad_fn(params, microbatch) -> (loss, grads)`` over
        ``schedule.microbatches`` slices of ``batch`` (split on the leading
        axis), issuing each gradient bucket's collective at its schedule
        slot.  Runs *inside* a fully-manual ``shard_map``.

        ``op`` selects the per-bucket collective:

        * ``"all_reduce"``     -> returns ``(mean_loss, reduced_tree)``;
        * ``"reduce_scatter"`` -> ``(mean_loss, (shards, bucket_plan))`` —
          each microbatch's buckets reduce-scatter as they are produced
          (streamed ZeRO), shards accumulate locally;
        * ``"none"``           -> ``(mean_loss, accumulated_tree)`` for
          modes whose reduction rides the autodiff transpose (FSDP); the
          schedule then only describes the intrinsic overlap.

        Buckets sharing a rail (``schedule.channels >= 1``) are chained with
        :func:`~repro.core.topology.order_token` so each rail issues FIFO in
        readiness order; rails stay independent.  ``channels == 0`` leaves
        every collective unconstrained.

        **Arena mode** (``arena`` given): gradients pack into the
        page-aligned :class:`~repro.mem.arena.CommArena` buffer and each
        issue slot reduces one contiguous arena *span* instead of a bucket
        — fewer, larger, aligned messages (``schedule`` must then be the
        span-level :meth:`arena_schedule`).  ``arena_buf`` is the persistent
        (donated) buffer from the step state; it is returned alongside the
        result so the caller can thread it back:

        * ``"all_reduce"``     -> ``(loss, (tree, arena_out))``;
        * ``"reduce_scatter"`` -> ``(loss, (span_shards, bucket_plan,
          arena_out))`` — invert with :meth:`all_gather` over the spans and
          :meth:`CommArena.unpack_spans <repro.mem.arena.CommArena
          .unpack_spans>`;
        * ``"none"``           -> ``(loss, (tree, arena_out))`` — the arena
          is the microbatch accumulation buffer (FSDP: reduction rides the
          gather transpose, so only residency changes).

        **Quantized arena mode** (``arena`` a
        :class:`~repro.mem.arena.QuantCommArena`): packing *encodes* (fused
        pack+quantize with the ``ef_buf`` error-feedback accumulator
        compensated at pack time), spans are decoded to fp32 before the
        collective (codec-capable transports re-encode on every hop, so
        the wire carries int8 + scales; others reduce fp32), and the
        reduced values re-encode into the arena for the fused
        dequant+unpack out.  Every return gains the threaded-back ``ef``:
        ``(loss, (tree, arena_out, ef_out))`` for ``all_reduce``/``none``,
        ``(loss, (span_shards, bucket_plan, arena_out, ef_out))`` for
        ``reduce_scatter``.
        """
        if op not in ("all_reduce", "reduce_scatter", "none"):
            raise ValueError(f"op must be all_reduce|reduce_scatter|none, "
                             f"got {op!r}")
        if op == "reduce_scatter" and not self.spec.supports_rs:
            raise ValueError(
                f"transport {self.cfg.transport!r} does not support "
                f"reduce-scatter (supports_rs=False)")
        if arena is not None:
            from repro.mem.arena import QuantCommArena

            if isinstance(arena, QuantCommArena):
                return self._reduce_scheduled_arena_quant(
                    grad_fn, params, batch, schedule, op, arena, arena_buf,
                    ef_buf)
            return self._reduce_scheduled_arena(grad_fn, params, batch,
                                                schedule, op, arena,
                                                arena_buf)
        if not self.axes:
            if op == "reduce_scatter":
                # downgrading would change the return shape from
                # (shards, plan) to a tree under the caller's feet
                raise ValueError(
                    "reduce_scatter schedule needs data axes; this "
                    "communicator's mesh has none")
            op = "none"                      # no data axes: nothing to reduce
        m = max(schedule.microbatches, 1)
        collective = (self.transport.all_reduce if op == "all_reduce"
                      else self.transport.reduce_scatter)

        micro = (jax.tree.map(
            lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)
            if m > 1 else None)
        inv = 1.0 / m
        deps: dict[int, jax.Array] = {}      # rail -> FIFO ordering token
        chained = schedule.channels >= 1

        def issue(bucket, channel):
            if not chained:
                return collective(bucket)
            y = collective(order_token(deps.get(channel), bucket))
            deps[channel] = y.reshape(-1)[0]
            return y

        streamed = schedule.policy != "accumulate_then_reduce"
        fused = self.cfg.fuse
        losses = []
        acc = None                           # tree (op=none) or bucket list
        bplan: BucketPlan | None = None
        treedef = None                       # unfused (per-tensor) layout
        for i in range(m):
            mb = batch if m == 1 else jax.tree.map(lambda x: x[i], micro)
            loss, grads = grad_fn(params, mb)
            losses.append(loss)
            if op == "none":
                if m > 1:
                    grads = jax.tree.map(
                        lambda g: g.astype(jnp.float32) * inv, grads)
                acc = (grads if acc is None
                       else jax.tree.map(jnp.add, acc, grads))
                continue
            if fused:
                buckets, bplan = self.bucketer.bucketize(grads)
                n_units = bplan.n_buckets
            else:                            # per-tensor: leaf == "bucket"
                buckets, treedef = jax.tree.flatten(grads)
                n_units = len(buckets)
            if n_units != schedule.n_buckets:
                raise ValueError(
                    f"schedule has {schedule.n_buckets} buckets but the "
                    f"gradient tree bucketizes into {n_units}; build "
                    f"the schedule with Communicator.schedule on the same "
                    f"tree")
            if m > 1:
                buckets = [b.astype(jnp.float32) * inv for b in buckets]
            if streamed:
                out: list = [None] * len(buckets)
                for slot in schedule.slots_for_phase(i):
                    for b in slot.bucket_ids:
                        out[b] = issue(buckets[b], slot.channel)
                acc = out if acc is None else [a + o for a, o in zip(acc, out)]
            else:
                acc = (buckets if acc is None
                       else [a + b for a, b in zip(acc, buckets)])
        if op != "none" and not streamed:
            out = [None] * len(acc)
            for slot in schedule.slots_for_phase(m - 1):
                for b in slot.bucket_ids:
                    out[b] = issue(acc[b], slot.channel)
            acc = out
        loss = losses[0] if m == 1 else jnp.mean(jnp.stack(losses))
        if op == "none":
            return loss, acc
        if not fused:                        # per-tensor mean, dtype-stable
            if self.cfg.mean:
                winv = 1.0 / self.world
                acc = [(a.astype(jnp.float32) * winv).astype(a.dtype)
                       for a in acc]
            return loss, jax.tree.unflatten(treedef, acc)
        acc = self._mean_buckets(acc)
        if op == "reduce_scatter":
            return loss, (acc, bplan)
        return loss, self.bucketer.debucketize(acc, bplan)

    def _reduce_scheduled_arena(self, grad_fn, params, batch,
                                schedule: CommSchedule, op: str,
                                arena: "CommArena",
                                arena_buf: jax.Array | None):
        """Arena-mode body of :meth:`reduce_scheduled` (see there).  Every
        collective moves one contiguous page-quantized span of the arena —
        padding crosses the wire, buckets never move individually."""
        layout = arena.layout
        if not self.axes:
            raise ValueError("arena mode needs data axes; this "
                             "communicator's mesh has none")
        if op != "none":
            if not self.cfg.fuse:
                raise ValueError("arena mode needs fused aligned buckets "
                                 "(fuse=True)")
            if schedule.n_buckets != layout.n_spans:
                raise ValueError(
                    f"arena mode expects a span-level schedule with "
                    f"{layout.n_spans} spans, got {schedule.n_buckets}; "
                    f"build it with Communicator.arena_schedule")
        m = max(schedule.microbatches, 1)
        collective = (self.transport.all_reduce if op == "all_reduce"
                      else self.transport.reduce_scatter)
        micro = (jax.tree.map(
            lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)
            if m > 1 else None)
        inv = 1.0 / m
        deps: dict[int, jax.Array] = {}
        chained = schedule.channels >= 1

        def issue(span_buf, channel):
            if not chained:
                return collective(span_buf)
            y = collective(order_token(deps.get(channel), span_buf))
            deps[channel] = y.reshape(-1)[0]
            return y

        def reduce_spans(buf, phase):
            """All-reduce each span in place (slice, reduce, write back)."""
            for slot in schedule.slots_for_phase(phase):
                for s in slot.bucket_ids:       # span indices
                    sp = layout.spans[s]
                    seg = lax.slice_in_dim(buf, sp.offset,
                                           sp.offset + sp.size, axis=0)
                    buf = lax.dynamic_update_slice_in_dim(
                        buf, issue(seg, slot.channel), sp.offset, axis=0)
            return buf

        def scatter_spans(buf, phase, out):
            """Reduce-scatter each span into its shard slot."""
            for slot in schedule.slots_for_phase(phase):
                for s in slot.bucket_ids:
                    sp = layout.spans[s]
                    seg = lax.slice_in_dim(buf, sp.offset,
                                           sp.offset + sp.size, axis=0)
                    out[s] = issue(seg, slot.channel)
            return out

        streamed = schedule.policy != "accumulate_then_reduce"
        losses = []
        acc = None                 # arena buffer, or span-shard list (RS)
        bplan: BucketPlan | None = None
        treedef = None             # op == "none": the grads tree layout
        leaf_meta: list[tuple] = []
        buf = arena_buf if arena_buf is not None else arena.zeros()
        for i in range(m):
            mb = batch if m == 1 else jax.tree.map(lambda x: x[i], micro)
            loss, grads = grad_fn(params, mb)
            losses.append(loss)
            if op == "none":
                leaves, treedef = jax.tree.flatten(grads)
                if len(leaves) != layout.n_segments:
                    raise ValueError(
                        f"arena has {layout.n_segments} segments but the "
                        f"gradient tree has {len(leaves)} leaves; build "
                        f"the arena from the same tree")
                leaf_meta = [(l.shape, l.dtype) for l in leaves]
                if m > 1:
                    leaves = [l.astype(jnp.float32) * inv for l in leaves]
                buf = arena.pack_into(buf, [l.reshape(-1) for l in leaves])
                acc = buf if acc is None else acc + buf
                continue
            buckets, bplan = self.bucketer.bucketize(grads)
            if bplan.n_buckets != layout.n_segments:
                raise ValueError(
                    f"arena has {layout.n_segments} segments but the "
                    f"gradient tree bucketizes into {bplan.n_buckets}; "
                    f"build the arena with Communicator.arena on the same "
                    f"tree")
            if m > 1:
                buckets = [b.astype(jnp.float32) * inv for b in buckets]
            buf = arena.pack_into(buf, buckets)
            if not streamed:
                acc = buf if acc is None else acc + buf
            elif op == "all_reduce":
                red = reduce_spans(buf, i)
                acc = red if acc is None else acc + red
            else:
                out = scatter_spans(buf, i, [None] * layout.n_spans)
                acc = out if acc is None else [a + o
                                               for a, o in zip(acc, out)]
        if op != "none" and not streamed:
            acc = (reduce_spans(acc, m - 1) if op == "all_reduce"
                   else scatter_spans(acc, m - 1, [None] * layout.n_spans))
        loss = losses[0] if m == 1 else jnp.mean(jnp.stack(losses))
        if op == "none":
            leaves = arena.unpack(acc)
            leaves = [u.reshape(shape).astype(jnp.float32 if m > 1
                                              else dtype)
                      for u, (shape, dtype) in zip(leaves, leaf_meta)]
            return loss, (jax.tree.unflatten(treedef, leaves), acc)
        if op == "reduce_scatter":
            inv_w = jnp.asarray(1.0 / self.world if self.cfg.mean else 1.0,
                                jnp.float32)
            return loss, ([s * inv_w for s in acc], bplan, buf)
        if self.cfg.mean:
            acc = acc * jnp.asarray(1.0 / self.world, jnp.float32)
        tree = self.bucketer.debucketize(arena.unpack(acc), bplan)
        return loss, (tree, acc)

    def _reduce_scheduled_arena_quant(self, grad_fn, params, batch,
                                      schedule: CommSchedule, op: str,
                                      arena: "QuantCommArena",
                                      arena_buf: jax.Array | None,
                                      ef_buf: jax.Array | None):
        """Quantized-arena body of :meth:`reduce_scheduled` (see there).

        The int8 arena cannot accumulate across microbatches, so gradients
        accumulate in fp32 (bucket lists, or reduced span values under the
        streamed policy) and the arena encodes at issue boundaries: fused
        pack+quantize on the way in (error feedback compensated from
        ``ef_buf``, residual written back), span dequant before each
        collective, and — for ``all_reduce`` — a final re-encode of the
        reduced mean so the gradient the caller sees comes out of the fused
        dequant+unpack, exactly what the next step's wire would carry.
        """
        layout = arena.layout
        if not self.axes:
            raise ValueError("arena mode needs data axes; this "
                             "communicator's mesh has none")
        if op != "none":
            if not self.cfg.fuse:
                raise ValueError("arena mode needs fused aligned buckets "
                                 "(fuse=True)")
            if schedule.n_buckets != layout.n_spans:
                raise ValueError(
                    f"arena mode expects a span-level schedule with "
                    f"{layout.n_spans} spans, got {schedule.n_buckets}; "
                    f"build it with Communicator.arena_schedule")
        m = max(schedule.microbatches, 1)
        collective = (self.transport.all_reduce if op == "all_reduce"
                      else self.transport.reduce_scatter)
        micro = (jax.tree.map(
            lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)
            if m > 1 else None)
        inv = 1.0 / m
        deps: dict[int, jax.Array] = {}
        chained = schedule.channels >= 1

        def issue(span_vals, channel):
            if not chained:
                return collective(span_vals)
            y = collective(order_token(deps.get(channel), span_vals))
            deps[channel] = y.reshape(-1)[0]
            return y

        buf = arena_buf if arena_buf is not None else arena.zeros()
        ef = ef_buf
        streamed = schedule.policy != "accumulate_then_reduce"
        losses = []
        span_acc: list | None = None   # fp32 reduced spans (AR) / shards (RS)
        bucket_acc: list | None = None  # accumulate_then_reduce fp32 buckets
        leaf_acc: list | None = None    # op == "none" fp32 leaves
        bplan: BucketPlan | None = None
        treedef = None
        leaf_meta: list[tuple] = []

        def run_phase(phase):
            """Decode each of the phase's spans and issue its collective."""
            nonlocal buf
            out: list = [None] * layout.n_spans
            for slot in schedule.slots_for_phase(phase):
                for s in slot.bucket_ids:       # span indices
                    out[s] = issue(arena.dequant_span(buf, s), slot.channel)
            return out

        for i in range(m):
            mb = batch if m == 1 else jax.tree.map(lambda x: x[i], micro)
            loss, grads = grad_fn(params, mb)
            losses.append(loss)
            if op == "none":
                leaves, treedef = jax.tree.flatten(grads)
                if len(leaves) != layout.n_segments:
                    raise ValueError(
                        f"arena has {layout.n_segments} segments but the "
                        f"gradient tree has {len(leaves)} leaves; build "
                        f"the arena from the same tree")
                leaf_meta = [(l.shape, l.dtype) for l in leaves]
                flat = [l.reshape(-1).astype(jnp.float32) for l in leaves]
                if m > 1:
                    flat = [l * inv for l in flat]
                leaf_acc = (flat if leaf_acc is None
                            else [a + l for a, l in zip(leaf_acc, flat)])
                continue
            buckets, bplan = self.bucketer.bucketize(grads)
            if bplan.n_buckets != layout.n_segments:
                raise ValueError(
                    f"arena has {layout.n_segments} segments but the "
                    f"gradient tree bucketizes into {bplan.n_buckets}; "
                    f"build the arena with Communicator.arena on the same "
                    f"tree")
            buckets = [b.astype(jnp.float32) for b in buckets]
            if m > 1:
                buckets = [b * inv for b in buckets]
            if not streamed:
                bucket_acc = (buckets if bucket_acc is None
                              else [a + b
                                    for a, b in zip(bucket_acc, buckets)])
                continue
            buf, ef = arena.pack_into(buf, buckets, ef)
            out = run_phase(i)
            span_acc = (out if span_acc is None
                        else [a + o for a, o in zip(span_acc, out)])
        if op != "none" and not streamed:
            buf, ef = arena.pack_into(buf, bucket_acc, ef)
            span_acc = run_phase(m - 1)
        loss = losses[0] if m == 1 else jnp.mean(jnp.stack(losses))
        if op == "none":
            buf, ef = arena.pack_into(buf, leaf_acc, ef)
            leaves = arena.unpack(buf)
            leaves = [u.reshape(shape).astype(jnp.float32 if m > 1
                                              else dtype)
                      for u, (shape, dtype) in zip(leaves, leaf_meta)]
            return loss, (jax.tree.unflatten(treedef, leaves), buf, ef)
        if op == "reduce_scatter":
            inv_w = jnp.asarray(1.0 / self.world if self.cfg.mean else 1.0,
                                jnp.float32)
            return loss, ([s * inv_w for s in span_acc], bplan, buf, ef)
        if self.cfg.mean:
            inv_w = jnp.asarray(1.0 / self.world, jnp.float32)
            span_acc = [s * inv_w for s in span_acc]
        for s, vals in enumerate(span_acc):
            buf = arena.requant_span(buf, s, vals)
        tree = self.bucketer.debucketize(arena.unpack(buf), bplan)
        return loss, (tree, buf, ef)

    # -- SPMD wrappers (called OUTSIDE shard_map) ----------------------------

    def reduce(self, grads, specs, ef_state=None):
        """Reduce ``grads`` (mean over the data axes) from the SPMD level.

        ``specs``: pytree of ``PartitionSpec`` congruent with ``grads`` (the
        model-sharding of each gradient).  Returns ``(reduced, ef_state)``.
        """
        if not self.axes:
            return grads, ef_state
        ef_spec = P(tuple(self.mesh.axis_names))
        has_ef = self._ef is not None and ef_state is not None
        in_specs = (specs, ef_spec) if has_ef else (specs,)
        out_specs = (specs, ef_spec) if has_ef else (specs,)

        def inner(*args):
            red, new_res = self.all_reduce_tree(
                args[0], args[1] if has_ef else None)
            return (red, new_res) if has_ef else (red,)

        args = (grads, ef_state) if has_ef else (grads,)
        out = compat.shard_map(inner, mesh=self.mesh, in_specs=in_specs,
                               out_specs=out_specs, check_vma=False)(*args)
        return (out[0], out[1]) if has_ef else (out[0], ef_state)

    def init_ef_state(self, grads_like, specs):
        """Zero residual buckets as *global* arrays, one local bucket per
        device (leading dim = all mesh axes); ``grads_like`` may be
        ``ShapeDtypeStruct``s.  ``None`` when the transport is lossless."""
        if self._ef is None:
            return None
        ef_spec = P(tuple(self.mesh.axis_names))

        def inner(g):
            buckets, _ = self.bucketer.bucketize(g)
            return [jnp.zeros_like(b) for b in buckets]

        fn = compat.shard_map(inner, mesh=self.mesh, in_specs=(specs,),
                              out_specs=ef_spec, check_vma=False)
        return jax.jit(fn)(grads_like) if not _is_abstract(grads_like) \
            else jax.eval_shape(fn, grads_like)

    # -- analysis ------------------------------------------------------------

    def predicted_collective_bytes(self, grads_like) -> dict[str, float]:
        """Napkin-math wire bytes per device (reads the :class:`CommPlan`)."""
        return self.plan(grads_like).predicted_collective_bytes()


def _is_abstract(tree) -> bool:
    leaves = jax.tree.leaves(tree)
    return bool(leaves) and isinstance(leaves[0], jax.ShapeDtypeStruct)

"""Transport registry: named collective schedules with declared capabilities.

A *transport* is one way of moving a flat, pre-padded bucket across the data
axes of the mesh — the role the PSM2 endpoint configuration plays in the
paper.  Each transport registers itself under a short name together with a
:class:`TransportSpec` declaring what it can do (``supports_rs`` for the
ZeRO reduce-scatter/all-gather paths, ``supports_codec`` / ``wire_dtypes``
for lossy or narrow wire formats), so an invalid combination fails when the
:class:`~repro.comm.api.Communicator` is constructed — not at trace time
deep inside a jitted step.

Built-in transports (the former ``ReduceConfig.policy`` branches):

========================  ====================================================
``ring``                  flat multi-channel bidirectional ring (pod-oblivious)
``ring_hier``             pod-aware hierarchical ring (RS inner, recurse outer)
``ring_compressed``       deprecated shim: ring_hier + ``wire_codec='int8'``
``psum``                  XLA's native all-reduce (vendor reference)
========================  ====================================================

Third-party schedules register the same way::

    @register_transport("my_ring", supports_rs=True)
    class MyRing(RingTransport):
        ...
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence, Type

import jax
from jax import lax

from repro.core import ring as ring_lib
from repro.core.ring import RingConfig

WIRE_DTYPES_ANY = (None, "bfloat16", "float16", "float32")


@dataclass(frozen=True)
class TransportSpec:
    """Construction-time capability declaration of one transport."""

    name: str
    supports_rs: bool                      # reduce_scatter / all_gather pairs
    supports_codec: bool                   # lossy block codec on the wire
    wire_dtypes: tuple[str | None, ...]    # allowed narrow wire dtypes
    codec: str | None                      # codec this transport always uses
    hierarchical: bool                     # pod-aware byte accounting
    description: str


_TRANSPORTS: dict[str, tuple[TransportSpec, Type["Transport"]]] = {}


def register_transport(name: str, *, supports_rs: bool,
                       supports_codec: bool = False,
                       wire_dtypes: tuple[str | None, ...] = WIRE_DTYPES_ANY,
                       codec: str | None = None,
                       hierarchical: bool = False,
                       description: str = "") -> Callable[[type], type]:
    """Class decorator registering a :class:`Transport` under ``name``."""

    def deco(cls: type) -> type:
        if name in _TRANSPORTS:
            raise ValueError(f"transport {name!r} already registered")
        spec = TransportSpec(name=name, supports_rs=supports_rs,
                             supports_codec=supports_codec,
                             wire_dtypes=wire_dtypes, codec=codec,
                             hierarchical=hierarchical,
                             description=description or (cls.__doc__ or "").strip())
        _TRANSPORTS[name] = (spec, cls)
        cls.spec = spec
        return cls

    return deco


def get_transport(name: str) -> tuple[TransportSpec, Type["Transport"]]:
    """Lookup; raises with the full menu on an unknown name."""
    try:
        return _TRANSPORTS[name]
    except KeyError:
        raise ValueError(
            f"unknown transport {name!r}; registered transports: "
            f"{tuple(sorted(_TRANSPORTS))}") from None


def list_transports() -> tuple[str, ...]:
    return tuple(sorted(_TRANSPORTS))


def transport_specs() -> dict[str, TransportSpec]:
    return {name: spec for name, (spec, _) in _TRANSPORTS.items()}


# ---------------------------------------------------------------------------
# transport implementations
# ---------------------------------------------------------------------------


class Transport:
    """One collective schedule over the data axes.

    All methods run *inside* a fully-manual ``shard_map`` on flat 1-D buffers
    already padded to :meth:`flat_divisor` (``core.bucketing`` guarantees
    that).  ``axes`` is mesh-ordered (outermost first, e.g. ``("pod",
    "data")``); schedules that care about pod locality reverse it themselves.
    """

    spec: TransportSpec  # filled in by @register_transport

    def __init__(self, axes: Sequence[str], ring_cfg: RingConfig):
        self.axes = tuple(axes)
        self.ring_cfg = ring_cfg

    # inner (fastest / intra-pod) axis first — RS ownership order
    @property
    def ordered_axes(self) -> tuple[str, ...]:
        return tuple(reversed(self.axes))

    def flat_divisor(self, axis_sizes: Sequence[int]) -> int:
        return self.ring_cfg.flat_divisor(axis_sizes)

    # -- collectives --------------------------------------------------------

    def all_reduce(self, flat: jax.Array) -> jax.Array:
        raise NotImplementedError

    def reduce_scatter(self, flat: jax.Array) -> jax.Array:
        raise NotImplementedError(
            f"transport {self.spec.name!r} does not support reduce-scatter")

    def all_gather(self, shard: jax.Array) -> jax.Array:
        raise NotImplementedError(
            f"transport {self.spec.name!r} does not support all-gather")

    # -- analysis -----------------------------------------------------------

    def predicted_bytes_per_device(self, n_elems: int,
                                   axis_sizes: Sequence[int]) -> float:
        """Napkin-math wire bytes per device for one all-reduce of
        ``n_elems`` elements (§Perf hypothesis logs / dry-run report)."""
        codec = self.ring_cfg.make_codec()
        wire_per_elem = codec.wire_bytes(max(n_elems, 1)) / max(n_elems, 1)
        if self.spec.hierarchical and len(axis_sizes) > 0:
            inner_p = axis_sizes[-1]
            world = 1
            for p in axis_sizes:
                world *= p
            outer = world // max(inner_p, 1)
            inner_bytes = 2 * (inner_p - 1) / max(inner_p, 1) * n_elems * wire_per_elem
            outer_bytes = (2 * (outer - 1) / outer * (n_elems / inner_p)
                           * wire_per_elem if outer > 1 else 0.0)
            return inner_bytes + outer_bytes
        total = 0.0
        for p in axis_sizes:
            total += 2 * (p - 1) / max(p, 1) * n_elems * wire_per_elem
        return total

    def predicted_messages_per_device(self, axis_sizes: Sequence[int]
                                      ) -> float:
        """Discrete sends per device for one all-reduce of one bucket —
        the α term of :class:`repro.comm.plan.LatencyModel`.  Baseline: a
        single ring per axis pays ``(p−1)`` reduce-scatter plus ``(p−1)``
        all-gather hops; explicit ring transports multiply by their
        chunk × direction parallel chains (more, smaller messages — same
        bytes), see :class:`RingTransport`."""
        return float(sum(2 * (p - 1) for p in axis_sizes))


@register_transport(
    "ring", supports_rs=True, supports_codec=True,
    description="flat multi-channel bidirectional ppermute ring; every byte "
                "crosses every axis at full size (pod-oblivious baseline)")
class RingTransport(Transport):
    """Flat ring: full-size ring all-reduce per data axis in turn."""

    def all_reduce(self, flat: jax.Array) -> jax.Array:
        return ring_lib.flat_all_reduce(flat, self.axes, self.ring_cfg)

    def predicted_messages_per_device(self, axis_sizes: Sequence[int]
                                      ) -> float:
        mult = self.ring_cfg.chunks * (2 if self.ring_cfg.bidirectional
                                       else 1)
        return super().predicted_messages_per_device(axis_sizes) * mult

    def reduce_scatter(self, flat: jax.Array) -> jax.Array:
        for axis in self.ordered_axes:
            flat = ring_lib.ring_reduce_scatter(flat, axis, self.ring_cfg)
        return flat

    def all_gather(self, shard: jax.Array) -> jax.Array:
        for axis in reversed(self.ordered_axes):
            shard = ring_lib.ring_all_gather(shard, axis, self.ring_cfg)
        return shard


@register_transport(
    "ring_hier", supports_rs=True, supports_codec=True, hierarchical=True,
    description="pod-aware hierarchical ring: reduce-scatter the intra-pod "
                "axis first so cross-pod bytes shrink by the pod size")
class HierRingTransport(RingTransport):
    """Hierarchical ring (the paper's optimised schedule; default)."""

    def all_reduce(self, flat: jax.Array) -> jax.Array:
        return ring_lib.hierarchical_all_reduce(flat, self.ordered_axes,
                                                self.ring_cfg)


@register_transport(
    "ring_compressed", supports_rs=True, supports_codec=True, codec="int8",
    hierarchical=True, wire_dtypes=(None,),
    description="deprecated shim: exactly ring_hier with wire_codec='int8' "
                "(prefer the CommConfig knob, which also enables the fused "
                "arena pack+quantize path)")
class CompressedRingTransport(HierRingTransport):
    """Deprecated shim: ``ring_hier`` whose spec pins ``codec='int8'``.

    Kept so existing configs keep running; the codec is now a
    :class:`~repro.comm.api.CommConfig` knob (``wire_codec``) orthogonal to
    the transport, and only the knob form gets the quantized-arena path
    (fused pack+quantize, error feedback in the train state, priced wire
    bytes).  Same hops, same codec, same numbers as before.
    """


@register_transport(
    "psum", supports_rs=False, wire_dtypes=(None,),
    description="XLA's built-in all-reduce (vendor reference point); "
                "no explicit schedule, no RS/AG decomposition")
class PsumTransport(Transport):
    """Native ``lax.psum`` over the data axes."""

    def all_reduce(self, flat: jax.Array) -> jax.Array:
        return lax.psum(flat, self.axes)

    def predicted_bytes_per_device(self, n_elems: int,
                                   axis_sizes: Sequence[int]) -> float:
        # assume the vendor collective is also a bandwidth-optimal ring
        return super().predicted_bytes_per_device(n_elems, axis_sizes)

    def predicted_messages_per_device(self, axis_sizes: Sequence[int]
                                      ) -> float:
        # one fused op over the joint group: a ring-equivalent hop count
        # over the whole world, not one ring per axis
        world = 1
        for p in axis_sizes:
            world *= p
        return float(2 * (world - 1)) if world > 1 else 0.0

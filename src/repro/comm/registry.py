"""Transport registry: named collective schedules with declared capabilities.

A *transport* is one way of moving a flat, pre-padded bucket across the data
axes of the mesh — the role the PSM2 endpoint configuration plays in the
paper.  Each transport registers itself under a short name together with a
:class:`TransportSpec` declaring what it can do (``supports_rs`` for the
ZeRO reduce-scatter/all-gather paths, ``supports_codec`` / ``wire_dtypes``
for lossy or narrow wire formats), so an invalid combination fails when the
:class:`~repro.comm.api.Communicator` is constructed — not at trace time
deep inside a jitted step.

Built-in transports (the former ``ReduceConfig.policy`` branches):

========================  ====================================================
``ring``                  flat multi-channel bidirectional ring (pod-oblivious)
``ring_hier``             pod-aware hierarchical ring (RS inner, recurse outer)
``psum``                  XLA's native all-reduce (vendor reference)
``a2a``                   native ``lax.all_to_all`` (EP dispatch/combine)
========================  ====================================================

(The old ``ring_compressed`` shim was removed: use any ring transport with
``CommConfig(wire_codec="int8")`` — see :mod:`repro.comm.wire_codec`.)

``supports_a2a`` marks transports that can move an expert-parallel capacity
buffer: ring transports implement it as ``p - 1`` explicit pairwise ppermute
hops, ``psum`` as the honest replicated fallback (scatter into the full
exchange matrix, all-reduce, slice own column — priced at its true
``2(p-1)`` cost), and ``a2a`` lowers to a single HLO ``all-to-all`` op.

Third-party schedules register the same way::

    @register_transport("my_ring", supports_rs=True)
    class MyRing(RingTransport):
        ...
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence, Type

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core import ring as ring_lib
from repro.core.ring import RingConfig

WIRE_DTYPES_ANY = (None, "bfloat16", "float16", "float32")


@dataclass(frozen=True)
class TransportSpec:
    """Construction-time capability declaration of one transport."""

    name: str
    supports_rs: bool                      # reduce_scatter / all_gather pairs
    supports_codec: bool                   # lossy block codec on the wire
    wire_dtypes: tuple[str | None, ...]    # allowed narrow wire dtypes
    codec: str | None                      # codec this transport always uses
    hierarchical: bool                     # pod-aware byte accounting
    supports_a2a: bool                     # all_to_all (EP dispatch/combine)
    description: str


_TRANSPORTS: dict[str, tuple[TransportSpec, Type["Transport"]]] = {}


def register_transport(name: str, *, supports_rs: bool,
                       supports_codec: bool = False,
                       wire_dtypes: tuple[str | None, ...] = WIRE_DTYPES_ANY,
                       codec: str | None = None,
                       hierarchical: bool = False,
                       supports_a2a: bool = False,
                       description: str = "") -> Callable[[type], type]:
    """Class decorator registering a :class:`Transport` under ``name``."""

    def deco(cls: type) -> type:
        if name in _TRANSPORTS:
            raise ValueError(f"transport {name!r} already registered")
        spec = TransportSpec(name=name, supports_rs=supports_rs,
                             supports_codec=supports_codec,
                             wire_dtypes=wire_dtypes, codec=codec,
                             hierarchical=hierarchical,
                             supports_a2a=supports_a2a,
                             description=description or (cls.__doc__ or "").strip())
        _TRANSPORTS[name] = (spec, cls)
        cls.spec = spec
        return cls

    return deco


def get_transport(name: str) -> tuple[TransportSpec, Type["Transport"]]:
    """Lookup; raises with the full menu on an unknown name."""
    try:
        return _TRANSPORTS[name]
    except KeyError:
        if name == "ring_compressed":
            raise ValueError(
                "transport 'ring_compressed' was removed; use a ring "
                "transport with CommConfig(wire_codec='int8') instead "
                "(codecs live in repro.comm.wire_codec)") from None
        raise ValueError(
            f"unknown transport {name!r}; registered transports: "
            f"{tuple(sorted(_TRANSPORTS))}") from None


def list_transports() -> tuple[str, ...]:
    return tuple(sorted(_TRANSPORTS))


def transport_specs() -> dict[str, TransportSpec]:
    return {name: spec for name, (spec, _) in _TRANSPORTS.items()}


# ---------------------------------------------------------------------------
# transport implementations
# ---------------------------------------------------------------------------


class Transport:
    """One collective schedule over the data axes.

    All methods run *inside* a fully-manual ``shard_map`` on flat 1-D buffers
    already padded to :meth:`flat_divisor` (``core.bucketing`` guarantees
    that).  ``axes`` is mesh-ordered (outermost first, e.g. ``("pod",
    "data")``); schedules that care about pod locality reverse it themselves.
    """

    spec: TransportSpec  # filled in by @register_transport

    def __init__(self, axes: Sequence[str], ring_cfg: RingConfig):
        self.axes = tuple(axes)
        self.ring_cfg = ring_cfg

    # inner (fastest / intra-pod) axis first — RS ownership order
    @property
    def ordered_axes(self) -> tuple[str, ...]:
        return tuple(reversed(self.axes))

    def flat_divisor(self, axis_sizes: Sequence[int]) -> int:
        return self.ring_cfg.flat_divisor(axis_sizes)

    # -- collectives --------------------------------------------------------

    def all_reduce(self, flat: jax.Array) -> jax.Array:
        raise NotImplementedError

    def reduce_scatter(self, flat: jax.Array) -> jax.Array:
        raise NotImplementedError(
            f"transport {self.spec.name!r} does not support reduce-scatter")

    def all_gather(self, shard: jax.Array) -> jax.Array:
        raise NotImplementedError(
            f"transport {self.spec.name!r} does not support all-gather")

    def all_to_all(self, x: jax.Array, axis: str, *, split_axis: int,
                   concat_axis: int) -> jax.Array:
        """Tiled all-to-all over a single mesh axis (EP dispatch/combine)."""
        raise NotImplementedError(
            f"transport {self.spec.name!r} does not support all-to-all")

    # -- analysis -----------------------------------------------------------

    def predicted_bytes_per_device(self, n_elems: int,
                                   axis_sizes: Sequence[int]) -> float:
        """Napkin-math wire bytes per device for one all-reduce of
        ``n_elems`` elements (§Perf hypothesis logs / dry-run report)."""
        codec = self.ring_cfg.make_codec()
        wire_per_elem = codec.wire_bytes(max(n_elems, 1)) / max(n_elems, 1)
        if self.spec.hierarchical and len(axis_sizes) > 0:
            inner_p = axis_sizes[-1]
            world = 1
            for p in axis_sizes:
                world *= p
            outer = world // max(inner_p, 1)
            inner_bytes = 2 * (inner_p - 1) / max(inner_p, 1) * n_elems * wire_per_elem
            outer_bytes = (2 * (outer - 1) / outer * (n_elems / inner_p)
                           * wire_per_elem if outer > 1 else 0.0)
            return inner_bytes + outer_bytes
        total = 0.0
        for p in axis_sizes:
            total += 2 * (p - 1) / max(p, 1) * n_elems * wire_per_elem
        return total

    def predicted_messages_per_device(self, axis_sizes: Sequence[int]
                                      ) -> float:
        """Discrete sends per device for one all-reduce of one bucket —
        the α term of :class:`repro.comm.plan.LatencyModel`.  Baseline: a
        single ring per axis pays ``(p−1)`` reduce-scatter plus ``(p−1)``
        all-gather hops; explicit ring transports multiply by their
        chunk × direction parallel chains (more, smaller messages — same
        bytes), see :class:`RingTransport`."""
        return float(sum(2 * (p - 1) for p in axis_sizes))

    def predicted_a2a_bytes_per_device(self, n_elems: int, axis_size: int,
                                       itemsize: int = 4) -> float:
        """Wire bytes per device for one all-to-all of a local ``n_elems``
        payload: ``(p-1)/p`` of it leaves the device (the own-block stays)."""
        p = max(int(axis_size), 1)
        return (p - 1) / p * n_elems * itemsize

    def predicted_a2a_messages_per_device(self, axis_size: int) -> float:
        """Sends per device for one all-to-all: ``p - 1`` pairwise hops."""
        return float(max(int(axis_size) - 1, 0))


@register_transport(
    "ring", supports_rs=True, supports_codec=True, supports_a2a=True,
    description="flat multi-channel bidirectional ppermute ring; every byte "
                "crosses every axis at full size (pod-oblivious baseline)")
class RingTransport(Transport):
    """Flat ring: full-size ring all-reduce per data axis in turn."""

    def all_reduce(self, flat: jax.Array) -> jax.Array:
        return ring_lib.flat_all_reduce(flat, self.axes, self.ring_cfg)

    def all_to_all(self, x: jax.Array, axis: str, *, split_axis: int,
                   concat_axis: int) -> jax.Array:
        return ring_lib.ring_all_to_all(x, axis, split_axis=split_axis,
                                        concat_axis=concat_axis)

    def predicted_messages_per_device(self, axis_sizes: Sequence[int]
                                      ) -> float:
        mult = self.ring_cfg.chunks * (2 if self.ring_cfg.bidirectional
                                       else 1)
        return super().predicted_messages_per_device(axis_sizes) * mult

    def reduce_scatter(self, flat: jax.Array) -> jax.Array:
        for axis in self.ordered_axes:
            flat = ring_lib.ring_reduce_scatter(flat, axis, self.ring_cfg)
        return flat

    def all_gather(self, shard: jax.Array) -> jax.Array:
        for axis in reversed(self.ordered_axes):
            shard = ring_lib.ring_all_gather(shard, axis, self.ring_cfg)
        return shard


@register_transport(
    "ring_hier", supports_rs=True, supports_codec=True, hierarchical=True,
    supports_a2a=True,
    description="pod-aware hierarchical ring: reduce-scatter the intra-pod "
                "axis first so cross-pod bytes shrink by the pod size")
class HierRingTransport(RingTransport):
    """Hierarchical ring (the paper's optimised schedule; default)."""

    def all_reduce(self, flat: jax.Array) -> jax.Array:
        return ring_lib.hierarchical_all_reduce(flat, self.ordered_axes,
                                                self.ring_cfg)


@register_transport(
    "psum", supports_rs=False, wire_dtypes=(None,), supports_a2a=True,
    description="XLA's built-in all-reduce (vendor reference point); "
                "no explicit schedule, no RS/AG decomposition; all_to_all "
                "is the honest replicated fallback (full-matrix psum)")
class PsumTransport(Transport):
    """Native ``lax.psum`` over the data axes."""

    def all_reduce(self, flat: jax.Array) -> jax.Array:
        return lax.psum(flat, self.axes)

    def all_to_all(self, x: jax.Array, axis: str, *, split_axis: int,
                   concat_axis: int) -> jax.Array:
        """Replicated-psum emulation — the pre-a2a MoE dispatch pattern.

        Each rank scatters its row of the (src, dst) exchange matrix into a
        zero-padded full buffer, all-reduces the whole matrix, then slices
        its own column.  Every byte of the matrix crosses the wire (the
        ``2(p-1)`` replicated tax this PR's ring/native paths eliminate);
        kept as the honest fallback so the A/B cost is measurable.
        """
        p = compat.axis_size(axis)
        if p == 1:
            return x
        n = x.shape[split_axis]
        if n % p != 0:
            raise ValueError(
                f"all_to_all split dim {n} not divisible by axis size {p}")
        blk = n // p
        blocks = jnp.stack(
            [lax.slice_in_dim(x, j * blk, (j + 1) * blk, axis=split_axis)
             for j in range(p)], axis=0)                  # (p_dst, ...)
        i = lax.axis_index(axis)
        full = jnp.zeros((p,) + blocks.shape, blocks.dtype)
        full = lax.dynamic_update_slice_in_dim(full, blocks[None], i, axis=0)
        full = lax.psum(full, axis)                       # (p_src, p_dst, ...)
        col = lax.dynamic_index_in_dim(full, i, axis=1, keepdims=False)
        return jnp.concatenate([col[j] for j in range(p)], axis=concat_axis)

    def predicted_bytes_per_device(self, n_elems: int,
                                   axis_sizes: Sequence[int]) -> float:
        # assume the vendor collective is also a bandwidth-optimal ring
        return super().predicted_bytes_per_device(n_elems, axis_sizes)

    def predicted_messages_per_device(self, axis_sizes: Sequence[int]
                                      ) -> float:
        # one fused op over the joint group: a ring-equivalent hop count
        # over the whole world, not one ring per axis
        world = 1
        for p in axis_sizes:
            world *= p
        return float(2 * (world - 1)) if world > 1 else 0.0

    def predicted_a2a_bytes_per_device(self, n_elems: int, axis_size: int,
                                       itemsize: int = 4) -> float:
        # honest replicated cost: the full (p, n) exchange matrix is
        # all-reduced, 2(p-1)/p of p*n elems per device
        p = max(int(axis_size), 1)
        return 2 * (p - 1) * n_elems * itemsize

    def predicted_a2a_messages_per_device(self, axis_size: int) -> float:
        p = max(int(axis_size), 1)
        return float(2 * (p - 1))


@register_transport(
    "a2a", supports_rs=False, wire_dtypes=(None,), supports_a2a=True,
    description="native lax.all_to_all (single HLO all-to-all op per "
                "exchange); all_reduce delegates to psum")
class NativeA2ATransport(Transport):
    """Native ``lax.all_to_all`` — the vendor collective for EP dispatch."""

    def all_reduce(self, flat: jax.Array) -> jax.Array:
        return lax.psum(flat, self.axes)

    def all_to_all(self, x: jax.Array, axis: str, *, split_axis: int,
                   concat_axis: int) -> jax.Array:
        if compat.axis_size(axis) == 1:
            return x
        return lax.all_to_all(x, axis, split_axis, concat_axis, tiled=True)

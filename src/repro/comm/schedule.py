"""CommSchedule: dependency-aware issue order for streamed bucket reduction.

The paper's headline speedup comes from keeping communication *in flight
while compute proceeds* — multiple PSM2 endpoints progressing concurrently
with the compute threads.  A :class:`CommSchedule` makes that structure an
explicit object instead of two string policies: an ordered list of
:class:`IssueSlot`\\ s, each saying *which buckets* go out *on which virtual
channel* after *which phase* of the step's compute, derived from
backward-pass readiness order (the last layer's gradients are ready first).

Three schedule families (``SCHEDULE_POLICIES``):

* ``accumulate_then_reduce`` — every bucket issues in the final phase, after
  all microbatch compute (comm-minimal; zero overlap — the reduction
  serialises after the last microbatch).
* ``stream`` — each microbatch's buckets issue as soon as that microbatch's
  backward finishes; microbatch ``i``'s collectives have no data dependency
  on microbatch ``i+1``'s compute, so the scheduler overlaps them.
* ``scheduled`` — like ``stream``, but within each phase buckets issue in
  *readiness order* (highest bucket index — the last layers' gradients —
  first), striped across the virtual channels with per-rail FIFO order.
  This matches when gradients actually materialise during backward, so even
  the final microbatch's early buckets overlap with its remaining backward
  compute.

Every slot records ``ready`` — the fraction of the step's (backward)
compute completed when the slot becomes issuable.  From that the schedule
derives :attr:`CommSchedule.overlap_fraction`, the napkin-math share of
collective traffic that can hide under remaining compute:

    overlap_fraction = sum_slots (w_slot / W) * (1 - ready_slot)

which :mod:`repro.launch.roofline` turns into
``t_exposed_collective = max(0, t_collective - overlap_fraction * t_compute)``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from repro.comm.plan import assign_channels

SCHEDULE_POLICIES = ("accumulate_then_reduce", "stream", "scheduled")

# halo-exchange issue orders (the paper's Seq / Concurrent / Threaded columns
# plus the interior-compute overlap schedule); executed by
# :func:`repro.core.halo.halo_exchange`
HALO_SCHEDULES = ("sequential", "concurrent", "chunked", "overlap")


@dataclass(frozen=True)
class IssueSlot:
    """One issue of one bucket's collective on one virtual channel.

    ``phase`` is the microbatch index after whose backward the slot becomes
    issuable; ``ready`` refines that to a fraction of the *whole step's*
    compute (``scheduled`` sub-divides a phase by bucket readiness).
    """

    phase: int
    bucket_ids: tuple[int, ...]
    channel: int
    ready: float

    @property
    def exposed(self) -> float:
        """Fraction of step compute with nothing left to hide this slot."""
        return max(0.0, min(1.0, self.ready))


@dataclass(frozen=True)
class CommSchedule:
    """Explicit issue order for one gradient reduction.

    ``channels == 0`` means the striping is unconstrained: every bucket is
    its own independent collective and executors must not chain issues
    (XLA's latency-hiding scheduler gets a free hand).  ``channels >= 1``
    means exactly that many guaranteed rails; each rail issues its slots in
    FIFO order (the executor threads an ordering token through them).
    """

    policy: str
    microbatches: int
    bucket_sizes: tuple[int, ...]
    channels: int                      # the *config knob* (0 = unconstrained)
    slots: tuple[IssueSlot, ...]

    # -- shape ---------------------------------------------------------------

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_sizes)

    @property
    def n_channels(self) -> int:
        return len({s.channel for s in self.slots}) if self.slots else 0

    @property
    def n_collectives(self) -> int:
        return sum(len(s.bucket_ids) for s in self.slots)

    def slots_for_phase(self, phase: int) -> tuple[IssueSlot, ...]:
        """This phase's slots, in issue order (readiness, then channel)."""
        return tuple(s for s in self.slots if s.phase == phase)

    # -- prediction ----------------------------------------------------------

    @property
    def total_weight(self) -> float:
        return float(sum(sum(self.bucket_sizes[b] for b in s.bucket_ids)
                         for s in self.slots))

    @property
    def overlap_fraction(self) -> float:
        """Weighted share of collective traffic issued while compute remains
        (0.0 = fully serialised after compute, -> 1.0 = fully hidden)."""
        w_total = self.total_weight
        if w_total <= 0.0:
            return 0.0
        acc = 0.0
        for s in self.slots:
            w = sum(self.bucket_sizes[b] for b in s.bucket_ids)
            acc += w * (1.0 - s.exposed)
        return acc / w_total

    def describe(self, max_slots: int = 128) -> dict:
        """JSON-friendly summary for the dry-run report.  Slot-by-slot
        detail is elided past ``max_slots`` (FSDP schedules can carry
        thousands of per-layer-group slots)."""
        out = {
            "policy": self.policy,
            "microbatches": self.microbatches,
            "n_buckets": self.n_buckets,
            "channels": self.channels,
            "n_collectives": self.n_collectives,
            "overlap_fraction": self.overlap_fraction,
        }
        if len(self.slots) <= max_slots:
            out["slots"] = [{"phase": s.phase, "buckets": list(s.bucket_ids),
                             "channel": s.channel, "ready": round(s.ready, 6)}
                            for s in self.slots]
        else:
            out["slots_elided"] = len(self.slots)
        return out

    def validate(self) -> None:
        """Structural invariants every executor relies on."""
        expected_phases = (range(self.microbatches)
                           if self.policy != "accumulate_then_reduce"
                           else (self.microbatches - 1,))
        for phase in expected_phases:
            seen = sorted(b for s in self.slots_for_phase(phase)
                          for b in s.bucket_ids)
            if seen != list(range(self.n_buckets)):
                raise ValueError(
                    f"schedule {self.policy!r} phase {phase}: buckets {seen} "
                    f"!= 0..{self.n_buckets - 1}")
        # rails issue FIFO: readiness must be non-decreasing per channel
        by_channel: dict[int, float] = {}
        for s in self.slots:
            prev = by_channel.get(s.channel, -1.0)
            if s.ready < prev - 1e-9:
                raise ValueError(
                    f"channel {s.channel} readiness not monotone: "
                    f"{s.ready} after {prev}")
            by_channel[s.channel] = s.ready


def _bucket_channels(bucket_sizes: Sequence[int], channels: int) -> list[int]:
    """bucket index -> channel id under the communicator's striping rule
    (``channels == 0``: one private channel per bucket)."""
    n = channels if channels >= 1 else max(len(bucket_sizes), 1)
    chan_of = [0] * len(bucket_sizes)
    for a in assign_channels(bucket_sizes, n):
        for b in a.buckets:
            chan_of[b] = a.channel
    return chan_of


def build_schedule(policy: str, bucket_sizes: Sequence[int],
                   microbatches: int = 1, channels: int = 0) -> CommSchedule:
    """Derive the issue slots for ``policy`` from the bucket layout.

    Readiness model: the step's compute divides evenly across
    ``microbatches`` phases; within a phase, bucket ``b`` of ``B`` becomes
    ready after the fraction of that phase's backward that produced it.
    Buckets are packed in parameter (layer) order, and backward runs last
    layer first — so bucket ``B-1`` is ready first and bucket ``0`` last.
    """
    if policy not in SCHEDULE_POLICIES:
        raise ValueError(f"unknown schedule policy {policy!r}; one of "
                         f"{SCHEDULE_POLICIES}")
    m = max(int(microbatches), 1)
    sizes = tuple(int(s) for s in bucket_sizes)
    B = len(sizes)
    chan_of = _bucket_channels(sizes, channels)
    slots: list[IssueSlot] = []

    if policy == "accumulate_then_reduce":
        # everything issues after the last phase's compute: ready == 1.0
        for b in range(B):
            slots.append(IssueSlot(phase=m - 1, bucket_ids=(b,),
                                   channel=chan_of[b], ready=1.0))
    elif policy == "stream":
        # per microbatch, all buckets issue after that phase's backward
        for i in range(m):
            ready = (i + 1) / m
            for b in range(B):
                slots.append(IssueSlot(phase=i, bucket_ids=(b,),
                                       channel=chan_of[b], ready=ready))
    else:  # scheduled: readiness order within each phase, last layers first
        total = float(sum(sizes)) or 1.0
        for i in range(m):
            done = 0.0
            for b in reversed(range(B)):         # bucket B-1 ready first
                done += sizes[b]
                ready = (i + done / total) / m
                slots.append(IssueSlot(phase=i, bucket_ids=(b,),
                                       channel=chan_of[b], ready=ready))
    sched = CommSchedule(policy=policy, microbatches=m, bucket_sizes=sizes,
                         channels=int(channels), slots=tuple(slots))
    sched.validate()
    return sched


def halo_interior_fraction(local_shape: Sequence[int], specs) -> float:
    """Share of local lattice sites computable before any halo arrives: the
    interior block, ``halo`` sites away from every exchanged face.  This is
    the compute an ``overlap`` halo schedule can hide face transfers under
    (:class:`repro.stencil.op.StencilOp` materialises exactly this split)."""
    frac = 1.0
    for s in specs:
        n = int(local_shape[s.dim])
        frac *= max(n - 2 * s.halo, 0) / max(n, 1)
    return frac


def halo_units(specs, local_shape: Sequence[int], *, schedule: str,
               chunks: int = 1, itemsize: int = 4,
               axis_sizes: dict | None = None
               ) -> tuple[tuple[str, ...], tuple[int, ...]]:
    """Enumerate one exchange's ``ppermute`` payloads: ``(keys, bytes)``,
    one entry per unit, in issue order — per spec the ``'-'`` then ``'+'``
    direction, each split into its chunk pieces under ``chunked``
    (``"x-#2"``-style keys).  ``axis_sizes`` (mesh axis -> size), when
    known, suppresses the chunk split on size-1 axes exactly like the
    executor does.  Single source of truth for :func:`build_halo_schedule`
    and :meth:`Communicator.halo_plan`."""
    from repro.core.halo import chunk_sizes, face_split_dim

    keys: list[str] = []
    unit_bytes: list[int] = []
    for s in specs:
        face_shape = [int(n) for n in local_shape]
        face_shape[s.dim] = s.halo
        elems = math.prod(face_shape)
        p = axis_sizes.get(s.axis, 2) if axis_sizes is not None else 2
        if schedule == "chunked" and chunks > 1 and p > 1:
            split_dim = face_split_dim(tuple(face_shape), s.dim)
            row = elems // max(face_shape[split_dim], 1)
            pieces = [row * c for c in
                      chunk_sizes(face_shape[split_dim], chunks)]
        else:
            pieces = [elems]
        for d in ("-", "+"):                  # both directions, spec order
            keys.extend(f"{s.axis}{d}" + (f"#{c}" if len(pieces) > 1 else "")
                        for c in range(len(pieces)))
            unit_bytes.extend(p * itemsize for p in pieces)
    return tuple(keys), tuple(unit_bytes)


def build_halo_schedule(specs, local_shape: Sequence[int], *,
                        schedule: str, channels: int = 0, chunks: int = 1,
                        itemsize: int = 4,
                        axis_sizes: dict | None = None) -> CommSchedule:
    """Issue slots for one Cartesian halo exchange, as a :class:`CommSchedule`.

    The *units* are the individual ``ppermute`` payloads the exchange puts in
    flight — one per direction (``(axis, '-')`` then ``(axis, '+')`` per
    spec, in spec order), further split into ``chunks`` uneven-tolerant
    pieces under the ``chunked`` schedule (mirroring
    :func:`repro.core.halo.chunk_sizes`).  ``bucket_sizes`` are payload
    *bytes*, so :attr:`CommSchedule.overlap_fraction` is traffic-weighted
    exactly like the reduction schedules.

    Channel semantics per schedule:

    * ``sequential`` — every unit on rail 0 (one FIFO chain: the executor's
      order token makes each transfer data-dependent on the previous);
    * ``concurrent`` / ``chunked`` — every unit its own rail (fully
      independent collectives, ``channels`` ignored);
    * ``overlap``    — units striped across ``channels`` guaranteed rails
      (``0`` = unconstrained), issued at ``ready = 1 - interior_fraction``:
      only the interior compute can hide a face still in flight, because the
      boundary sites wait for it.
    """
    if schedule not in HALO_SCHEDULES:
        raise ValueError(f"unknown halo schedule {schedule!r}; one of "
                         f"{HALO_SCHEDULES}")
    _, unit_bytes = halo_units(specs, local_shape, schedule=schedule,
                               chunks=chunks, itemsize=itemsize,
                               axis_sizes=axis_sizes)
    n_units = len(unit_bytes)
    ready = 1.0
    if schedule == "overlap":
        ready = 1.0 - halo_interior_fraction(local_shape, specs)
    if schedule == "sequential":
        chan_of = [0] * n_units
        knob = 1
    elif schedule == "overlap" and channels >= 1:
        chan_of = [0] * n_units
        for a in assign_channels(unit_bytes, channels):
            for u in a.buckets:
                chan_of[u] = a.channel
        knob = channels
    else:                                     # concurrent/chunked/overlap@0
        chan_of = list(range(n_units))
        knob = 0
    slots = tuple(IssueSlot(phase=0, bucket_ids=(u,), channel=chan_of[u],
                            ready=ready) for u in range(n_units))
    sched = CommSchedule(policy=schedule, microbatches=1,
                         bucket_sizes=tuple(unit_bytes), channels=knob,
                         slots=slots)
    sched.validate()
    return sched


def build_moe_schedule(phase_bytes: float, rails: int = 1) -> CommSchedule:
    """Issue slots for one EP dispatch + combine all-to-all round-trip.

    The *units* are per-rail all-to-all payloads: ``rails`` dispatch units
    (the capacity buffer striped along its feature dimension) followed by
    ``rails`` combine units of the same size.  Rail ``c`` carries dispatch
    unit ``c`` and combine unit ``rails + c`` in FIFO order; staggered
    readiness models the rail pipeline — rail ``c``'s dispatch flies while
    rail ``c - 1``'s expert GEMM chunk runs, and each combine overlaps the
    remaining expert compute — so :attr:`CommSchedule.overlap_fraction`
    prices how much of the dispatch tax the GEMMs can hide.
    """
    rails = max(int(rails), 1)
    n = 2 * rails
    per = int(round(phase_bytes / rails))
    slots = []
    for c in range(rails):                     # dispatch rails, issued early
        slots.append(IssueSlot(phase=0, bucket_ids=(c,), channel=c,
                               ready=c / n))
    for c in range(rails):                     # combine rails, after GEMM c
        slots.append(IssueSlot(phase=0, bucket_ids=(rails + c,), channel=c,
                               ready=(rails + c) / n))
    sched = CommSchedule(policy="moe", microbatches=1,
                         bucket_sizes=tuple(per for _ in range(n)),
                         channels=rails, slots=tuple(slots))
    sched.validate()
    return sched

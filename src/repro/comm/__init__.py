"""repro.comm — unified Communicator API for every collective in the system.

One schedulable substrate for gradient reduction (SGD) and halo exchange
(QCD), in the spirit of MPI communicators: a :class:`Communicator` built
from ``(mesh, CommConfig)`` exposes ``all_reduce`` / ``reduce_scatter`` /
``all_gather`` / ``halo_exchange`` / ``stripe`` over named *transports*
registered in :mod:`repro.comm.registry`, with channel striping
(multi-rail concurrency) as a config knob.

Legacy string policies (``ReduceConfig.policy``) map onto transports via
:data:`POLICY_TO_TRANSPORT`; :class:`repro.core.reducer.GradientReducer`
remains as a deprecated shim over this package.
"""

from repro.comm.api import CommConfig, Communicator
# legacy string-policy mapping: lives with the GradientReducer shim
from repro.core.reducer import POLICY_TO_TRANSPORT, comm_config_from_policy
from repro.comm.plan import (A2APlan, ALPHA_S, ChannelAssignment, CommPlan,
                             HaloChannel, HaloPlan, LatencyModel,
                             assign_channels)
from repro.comm.registry import (Transport, TransportSpec, get_transport,
                                 list_transports, register_transport,
                                 transport_specs)
from repro.comm.schedule import (CommSchedule, HALO_SCHEDULES, IssueSlot,
                                 SCHEDULE_POLICIES, build_halo_schedule,
                                 build_moe_schedule, build_schedule,
                                 halo_interior_fraction, halo_units)
from repro.comm.wire_codec import (ErrorFeedback, IdentityCodec,
                                   Int8BlockCodec, make_codec)

__all__ = [
    "A2APlan", "ALPHA_S", "ChannelAssignment", "CommConfig", "CommPlan",
    "CommSchedule",
    "Communicator", "ErrorFeedback", "HALO_SCHEDULES", "HaloChannel",
    "HaloPlan", "IdentityCodec", "Int8BlockCodec", "IssueSlot",
    "LatencyModel", "POLICY_TO_TRANSPORT", "SCHEDULE_POLICIES",
    "assign_channels",
    "build_halo_schedule", "build_moe_schedule", "build_schedule",
    "comm_config_from_policy",
    "get_transport", "halo_interior_fraction", "halo_units",
    "list_transports", "make_codec", "register_transport", "Transport",
    "TransportSpec", "transport_specs",
]

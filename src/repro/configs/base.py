"""Config dataclasses: model architecture, input shapes, distribution.

Every assigned architecture file in this package instantiates ``ModelConfig``
with the exact public hyperparameters and registers itself.  Shapes are
global (pre-sharding); the sharding policy maps them onto the mesh.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class AttnConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    window: int | None = None          # sliding-window size (SWA layers)
    chunk: int | None = None           # llama4-style chunked-local attention
    global_every: int = 0              # every Nth layer is global (0 = per window/chunk only)
    global_layers: tuple[int, ...] = ()  # explicit global-attention layer ids


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int
    shared_expert_ff: int = 0
    interleave_step: int = 1           # every Nth layer is MoE (1 = all)
    capacity_factor: float = 1.25
    parallelism: str = "ep"            # "ep" (experts over model) | "tp" (ffn over model)


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2
    dt_rank: int = 0                   # 0 = ceil(d_model / 16)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: AttnConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # encoder-decoder extras
    enc_layers: int = 0
    enc_seq: int = 0                   # encoder (frontend) sequence length
    frontend: str | None = None        # "audio_stub" | "vision_stub"
    frontend_seq: int = 0              # patch/frame tokens prepended (vlm)
    # numerics / structure
    norm_eps: float = 1e-5
    act: str = "silu"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"            # activation/compute dtype
    param_dtype: str = "float32"
    remat: str = "layer"               # "none" | "layer"
    # sharding policy: "tp" (replicated params) | "fsdp" (params over data too)
    sharding: str = "tp"

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def layer_kind(self, i: int) -> dict:
        """Resolve per-layer structure: attention flavour + mlp flavour."""
        kind: dict = {"mixer": "attn", "mlp": "dense"}
        if self.family == "ssm":
            kind["mixer"] = "ssm"
        elif self.family == "hybrid":
            kind["mixer"] = "hybrid"
        if self.moe is not None:
            step = max(self.moe.interleave_step, 1)
            # hf llama4 convention: layers (step-1, 2*step-1, ...) are MoE when
            # interleaved; step == 1 -> every layer.
            if (i + 1) % step == 0:
                kind["mlp"] = "moe"
        if self.attn is not None:
            a = self.attn
            is_global = (i in a.global_layers or
                         (a.global_every and (i + 1) % a.global_every == 0) or
                         (a.window is None and a.chunk is None))
            kind["attn_global"] = bool(is_global)
        return kind


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                          # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def sub_quadratic(cfg: ModelConfig) -> bool:
    """Can this arch run long_500k?  SSM state, SWA or chunked attention."""
    if cfg.family in ("ssm", "hybrid"):
        return True
    if cfg.attn is not None and (cfg.attn.window or cfg.attn.chunk):
        return True
    return False


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if sub_quadratic(cfg):
        out.append("long_500k")
    return out

"""falcon-mamba-7b [ssm]: attention-free Mamba-1 stack (64 blocks,
d_inner = 2*4096, state 16).  [arXiv:2410.05355; unverified]"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    d_ff=0,                        # no MLP sublayer: pure Mamba blocks
    vocab_size=65024,
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    sharding="fsdp",
)

"""Architecture registry: ``--arch <id>`` resolves here.

Each module defines ``CONFIG`` (exact public hyperparameters) and the
registry exposes ``get_config`` / ``reduced_config`` (smoke-scale same-family
variant) / ``list_archs``.
"""

from __future__ import annotations

import dataclasses

from repro.configs import base
from repro.configs.base import (SHAPES, AttnConfig, ModelConfig, MoEConfig,
                                ShapeConfig, SSMConfig, applicable_shapes,
                                sub_quadratic)

_ARCH_MODULES = [
    "llava_next_34b", "hymba_1p5b", "phi3_medium_14b", "minicpm_2b",
    "llama3p2_1b", "qwen2_7b", "llama4_maverick", "mixtral_8x7b",
    "whisper_base", "falcon_mamba_7b",
]


def _load() -> dict[str, ModelConfig]:
    import importlib

    out = {}
    for m in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        out[mod.CONFIG.name] = mod.CONFIG
    return out


_REGISTRY: dict[str, ModelConfig] | None = None


def registry() -> dict[str, ModelConfig]:
    global _REGISTRY
    if _REGISTRY is None:
        _REGISTRY = _load()
    return _REGISTRY


def list_archs() -> list[str]:
    return sorted(registry().keys())


def get_config(name: str) -> ModelConfig:
    reg = registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(reg)}")
    return reg[name]


def reduced_config(name: str) -> ModelConfig:
    """Smoke-scale config of the same family: tiny dims, same structure."""
    cfg = get_config(name)
    attn = cfg.attn
    if attn is not None:
        attn = dataclasses.replace(
            attn, num_heads=4, num_kv_heads=2, head_dim=16,
            window=None if attn.window is None else 32,
            chunk=None if attn.chunk is None else 32,
            global_layers=tuple(i for i in attn.global_layers if i < 2),
        )
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, num_experts=4,
                                  top_k=min(moe.top_k, 2), expert_ff=64,
                                  shared_expert_ff=64 if moe.shared_expert_ff else 0)
    ssm = cfg.ssm
    if ssm is not None:
        ssm = dataclasses.replace(ssm, state_dim=4, dt_rank=8)
    return cfg.with_(
        num_layers=2, d_model=64, d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512, attn=attn, moe=moe, ssm=ssm,
        enc_layers=2 if cfg.enc_layers else 0,
        enc_seq=16 if cfg.enc_seq else 0,
        frontend_seq=8 if cfg.frontend_seq else 0,
        dtype="float32", remat="none", sharding="tp",
    )


__all__ = ["AttnConfig", "ModelConfig", "MoEConfig", "SSMConfig",
           "ShapeConfig", "SHAPES", "applicable_shapes", "sub_quadratic",
           "get_config", "reduced_config", "list_archs", "registry", "base"]

"""mixtral-8x7b [moe]: 8 experts top-2 on every layer, sliding-window
attention.  Experts are TP-sharded (8 experts < model axis).  [arXiv:2401.04088]"""

from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    d_ff=0,                        # all layers are MoE
    vocab_size=32000,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                    window=4096, rope_theta=1_000_000.0),
    moe=MoEConfig(num_experts=8, top_k=2, expert_ff=14336,
                  interleave_step=1, capacity_factor=1.25, parallelism="tp"),
    sharding="fsdp",
)

"""minicpm-2b [dense]: llama-like arch; trains with the WSD schedule
(wired in optim.schedules / launch.train).  [arXiv:2404.06395; hf]"""

from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    d_ff=5760,
    vocab_size=122753,
    attn=AttnConfig(num_heads=36, num_kv_heads=36, head_dim=64),
    tie_embeddings=True,
    sharding="tp",
)

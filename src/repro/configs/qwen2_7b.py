"""qwen2-7b [dense]: GQA with QKV bias.  [arXiv:2407.10671; hf]"""

from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    d_ff=18944,
    vocab_size=152064,
    attn=AttnConfig(num_heads=28, num_kv_heads=4, head_dim=128,
                    qkv_bias=True, rope_theta=1_000_000.0),
    sharding="fsdp",
)

"""llama4-maverick-400b-a17b [moe]: 128-expert top-1 MoE on alternating
layers with a shared expert; iRoPE-style chunked-local attention (8192)
with a global layer every 4th; early-fusion modality is out of scope
(text backbone per assignment).  [hf:meta-llama/Llama-4-*; unverified]"""

from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    d_ff=16384,                    # dense (non-MoE) layers
    vocab_size=202048,
    attn=AttnConfig(num_heads=40, num_kv_heads=8, head_dim=128,
                    chunk=8192, global_every=4, rope_theta=500_000.0),
    moe=MoEConfig(num_experts=128, top_k=1, expert_ff=8192,
                  shared_expert_ff=8192, interleave_step=2,
                  capacity_factor=1.25, parallelism="ep"),
    sharding="fsdp",
)

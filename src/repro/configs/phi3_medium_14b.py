"""phi3-medium-14b [dense]: RoPE + SwiGLU + GQA.  [arXiv:2404.14219]"""

from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    d_ff=17920,
    vocab_size=100352,
    attn=AttnConfig(num_heads=40, num_kv_heads=10, head_dim=128),
    sharding="fsdp",
)

"""llama3.2-1b [dense]: small llama3; tied embeddings.
[hf:meta-llama/Llama-3.2-1B; unverified]"""

from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    d_ff=8192,
    vocab_size=128256,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=64,
                    rope_theta=500_000.0),
    tie_embeddings=True,
    sharding="tp",
)

"""whisper-base [audio]: encoder-decoder backbone; the conv/mel frontend is
a stub per assignment (``input_specs`` provides 1500 precomputed frame
embeddings).  decode_32k is exercised mechanically though the real model
caps at 448 positions (DESIGN.md).  [arXiv:2212.04356; unverified]"""

from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,                  # decoder layers
    enc_layers=6,
    enc_seq=1500,
    d_model=512,
    d_ff=2048,
    vocab_size=51865,
    attn=AttnConfig(num_heads=8, num_kv_heads=8, head_dim=64),
    frontend="audio_stub",
    sharding="tp",
)

"""hymba-1.5b [hybrid]: parallel attention + Mamba heads per layer; SWA on
all but three global-attention layers (first/middle/last); meta-tokens are
out of scope (noted in DESIGN.md).  [arXiv:2411.13676; hf]"""

from repro.configs.base import AttnConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    d_ff=5504,
    vocab_size=32001,
    attn=AttnConfig(num_heads=25, num_kv_heads=5, head_dim=64,
                    window=1024, global_layers=(0, 15, 31)),
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    sharding="tp",
)

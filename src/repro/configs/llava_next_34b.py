"""llava-next-34b [vlm]: anyres-tiled VLM; the assigned cell is the 34B
transformer BACKBONE — the vision tower is a stub (``input_specs`` provides
precomputed patch embeddings).  [hf:llava-hf/llava-v1.6-*; unverified]"""

from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    d_ff=20480,
    vocab_size=64000,
    attn=AttnConfig(num_heads=56, num_kv_heads=8, head_dim=128,
                    rope_theta=5_000_000.0),
    frontend="vision_stub",
    frontend_seq=576,            # one 24x24 anyres base tile of patch embeds
    sharding="fsdp",
)

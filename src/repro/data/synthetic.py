"""Deterministic synthetic token pipeline.

Produces Zipf-distributed token streams with EOS-delimited documents and
next-token labels.  Deterministic in (seed, step): any host can regenerate
any global batch — which is what makes checkpoint-restart and elastic
re-sharding trivial (no data-state to save beyond the step counter, the
strongest form of the paper's 'guarantee, don't hope' ethos applied to
input pipelines).  A real deployment swaps this for a sharded file-backed
loader with the same ``batch_at(step)`` contract.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.sharding import rules as shard_rules


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    eos_id: int = 1
    mean_doc_len: int = 512


class SyntheticTokens:
    """Stateless batch generator: ``batch_at(step)`` is pure."""

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig | None = None):
        self.cfg = cfg
        self.model_cfg = model_cfg
        # Precompute a Zipf CDF over the vocab (stable across processes).
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / np.power(ranks, cfg.zipf_a)
        self._cdf = np.cumsum(probs / probs.sum())

    def _tokens(self, rng: np.random.Generator, shape) -> np.ndarray:
        u = rng.random(shape)
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        return np.minimum(toks, self.cfg.vocab_size - 1)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
        b, s = cfg.global_batch, cfg.seq_len
        mc = self.model_cfg
        text = s
        extra: dict = {}
        if mc is not None and mc.frontend == "vision_stub" and mc.frontend_seq:
            text = s - mc.frontend_seq
            extra["extra_embeds"] = jnp.asarray(
                rng.standard_normal((b, mc.frontend_seq, mc.d_model),
                                    dtype=np.float32) * 0.02, jnp.bfloat16)
        if mc is not None and (mc.family == "encdec" or mc.frontend == "audio_stub"):
            extra["frames"] = jnp.asarray(
                rng.standard_normal((b, mc.enc_seq, mc.d_model),
                                    dtype=np.float32) * 0.02, jnp.bfloat16)

        toks = self._tokens(rng, (b, text + 1))
        # EOS-delimited documents: geometric doc lengths
        eos_mask = rng.random((b, text + 1)) < 1.0 / max(self.cfg.mean_doc_len, 2)
        toks = np.where(eos_mask, cfg.eos_id, toks)
        tokens = toks[:, :-1]
        labels = toks[:, 1:]
        out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        out.update(extra)
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_specs(model_cfg: ModelConfig, shape_cfg, mesh):
    """PartitionSpecs for a training batch dict on the manual mesh."""
    from jax.sharding import PartitionSpec as P

    bspec = shard_rules.batch_spec(shape_cfg.global_batch, mesh)
    ax = tuple(bspec)[0] if len(bspec) else None
    out = {"tokens": P(ax, None), "labels": P(ax, None)}
    if model_cfg.frontend == "vision_stub" and model_cfg.frontend_seq:
        out["extra_embeds"] = P(ax, None, None)
    if model_cfg.family == "encdec" or model_cfg.frontend == "audio_stub":
        out["frames"] = P(ax, None, None)
    return out

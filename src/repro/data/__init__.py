from repro.data.synthetic import DataConfig, SyntheticTokens, make_batch_specs

__all__ = ["DataConfig", "SyntheticTokens", "make_batch_specs"]

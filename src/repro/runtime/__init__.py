from repro.runtime.train_step import TrainStepConfig, build_train_step, init_train_state
from repro.runtime.serve_step import build_decode_step, build_prefill

__all__ = ["TrainStepConfig", "build_train_step", "init_train_state",
           "build_decode_step", "build_prefill"]

"""Fault tolerance: straggler detection, heartbeats, elastic re-meshing.

On a real multi-pod deployment these hook into the cluster scheduler; here
they are host-level components with the same decision logic, exercised by
the FT tests via simulated failures.

* ``StragglerMonitor`` — EWMA of step wall-times; flags steps slower than
  ``threshold x`` the running estimate.  At scale the flagged rank triggers
  (a) re-dispatch of its shard (synchronous recovery) or (b) its removal at
  the next elastic boundary; here we count + expose events.
* ``Heartbeat`` — liveness file per host; ``dead_hosts`` reports hosts whose
  beat is older than the timeout (scheduler would drain them).
* ``elastic_remesh`` — rebuilds the largest usable (data, model) mesh from
  the surviving device count; training resumes from the latest committed
  checkpoint (global arrays reshard transparently in the manual step).
"""

from __future__ import annotations

import os
import statistics
import time
from dataclasses import dataclass, field

import jax


@dataclass
class StragglerMonitor:
    """EWMA straggler detector.

    The first ``warmup_steps`` samples only *collect*: the EWMA is seeded
    from their **median**, not from the first step — step 0 is the compile
    step, typically 10-1000x a steady-state step, and seeding from it
    inflates the baseline so early real stragglers sail under
    ``threshold × ewma`` unflagged.  Warmup samples never emit events.
    """

    threshold: float = 2.0
    decay: float = 0.9
    warmup_steps: int = 3
    _ewma: float | None = None
    _steps: int = 0
    _warmup: list = field(default_factory=list)
    events: list = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        """Returns True when this step is flagged as a straggler."""
        self._steps += 1
        if self._steps <= self.warmup_steps:
            # warmup: collect only — no baseline yet, no events
            self._warmup.append(seconds)
            if self._steps == self.warmup_steps:
                self._ewma = statistics.median(self._warmup)
            return False
        if self._ewma is None:   # warmup_steps == 0: seed from first sample
            self._ewma = seconds
            return False
        flagged = seconds > self.threshold * self._ewma
        if flagged:
            self.events.append((step, seconds, self._ewma))
        else:
            # stragglers are excluded from the estimate (they'd poison it)
            self._ewma = self.decay * self._ewma + (1 - self.decay) * seconds
        return flagged


class Heartbeat:
    """File-based liveness beacons (one per host)."""

    def __init__(self, beat_dir: str, host_id: str, timeout: float = 60.0):
        self.beat_dir = beat_dir
        self.host_id = host_id
        self.timeout = timeout
        os.makedirs(beat_dir, exist_ok=True)

    def beat(self, now: float | None = None):
        now = time.time() if now is None else now
        with open(os.path.join(self.beat_dir, f"{self.host_id}.beat"), "w") as f:
            f.write(f"{now:.3f}\n")

    def dead_hosts(self, now: float | None = None) -> list[str]:
        now = time.time() if now is None else now
        dead = []
        for name in os.listdir(self.beat_dir):
            if not name.endswith(".beat"):
                continue
            with open(os.path.join(self.beat_dir, name)) as f:
                last = float(f.read().strip() or 0)
            if now - last > self.timeout:
                dead.append(name[:-5])
        return sorted(dead)


def elastic_shape(n_devices: int, *, model_parallel: int = 16,
                  want_pods: int = 1) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest (pod, data, model) shape that fits ``n_devices`` surviving
    devices, shrinking data-parallelism first (the dimension the synchronous
    SGD math tolerates: global batch per step shrinks, semantics don't)."""
    model = model_parallel
    while model > 1 and n_devices % model != 0:
        model //= 2
    rest = n_devices // model
    pods = want_pods
    while pods > 1 and rest % pods != 0:
        pods -= 1
    data = rest // pods
    if data < 1:
        raise ValueError(f"cannot build a mesh from {n_devices} devices")
    shape = (pods, data, model) if pods > 1 else (data, model)
    names = ("pod", "data", "model") if pods > 1 else ("data", "model")
    return shape, names


def elastic_remesh(n_devices: int, *, model_parallel: int = 16,
                   want_pods: int = 1):
    shape, names = elastic_shape(n_devices, model_parallel=model_parallel,
                                 want_pods=want_pods)
    from repro import compat

    return compat.make_mesh(shape, names)

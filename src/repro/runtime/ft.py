"""Fault tolerance: straggler detection, heartbeats, elastic re-meshing.

On a real multi-pod deployment these hook into the cluster scheduler; here
they are host-level components with the same decision logic, exercised by
the FT tests via simulated failures.

* ``StragglerMonitor`` — EWMA of step wall-times; flags steps slower than
  ``threshold x`` the running estimate.  At scale the flagged rank triggers
  (a) re-dispatch of its shard (synchronous recovery) or (b) its removal at
  the next elastic boundary; here every sample yields a structured
  :class:`StragglerEvent` (routed onto the obs bus when one is attached).
* ``Heartbeat`` — liveness file per host; ``dead_hosts`` reports *other*
  hosts whose beat is older than the timeout (the caller's own liveness is
  self-evident — it is running); ``prune_stale`` garbage-collects beat
  files of hosts long gone so a drained host doesn't alarm forever.
* ``elastic_remesh`` — rebuilds the largest usable (data, model) mesh from
  the surviving device count; training resumes from the latest committed
  checkpoint (global arrays reshard transparently in the manual step).
"""

from __future__ import annotations

import os
import statistics
import time
from dataclasses import dataclass, field
from typing import Any

from repro.obs.bus import NULL_BUS


@dataclass(frozen=True)
class StragglerEvent:
    """One step's verdict.  Truthiness == ``flagged``, so every call site
    that treated :meth:`StragglerMonitor.record`'s old bare bool as a
    condition keeps working unchanged."""

    step: int
    seconds: float
    ewma: float          # the baseline the step was judged against
                         # (0.0 during warmup: no baseline yet)
    flagged: bool

    @property
    def ratio(self) -> float:
        """How many baselines this step took (inf with no baseline)."""
        return self.seconds / self.ewma if self.ewma > 0 else float("inf")

    def __bool__(self) -> bool:
        return self.flagged


@dataclass
class StragglerMonitor:
    """EWMA straggler detector.

    The first ``warmup_steps`` samples only *collect*: the EWMA is seeded
    from their **median**, not from the first step — step 0 is the compile
    step, typically 10-1000x a steady-state step, and seeding from it
    inflates the baseline so early real stragglers sail under
    ``threshold × ewma`` unflagged.  Warmup samples never emit events.

    With a ``bus`` attached, every flagged step publishes a ``straggler``
    event and bumps the ``straggler_events`` counter.
    """

    threshold: float = 2.0
    decay: float = 0.9
    warmup_steps: int = 3
    bus: Any = field(default=None, repr=False)
    _ewma: float | None = None
    _steps: int = 0
    _warmup: list = field(default_factory=list)
    events: list = field(default_factory=list)

    def record(self, step: int, seconds: float) -> StragglerEvent:
        """Judge one step; returns a :class:`StragglerEvent` (truthy when
        flagged).  Flagged events accumulate in ``self.events``."""
        bus = self.bus if self.bus is not None else NULL_BUS
        self._steps += 1
        if self._steps <= self.warmup_steps:
            # warmup: collect only — no baseline yet, no events
            self._warmup.append(seconds)
            if self._steps == self.warmup_steps:
                self._ewma = statistics.median(self._warmup)
            return StragglerEvent(step, seconds, 0.0, False)
        if self._ewma is None:   # warmup_steps == 0: seed from first sample
            self._ewma = seconds
            return StragglerEvent(step, seconds, 0.0, False)
        flagged = seconds > self.threshold * self._ewma
        ev = StragglerEvent(step, seconds, self._ewma, flagged)
        if flagged:
            self.events.append(ev)
            bus.counter("straggler_events")
            bus.event("straggler", step=step, seconds=seconds,
                      ewma=self._ewma, ratio=ev.ratio,
                      threshold=self.threshold)
        else:
            # stragglers are excluded from the estimate (they'd poison it)
            self._ewma = self.decay * self._ewma + (1 - self.decay) * seconds
        return ev


class Heartbeat:
    """File-based liveness beacons (one per host), publishing onto the obs
    bus when one is attached."""

    def __init__(self, beat_dir: str, host_id: str, timeout: float = 60.0,
                 bus: Any = None):
        self.beat_dir = beat_dir
        self.host_id = host_id
        self.timeout = timeout
        self.bus = bus if bus is not None else NULL_BUS
        self._dead_seen: set[str] = set()
        os.makedirs(beat_dir, exist_ok=True)

    def _path(self, host_id: str) -> str:
        return os.path.join(self.beat_dir, f"{host_id}.beat")

    def beat(self, now: float | None = None):
        now = time.time() if now is None else now
        with open(self._path(self.host_id), "w") as f:
            f.write(f"{now:.3f}\n")
        self.bus.gauge("heartbeat_ts", now, host=self.host_id)

    def _last_beats(self) -> dict[str, float]:
        beats = {}
        for name in os.listdir(self.beat_dir):
            if not name.endswith(".beat"):
                continue
            with open(os.path.join(self.beat_dir, name)) as f:
                beats[name[:-5]] = float(f.read().strip() or 0)
        return beats

    def dead_hosts(self, now: float | None = None) -> list[str]:
        """Hosts whose last beat is *strictly* older than ``timeout``
        seconds — excluding this host (its liveness is self-evident; a
        scheduler draining "dead" hosts must never drain the reporter on
        the strength of its own stale file).  Newly-dead hosts publish a
        ``host_dead`` event; the ``dead_hosts`` gauge tracks the count."""
        now = time.time() if now is None else now
        dead = []
        for host, last in self._last_beats().items():
            if host == self.host_id:
                continue
            if now - last > self.timeout:
                dead.append(host)
        dead = sorted(dead)
        for host in dead:
            if host not in self._dead_seen:
                self.bus.event("host_dead", host=host,
                               stale_s=now - self._last_beats()[host])
        self._dead_seen = set(dead)
        self.bus.gauge("dead_hosts", len(dead))
        return dead

    def prune_stale(self, now: float | None = None,
                    grace: float | None = None) -> list[str]:
        """Remove beat files (other hosts') stale past ``grace`` seconds
        (default ``10 × timeout``): a host drained long ago stops showing
        up in ``dead_hosts`` forever.  Returns the pruned host ids."""
        now = time.time() if now is None else now
        grace = 10.0 * self.timeout if grace is None else grace
        pruned = []
        for host, last in self._last_beats().items():
            if host == self.host_id:
                continue
            if now - last > grace:
                os.remove(self._path(host))
                pruned.append(host)
        pruned = sorted(pruned)
        for host in pruned:
            self._dead_seen.discard(host)
            self.bus.event("host_pruned", host=host)
        return pruned


def elastic_shape(n_devices: int, *, model_parallel: int = 16,
                  want_pods: int = 1) -> tuple[tuple[int, ...], tuple[str, ...]]:
    """Largest (pod, data, model) shape that fits ``n_devices`` surviving
    devices, shrinking data-parallelism first (the dimension the synchronous
    SGD math tolerates: global batch per step shrinks, semantics don't)."""
    model = model_parallel
    while model > 1 and n_devices % model != 0:
        model //= 2
    rest = n_devices // model
    pods = want_pods
    while pods > 1 and rest % pods != 0:
        pods -= 1
    data = rest // pods
    if data < 1:
        raise ValueError(f"cannot build a mesh from {n_devices} devices")
    shape = (pods, data, model) if pods > 1 else (data, model)
    names = ("pod", "data", "model") if pods > 1 else ("data", "model")
    return shape, names


def elastic_remesh(n_devices: int, *, model_parallel: int = 16,
                   want_pods: int = 1):
    shape, names = elastic_shape(n_devices, model_parallel=model_parallel,
                                 want_pods=want_pods)
    from repro import compat

    return compat.make_mesh(shape, names)

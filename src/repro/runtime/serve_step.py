"""Serving-step builders: prefill and single-token decode over the manual
mesh.  Decode state is donated so caches update in place.

``weight_mode``:
* ``resident`` — params live model-sharded (replicated over data); right for
  archs whose bf16 weights fit 16 GB / model_size.
* ``gathered`` — params stored as FSDP flat shards over (pod, data) and
  ring-all-gathered per layer at use (the only way a 400B model serves on a
  (16, 16) mesh; the roofline shows the cost honestly).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.model_api import Model
from repro.runtime.train_step import (FsdpPlan, TrainStepConfig, _flat_spec,
                                      make_ctx, _slice_to_local)
from repro.sharding import rules as shard_rules


def _require_decoder_only(cfg, what: str) -> None:
    """Gathered serving streams params through ``transformer.forward`` /
    ``decode_step``, which only model decoder-only transformer stacks.  Any
    other family (encdec cross-attention, ssm / hybrid recurrent state,
    audio frontends) would silently produce garbage, so refuse at build
    time — not at trace time, and not just for encdec."""
    if cfg.family not in ("dense", "moe") or cfg.frontend is not None:
        raise NotImplementedError(
            f"gathered {what} is decoder-only: family={cfg.family!r} "
            f"frontend={cfg.frontend!r} is not supported (use "
            f"weight_mode='resident')")


def _batch_axis(mesh: Mesh, global_batch: int):
    bspec = shard_rules.batch_spec(global_batch, mesh)
    return tuple(bspec)[0] if len(bspec) else None


def _batch_specs(batch_abs, batch_axes):
    def one(path, leaf):
        return P(*((batch_axes,) + (None,) * (len(leaf.shape) - 1)))
    return jax.tree_util.tree_map_with_path(one, batch_abs)


def build_prefill(model: Model, mesh: Mesh, shape_cfg, *,
                  weight_mode: str = "resident", causal_skip: bool = True):
    """Returns (prefill_fn(params, batch) -> local-vocab logits, param_specs)."""
    ctx = make_ctx(mesh)
    batch_axes = _batch_axis(mesh, shape_cfg.global_batch)
    vocab_ax = "model" if "model" in mesh.axis_names else None
    specs_abs = model.input_specs(shape_cfg)
    bspecs = _batch_specs(specs_abs, batch_axes)

    if weight_mode == "gathered":
        _require_decoder_only(model.cfg, "prefill")
        plan = FsdpPlan(model, mesh, TrainStepConfig(dp_mode="fsdp"))
        pspecs = {"groups": {name: [_flat_spec(mesh)] * plan.plans[name].n_buckets
                             for name in plan.groups}}

        def fn(params, batch):
            tree, resolver = plan.params_and_resolver(params["groups"],
                                                      jnp.bfloat16)
            from repro.models import transformer

            logits, _, _ = transformer.forward(tree, batch["tokens"], model.cfg,
                                               ctx=ctx,
                                               extra_embeds=batch.get("extra_embeds"),
                                               causal_skip=causal_skip,
                                               block_resolver=resolver)
            return logits
    else:
        pspecs = model.param_specs(mesh)

        def fn(params, batch):
            return model.forward(params, batch, ctx=ctx,
                                 causal_skip=causal_skip)

    out_spec = P(batch_axes, None, vocab_ax)
    sharded = compat.shard_map(fn, mesh=mesh, in_specs=(pspecs, bspecs),
                               out_specs=out_spec, check_vma=False)
    return jax.jit(sharded), pspecs


def build_decode_step(model: Model, mesh: Mesh, shape_cfg, *,
                      weight_mode: str = "resident", donate: bool = True):
    """Returns (decode(params, token, state, pos) -> (logits, state),
    param_specs, state_specs)."""
    ctx = make_ctx(mesh)
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    state_abs = model.abstract_decode_state(b, s)
    state_specs = shard_rules.decode_state_specs(state_abs, model.cfg, mesh, b)
    batch_axes = _batch_axis(mesh, b)
    vocab_ax = "model" if "model" in mesh.axis_names else None

    if weight_mode == "gathered":
        _require_decoder_only(model.cfg, "decode")
        plan = FsdpPlan(model, mesh, TrainStepConfig(dp_mode="fsdp"))
        pspecs = {"groups": {name: [_flat_spec(mesh)] * plan.plans[name].n_buckets
                             for name in plan.groups}}

        def fn(params, token, state, pos):
            tree, resolver = plan.params_and_resolver(params["groups"],
                                                      jnp.bfloat16)
            return model.decode_step(tree, token, state, pos, ctx=ctx,
                                     seq_len=s, block_resolver=resolver)
    else:
        pspecs = model.param_specs(mesh)

        def fn(params, token, state, pos):
            return model.decode_step(params, token, state, pos, ctx=ctx,
                                     seq_len=s)

    sharded = compat.shard_map(
        fn, mesh=mesh,
        in_specs=(pspecs, P(batch_axes), state_specs, P()),
        out_specs=(P(batch_axes, vocab_ax), state_specs),
        check_vma=False)
    step = jax.jit(sharded, donate_argnums=(2,) if donate else ())
    return step, pspecs, state_specs

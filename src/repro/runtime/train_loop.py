"""Trainer: steps, metrics, checkpoint-restart, straggler accounting.

The fault-tolerance contract: every ``ckpt_every`` steps the full train
state is saved (atomically, async); on construction the trainer resumes
from the newest committed step.  Data is stateless-deterministic, so resume
== replay from the same step on any mesh that can hold the state.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticTokens, make_batch_specs
from repro.models.model_api import Model
from repro.runtime.ft import StragglerMonitor
from repro.runtime.train_step import (TrainStepConfig, build_train_step,
                                      init_train_state)


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, model: Model, mesh, step_cfg: TrainStepConfig,
                 data: SyntheticTokens, shape_cfg, tcfg: TrainerConfig,
                 log: Callable[[str], None] = print):
        self.model = model
        self.mesh = mesh
        self.step_cfg = step_cfg
        self.data = data
        self.tcfg = tcfg
        self.log = log
        self.monitor = StragglerMonitor()
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir)
                     if tcfg.ckpt_dir else None)

        batch_specs = make_batch_specs(model.cfg, shape_cfg, mesh)
        with mesh:
            self.step_fn = build_train_step(model, mesh, step_cfg, batch_specs)
            state, self.state_specs = init_train_state(
                model, mesh, step_cfg, key=jax.random.key(tcfg.seed))
        self.state = state
        self.start_step = 0
        if self.ckpt is not None:
            try:
                restored, step = self.ckpt.restore_latest(self.state)
            except ValueError as e:
                if "strict=False" not in str(e):
                    raise
                # structural change (e.g. toggling use_arena's scratch comm
                # buffer): retry path-matched, loudly — leaves absent from
                # the checkpoint keep their fresh-init values
                restored, step = self.ckpt.restore_latest(self.state,
                                                          strict=False)
                self.log(f"[trainer] state structure changed since the "
                         f"checkpoint; resumed by path matching ({e})")
            if restored is not None:
                self.state = restored
                self.start_step = int(step)
                self.log(f"[trainer] resumed from step {step}")

    def run(self) -> dict:
        history: list[dict] = []
        t_total = time.time()
        for step in range(self.start_step, self.tcfg.steps):
            batch = self.data.batch_at(step)
            t0 = time.time()
            with self.mesh:
                self.state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])          # blocks on completion
            dt = time.time() - t0
            straggler = self.monitor.record(step, dt)
            rec = {"step": step, "loss": loss,
                   "grad_norm": float(metrics["grad_norm"]),
                   "lr": float(metrics["lr"]), "sec": dt,
                   "straggler": straggler}
            history.append(rec)
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps - 1:
                self.log(f"[train] step {step:5d} loss {loss:.4f} "
                         f"gnorm {rec['grad_norm']:.3f} lr {rec['lr']:.2e} "
                         f"{dt*1e3:.0f} ms" + (" STRAGGLER" if straggler else ""))
            if self.ckpt is not None and (step + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save(self.state, step + 1)
        if self.ckpt is not None:
            self.ckpt.save(self.state, self.tcfg.steps)
            self.ckpt.wait()
        return {"history": history, "wall": time.time() - t_total,
                "straggler_events": self.monitor.events}

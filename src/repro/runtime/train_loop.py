"""Trainer: steps, metrics, checkpoint-restart, straggler accounting.

The fault-tolerance contract: every ``ckpt_every`` steps the full train
state is saved (atomically, async); on construction the trainer resumes
from the newest committed step.  Data is stateless-deterministic, so resume
== replay from the same step on any mesh that can hold the state.

Observability: with ``TrainerConfig.obs`` set, the trainer publishes onto a
:class:`repro.obs.MetricsBus` — phase spans (data / step: dispatch + wait /
ckpt), per-step gauges (step time, loss, grad norm, lr, MoE drop fraction),
straggler events (via the monitor's bus) — and, when a step-time prediction
is available (explicit, AOT roofline, or tuning-DB priced), feeds a
:class:`repro.obs.DriftDetector` so the live ``model_error`` gauge tracks
how far the latency model sits from the machine.  Obs is pure host-side
bookkeeping around the jitted step: it never changes what gets compiled.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticTokens, make_batch_specs
from repro.models.model_api import Model
from repro.obs import ObsConfig, make_obs
from repro.runtime.ft import StragglerMonitor
from repro.runtime.train_step import (TrainStepConfig, _mesh_axes,
                                      build_step_schedule, build_train_step,
                                      init_train_state)


@dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    log_every: int = 10
    seed: int = 0
    obs: ObsConfig | None = None   # None -> NULL_OBS: zero-overhead no-op


class Trainer:
    def __init__(self, model: Model, mesh, step_cfg: TrainStepConfig,
                 data: SyntheticTokens, shape_cfg, tcfg: TrainerConfig,
                 log: Callable[[str], None] = print):
        self.model = model
        self.mesh = mesh
        self.step_cfg = step_cfg
        self.data = data
        self.tcfg = tcfg
        self.log = log
        self.obs = make_obs(tcfg.obs)
        self.monitor = StragglerMonitor(bus=self.obs.bus)
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir)
                     if tcfg.ckpt_dir else None)

        batch_specs = make_batch_specs(model.cfg, shape_cfg, mesh)
        with mesh:
            self.step_fn = build_train_step(model, mesh, step_cfg, batch_specs)
            state, self.state_specs = init_train_state(
                model, mesh, step_cfg, key=jax.random.key(tcfg.seed))
        self.state = state
        self.start_step = 0
        if self.ckpt is not None:
            try:
                restored, step = self.ckpt.restore_latest(self.state)
            except ValueError as e:
                if "strict=False" not in str(e):
                    raise
                # structural change (e.g. toggling use_arena's scratch comm
                # buffer): retry path-matched, loudly — leaves absent from
                # the checkpoint keep their fresh-init values
                restored, step = self.ckpt.restore_latest(self.state,
                                                          strict=False)
                self.log(f"[trainer] state structure changed since the "
                         f"checkpoint; resumed by path matching ({e})")
            if restored is not None:
                self.state = restored
                self.start_step = int(step)
                self.log(f"[trainer] resumed from step {step}")
        self.drift = self._init_drift()

    def _init_drift(self):
        """Wire a DriftDetector when the obs config carries (or asks us to
        compute) a step-time prediction; None otherwise."""
        cfg = self.tcfg.obs
        if not self.obs.enabled or cfg is None:
            return None
        if cfg.predicted_step_s is not None:
            return self.obs.drift_detector(cfg.predicted_step_s,
                                           source="explicit")
        if not (cfg.predict or cfg.tuned_db):
            return None
        try:
            from repro.obs import predict as obs_predict

            latency = None
            source = "roofline"
            if cfg.tuned_db:
                data_axes, _ = _mesh_axes(self.mesh)
                ccfg = self.step_cfg.comm_config(data_axes)
                mesh_label = "x".join(
                    str(d) for d in self.mesh.devices.shape)
                got = obs_predict.tuned_latency(
                    cfg.tuned_db, transport=ccfg.transport,
                    mesh_label=mesh_label, channels=ccfg.channels,
                    page_bytes=ccfg.page_bytes)
                if got is not None:
                    latency, fit_err, key = got
                    source = "tuned"
                    self.obs.event("tuned_record", key=key, **fit_err)
            sched = build_step_schedule(self.model, self.mesh, self.step_cfg)
            pred = obs_predict.predict_step_time(
                self.step_fn, (self.state, self.data.batch_at(0)),
                mesh=self.mesh, overlap_fraction=sched.overlap_fraction,
                latency=latency)
            self.obs.event("prediction", **pred)
            self.log(f"[obs] predicted step {pred['t_step_s']*1e3:.1f} ms "
                     f"({pred['bottleneck']}-bound, {pred['source']})")
            return self.obs.drift_detector(pred["t_step_s"], source=source)
        except Exception as e:   # prediction is advisory — never kill a run
            self.obs.event("predict_failed", error=repr(e))
            self.log(f"[obs] step-time prediction failed ({e!r}); "
                     f"drift detection disabled")
            return None

    def run(self) -> dict:
        history: list[dict] = []
        obs = self.obs
        t_total = time.time()
        for step in range(self.start_step, self.tcfg.steps):
            with obs.span("data", step=step):
                batch = self.data.batch_at(step)
            t0 = time.time()
            with obs.span("step", step=step):
                with obs.span("dispatch", step=step):
                    with self.mesh:
                        self.state, metrics = self.step_fn(self.state, batch)
                with obs.span("wait", step=step) as sp:
                    sp.fence(metrics)
                    loss = float(metrics["loss"])   # blocks on completion
            dt = time.time() - t0
            ev = self.monitor.record(step, dt)
            obs.counter("steps")
            obs.gauge("step_time_s", dt)
            obs.gauge("loss", loss)
            obs.gauge("grad_norm", float(metrics["grad_norm"]))
            obs.gauge("lr", float(metrics["lr"]))
            if "moe_drop_fraction" in metrics:
                obs.gauge("moe_drop_fraction",
                          float(metrics["moe_drop_fraction"]))
            if self.drift is not None:
                self.drift.update(step, dt)
            rec = {"step": step, "loss": loss,
                   "grad_norm": float(metrics["grad_norm"]),
                   "lr": float(metrics["lr"]), "sec": dt,
                   "straggler": bool(ev)}
            history.append(rec)
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps - 1:
                self.log(f"[train] step {step:5d} loss {loss:.4f} "
                         f"gnorm {rec['grad_norm']:.3f} lr {rec['lr']:.2e} "
                         f"{dt*1e3:.0f} ms" + (" STRAGGLER" if ev else ""))
            if self.ckpt is not None and (step + 1) % self.tcfg.ckpt_every == 0:
                with obs.span("ckpt", step=step):
                    self.ckpt.save(self.state, step + 1)
        if self.ckpt is not None:
            with obs.span("ckpt", step=self.tcfg.steps):
                self.ckpt.save(self.state, self.tcfg.steps)
                self.ckpt.wait()
        wall = time.time() - t_total
        obs.event("run_done", steps=self.tcfg.steps - self.start_step,
                  wall_s=wall, stragglers=len(self.monitor.events),
                  drifting=bool(self.drift.drifting) if self.drift else False)
        paths = obs.finish()
        return {"history": history, "wall": wall,
                "straggler_events": self.monitor.events,
                "obs": paths}

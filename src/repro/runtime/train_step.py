"""Train-step builders: the paper's communication engine fused into a
fully-manual SPMD step.

The step runs inside ``shard_map`` with **every** mesh axis manual: tensor
parallelism is explicit (``ParallelCtx.psum`` in the models), and the
data-parallel gradient reduction is the :class:`repro.comm.Communicator`'s
transport — XLA never inserts an opaque grad all-reduce, so §Perf
before/after measures the paper's technique and nothing else.  All three DP
modes draw their collectives from the same communicator: all-reduce
(replicated), reduce-scatter/all-gather of flat bucket shards (ZeRO-1), and
per-layer weight gather whose autodiff transpose is the reduce-scatter
(FSDP/ZeRO-3).

DP modes (rungs of the paper's ladder):

* ``replicated`` — params + optimizer state replicated over data; grads
  all-reduced (mean) by the communicator's transport.  The 2017 paper's
  setting.
* ``zero1``      — grads *reduce-scattered* into flat bucket shards; AdamW
  updates the shard; the param **delta** is ring-all-gathered and applied.
  Same comm volume as all-reduce (RS+AG), optimizer memory / dp_world.
* ``fsdp``       — ZeRO-3: per-layer-group params stored as flat bucket
  shards; each rematerialised layer ring-all-gathers its bf16 weights on
  entry, and the *autodiff transpose of that gather is exactly the ring
  reduce-scatter*, so gradients arrive pre-sharded for free.  Built entirely
  from the paper's collectives.

When each bucket's reduction is *issued* is no longer implicit: every mode
executes a :class:`repro.comm.schedule.CommSchedule`
(:func:`build_step_schedule`) via ``Communicator.reduce_scheduled``, so
streamed per-bucket reduction overlaps with remaining backward compute and
the dry-run/roofline layers can predict the exposed communication.

``use_arena`` switches all three modes onto the :mod:`repro.mem`
communication arena: gradients pack into one page-aligned, allocate-once
buffer carried in the train state and **donated** through the jitted step
(XLA reuses the allocation in place, the paper's persistent huge-page
registration).  ``replicated`` all-reduces fused contiguous spans (fewer,
larger, aligned messages); ``zero1`` reduce-scatters span shards; ``fsdp``
uses the arena as its microbatch accumulation buffer (its reduction rides
the gather transpose, so only buffer residency changes).

``wire_codec='int8'`` makes the wire quantized: with ``use_arena`` the
arena leaf becomes the int8 payload + fp32-scale buffer written by the
fused pack+quantize kernels (:mod:`repro.kernels.pack_quant`) and the
train state grows an ``"ef"`` leaf — the per-element error-feedback
residual, compensated into every encode so the quantization error
telescopes instead of accumulating.  Without the arena it falls back to
the legacy per-hop ring codec (the ring transports re-encode every hop).

MoE expert parallelism rides its own communicator: ``moe_transport`` /
``moe_channels`` configure the single-axis all-to-all the models reach via
``ParallelCtx.all_to_all`` (dispatch/combine of the capacity buffer), and
the routing layer's capacity-overflow drops surface as the
``moe_drop_fraction`` metric next to loss/grad_norm.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import numpy as np

from repro import compat
from repro.comm import CommConfig, Communicator
from repro.comm.schedule import CommSchedule, SCHEDULE_POLICIES, build_schedule
from repro.core.bucketing import BucketPlan
from repro.core.reducer import ReduceConfig
from repro.mem.arena import CommArena, QuantCommArena
from repro.mem.layout import (ArenaLayout, QuantArenaLayout, plan_arena,
                              plan_quant_arena)
from repro.models.model_api import Model
from repro.models.parallel import ParallelCtx
from repro.optim import (OptimConfig, adamw_flat_update, adamw_tree_update,
                         init_opt_state, make_schedule)
from repro.optim.adamw import clip_factor, global_grad_norm
from repro.sharding import rules as shard_rules
from repro.sharding.rules import MODEL_AXIS

DP_MODES = ("replicated", "zero1", "fsdp")


@dataclass(frozen=True)
class TrainStepConfig:
    dp_mode: str = "replicated"
    comm: CommConfig | None = None     # preferred: the Communicator config
    reduce: ReduceConfig = field(default_factory=ReduceConfig)  # legacy
    optim: OptimConfig = field(default_factory=OptimConfig)
    microbatches: int = 1              # grad-accumulation slices
    schedule: str = "accumulate_then_reduce"  # SCHEDULE_POLICIES member
    use_arena: bool = False            # repro.mem CommArena (page-aligned,
                                       # donated, fused-span collectives)
    wire_codec: str | None = None      # None | "int8": quantized wire; with
                                       # use_arena the arena is the int8
                                       # payload + scale buffer and the train
                                       # state carries the error-feedback
                                       # accumulator ("ef" leaf)
    causal_skip: bool = False
    gather_dtype: str = "bfloat16"     # fsdp weight-gather wire dtype
    fsdp_bucket_bytes: int = 512 * 2**20
    fsdp_gather: str = "native"        # "native" (one all-gather op) | "ring"
                                       # (our unrolled schedule; hillclimb knob)
    moe_transport: str = "a2a"         # EP dispatch/combine transport over the
                                       # model axis: "a2a" (native HLO
                                       # all-to-all) | "ring" | "ring_hier"
                                       # (ppermute hops) | "psum" (honest
                                       # replicated fallback)
    moe_channels: int = 0              # stripe the EP payload's feature dim
                                       # into N independent rails (0/1 = one)

    def comm_config(self, data_axes: tuple[str, ...]) -> CommConfig:
        """The communicator config for this step: ``comm`` when given,
        otherwise the legacy ``reduce`` policy mapped onto a transport."""
        ccfg = self.comm if self.comm is not None else self.reduce.comm_config()
        if self.wire_codec is not None:
            ccfg = replace(ccfg, wire_codec=self.wire_codec)
        if (ccfg.wire_codec is not None and self.dp_mode == "fsdp"
                and self.fsdp_gather == "ring"):
            # the codec encode (round/clip) has zero gradient, so the
            # unrolled ring gather's autodiff transpose — which IS the
            # fsdp reduction — would silently drop it
            raise ValueError(
                "wire_codec is incompatible with fsdp_gather='ring' "
                "(the reduction rides the gather transpose and the "
                "codec has no useful gradient); use fsdp_gather="
                "'native'")
        return replace(ccfg, data_axes=data_axes)

    @property
    def schedule_policy(self) -> str:
        """The (validated) issue-schedule family the step executes."""
        if self.schedule not in SCHEDULE_POLICIES:
            raise ValueError(f"unknown schedule policy {self.schedule!r}; "
                             f"one of {SCHEDULE_POLICIES}")
        return self.schedule


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------


def _mesh_axes(mesh: Mesh) -> tuple[tuple[str, ...], str | None]:
    names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    model_axis = "model" if "model" in names else None
    return data_axes, model_axis


def make_ctx(mesh: Mesh, cfg: TrainStepConfig | None = None) -> ParallelCtx:
    """The models' explicit-collective context.  With a ``cfg`` and a model
    axis the ctx carries the configured EP all-to-all (``moe_transport`` /
    ``moe_channels``) as its dispatch/combine primitive; without one the
    ctx falls back to the native tiled ``lax.all_to_all``."""
    data_axes, model_axis = _mesh_axes(mesh)
    moe_comm = build_moe_comm(mesh, cfg) if cfg is not None else None
    a2a = moe_comm.all_to_all if moe_comm is not None else None
    return ParallelCtx(model_axis=model_axis, data_axes=data_axes, a2a=a2a)


def build_moe_comm(mesh: Mesh, cfg: TrainStepConfig) -> Communicator | None:
    """The EP communicator :func:`make_ctx` attaches (None without a model
    axis) — the dry-run prices its :meth:`~repro.comm.Communicator.a2a_plan`
    against the lowered HLO."""
    _, model_axis = _mesh_axes(mesh)
    if model_axis is None:
        return None
    return Communicator(mesh, CommConfig(
        transport=cfg.moe_transport, data_axes=(model_axis,),
        channels=cfg.moe_channels))


def _sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _flat_spec(mesh: Mesh) -> P:
    return P(tuple(mesh.axis_names))


def build_comm(mesh: Mesh, cfg: TrainStepConfig, *,
               bucket_bytes: int | None = None) -> Communicator:
    """The step's communicator over the mesh's data axes."""
    data_axes, _ = _mesh_axes(mesh)
    ccfg = cfg.comm_config(data_axes)
    if bucket_bytes is not None:
        ccfg = replace(ccfg, bucket_bytes=bucket_bytes)
    return Communicator(mesh, ccfg)


def _local_shapes(tree_abs, specs, mesh: Mesh):
    """Per-device shapes given PartitionSpecs (all axes manual)."""
    sizes = _sizes(mesh)

    def shrink(leaf, spec):
        shape = list(leaf.shape)
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                shape[d] //= sizes[a]
        return jax.ShapeDtypeStruct(tuple(shape), leaf.dtype)

    return jax.tree.map(shrink, tree_abs, specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _slice_to_local(tree_full, specs):
    """Inside manual shard_map: slice full arrays down to this device's shard."""
    def one(leaf, spec):
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            idx = jnp.zeros((), jnp.int32)
            p = 1
            for a in axes:
                idx = idx * compat.axis_size(a) + jax.lax.axis_index(a)
                p *= compat.axis_size(a)
            seg = leaf.shape[d] // p
            leaf = jax.lax.dynamic_slice_in_dim(leaf, idx * seg, seg, axis=d)
        return leaf

    return jax.tree.map(one, tree_full, specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# norm-accounting weights: model-replicated fields must be counted once in
# the global grad norm, not model_size times (kv projections replicate)
# ---------------------------------------------------------------------------


def build_norm_weights(plan: BucketPlan, specs_flat: list, model_size: int
                       ) -> list[np.ndarray]:
    """Per-bucket fp32 weight vector: 1.0 on model-sharded fields,
    1/model_size on replicated fields (so a psum over the model axis counts
    each parameter exactly once)."""
    rep_w = 1.0 / max(model_size, 1)
    weights = [np.full((n,), rep_w, np.float32) for n in plan.bucket_sizes]
    for f in plan.fields:
        spec = specs_flat[f.leaf]
        sharded = any(MODEL_AXIS in (ax if isinstance(ax, tuple) else (ax,))
                      for ax in spec if ax is not None)
        if sharded:
            weights[f.bucket][f.offset:f.offset + f.size] = 1.0
    return weights


def build_span_norm_weights(layout: ArenaLayout,
                            bucket_weights: list[np.ndarray]
                            ) -> list[np.ndarray]:
    """Per-*span* norm weights for the arena ZeRO path: each span's vector
    is its member buckets' weights at their intra-span offsets, zero on the
    page padding (padding elements must never count in the grad norm)."""
    out = []
    for sp in layout.spans:
        w = np.zeros((sp.size,), np.float32)
        for b in sp.buckets:
            seg = layout.segment_of(b)
            off = seg.offset - sp.offset
            w[off:off + seg.size] = bucket_weights[b]
        out.append(w)
    return out


def _slice_like_shard(w: jax.Array, axes: tuple[str, ...]) -> jax.Array:
    """Slice a per-bucket weight vector down to this rank's RS-shard, using
    the same ownership layout as hierarchical reduce-scatter (inner axis
    segments first)."""
    for ax in axes:
        p = compat.axis_size(ax)
        r = jax.lax.axis_index(ax)
        seg = w.shape[0] // p
        w = jax.lax.dynamic_slice_in_dim(w, r * seg, seg)
    return w


# ---------------------------------------------------------------------------
# fsdp (ZeRO-3) planning
# ---------------------------------------------------------------------------


class FsdpPlan:
    """Per-group flat-bucket layout: every block (and each root entry) is
    bucketised separately so layers gather/release independently inside
    their remat boundary."""

    def __init__(self, model: Model, mesh: Mesh, cfg: TrainStepConfig):
        self.model = model
        self.mesh = mesh
        self.gather_impl = cfg.fsdp_gather
        data_axes, _ = _mesh_axes(mesh)
        self.data_axes = data_axes
        self.comm = build_comm(mesh, cfg, bucket_bytes=cfg.fsdp_bucket_bytes)
        if self.gather_impl == "ring" and not self.comm.spec.supports_rs:
            raise ValueError(
                f"fsdp_gather='ring' needs a transport with supports_rs; "
                f"{self.comm.cfg.transport!r} has none — use fsdp_gather="
                f"'native' or a ring transport")
        self.dp_world = self.comm.world
        self.bucketer = self.comm.bucketer
        self.pspecs = model.param_specs(mesh)
        local = _local_shapes(model.abstract_params(), self.pspecs, mesh)
        self.local_abs = local
        self.block_keys = [k for k in ("blocks", "enc_blocks", "dec_blocks")
                           if isinstance(local, dict) and k in local]
        self.groups: dict[str, Any] = {}
        for k in local:
            if k in self.block_keys:
                for i, blk in enumerate(local[k]):
                    self.groups[f"{k}.{i}"] = blk
            else:
                self.groups[f"root.{k}"] = local[k]
        self.plans = {name: self.bucketer.plan(tree)
                      for name, tree in self.groups.items()}
        # arena accumulation buffer: one segment per group-bucket *shard*,
        # in grads-tree leaf order (dicts flatten key-sorted); quantized
        # (int8 payload + scales + error feedback) under wire_codec
        self.arena_layout: ArenaLayout | QuantArenaLayout | None = None
        if cfg.use_arena:
            shard_sizes = [n // max(self.dp_world, 1)
                           for name in sorted(self.plans)
                           for n in self.plans[name].bucket_sizes]
            if self.comm.codec is not None:
                self.arena_layout = plan_quant_arena(
                    shard_sizes, page_bytes=self.comm.cfg.page_bytes,
                    block=self.comm.cfg.codec_block)
            else:
                self.arena_layout = plan_arena(
                    shard_sizes, page_bytes=self.comm.cfg.page_bytes,
                    dtype=jnp.float32)
        # static norm-accounting weights per group (model-replication aware)
        msize = _sizes(mesh).get("model", 1)
        self.norm_weights = {}
        for name in self.groups:
            spec_tree = self._group_of_tree(self.pspecs, name)
            sflat = jax.tree_util.tree_flatten(
                spec_tree, is_leaf=lambda x: isinstance(x, P))[0]
            self.norm_weights[name] = build_norm_weights(
                self.plans[name], sflat, msize)

    @staticmethod
    def _group_of_tree(tree, name):
        kind, _, idx = name.partition(".")
        if kind in ("blocks", "enc_blocks", "dec_blocks"):
            return tree[kind][int(idx)]
        return tree[idx]

    # inside manual shard_map -------------------------------------------------

    def shard_group(self, tree_local, name):
        """Local-model group tree -> flat shards over the data axes."""
        buckets, _ = self.bucketer.bucketize(tree_local, self.plans[name])
        out = []
        for b in buckets:
            for ax in reversed(self.data_axes):      # outermost segment first
                p = compat.axis_size(ax)
                r = jax.lax.axis_index(ax)
                seg = b.shape[0] // p
                b = jax.lax.dynamic_slice_in_dim(b, r * seg, seg)
            out.append(b)
        return out

    def gather_group(self, shards, name, dtype=None):
        """Flat shards -> full group tree via all-gather over the data axes.

        ``native``: one XLA all-gather op per bucket per axis (transpose =
        psum_scatter).  ``ring``: our unrolled ppermute schedule (transpose
        == ring reduce-scatter-sum, verified) — exposes every hop to the
        scheduler/roofline at the cost of much larger HLO.
        """
        full = []
        for s in shards:
            if dtype is not None:
                s = s.astype(dtype)
            full.append(self.comm.gather_flat(
                s, native=self.gather_impl != "ring"))
        return self.bucketer.debucketize(full, self.plans[name],
                                         cast_to=dtype)

    def shard_state(self, params_local):
        groups = {}
        for name in self.groups:
            groups[name] = self.shard_group(self._group_of(params_local, name),
                                            name)
        return groups

    def _group_of(self, params, name):
        kind, _, idx = name.partition(".")
        if kind in ("blocks", "enc_blocks", "dec_blocks"):
            return params[kind][int(idx)]
        return params[idx]

    def params_and_resolver(self, groups, dtype):
        """Root groups gathered eagerly; blocks left as shard lists with a
        resolver the model calls inside each layer's remat boundary."""
        params: dict = {}
        for name, shards in groups.items():
            kind, _, idx = name.partition(".")
            if kind == "root":
                params[idx] = self.gather_group(shards, name, dtype)
        for k in self.block_keys:
            n = len([1 for name in groups if name.startswith(k + ".")])
            params[k] = [groups[f"{k}.{i}"] for i in range(n)]

        def resolver(kind: str, i: int, shards):
            return self.gather_group(shards, f"{kind}.{i}", dtype)

        return params, resolver


# ---------------------------------------------------------------------------
# state init
# ---------------------------------------------------------------------------


def init_train_state(model: Model, mesh: Mesh, cfg: TrainStepConfig,
                     key=None, abstract: bool = False):
    """Returns (state, state_specs).  ``abstract=True`` -> ShapeDtypeStructs."""
    pspecs = model.param_specs(mesh)
    flat = _flat_spec(mesh)
    key = key if key is not None else jax.random.key(0)

    # use_arena: the persistent page-aligned comm buffer lives in the state
    # (one flat leaf, donated with the rest), so every step reuses the same
    # allocation — the paper's allocate-once registration.  Under
    # wire_codec='int8' the arena leaf is the int8 payload+scale buffer and
    # an fp32 "ef" leaf carries the error-feedback residuals; both donated,
    # both restored by path (ckpt.restore keeps the fresh zeros when a
    # checkpoint written without them is loaded).
    arena_elems = 0
    arena_dtype = jnp.float32
    ef_elems = 0

    def _arena_leaves(state):
        state["arena"] = jnp.zeros((arena_elems,), arena_dtype)
        if ef_elems:
            state["ef"] = jnp.zeros((ef_elems,), jnp.float32)
        return state

    def _arena_specs(specs, layout):
        nonlocal arena_elems, arena_dtype, ef_elems
        arena_elems = layout.total_elems
        arena_dtype = jnp.dtype(layout.dtype)
        specs["arena"] = flat
        if isinstance(layout, QuantArenaLayout):
            ef_elems = layout.payload_elems
            specs["ef"] = flat

    if cfg.dp_mode == "replicated":
        specs = {"params": pspecs, "opt": {"mu": pspecs, "nu": pspecs},
                 "step": P()}
        if cfg.use_arena:
            comm = build_comm(mesh, cfg)
            local = _local_shapes(model.abstract_params(), pspecs, mesh)
            _arena_specs(specs, comm.arena_layout(local))

        def mk(k):
            p_local = _slice_to_local(model.init(k), pspecs)
            state = {"params": p_local, "opt": init_opt_state(p_local),
                     "step": jnp.zeros((), jnp.int32)}
            return _arena_leaves(state) if cfg.use_arena else state

    elif cfg.dp_mode == "zero1":
        comm = build_comm(mesh, cfg)
        local = _local_shapes(model.abstract_params(), pspecs, mesh)
        plan = comm.bucketer.plan(local)
        if cfg.use_arena:
            # optimizer shards follow the fused-span layout, not the buckets
            layout = comm.arena_layout(local)
            shard_sizes = [sp.size // comm.world for sp in layout.spans]
        else:
            shard_sizes = [n // comm.world for n in plan.bucket_sizes]
        specs = {"params": pspecs,
                 "opt": {"mu": [flat] * len(shard_sizes),
                         "nu": [flat] * len(shard_sizes)},
                 "step": P()}
        if cfg.use_arena:
            _arena_specs(specs, layout)

        def mk(k):
            p_local = _slice_to_local(model.init(k), pspecs)
            zeros = lambda: [jnp.zeros((n,), jnp.float32) for n in shard_sizes]
            state = {"params": p_local, "opt": {"mu": zeros(), "nu": zeros()},
                     "step": jnp.zeros((), jnp.int32)}
            return _arena_leaves(state) if cfg.use_arena else state

    elif cfg.dp_mode == "fsdp":
        plan = FsdpPlan(model, mesh, cfg)
        spec_groups = {name: [flat] * plan.plans[name].n_buckets
                       for name in plan.groups}
        specs = {"groups": spec_groups,
                 "opt": {"mu": spec_groups, "nu": spec_groups},
                 "step": P()}
        if cfg.use_arena:
            _arena_specs(specs, plan.arena_layout)

        def mk(k):
            p_local = _slice_to_local(model.init(k), pspecs)
            groups = plan.shard_state(p_local)
            zeros = lambda: jax.tree.map(
                lambda s: jnp.zeros_like(s, jnp.float32), groups)
            state = {"groups": groups, "opt": {"mu": zeros(), "nu": zeros()},
                     "step": jnp.zeros((), jnp.int32)}
            return _arena_leaves(state) if cfg.use_arena else state

    else:
        raise ValueError(f"dp_mode must be one of {DP_MODES}")

    def mk_from_data(kd):
        return mk(jax.random.wrap_key_data(kd))

    fn = compat.shard_map(mk_from_data, mesh=mesh, in_specs=P(),
                          out_specs=specs, check_vma=False)
    if abstract:
        kd_abs = jax.eval_shape(jax.random.key_data, jax.random.key(0))
        return jax.eval_shape(fn, kd_abs), specs
    return jax.jit(fn)(jax.random.key_data(key)), specs


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_step_schedule(model: Model, mesh: Mesh, cfg: TrainStepConfig
                        ) -> CommSchedule:
    """The :class:`CommSchedule` the step executes (also what the dry-run
    records and the roofline's overlap fraction reads).

    ``replicated`` / ``zero1`` derive issue slots from the communicator's
    bucket layout of the local gradient tree — span-level
    (:meth:`~repro.comm.Communicator.arena_schedule`) when ``use_arena``
    fuses each channel's contiguous arena span into one collective.
    ``fsdp`` always reports the ``scheduled`` readiness model regardless of
    the configured policy: its reduce-scatter is the autodiff transpose of
    the per-layer weight gather, so streaming in backward readiness order
    is *intrinsic* — the schedule policy only shapes local shard
    accumulation, never serialises comm.
    """
    policy = cfg.schedule_policy
    m = cfg.microbatches
    if cfg.dp_mode == "fsdp":
        return _fsdp_schedule(FsdpPlan(model, mesh, cfg), m)
    comm = build_comm(mesh, cfg)
    pspecs = model.param_specs(mesh)
    local = _local_shapes(model.abstract_params(), pspecs, mesh)
    if cfg.use_arena:
        return comm.arena_schedule(local, policy, m)
    return comm.schedule(local, policy, m)


def _fsdp_schedule(plan: FsdpPlan, microbatches: int) -> CommSchedule:
    sizes = [n for name in sorted(plan.plans)
             for n in plan.plans[name].bucket_sizes]
    return build_schedule("scheduled", sizes, microbatches=microbatches,
                          channels=plan.comm.cfg.channels)


def build_train_step(model: Model, mesh: Mesh, cfg: TrainStepConfig,
                     batch_pspecs, donate: bool = True):
    """Returns ``step(state, batch) -> (state, metrics)`` jitted over the
    fully-manual mesh."""
    pspecs = model.param_specs(mesh)
    ctx = make_ctx(mesh, cfg)
    schedule = make_schedule(cfg.optim.schedule, base_lr=cfg.optim.base_lr,
                             warmup=cfg.optim.warmup,
                             total=cfg.optim.total_steps)
    _, state_specs = init_train_state(model, mesh, cfg, abstract=True)
    metric_specs = {"loss": P(), "grad_norm": P(), "lr": P(),
                    "moe_drop_fraction": P()}

    if cfg.dp_mode in ("replicated", "zero1"):
        comm = build_comm(mesh, cfg)
        local_abs = _local_shapes(model.abstract_params(), pspecs, mesh)
        # single source with the dry-run's prediction: the schedule the step
        # executes IS the one build_step_schedule reports (span-level when
        # the arena fuses each channel into one collective)
        comm_sched = build_step_schedule(model, mesh, cfg)
        comm_arena = comm.arena(local_abs) if cfg.use_arena else None
        zero1_norm_weights = None
        if cfg.dp_mode == "zero1":
            if not comm.spec.supports_rs:
                raise ValueError(
                    f"dp_mode='zero1' needs a transport with supports_rs; "
                    f"{comm.cfg.transport!r} has none (registered ring "
                    f"transports do)")
            z1_plan = comm.bucketer.plan(local_abs)
            specs_flat = jax.tree_util.tree_flatten(
                pspecs, is_leaf=lambda x: isinstance(x, P))[0]
            zero1_norm_weights = build_norm_weights(
                z1_plan, specs_flat, _sizes(mesh).get("model", 1))
            if comm_arena is not None:
                # shards follow the fused spans; padding weighs zero
                zero1_norm_weights = build_span_norm_weights(
                    comm_arena.layout, zero1_norm_weights)

        def step_fn(state, batch):
            drops: list = []             # per-microbatch moe_drop_fraction

            def gfn(p, mb):
                stats: list = []
                loss = model.loss_fn(p, mb, ctx=ctx,
                                     causal_skip=cfg.causal_skip,
                                     stats_out=stats)
                drop = (stats[0]["moe_drop_fraction"] if stats
                        else jnp.zeros((), jnp.float32))
                return loss, drop

            def grad_fn(p, mb):
                (loss, drop), g = jax.value_and_grad(gfn, has_aux=True)(p, mb)
                drops.append(drop)
                return loss, g

            new_arena = None
            new_ef = None
            quant = isinstance(comm_arena, QuantCommArena)
            if cfg.dp_mode == "replicated":
                if quant:
                    loss, (grads, new_arena, new_ef) = comm.reduce_scheduled(
                        grad_fn, state["params"], batch, comm_sched,
                        op="all_reduce", arena=comm_arena,
                        arena_buf=state["arena"], ef_buf=state["ef"])
                elif comm_arena is not None:
                    loss, (grads, new_arena) = comm.reduce_scheduled(
                        grad_fn, state["params"], batch, comm_sched,
                        op="all_reduce", arena=comm_arena,
                        arena_buf=state["arena"])
                else:
                    loss, grads = comm.reduce_scheduled(
                        grad_fn, state["params"], batch, comm_sched,
                        op="all_reduce")
                gnorm = global_grad_norm(grads, pspecs, ctx)
                factor = clip_factor(gnorm, cfg.optim.clip_norm)
                grads = jax.tree.map(lambda g: g * factor, grads)
                lr = schedule(state["step"])
                new_p, new_opt = adamw_tree_update(
                    state["params"], grads, state["opt"], state["step"], lr,
                    cfg.optim)
                new_state = {"params": new_p, "opt": new_opt,
                             "step": state["step"] + 1}
            else:  # zero1: buckets reduce-scatter as their microbatch's
                   # backward finishes (streamed ZeRO); shards accumulate
                if quant:
                    loss, (shards, plan, new_arena, new_ef) = (
                        comm.reduce_scheduled(
                            grad_fn, state["params"], batch, comm_sched,
                            op="reduce_scatter", arena=comm_arena,
                            arena_buf=state["arena"], ef_buf=state["ef"]))
                elif comm_arena is not None:
                    loss, (shards, plan, new_arena) = comm.reduce_scheduled(
                        grad_fn, state["params"], batch, comm_sched,
                        op="reduce_scatter", arena=comm_arena,
                        arena_buf=state["arena"])
                else:
                    loss, (shards, plan) = comm.reduce_scheduled(
                        grad_fn, state["params"], batch, comm_sched,
                        op="reduce_scatter")
                # exact global norm over the *reduced* gradient: weight
                # model-replicated fields by 1/model_size before the psum
                ordered = comm.ordered_axes
                sq = jnp.zeros((), jnp.float32)
                for s, w in zip(shards, zero1_norm_weights):
                    wl = _slice_like_shard(jnp.asarray(w), ordered)
                    sq = sq + jnp.sum(jnp.square(s) * wl)
                gnorm = jnp.sqrt(ctx.psum(ctx.psum_data(sq)))
                factor = clip_factor(gnorm, cfg.optim.clip_norm)
                shards = [s * factor for s in shards]
                lr = schedule(state["step"])
                deltas, new_opt = adamw_flat_update(shards, state["opt"],
                                                    state["step"], lr,
                                                    cfg.optim)
                if comm_arena is not None:
                    spans = comm.all_gather(deltas)
                    delta_tree = comm.bucketer.debucketize(
                        comm_arena.unpack_spans(spans), plan)
                else:
                    delta_tree = comm.all_gather_buckets(deltas, plan)
                wd = 1 - lr * cfg.optim.weight_decay
                new_p = jax.tree.map(
                    lambda p, d: (p.astype(jnp.float32) * wd
                                  + d.astype(jnp.float32)).astype(p.dtype),
                    state["params"], delta_tree)
                new_state = {"params": new_p, "opt": new_opt,
                             "step": state["step"] + 1}
            if new_arena is not None:
                new_state["arena"] = new_arena
            if new_ef is not None:
                new_state["ef"] = new_ef
            drop = sum(drops) / max(len(drops), 1)
            metrics = {"loss": ctx.pmean_data(loss), "grad_norm": gnorm,
                       "lr": lr, "moe_drop_fraction": ctx.pmean_data(drop)}
            return new_state, metrics

    else:  # fsdp / ZeRO-3
        plan = FsdpPlan(model, mesh, cfg)
        gdt = jnp.dtype(cfg.gather_dtype)
        # reduction rides the autodiff transpose of the per-layer gather, so
        # streaming in readiness order is intrinsic; the schedule records it
        comm_sched = _fsdp_schedule(plan, cfg.microbatches)
        fsdp_impl = ("pallas" if plan.comm.cfg.local_op == "pallas"
                     else "jnp")
        fsdp_arena = None
        if cfg.use_arena:
            fsdp_arena = (QuantCommArena(plan.arena_layout, impl=fsdp_impl)
                          if isinstance(plan.arena_layout, QuantArenaLayout)
                          else CommArena(plan.arena_layout, impl=fsdp_impl))

        def step_fn(state, batch):
            drops: list = []             # per-microbatch moe_drop_fraction

            def gfn(groups, mb):
                params, resolver = plan.params_and_resolver(groups, gdt)
                stats: list = []
                loss = model.loss_fn(params, mb, ctx=ctx,
                                     causal_skip=cfg.causal_skip,
                                     block_resolver=resolver,
                                     stats_out=stats)
                drop = (stats[0]["moe_drop_fraction"] if stats
                        else jnp.zeros((), jnp.float32))
                return loss, drop

            def grad_fn(groups, mb):
                (loss, drop), g = jax.value_and_grad(gfn, has_aux=True)(
                    groups, mb)
                drops.append(drop)
                return loss, g

            new_arena = None
            new_ef = None
            if isinstance(fsdp_arena, QuantCommArena):
                # quantized accumulation buffer: pack+quantize with error
                # feedback once per step, fused dequant+unpack out
                loss, (grads, new_arena, new_ef) = plan.comm.reduce_scheduled(
                    grad_fn, state["groups"], batch, comm_sched, op="none",
                    arena=fsdp_arena, arena_buf=state["arena"],
                    ef_buf=state["ef"])
            elif fsdp_arena is not None:
                # the arena is the microbatch accumulation buffer (grads
                # arrive pre-sharded via the gather transpose)
                loss, (grads, new_arena) = plan.comm.reduce_scheduled(
                    grad_fn, state["groups"], batch, comm_sched, op="none",
                    arena=fsdp_arena, arena_buf=state["arena"])
            else:
                loss, grads = plan.comm.reduce_scheduled(
                    grad_fn, state["groups"], batch, comm_sched, op="none")
            # grads are flat shards already (AG-transpose == RS-sum over the
            # data axes); normalise the sum into a mean.
            inv = 1.0 / max(plan.dp_world, 1)
            grads = jax.tree.map(lambda g: g * inv, grads)
            ordered = tuple(reversed(plan.data_axes))
            sq = jnp.zeros((), jnp.float32)
            for name in sorted(plan.groups):
                for g, w in zip(grads[name], plan.norm_weights[name]):
                    wl = _slice_like_shard(jnp.asarray(w), ordered)
                    sq = sq + jnp.sum(jnp.square(g.astype(jnp.float32)) * wl)
            gnorm = jnp.sqrt(ctx.psum(ctx.psum_data(sq)))
            factor = clip_factor(gnorm, cfg.optim.clip_norm)
            lr = schedule(state["step"])
            wd = 1 - lr * cfg.optim.weight_decay
            new_groups, new_mu, new_nu = {}, {}, {}
            for name in state["groups"]:
                gsh = [g * factor for g in grads[name]]
                deltas, nopt = adamw_flat_update(
                    gsh, {"mu": state["opt"]["mu"][name],
                          "nu": state["opt"]["nu"][name]},
                    state["step"], lr, cfg.optim)
                new_groups[name] = [
                    (p.astype(jnp.float32) * wd + d).astype(p.dtype)
                    for p, d in zip(state["groups"][name], deltas)]
                new_mu[name] = nopt["mu"]
                new_nu[name] = nopt["nu"]
            new_state = {"groups": new_groups,
                         "opt": {"mu": new_mu, "nu": new_nu},
                         "step": state["step"] + 1}
            if new_arena is not None:
                new_state["arena"] = new_arena
            if new_ef is not None:
                new_state["ef"] = new_ef
            drop = sum(drops) / max(len(drops), 1)
            metrics = {"loss": ctx.pmean_data(loss), "grad_norm": gnorm,
                       "lr": lr, "moe_drop_fraction": ctx.pmean_data(drop)}
            return new_state, metrics

    sharded = compat.shard_map(step_fn, mesh=mesh,
                               in_specs=(state_specs, batch_pspecs),
                               out_specs=(state_specs, metric_specs),
                               check_vma=False)
    return jax.jit(sharded, donate_argnums=(0,) if donate else ())

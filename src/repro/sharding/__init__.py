from repro.sharding.rules import (batch_spec, decode_state_specs, param_specs,
                                  shardings_of)

__all__ = ["batch_spec", "decode_state_specs", "param_specs", "shardings_of"]

"""Logical-axis sharding rules: param pytrees -> PartitionSpec trees.

Policies:
* ``tp``   — tensor parallelism over "model" only; params replicated over
  the data axes (small archs).
* ``fsdp`` — additionally shard the non-model dim of every large matrix over
  "data" (ZeRO-3-style; XLA all-gathers per layer on use).  Required for the
  >=14B archs to fit 16 GB/chip (proven by ``memory_analysis`` in the
  dry-run).

Rules are name-based over the param dict paths emitted by ``repro.models``.
Dims shard only when they divide the mesh axis — otherwise they stay
replicated (kv-head replication for GQA archs whose kv count doesn't tile
the model axis; query heads are already padded by the model).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

MODEL_AXIS = "model"
DATA_AXIS = "data"
POD_AXIS = "pod"


def _axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def batch_spec(global_batch: int, mesh: Mesh) -> P:
    """Shard the batch over ("pod","data") when divisible, else fewer axes."""
    sizes = _axis_sizes(mesh)
    axes = [a for a in (POD_AXIS, DATA_AXIS) if a in sizes]
    prod = 1
    for a in axes:
        prod *= sizes[a]
    if axes and _div(global_batch, prod):
        return P(tuple(axes))
    if DATA_AXIS in sizes and _div(global_batch, sizes[DATA_AXIS]):
        return P(DATA_AXIS)
    return P()


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------


def _rule(path: tuple[str, ...], shape: tuple[int, ...], cfg: ModelConfig,
          sizes: dict[str, int]) -> P:
    fsdp = DATA_AXIS if (cfg.sharding == "fsdp" and DATA_AXIS in sizes) else None
    tp = MODEL_AXIS if MODEL_AXIS in sizes else None
    m = sizes.get(MODEL_AXIS, 1)
    d = sizes.get(DATA_AXIS, 1)
    name = path[-1] if path else ""
    joined = "/".join(path)

    def ok(dim_size, axis):
        size = sizes.get(axis or "", 1)
        return axis is not None and _div(dim_size, size)

    # kv projections are NEVER model-sharded: no assigned arch has kv heads
    # divisible by the 16-wide model axis; each TP rank keeps full kv and
    # gathers the heads its local q heads group to (attention._gather_kv...).
    is_kv = any(k in joined for k in ("wk/", "wv/")) or name in ("wk", "wv")

    # 1-d params: shard vectors that live on a TP-sharded feature dim
    if len(shape) <= 1:
        if not shape:
            return P()
        sharded_vec = (name in ("conv_b", "d_skip") or
                       ("dt_proj" in joined and name == "b") or
                       ("wq" in joined and name == "b"))
        if sharded_vec and not is_kv and ok(shape[0], tp):
            return P(tp)
        return P()

    if "embed" in joined and name == "table":            # (V, d)
        v_ax = tp if ok(shape[0], tp) else None
        d_ax = fsdp if ok(shape[1], fsdp) else None
        return P(v_ax, d_ax)

    if name in ("conv_w",):                              # (W, Din)
        return P(None, tp if ok(shape[1], tp) else None)
    if name == "a_log":                                  # (Din, N)
        return P(tp if ok(shape[0], tp) else None, None)

    # MoE expert stacks: (E, d, f) / (E, f, d)
    if "moe" in joined and name in ("w_gate", "w_up", "w_down") and len(shape) == 3:
        ep = cfg.moe is not None and cfg.moe.parallelism == "ep"
        if ep and ok(shape[0], tp):
            return P(tp, fsdp if ok(shape[1], fsdp) else None, None)
        # tp-in-expert: shard the ffn dim
        ff_dim = 2 if name in ("w_gate", "w_up") else 1
        spec = [None, None, None]
        if ok(shape[ff_dim], tp):
            spec[ff_dim] = tp
        other = 2 if ff_dim == 1 else 1
        if ok(shape[other], fsdp):
            spec[other] = fsdp
        return P(*spec)

    if len(shape) == 2:
        din, dout = shape
        if is_kv or "router" in joined:
            return P(fsdp if ok(din, fsdp) else None, None)
        row_parallel = any(k in joined for k in ("wo", "w_down", "out_proj",
                                                 "x_proj"))
        col_parallel = any(k in joined for k in ("wq", "w_gate", "w_up",
                                                 "in_proj", "dt_proj",
                                                 "lm_head"))
        if row_parallel:
            return P(tp if ok(din, tp) else None, fsdp if ok(dout, fsdp) else None)
        if col_parallel:
            return P(fsdp if ok(din, fsdp) else None, tp if ok(dout, tp) else None)
        return P(fsdp if ok(din, fsdp) else None, None)

    return P()


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh):
    """PartitionSpec tree congruent with ``params`` (arrays or SDS)."""
    sizes = _axis_sizes(mesh)

    def visit(path, leaf):
        keys = tuple(_key_name(k) for k in path)
        return _rule(keys, tuple(leaf.shape), cfg, sizes)

    return jax.tree_util.tree_map_with_path(visit, params)


def _key_name(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


def shardings_of(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# decode state rules
# ---------------------------------------------------------------------------


def decode_state_specs(state: Any, cfg: ModelConfig, mesh: Mesh,
                       global_batch: int):
    """KV caches (B,Hkv,C,D), SSM h (B,Din,N), conv (B,W-1,Din), cross k/v."""
    sizes = _axis_sizes(mesh)
    bspec = batch_spec(global_batch, mesh)
    batch_axes = bspec[0] if len(bspec) else None
    tp = MODEL_AXIS if MODEL_AXIS in sizes else None

    # Paged serving state (repro.serve): the KV arena and its bookkeeping
    # are REPLICATED — page-parallelism is expressed inside the engine (each
    # model rank scores its static slice of page-table columns), and the
    # slot vectors index *sequences*, not the data batch.  The generic
    # shape[0] == global_batch fallback below must not capture them: on a
    # data > 1 mesh it would scatter slot_len / page_table over data ranks
    # and every rank would see garbage lengths for the slots it didn't get.
    _PAGED_STATE = ("pages", "page_table", "slot_len", "slot_valid")

    def visit(path, leaf):
        keys = tuple(_key_name(k) for k in path)
        name = keys[-1] if keys else ""
        shape = tuple(leaf.shape)
        if name in _PAGED_STATE:
            return P()
        if name in ("k", "v", "cross_k", "cross_v") and len(shape) == 4:
            # kv heads replicate across TP ranks (see rules above); long
            # caches are *sequence-sharded* over the model axis instead
            # (context-parallel decode) so 32k x big-batch caches fit.
            seq_ax = (tp if name in ("k", "v") and tp is not None
                      and shape[2] >= 8192 and _div(shape[2], sizes[tp])
                      else None)
            return P(batch_axes, None, seq_ax, None)
        if name == "h" and len(shape) == 3:
            h_ax = tp if _div(shape[1], sizes.get(tp or "", 1)) else None
            return P(batch_axes, h_ax, None)
        if name == "conv" and len(shape) == 3:
            h_ax = tp if _div(shape[2], sizes.get(tp or "", 1)) else None
            return P(batch_axes, None, h_ax)
        return P(batch_axes) if shape and shape[0] == global_batch else P()

    return jax.tree_util.tree_map_with_path(visit, state)

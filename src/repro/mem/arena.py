"""CommArena: allocate-once, donate-every-step communication buffers.

The executable half of :mod:`repro.mem.layout`: a :class:`CommArena` owns
an :class:`~repro.mem.layout.ArenaLayout` and moves flat buckets in and out
of the arena buffer.

The persistence contract mirrors the paper's pre-registered huge-page
buffers: the arena is allocated **once** (as part of the train state) and
threaded through the jitted step as a **donated** argument, so XLA aliases
the input buffer to the output and every step reuses the same page-aligned
allocation — no per-step transient comm buffers.  Packing therefore writes
*into* the existing buffer (:meth:`pack_into`, N aliased segment copies)
rather than concatenating a fresh one; the page-padding gaps keep whatever
bytes they held (they are never read back), exactly like the slack of a
pinned registration.

Both directions ship two implementations, selected by ``impl``:

* ``"jnp"``    — ``dynamic_update_slice`` / ``slice`` reference path;
* ``"pallas"`` — the :mod:`repro.kernels.pack` flat-copy kernels
  (lane-tiled, in-place via ``input_output_aliases``; interpret mode
  off-TPU), with automatic fallback to the reference for unaligned shapes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.mem.layout import ArenaLayout

PACK_IMPLS = ("jnp", "pallas")


@dataclass(frozen=True)
class CommArena:
    """One persistent, page-aligned communication buffer + its layout."""

    layout: ArenaLayout
    impl: str = "jnp"

    def __post_init__(self):
        if self.impl not in PACK_IMPLS:
            raise ValueError(f"impl must be one of {PACK_IMPLS}, "
                             f"got {self.impl!r}")

    # -- allocation ----------------------------------------------------------

    def zeros(self) -> jax.Array:
        """A fresh zero arena (the allocate-once step-state initialiser)."""
        return jnp.zeros((self.layout.total_elems,), self.layout.dtype)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct((self.layout.total_elems,),
                                    jnp.dtype(self.layout.dtype))

    # -- pack / unpack (run inside jit / shard_map) --------------------------

    def _write(self, arena: jax.Array, src: jax.Array, offset: int
               ) -> jax.Array:
        if self.impl == "pallas":
            from repro.kernels.pack import write_flat

            return write_flat(arena, src, offset)
        from repro.kernels.pack import ref

        return ref.write_flat(arena, src, offset)

    def _read(self, arena: jax.Array, offset: int, size: int) -> jax.Array:
        if self.impl == "pallas":
            from repro.kernels.pack import read_flat

            return read_flat(arena, offset, size)
        from repro.kernels.pack import ref

        return ref.read_flat(arena, offset, size)

    def pack_into(self, arena: jax.Array, buffers: Sequence[jax.Array]
                  ) -> jax.Array:
        """Write ``buffers[i]`` into segment ``i``'s slot of ``arena``.

        ``buffers`` are the flat buckets in bucket-id order (the bucketer's
        output).  Padding gaps keep the arena's previous contents — pass a
        donated step-state buffer here so XLA updates it in place.
        """
        lay = self.layout
        if len(buffers) != lay.n_segments:
            raise ValueError(f"arena has {lay.n_segments} segments, got "
                             f"{len(buffers)} buffers")
        if arena.shape != (lay.total_elems,):
            raise ValueError(f"arena shape {arena.shape} != "
                             f"({lay.total_elems},)")
        for seg in lay.segments:
            b = buffers[seg.bucket].reshape(-1)
            if b.shape[0] != seg.size:
                raise ValueError(f"bucket {seg.bucket} has {b.shape[0]} "
                                 f"elems, segment expects {seg.size}")
            arena = self._write(arena, b.astype(lay.dtype), seg.offset)
        return arena

    def pack(self, buffers: Sequence[jax.Array]) -> jax.Array:
        """Fresh arena with ``buffers`` packed and padding zeroed (the
        reference entry point; prefer :meth:`pack_into` on the persistent
        donated buffer inside the step)."""
        return self.pack_into(self.zeros(), buffers)

    def unpack(self, arena: jax.Array) -> list[jax.Array]:
        """Segment payloads out of ``arena``, indexed by bucket id."""
        lay = self.layout
        if arena.shape != (lay.total_elems,):
            raise ValueError(f"arena shape {arena.shape} != "
                             f"({lay.total_elems},)")
        out: list[jax.Array | None] = [None] * lay.n_segments
        for seg in lay.segments:
            out[seg.bucket] = self._read(arena, seg.offset, seg.size)
        return out

    def unpack_spans(self, spans: Sequence[jax.Array]) -> list[jax.Array]:
        """Bucket payloads out of per-span buffers (e.g. all-gathered
        ZeRO spans), indexed by bucket id."""
        lay = self.layout
        if len(spans) != lay.n_spans:
            raise ValueError(f"arena has {lay.n_spans} spans, got "
                             f"{len(spans)}")
        out: list[jax.Array | None] = [None] * lay.n_segments
        for idx, sp in enumerate(lay.spans):
            buf = spans[idx].reshape(-1)
            if buf.shape[0] != sp.size:
                raise ValueError(f"span {idx} has {buf.shape[0]} elems, "
                                 f"expected {sp.size}")
            for b in sp.buckets:
                seg = lay.segment_of(b)
                out[b] = self._read(buf, seg.offset - sp.offset, seg.size)
        return out

"""CommArena: allocate-once, donate-every-step communication buffers.

The executable half of :mod:`repro.mem.layout`: a :class:`CommArena` owns
an :class:`~repro.mem.layout.ArenaLayout` and moves flat buckets in and out
of the arena buffer.

The persistence contract mirrors the paper's pre-registered huge-page
buffers: the arena is allocated **once** (as part of the train state) and
threaded through the jitted step as a **donated** argument, so XLA aliases
the input buffer to the output and every step reuses the same page-aligned
allocation — no per-step transient comm buffers.  Packing therefore writes
*into* the existing buffer (:meth:`pack_into`, N aliased segment copies)
rather than concatenating a fresh one; the page-padding gaps keep whatever
bytes they held (they are never read back), exactly like the slack of a
pinned registration.

Both directions ship two implementations, selected by ``impl``:

* ``"jnp"``    — ``dynamic_update_slice`` / ``slice`` reference path;
* ``"pallas"`` — the :mod:`repro.kernels.pack` flat-copy kernels
  (lane-tiled, in-place via ``input_output_aliases``; interpret mode
  off-TPU), with automatic fallback to the reference for unaligned shapes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.topology import padded_size
from repro.mem.layout import ArenaLayout, QuantArenaLayout

PACK_IMPLS = ("jnp", "pallas")


@dataclass(frozen=True)
class CommArena:
    """One persistent, page-aligned communication buffer + its layout."""

    layout: ArenaLayout
    impl: str = "jnp"

    def __post_init__(self):
        if self.impl not in PACK_IMPLS:
            raise ValueError(f"impl must be one of {PACK_IMPLS}, "
                             f"got {self.impl!r}")

    # -- allocation ----------------------------------------------------------

    def zeros(self) -> jax.Array:
        """A fresh zero arena (the allocate-once step-state initialiser)."""
        return jnp.zeros((self.layout.total_elems,), self.layout.dtype)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct((self.layout.total_elems,),
                                    jnp.dtype(self.layout.dtype))

    # -- pack / unpack (run inside jit / shard_map) --------------------------

    def _write(self, arena: jax.Array, src: jax.Array, offset: int
               ) -> jax.Array:
        if self.impl == "pallas":
            from repro.kernels.pack import write_flat

            return write_flat(arena, src, offset)
        from repro.kernels.pack import ref

        return ref.write_flat(arena, src, offset)

    def _read(self, arena: jax.Array, offset: int, size: int) -> jax.Array:
        if self.impl == "pallas":
            from repro.kernels.pack import read_flat

            return read_flat(arena, offset, size)
        from repro.kernels.pack import ref

        return ref.read_flat(arena, offset, size)

    def pack_into(self, arena: jax.Array, buffers: Sequence[jax.Array]
                  ) -> jax.Array:
        """Write ``buffers[i]`` into segment ``i``'s slot of ``arena``.

        ``buffers`` are the flat buckets in bucket-id order (the bucketer's
        output).  Padding gaps keep the arena's previous contents — pass a
        donated step-state buffer here so XLA updates it in place.
        """
        lay = self.layout
        if len(buffers) != lay.n_segments:
            raise ValueError(f"arena has {lay.n_segments} segments, got "
                             f"{len(buffers)} buffers")
        if arena.shape != (lay.total_elems,):
            raise ValueError(f"arena shape {arena.shape} != "
                             f"({lay.total_elems},)")
        for seg in lay.segments:
            b = buffers[seg.bucket].reshape(-1)
            if b.shape[0] != seg.size:
                raise ValueError(f"bucket {seg.bucket} has {b.shape[0]} "
                                 f"elems, segment expects {seg.size}")
            arena = self._write(arena, b.astype(lay.dtype), seg.offset)
        return arena

    def pack(self, buffers: Sequence[jax.Array]) -> jax.Array:
        """Fresh arena with ``buffers`` packed and padding zeroed (the
        reference entry point; prefer :meth:`pack_into` on the persistent
        donated buffer inside the step)."""
        return self.pack_into(self.zeros(), buffers)

    def unpack(self, arena: jax.Array) -> list[jax.Array]:
        """Segment payloads out of ``arena``, indexed by bucket id."""
        lay = self.layout
        if arena.shape != (lay.total_elems,):
            raise ValueError(f"arena shape {arena.shape} != "
                             f"({lay.total_elems},)")
        out: list[jax.Array | None] = [None] * lay.n_segments
        for seg in lay.segments:
            out[seg.bucket] = self._read(arena, seg.offset, seg.size)
        return out

    def unpack_spans(self, spans: Sequence[jax.Array]) -> list[jax.Array]:
        """Bucket payloads out of per-span buffers (e.g. all-gathered
        ZeRO spans), indexed by bucket id."""
        lay = self.layout
        if len(spans) != lay.n_spans:
            raise ValueError(f"arena has {lay.n_spans} spans, got "
                             f"{len(spans)}")
        out: list[jax.Array | None] = [None] * lay.n_segments
        for idx, sp in enumerate(lay.spans):
            buf = spans[idx].reshape(-1)
            if buf.shape[0] != sp.size:
                raise ValueError(f"span {idx} has {buf.shape[0]} elems, "
                                 f"expected {sp.size}")
            for b in sp.buckets:
                seg = lay.segment_of(b)
                out[b] = self._read(buf, seg.offset - sp.offset, seg.size)
        return out


@dataclass(frozen=True)
class QuantCommArena:
    """The quantized-wire arena: one persistent donated **int8** buffer
    holding per-block absmax int8 payload plus the trailing fp32 scale
    segment (:class:`~repro.mem.layout.QuantArenaLayout`).

    Packing *encodes*: :meth:`pack_into` runs the fused pack+quantize
    kernel per segment — error-feedback compensation applied on the way in,
    residual emitted on the way out — and :meth:`unpack` /
    :meth:`dequant_span` run the fused dequant+unpack.  The persistence
    contract is :class:`CommArena`'s: thread the buffer (and the fp32
    error-feedback accumulator) through the jitted step donated, so both
    live in the same allocation step over step.
    """

    layout: QuantArenaLayout
    impl: str = "jnp"

    def __post_init__(self):
        if self.impl not in PACK_IMPLS:
            raise ValueError(f"impl must be one of {PACK_IMPLS}, "
                             f"got {self.impl!r}")

    # -- allocation ----------------------------------------------------------

    def zeros(self) -> jax.Array:
        return jnp.zeros((self.layout.total_elems,), self.layout.dtype)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct((self.layout.total_elems,),
                                    jnp.dtype(self.layout.dtype))

    def ef_zeros(self) -> jax.Array:
        """A fresh zero error-feedback accumulator — one fp32 residual per
        payload element, donated alongside the arena."""
        return jnp.zeros((self.layout.payload_elems,), jnp.float32)

    def ef_abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct((self.layout.payload_elems,),
                                    jnp.float32)

    # -- fused encode / decode (run inside jit / shard_map) ------------------

    def _write_quant(self, arena: jax.Array, src: jax.Array, offset: int):
        if self.impl == "pallas":
            from repro.kernels.pack_quant import write_quant_flat

            return write_quant_flat(arena, src, offset,
                                    self.layout.scale_offset,
                                    self.layout.block)
        from repro.kernels.pack_quant import ref

        return ref.write_quant_flat(arena, src, offset,
                                    self.layout.scale_offset,
                                    self.layout.block)

    def _read_dequant(self, arena: jax.Array, offset: int, size: int
                      ) -> jax.Array:
        if self.impl == "pallas":
            from repro.kernels.pack_quant import read_dequant_flat

            return read_dequant_flat(arena, offset, size,
                                     self.layout.scale_offset,
                                     self.layout.block)
        from repro.kernels.pack_quant import ref

        return ref.read_dequant_flat(arena, offset, size,
                                     self.layout.scale_offset,
                                     self.layout.block)

    def pack_into(self, arena: jax.Array, buffers: Sequence[jax.Array],
                  ef: jax.Array | None = None):
        """Quantize ``buffers[i]`` into segment ``i`` + trailing scales.

        When ``ef`` (the flat fp32 error-feedback accumulator) is given,
        each bucket is compensated with its stored residual before
        encoding and the accumulator is updated from the fresh
        quantization residual.  Returns ``(arena, ef)``.
        """
        lay = self.layout
        if len(buffers) != lay.n_segments:
            raise ValueError(f"arena has {lay.n_segments} segments, got "
                             f"{len(buffers)} buffers")
        if arena.shape != (lay.total_elems,):
            raise ValueError(f"arena shape {arena.shape} != "
                             f"({lay.total_elems},)")
        if ef is not None and ef.shape != (lay.payload_elems,):
            raise ValueError(f"ef shape {ef.shape} != "
                             f"({lay.payload_elems},)")
        from jax import lax
        for seg in lay.segments:
            b = buffers[seg.bucket].reshape(-1)
            if b.shape[0] != seg.size:
                raise ValueError(f"bucket {seg.bucket} has {b.shape[0]} "
                                 f"elems, segment expects {seg.size}")
            # encode whole quant blocks: sizes not already block multiples
            # (e.g. per-shard FSDP units) are zero-extended into the
            # segment's block-aligned padding
            bsize = padded_size(seg.size, lay.block)
            b = b.astype(jnp.float32)
            if bsize != seg.size:
                b = jnp.pad(b, (0, bsize - seg.size))
            if ef is not None:
                b = b + lax.slice_in_dim(ef, seg.offset, seg.offset + bsize,
                                         axis=0)
            arena, residual = self._write_quant(arena, b, seg.offset)
            if ef is not None:
                ef = lax.dynamic_update_slice_in_dim(ef, residual,
                                                     seg.offset, axis=0)
        return arena, ef

    def pack(self, buffers: Sequence[jax.Array],
             ef: jax.Array | None = None):
        return self.pack_into(self.zeros(), buffers, ef)

    def unpack(self, arena: jax.Array) -> list[jax.Array]:
        """Fused dequant+unpack: fp32 segment payloads, by bucket id."""
        lay = self.layout
        if arena.shape != (lay.total_elems,):
            raise ValueError(f"arena shape {arena.shape} != "
                             f"({lay.total_elems},)")
        out: list[jax.Array | None] = [None] * lay.n_segments
        for seg in lay.segments:
            bsize = padded_size(seg.size, lay.block)
            dec = self._read_dequant(arena, seg.offset, bsize)
            out[seg.bucket] = dec[:seg.size] if bsize != seg.size else dec
        return out

    # -- span mode (the fused-collective path) -------------------------------

    def dequant_span(self, arena: jax.Array, idx: int) -> jax.Array:
        """Decode span ``idx``'s payload to fp32 (span sizes are whole
        quant blocks by layout)."""
        sp = self.layout.spans[idx]
        return self._read_dequant(arena, sp.offset, sp.size)

    def requant_span(self, arena: jax.Array, idx: int,
                     values: jax.Array) -> jax.Array:
        """Re-encode reduced fp32 ``values`` into span ``idx``'s payload +
        scales (residual discarded: error feedback compensates the encode
        of the *local* gradient, not the reduced sum)."""
        sp = self.layout.spans[idx]
        if values.shape != (sp.size,):
            raise ValueError(f"span {idx} expects ({sp.size},), got "
                             f"{values.shape}")
        arena, _ = self._write_quant(arena, values, sp.offset)
        return arena

    def unpack_spans(self, spans: Sequence[jax.Array]) -> list[jax.Array]:
        """Bucket payloads out of per-span **fp32** buffers (e.g.
        all-gathered ZeRO deltas) — plain slicing, no codec."""
        return CommArena(self.layout.payload,
                         self.impl).unpack_spans(spans)

"""ArenaLayout: page-quantized placement of communication buffers.

The paper's third pillar: near-wirespeed collectives are only *robust* when
the buffers they reduce out of come from carefully allocated 2 MB huge
pages (the libhugetlbfs LD_PRELOAD trick) — large, stable, fused
allocations instead of many small transient ones.  The TPU/XLA analogue is
a single flat **arena** per gradient pytree:

* every :class:`~repro.core.bucketing.BucketPlan` bucket (or halo face
  payload) becomes an :class:`ArenaSegment` whose element offset and padded
  size are quantized to ``page_bytes`` (default 2 MiB), so segment starts
  can never straddle a page and the allocation is exactly a whole number of
  pages;
* segments sharing a virtual channel are laid out contiguously and fused
  into an :class:`ArenaSpan` — one collective per span moves the paper's
  "fewer, larger, aligned messages" instead of one per bucket (the
  :class:`~repro.comm.plan.LatencyModel` α-term prices exactly this);
* the page padding is accounted per segment (waste/fragmentation) and in
  aggregate (:attr:`ArenaLayout.padding_fraction`), because in arena mode
  the padding *does* cross the wire — the roofline folds it into the
  wire-byte prediction rather than pretending it is free.

An oversized bucket (a single pytree leaf larger than the bucketer's
``bucket_bytes`` target — the bucketer never splits leaves) is handled as a
dedicated page-aligned segment like any other, but a warning is emitted
once so silent target overruns are visible (see
``GradientBucketer``'s oversized-leaf invariant).

This module deliberately depends only on :mod:`repro.core` and
:mod:`repro.comm.schedule` (never :mod:`repro.comm.api`), so
``repro.comm`` can import it without a cycle.
"""

from __future__ import annotations

import math
import warnings
from dataclasses import dataclass
from typing import Sequence

import jax.numpy as jnp

from repro.comm.schedule import CommSchedule, IssueSlot
from repro.core.bucketing import BucketPlan
from repro.core.topology import padded_size

PAGE_BYTES = 2 * 2**20     # the paper's huge-page size


@dataclass(frozen=True)
class ArenaSegment:
    """One source buffer's page-quantized slot inside the arena."""

    bucket: int        # source bucket / unit id
    channel: int       # virtual channel carrying this segment
    offset: int        # element offset into the arena (quantum-aligned)
    size: int          # used elements (the source buffer's length)
    padded: int        # quantum-aligned element count (>= size)

    @property
    def padding(self) -> int:
        return self.padded - self.size

    @property
    def waste(self) -> float:
        """This segment's fragmentation: padding share of its footprint."""
        return self.padding / self.padded if self.padded else 0.0


@dataclass(frozen=True)
class ArenaSpan:
    """A contiguous run of same-channel segments — one fused collective."""

    channel: int
    buckets: tuple[int, ...]   # member bucket ids, in arena order
    offset: int                # element offset of the first segment
    size: int                  # padded elements covered (incl. padding)


@dataclass(frozen=True)
class ArenaLayout:
    """Placement of one pytree's communication buffers in one flat arena."""

    dtype: object              # jnp dtype of the arena
    page_bytes: int            # requested page size (allocation granule)
    quantum: int               # element quantization unit (see plan_arena)
    segments: tuple[ArenaSegment, ...]   # in arena (offset) order
    spans: tuple[ArenaSpan, ...]

    # -- shape ---------------------------------------------------------------

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    @property
    def n_spans(self) -> int:
        return len(self.spans)

    @property
    def total_elems(self) -> int:
        last = self.segments[-1] if self.segments else None
        return last.offset + last.padded if last else 0

    @property
    def total_bytes(self) -> int:
        return self.total_elems * jnp.dtype(self.dtype).itemsize

    @property
    def n_pages(self) -> int:
        """Whole pages the arena allocates (total is page-quantized)."""
        return -(-self.total_bytes // self.page_bytes)

    # -- padding accounting --------------------------------------------------

    @property
    def used_elems(self) -> int:
        return sum(s.size for s in self.segments)

    @property
    def padding_elems(self) -> int:
        return self.total_elems - self.used_elems

    @property
    def padding_fraction(self) -> float:
        t = self.total_elems
        return self.padding_elems / t if t else 0.0

    # -- lookup --------------------------------------------------------------

    def segment_of(self, bucket: int) -> ArenaSegment:
        for s in self.segments:
            if s.bucket == bucket:
                return s
        raise KeyError(bucket)

    def span_of(self, bucket: int) -> ArenaSpan:
        for sp in self.spans:
            if bucket in sp.buckets:
                return sp
        raise KeyError(bucket)

    def validate(self) -> None:
        """Structural invariants the executors rely on."""
        end = 0
        by_bucket = {}
        for s in self.segments:
            if s.offset % self.quantum or s.padded % self.quantum:
                raise ValueError(f"segment {s.bucket}: offset/padded not "
                                 f"quantized to {self.quantum} elems")
            if s.offset < end:
                raise ValueError(f"segment {s.bucket} overlaps its "
                                 f"predecessor ({s.offset} < {end})")
            if s.size > s.padded:
                raise ValueError(f"segment {s.bucket}: size {s.size} > "
                                 f"padded {s.padded}")
            end = s.offset + s.padded
            by_bucket[s.bucket] = s
        for sp in self.spans:
            segs = [by_bucket[b] for b in sp.buckets]
            if not segs:
                raise ValueError("empty span")
            if sp.offset != segs[0].offset:
                raise ValueError(f"span@{sp.offset}: first segment at "
                                 f"{segs[0].offset}")
            if sp.size != sum(s.padded for s in segs):
                raise ValueError(f"span@{sp.offset}: size {sp.size} != "
                                 f"member total")
            run = sp.offset
            for s in segs:
                if s.offset != run or s.channel != sp.channel:
                    raise ValueError(f"span@{sp.offset}: segment "
                                     f"{s.bucket} not contiguous on "
                                     f"channel {sp.channel}")
                run += s.padded

    def describe(self) -> dict:
        """JSON-friendly summary for the dry-run report."""
        return {
            "page_bytes": self.page_bytes,
            "quantum_elems": self.quantum,
            "dtype": jnp.dtype(self.dtype).name,
            "n_segments": self.n_segments,
            "n_spans": self.n_spans,
            "total_elems": self.total_elems,
            "total_bytes": self.total_bytes,
            "n_pages": self.n_pages,
            "padding_elems": self.padding_elems,
            "padding_fraction": self.padding_fraction,
            "segments": [{"bucket": s.bucket, "channel": s.channel,
                          "offset": s.offset, "size": s.size,
                          "padded": s.padded, "waste": s.waste}
                         for s in self.segments],
            "spans": [{"channel": sp.channel, "buckets": list(sp.buckets),
                       "offset": sp.offset, "size": sp.size}
                      for sp in self.spans],
        }


SCALE_BYTES = 4  # one fp32 scale per codec block, bitcast to arena bytes


@dataclass(frozen=True)
class QuantArenaLayout:
    """Placement of a wire-codec arena: the int8 quantized payload laid out
    exactly like an fp32 :class:`ArenaLayout` (one elem == one byte), plus
    one trailing page-quantized **scale segment** holding the per-block
    fp32 scales bitcast to bytes.  One donated flat int8 buffer carries
    payload and scales; segments/spans delegate to the payload layout, so
    every consumer of the fp32 arena (schedule fusing, span norms, shard
    sizing) works unchanged on element counts.

    Payload offsets and padded sizes are ``block`` multiples (the plan
    folds the codec block into the pad multiple), so (a) a segment's scale
    index is ``offset // block`` — segments never share a scale block, an
    oversized leaf's dedicated segment keeps its own scales — and (b)
    padding occupies whole quant blocks, confining any stale-byte decode to
    elements no reader ever consumes.
    """

    payload: ArenaLayout       # int8 payload placement
    block: int                 # codec block: payload elements per scale

    # -- payload delegation (element counts == byte counts for int8) ---------

    @property
    def dtype(self) -> object:
        return self.payload.dtype

    @property
    def page_bytes(self) -> int:
        return self.payload.page_bytes

    @property
    def quantum(self) -> int:
        return self.payload.quantum

    @property
    def segments(self) -> tuple[ArenaSegment, ...]:
        return self.payload.segments

    @property
    def spans(self) -> tuple[ArenaSpan, ...]:
        return self.payload.spans

    @property
    def n_segments(self) -> int:
        return self.payload.n_segments

    @property
    def n_spans(self) -> int:
        return self.payload.n_spans

    @property
    def used_elems(self) -> int:
        return self.payload.used_elems

    @property
    def padding_elems(self) -> int:
        return self.payload.padding_elems

    @property
    def padding_fraction(self) -> float:
        return self.payload.padding_fraction

    def segment_of(self, bucket: int) -> ArenaSegment:
        return self.payload.segment_of(bucket)

    def span_of(self, bucket: int) -> ArenaSpan:
        return self.payload.span_of(bucket)

    # -- the trailing scale segment ------------------------------------------

    @property
    def payload_elems(self) -> int:
        return self.payload.total_elems

    @property
    def n_scales(self) -> int:
        return self.payload.total_elems // self.block

    @property
    def scale_offset(self) -> int:
        """Byte/element offset of the scale segment (page-aligned, since
        the payload total is quantum-aligned)."""
        return self.payload.total_elems

    @property
    def scale_region_bytes(self) -> int:
        return padded_size(max(self.n_scales * SCALE_BYTES, 1),
                           self.page_bytes)

    @property
    def total_elems(self) -> int:
        return self.scale_offset + self.scale_region_bytes

    @property
    def total_bytes(self) -> int:
        return self.total_elems  # int8

    @property
    def n_pages(self) -> int:
        return -(-self.total_bytes // self.page_bytes)

    def scale_byte_range(self, offset: int, size: int) -> tuple[int, int]:
        """Arena byte range of the scales covering payload
        ``[offset : offset + size]``."""
        lo = self.scale_offset + (offset // self.block) * SCALE_BYTES
        return lo, lo + (size // self.block) * SCALE_BYTES

    # -- wire accounting -----------------------------------------------------

    @property
    def wire_bytes_per_elem(self) -> float:
        """Bytes one payload element costs on the wire: the int8 value plus
        its amortized share of the block scale."""
        return 1.0 + SCALE_BYTES / self.block

    def validate(self) -> None:
        self.payload.validate()
        if jnp.dtype(self.payload.dtype) != jnp.int8:
            raise ValueError(f"quant arena payload must be int8, got "
                             f"{self.payload.dtype}")
        if self.block <= 0:
            raise ValueError(f"block must be positive, got {self.block}")
        for s in self.segments:
            if s.offset % self.block or s.padded % self.block:
                raise ValueError(f"segment {s.bucket}: offset/padded not a "
                                 f"multiple of codec block {self.block}")

    def describe(self) -> dict:
        return self.payload.describe() | {
            "codec": "int8",
            "codec_block": self.block,
            "payload_elems": self.payload_elems,
            "n_scales": self.n_scales,
            "scale_offset": self.scale_offset,
            "scale_region_bytes": self.scale_region_bytes,
            "total_elems": self.total_elems,
            "total_bytes": self.total_bytes,
            "n_pages": self.n_pages,
            "wire_bytes_per_elem": self.wire_bytes_per_elem,
        }


# emit the oversized-bucket warning once per process, not once per plan
_warned_oversized = False


def plan_arena(sizes: Sequence[int], *, page_bytes: int = PAGE_BYTES,
               dtype=jnp.float32, channel_of: Sequence[int] | None = None,
               pad_multiple: int = 1, bucket_bytes: int | None = None,
               warn_oversized: bool = True) -> ArenaLayout:
    """Pack flat buffers of ``sizes`` elements into one page-quantized arena.

    ``channel_of[i]`` is the virtual channel carrying buffer ``i`` (default:
    every buffer its own channel — no fusing, matching ``channels == 0``).
    Buffers are laid out grouped by channel (ascending, original order
    within a channel), so each channel's segments form one contiguous
    :class:`ArenaSpan`.

    The quantization unit is ``lcm(page_bytes / itemsize, pad_multiple)``:
    page alignment *and* the transport's flat-buffer divisor (so a fused
    span can still be ring reduce-scattered).  ``bucket_bytes``, when given,
    is the bucketer's target size; any buffer exceeding it (an oversized
    pytree leaf the bucketer refused to split) still gets its dedicated
    page-aligned segment, but a warning is emitted once per process —
    ``warn_oversized=False`` suppresses it for pure-prediction callers
    (e.g. :meth:`repro.comm.Communicator.plan`, which lays out the arena
    for every dry-run cell whether or not arena mode runs).
    """
    dtype = jnp.dtype(dtype)
    if page_bytes <= 0 or page_bytes % dtype.itemsize:
        raise ValueError(f"page_bytes must be a positive multiple of the "
                         f"itemsize ({dtype.itemsize}), got {page_bytes}")
    if pad_multiple <= 0:
        raise ValueError(f"pad_multiple must be positive, got {pad_multiple}")
    sizes = [int(n) for n in sizes]
    if channel_of is None:
        channel_of = list(range(len(sizes)))
    if len(channel_of) != len(sizes):
        raise ValueError(f"channel_of has {len(channel_of)} entries for "
                         f"{len(sizes)} buffers")
    quantum = math.lcm(page_bytes // dtype.itemsize, int(pad_multiple))

    if bucket_bytes is not None and warn_oversized:
        oversized = [i for i, n in enumerate(sizes)
                     if n * dtype.itemsize > bucket_bytes]
        global _warned_oversized
        if oversized and not _warned_oversized:
            _warned_oversized = True
            warnings.warn(
                f"{len(oversized)} bucket(s) exceed the {bucket_bytes}-byte "
                f"target (oversized pytree leaves are never split); each "
                f"gets a dedicated page-aligned arena segment "
                f"(ids {oversized[:8]}{'...' if len(oversized) > 8 else ''})",
                RuntimeWarning, stacklevel=2)

    # channel-grouped order: each channel's buffers land contiguously
    order = sorted(range(len(sizes)), key=lambda i: (channel_of[i], i))
    segments: list[ArenaSegment] = []
    spans: list[ArenaSpan] = []
    offset = 0
    for i in order:
        padded = padded_size(max(sizes[i], 1), quantum)
        seg = ArenaSegment(bucket=i, channel=int(channel_of[i]),
                           offset=offset, size=sizes[i], padded=padded)
        segments.append(seg)
        if spans and spans[-1].channel == seg.channel:
            last = spans[-1]
            spans[-1] = ArenaSpan(channel=last.channel,
                                  buckets=last.buckets + (i,),
                                  offset=last.offset,
                                  size=last.size + padded)
        else:
            spans.append(ArenaSpan(channel=seg.channel, buckets=(i,),
                                   offset=offset, size=padded))
        offset += padded

    layout = ArenaLayout(dtype=dtype, page_bytes=int(page_bytes),
                         quantum=quantum, segments=tuple(segments),
                         spans=tuple(spans))
    layout.validate()
    return layout


def arena_from_bucket_plan(plan: BucketPlan, *,
                           page_bytes: int = PAGE_BYTES,
                           channel_of: Sequence[int] | None = None,
                           pad_multiple: int = 1,
                           bucket_bytes: int | None = None,
                           warn_oversized: bool = True) -> ArenaLayout:
    """Arena layout for a :class:`~repro.core.bucketing.BucketPlan`: one
    segment per bucket, in the plan's dtype."""
    return plan_arena(plan.bucket_sizes, page_bytes=page_bytes,
                      dtype=plan.bucket_dtype, channel_of=channel_of,
                      pad_multiple=max(pad_multiple, plan.pad_multiple),
                      bucket_bytes=bucket_bytes,
                      warn_oversized=warn_oversized)


def plan_quant_arena(sizes: Sequence[int], *, page_bytes: int = PAGE_BYTES,
                     block: int = 512,
                     channel_of: Sequence[int] | None = None,
                     pad_multiple: int = 1, bucket_bytes: int | None = None,
                     warn_oversized: bool = True) -> QuantArenaLayout:
    """Quantized-wire variant of :func:`plan_arena`: ``sizes`` are fp32
    *value* counts, placed as int8 payload with the codec ``block`` folded
    into the pad multiple (so segment offsets/padded sizes hold whole quant
    blocks) and a trailing page-quantized scale segment appended."""
    if block <= 0:
        raise ValueError(f"block must be positive, got {block}")
    pad = math.lcm(int(pad_multiple), int(block))
    # sizes count fp32 gradient values; scale the oversized threshold to
    # the int8 itemsize so the warning fires for the same leaves as fp32
    bb = None if bucket_bytes is None else max(1, int(bucket_bytes) // 4)
    payload = plan_arena(sizes, page_bytes=page_bytes, dtype=jnp.int8,
                         channel_of=channel_of, pad_multiple=pad,
                         bucket_bytes=bb, warn_oversized=warn_oversized)
    layout = QuantArenaLayout(payload=payload, block=int(block))
    layout.validate()
    return layout


def quant_arena_from_bucket_plan(plan: BucketPlan, *,
                                 page_bytes: int = PAGE_BYTES,
                                 block: int = 512,
                                 channel_of: Sequence[int] | None = None,
                                 pad_multiple: int = 1,
                                 bucket_bytes: int | None = None,
                                 warn_oversized: bool = True
                                 ) -> QuantArenaLayout:
    """Quantized arena layout for a bucket plan: one int8 segment per
    bucket plus the trailing scale segment."""
    return plan_quant_arena(plan.bucket_sizes, page_bytes=page_bytes,
                            block=block, channel_of=channel_of,
                            pad_multiple=max(pad_multiple,
                                             plan.pad_multiple),
                            bucket_bytes=bucket_bytes,
                            warn_oversized=warn_oversized)


def arena_from_halo_plan(halo_plan, *, page_bytes: int = PAGE_BYTES,
                         itemsize: int = 4, dtype=jnp.float32,
                         pad_multiple: int = 1) -> ArenaLayout:
    """Arena layout for halo face payloads: one segment per exchange unit
    of a :class:`~repro.comm.plan.HaloPlan` (whose ``unit_bytes`` are
    *bytes*; segments here are elements), grouped by the plan's halo
    channels so each rail's faces fuse into one contiguous span."""
    sizes = [-(-int(b) // itemsize) for b in halo_plan.unit_bytes]
    chan_of = [0] * len(sizes)
    for hc in halo_plan.channels:
        for u in hc.units:
            chan_of[u] = hc.channel
    return plan_arena(sizes, page_bytes=page_bytes, dtype=dtype,
                      channel_of=chan_of, pad_multiple=pad_multiple)


def fuse_schedule(schedule: CommSchedule, layout: ArenaLayout
                  ) -> CommSchedule:
    """The span-level :class:`~repro.comm.schedule.CommSchedule` an arena
    executor runs: per phase, each :class:`ArenaSpan` issues **one**
    collective covering its members' contiguous segments (padding
    included).  Slot ``bucket_ids`` index :attr:`ArenaLayout.spans`;
    ``bucket_sizes`` are span element counts, so ``overlap_fraction`` stays
    traffic-weighted.  A span becomes ready only when its *last* member
    does, so fused overlap is never optimistically higher than the
    per-bucket schedule's."""
    if layout.n_segments != schedule.n_buckets:
        raise ValueError(
            f"layout has {layout.n_segments} segments but the schedule has "
            f"{schedule.n_buckets} buckets; build both from the same plan")
    phases = sorted({s.phase for s in schedule.slots})
    span_sizes = tuple(sp.size for sp in layout.spans)
    slots: list[IssueSlot] = []
    for phase in phases:
        ready_of = {}
        for s in schedule.slots_for_phase(phase):
            for b in s.bucket_ids:
                ready_of[b] = max(ready_of.get(b, 0.0), s.ready)
        phase_slots = []
        for idx, sp in enumerate(layout.spans):
            ready = max(ready_of[b] for b in sp.buckets)
            phase_slots.append(IssueSlot(phase=phase, bucket_ids=(idx,),
                                         channel=sp.channel, ready=ready))
        slots.extend(sorted(phase_slots,
                            key=lambda s: (s.ready, s.channel)))
    fused = CommSchedule(policy=schedule.policy,
                         microbatches=schedule.microbatches,
                         bucket_sizes=span_sizes,
                         channels=schedule.channels, slots=tuple(slots))
    fused.validate()
    return fused

"""repro.mem — page-aligned communication-buffer arenas.

The paper's memory pillar as a subsystem: :mod:`repro.mem.layout` plans a
page-quantized :class:`ArenaLayout` (segments per bucket, fused spans per
virtual channel, padding/fragmentation accounting) and
:mod:`repro.mem.arena` executes it (:class:`CommArena`: allocate-once,
donate-every-step pack/unpack with jnp and Pallas flat-copy paths).
``Communicator.reduce_scheduled(..., arena=...)`` reduces contiguous arena
spans instead of bucket pytrees; ``TrainStepConfig.use_arena`` threads it
through all three DP modes.
"""

from repro.mem.arena import CommArena, PACK_IMPLS
from repro.mem.layout import (ArenaLayout, ArenaSegment, ArenaSpan,
                              PAGE_BYTES, arena_from_bucket_plan,
                              arena_from_halo_plan, fuse_schedule,
                              plan_arena)

__all__ = [
    "ArenaLayout", "ArenaSegment", "ArenaSpan", "CommArena", "PACK_IMPLS",
    "PAGE_BYTES", "arena_from_bucket_plan", "arena_from_halo_plan",
    "fuse_schedule", "plan_arena",
]

"""repro.mem — page-aligned communication-buffer arenas.

The paper's memory pillar as a subsystem: :mod:`repro.mem.layout` plans a
page-quantized :class:`ArenaLayout` (segments per bucket, fused spans per
virtual channel, padding/fragmentation accounting) and
:mod:`repro.mem.arena` executes it (:class:`CommArena`: allocate-once,
donate-every-step pack/unpack with jnp and Pallas flat-copy paths).
``Communicator.reduce_scheduled(..., arena=...)`` reduces contiguous arena
spans instead of bucket pytrees; ``TrainStepConfig.use_arena`` threads it
through all three DP modes.

Under ``wire_codec='int8'`` the arena is a :class:`QuantArenaLayout` /
:class:`QuantCommArena` pair: int8 payload + trailing fp32 block scales in
one donated buffer, packed by the fused pack+quantize kernels with
error-feedback residuals (:mod:`repro.kernels.pack_quant`).
"""

from repro.mem.arena import CommArena, PACK_IMPLS, QuantCommArena
from repro.mem.layout import (ArenaLayout, ArenaSegment, ArenaSpan,
                              PAGE_BYTES, QuantArenaLayout,
                              arena_from_bucket_plan, arena_from_halo_plan,
                              fuse_schedule, plan_arena, plan_quant_arena,
                              quant_arena_from_bucket_plan)

__all__ = [
    "ArenaLayout", "ArenaSegment", "ArenaSpan", "CommArena", "PACK_IMPLS",
    "PAGE_BYTES", "QuantArenaLayout", "QuantCommArena",
    "arena_from_bucket_plan", "arena_from_halo_plan", "fuse_schedule",
    "plan_arena", "plan_quant_arena", "quant_arena_from_bucket_plan",
]

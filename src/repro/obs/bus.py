"""MetricsBus: process-local counters/gauges/histograms with a JSONL sink.

The bus is the repo's single telemetry spine: the Trainer, the serve
scheduler/engine, the fault-tolerance monitors and the DriftDetector all
publish through it, and ``repro.obs.report`` re-aggregates the JSONL stream
after the fact.  Design constraints, in order:

* **Host-side only.**  Nothing here touches jax — publishing a metric never
  inserts an op, changes a traced shape, or perturbs the lowered HLO (the
  obs-off HLO-identity pin in ``tests/test_obs.py`` holds the step program
  byte-identical with the bus present).
* **Zero-overhead opt-out.**  :data:`NULL_BUS` implements the same surface
  as no-ops; callers hold a bus reference unconditionally and never branch.
* **The JSONL file is the source of truth.**  In-memory aggregates exist
  for tests and end-of-run summaries; the report CLI reads only the file,
  so a crashed run's telemetry survives up to the last flush.

Record shapes (one JSON object per line)::

    {"ts": s, "kind": "counter|gauge|hist", "name": n, "value": v,
     "labels": {...}}
    {"ts": s, "kind": "span",  "name": n, "dur_s": d, "labels": {...}}
    {"ts": s, "kind": "event", "name": n, "fields": {...}}
"""

from __future__ import annotations

import json
import os
import time


def _jsonable(obj):
    """numpy scalars (and anything with ``.item()``) -> python scalars."""
    if hasattr(obj, "item"):
        try:
            return obj.item()
        except Exception:
            pass
    return str(obj)


class MetricsBus:
    """Labelled counters, gauges and histograms with an append-only JSONL
    sink (``<run_dir>/events.jsonl``); ``run_dir=None`` keeps everything
    in memory (aggregates only, no file)."""

    def __init__(self, run_dir: str | None = None, *, flush_every: int = 64,
                 clock=time.time):
        self.run_dir = run_dir
        self.flush_every = max(int(flush_every), 1)
        self._clock = clock
        self.counters: dict = {}
        self.gauges: dict = {}
        self.hists: dict = {}
        self.spans: dict = {}
        self.n_records = 0
        self._buf: list[str] = []
        self._fh = None
        self.path = None
        if run_dir is not None:
            os.makedirs(run_dir, exist_ok=True)
            self.path = os.path.join(run_dir, "events.jsonl")

    # -- keys ----------------------------------------------------------------

    @staticmethod
    def _key(name: str, labels: dict) -> tuple:
        return (name, tuple(sorted(labels.items())))

    # -- sink ----------------------------------------------------------------

    def _emit(self, rec: dict) -> None:
        self.n_records += 1
        if self.path is None:
            return
        self._buf.append(json.dumps(rec, default=_jsonable))
        if len(self._buf) >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        if self.path is None or not self._buf:
            return
        if self._fh is None:
            self._fh = open(self.path, "a")
        self._fh.write("\n".join(self._buf) + "\n")
        self._fh.flush()
        self._buf.clear()

    def close(self) -> None:
        self.flush()
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- instruments ---------------------------------------------------------

    def counter(self, name: str, value: float = 1.0, **labels) -> float:
        """Monotonic accumulator; returns the new total."""
        k = self._key(name, labels)
        total = self.counters.get(k, 0.0) + float(value)
        self.counters[k] = total
        self._emit({"ts": self._clock(), "kind": "counter", "name": name,
                    "value": float(value), "labels": labels})
        return total

    def gauge(self, name: str, value: float, **labels) -> None:
        """Last-value-wins instrument (step time, queue depth, model_error)."""
        self.gauges[self._key(name, labels)] = float(value)
        self._emit({"ts": self._clock(), "kind": "gauge", "name": name,
                    "value": float(value), "labels": labels})

    def observe(self, name: str, value: float, **labels) -> None:
        """Histogram sample (summarised by :meth:`hist_summary`)."""
        self.hists.setdefault(self._key(name, labels), []).append(float(value))
        self._emit({"ts": self._clock(), "kind": "hist", "name": name,
                    "value": float(value), "labels": labels})

    def event(self, name: str, **fields) -> None:
        """Structured one-off record (straggler, drift_alarm, admit, ...)."""
        self._emit({"ts": self._clock(), "kind": "event", "name": name,
                    "fields": fields})

    def span(self, name: str, dur_s: float, **labels) -> None:
        """Completed phase-span occurrence (published by the Tracer)."""
        self.spans.setdefault(name, []).append(float(dur_s))
        self._emit({"ts": self._clock(), "kind": "span", "name": name,
                    "dur_s": float(dur_s), "labels": labels})

    # -- reading (tests / end-of-run summaries) ------------------------------

    def counter_value(self, name: str, **labels) -> float:
        return self.counters.get(self._key(name, labels), 0.0)

    def counter_total(self, name: str) -> float:
        """Sum over every label combination of ``name``."""
        return sum(v for (n, _), v in self.counters.items() if n == name)

    def gauge_value(self, name: str, **labels) -> float | None:
        return self.gauges.get(self._key(name, labels))

    def has_gauge(self, name: str) -> bool:
        return any(n == name for (n, _) in self.gauges)

    def hist_summary(self, name: str, **labels) -> dict | None:
        vals = self.hists.get(self._key(name, labels))
        if not vals:
            return None
        s = sorted(vals)
        return {"count": len(s), "sum": sum(s), "min": s[0], "max": s[-1],
                "mean": sum(s) / len(s), "p50": s[len(s) // 2]}

    def summary(self) -> dict:
        def label_str(key):
            name, items = key
            if not items:
                return name
            return name + "{" + ",".join(f"{k}={v}" for k, v in items) + "}"

        return {
            "counters": {label_str(k): v for k, v in self.counters.items()},
            "gauges": {label_str(k): v for k, v in self.gauges.items()},
            "hists": {label_str(k): len(v) for k, v in self.hists.items()},
            "spans": {n: {"count": len(d), "total_s": sum(d)}
                      for n, d in self.spans.items()},
            "n_records": self.n_records,
        }


class _NullBus:
    """The opt-out: every instrument is a no-op, every read is empty.  Hot
    paths hold this unconditionally — no ``if obs:`` branches anywhere."""

    path = None
    run_dir = None
    counters: dict = {}
    gauges: dict = {}
    hists: dict = {}
    spans: dict = {}
    n_records = 0

    def counter(self, name, value=1.0, **labels):
        return 0.0

    def gauge(self, name, value, **labels):
        pass

    def observe(self, name, value, **labels):
        pass

    def event(self, name, **fields):
        pass

    def span(self, name, dur_s, **labels):
        pass

    def flush(self):
        pass

    def close(self):
        pass

    def counter_value(self, name, **labels):
        return 0.0

    def counter_total(self, name):
        return 0.0

    def gauge_value(self, name, **labels):
        return None

    def has_gauge(self, name):
        return False

    def hist_summary(self, name, **labels):
        return None

    def summary(self):
        return {"counters": {}, "gauges": {}, "hists": {}, "spans": {},
                "n_records": 0}


NULL_BUS = _NullBus()

"""The shared machine-readable result schema for benches and obs consumers.

Every registered benchmark prints CSV blocks for humans; this module turns
them into one canonical JSON artifact per bench —
``BENCH_<name>.json`` — so the perf trajectory is diffable run-over-run
(``benchmarks/run.py --json <dir>``).  The same record shape carries any
tabular obs payload (probe matrices, report summaries), so there is exactly
one "rows + meta" format in the repo.

Record shape::

    {"schema": "repro.obs.bench/v1", "name": ..., "created": iso8601,
     "n_rows": N, "rows": [{col: scalar, ...}, ...], "meta": {...}}

Pure stdlib — importable from the report CLI and the bench harness without
pulling jax.
"""

from __future__ import annotations

import json
import os
import time

SCHEMA = "repro.obs.bench/v1"

_SCALARS = (str, int, float, bool, type(None))


def _coerce(cell: str):
    """CSV cell -> int | float | str (in that preference order)."""
    try:
        return int(cell)
    except ValueError:
        pass
    try:
        return float(cell)
    except ValueError:
        return cell


def rows_from_csv(text: str) -> list[dict]:
    """Parse bench stdout into row dicts.

    The benches print one or more CSV blocks: an all-string header line
    names the columns; data lines map onto it positionally.  Blank lines
    end a block (the next block may carry a new header); ``#`` lines are
    commentary.  Data rows with no preceding header (or a mismatched column
    count) fall back to positional ``col<i>`` keys — parse never fails, it
    degrades."""
    rows: list[dict] = []
    header: list[str] | None = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            header = None
            continue
        if line.startswith("#"):
            continue
        if "," not in line:
            continue
        cells = [c.strip() for c in line.split(",")]
        vals = [_coerce(c) for c in cells]
        all_str = all(isinstance(v, str) for v in vals)
        if header is None and all_str:
            header = cells
            continue
        if header is not None and len(cells) != len(header):
            if all_str:                     # a new header mid-block
                header = cells
                continue
            header = None                   # shape changed: degrade
        keys = header if header is not None \
            else [f"col{i}" for i in range(len(cells))]
        rows.append(dict(zip(keys, vals)))
    return rows


def bench_record(name: str, rows: list[dict], meta: dict | None = None,
                 created: str | None = None) -> dict:
    """Build (and validate) one schema record."""
    rec = {
        "schema": SCHEMA,
        "name": str(name),
        "created": created or time.strftime("%Y-%m-%dT%H:%M:%S"),
        "n_rows": len(rows),
        "rows": list(rows),
        "meta": dict(meta or {}),
    }
    validate_record(rec)
    return rec


def validate_record(rec: dict) -> None:
    """Raise ``ValueError`` unless ``rec`` is a well-formed schema record."""
    if not isinstance(rec, dict) or rec.get("schema") != SCHEMA:
        raise ValueError(f"not a {SCHEMA} record: "
                         f"schema={rec.get('schema') if isinstance(rec, dict) else rec!r}")
    for field in ("name", "created", "rows", "meta", "n_rows"):
        if field not in rec:
            raise ValueError(f"record missing field {field!r}")
    rows = rec["rows"]
    if not isinstance(rows, list) or rec["n_rows"] != len(rows):
        raise ValueError("rows must be a list with n_rows == len(rows)")
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            raise ValueError(f"row {i} is not a dict: {row!r}")
        for k, v in row.items():
            if not isinstance(k, str) or not isinstance(v, _SCALARS):
                raise ValueError(
                    f"row {i} cell {k!r} must be a str key with a scalar "
                    f"value, got {type(v).__name__}")


def bench_path(out_dir: str, name: str) -> str:
    return os.path.join(out_dir, f"BENCH_{name}.json")


def write_bench_record(out_dir: str, name: str, rows: list[dict],
                       meta: dict | None = None) -> str:
    """Write ``BENCH_<name>.json`` under ``out_dir``; returns the path."""
    rec = bench_record(name, rows, meta=meta)
    os.makedirs(out_dir, exist_ok=True)
    path = bench_path(out_dir, name)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, sort_keys=True)
    return path


def load_bench_record(path: str) -> dict:
    with open(path) as f:
        rec = json.load(f)
    validate_record(rec)
    return rec

"""repro.obs — runtime telemetry bus, phase-span tracing, drift detection.

The measurement half of the repo's predict-everything architecture: the
dry-run/roofline/TuningDB layers *predict* bytes, messages and seconds;
this package *measures* live runs through one spine —

* :class:`~repro.obs.bus.MetricsBus` — counters/gauges/histograms with
  labels, JSONL sink (``events.jsonl``);
* :class:`~repro.obs.trace.Tracer` — host wall-clock phase spans with
  optional ``block_until_ready`` fencing, exported as Chrome
  ``trace_event`` JSON (Perfetto-loadable ``trace.json``);
* :class:`~repro.obs.drift.DriftDetector` — per-step measured-vs-predicted
  comparison emitting ``model_error`` gauges and ``drift_alarm`` events;
* :mod:`repro.obs.schema` — the shared ``BENCH_<name>.json`` row schema;
* ``python -m repro.obs.report <run_dir>`` — the offline summarizer.

Everything importable here is stdlib-only (jax is touched lazily, inside
span fencing and the ``repro.obs.predict`` bridge), so the report CLI and
the bench harness stay light.  ``ObsConfig(enabled=False)`` — or simply a
``None`` config — resolves to :data:`NULL_OBS`, whose every operation is a
no-op: an uninstrumented step and an obs-disabled step lower to the
identical HLO (pinned in ``tests/test_obs.py``).
"""

from __future__ import annotations

import os
from dataclasses import dataclass

from repro.obs.bus import MetricsBus, NULL_BUS
from repro.obs.drift import DriftDetector, DriftSample
from repro.obs.schema import (bench_record, load_bench_record, rows_from_csv,
                              write_bench_record)
from repro.obs.trace import NULL_SPAN, NULL_TRACER, Span, Tracer

__all__ = [
    "ObsConfig", "Obs", "make_obs", "NULL_OBS",
    "MetricsBus", "NULL_BUS", "Tracer", "Span", "NULL_TRACER", "NULL_SPAN",
    "DriftDetector", "DriftSample",
    "bench_record", "write_bench_record", "load_bench_record",
    "rows_from_csv",
]


@dataclass(frozen=True)
class ObsConfig:
    """Everything the runtime needs to instrument (or not instrument) a run.

    ``enabled=False`` is the hard opt-out: :func:`make_obs` returns
    :data:`NULL_OBS` and no clock, file or dict is ever touched.  With
    ``run_dir=None`` the bus aggregates in memory only (no JSONL sink, no
    trace file) — useful for tests and embedded use."""

    enabled: bool = True
    run_dir: str | None = None
    trace: bool = True                 # collect spans + export trace.json
    flush_every: int = 64              # JSONL buffer flush cadence
    # drift detection (active only when a prediction is available)
    drift_threshold: float = 0.5       # |rolling median rel err| alarm bar
    drift_window: int = 8
    drift_warmup: int = 1              # leading samples excluded (compile)
    drift_min_samples: int = 3
    predicted_step_s: float | None = None  # explicit prediction (wins)
    predict: bool = False              # AOT-lower + roofline at init
    tuned_db: str | None = None        # price with measured α/β from this DB

    @classmethod
    def off(cls) -> "ObsConfig":
        return cls(enabled=False)


class Obs:
    """The bundle a run holds: one bus + one tracer + config, with the
    convenience delegates hot loops call."""

    enabled = True

    def __init__(self, cfg: ObsConfig):
        self.cfg = cfg
        self.bus = MetricsBus(cfg.run_dir, flush_every=cfg.flush_every)
        self.tracer = Tracer(self.bus, enabled=cfg.trace)

    # -- delegates -----------------------------------------------------------

    def span(self, name: str, **labels):
        return self.tracer.span(name, **labels)

    def counter(self, name: str, value: float = 1.0, **labels):
        return self.bus.counter(name, value, **labels)

    def gauge(self, name: str, value: float, **labels) -> None:
        self.bus.gauge(name, value, **labels)

    def observe(self, name: str, value: float, **labels) -> None:
        self.bus.observe(name, value, **labels)

    def event(self, name: str, **fields) -> None:
        self.bus.event(name, **fields)

    # -- drift ---------------------------------------------------------------

    def drift_detector(self, predicted_s: float,
                       metric: str = "step_time_s",
                       source: str = "roofline") -> DriftDetector:
        """A detector wired to this bus with the config's thresholds."""
        return DriftDetector(predicted_s, metric=metric, bus=self.bus,
                             threshold=self.cfg.drift_threshold,
                             window=self.cfg.drift_window,
                             warmup=self.cfg.drift_warmup,
                             min_samples=self.cfg.drift_min_samples,
                             source=source)

    # -- lifecycle -----------------------------------------------------------

    def finish(self) -> dict:
        """Flush the sink and (when a run_dir is bound) export the Chrome
        trace; returns the artifact paths."""
        trace_path = None
        if (self.cfg.run_dir is not None and self.tracer.enabled
                and self.tracer.events):
            trace_path = self.tracer.export_chrome(
                os.path.join(self.cfg.run_dir, "trace.json"))
        self.bus.close()
        return {"events": self.bus.path, "trace": trace_path}


class _NullObs:
    """`Obs` with every operation a no-op (the ``enabled=False`` lowering)."""

    enabled = False
    cfg = ObsConfig(enabled=False)
    bus = NULL_BUS
    tracer = NULL_TRACER

    def span(self, name, **labels):
        return NULL_SPAN

    def counter(self, name, value=1.0, **labels):
        return 0.0

    def gauge(self, name, value, **labels):
        pass

    def observe(self, name, value, **labels):
        pass

    def event(self, name, **fields):
        pass

    def drift_detector(self, predicted_s, metric="step_time_s",
                       source="roofline"):
        return None

    def finish(self):
        return {"events": None, "trace": None}


NULL_OBS = _NullObs()


def make_obs(cfg: ObsConfig | None) -> Obs | _NullObs:
    """The single constructor every subsystem funnels through: a real
    :class:`Obs` when ``cfg.enabled``, else the shared :data:`NULL_OBS`."""
    if cfg is None or not cfg.enabled:
        return NULL_OBS
    return Obs(cfg)

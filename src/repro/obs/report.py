"""Run-directory summarizer: ``python -m repro.obs.report <run_dir>``.

Reads the JSONL event stream a :class:`~repro.obs.bus.MetricsBus` wrote
(plus the Chrome ``trace.json`` when present) and renders:

* the per-phase time breakdown (span name → count / total / mean / p50 /
  max, sorted by total time);
* the predicted-vs-measured drift table (from ``drift_sample`` events:
  last samples, the rolling median, alarm transitions) and the top drift
  cells — the steps where the latency model sat furthest from reality;
* counters (stragglers, serve stalls, drift alarms, ...) and final gauges.

Stdlib-only, so summarizing a run never needs jax.  ``--json`` emits the
summary as one machine-readable object (the same rows+meta shape as
``BENCH_*.json`` consumers expect).
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def read_events(run_dir: str) -> list[dict]:
    path = os.path.join(run_dir, "events.jsonl")
    if not os.path.exists(path):
        raise FileNotFoundError(f"no events.jsonl under {run_dir!r} — was "
                                f"the run instrumented (ObsConfig.run_dir)?")
    records = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: bad JSONL line: {e}")
    return records


def _label_str(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


def summarize(run_dir: str) -> dict:
    """Aggregate the event stream into the report's data model."""
    records = read_events(run_dir)
    spans: dict[str, list[float]] = {}
    counters: dict[str, float] = {}
    gauges: dict[str, float] = {}
    drift_samples: list[dict] = []
    alarms: list[dict] = []
    events: dict[str, int] = {}
    t_lo, t_hi = None, None
    for rec in records:
        ts = rec.get("ts")
        if isinstance(ts, (int, float)):
            t_lo = ts if t_lo is None else min(t_lo, ts)
            t_hi = ts if t_hi is None else max(t_hi, ts)
        kind = rec.get("kind")
        if kind == "span":
            spans.setdefault(rec["name"], []).append(float(rec["dur_s"]))
        elif kind == "counter":
            key = _label_str(rec["name"], rec.get("labels") or {})
            counters[key] = counters.get(key, 0.0) + float(rec["value"])
        elif kind == "gauge":
            key = _label_str(rec["name"], rec.get("labels") or {})
            gauges[key] = float(rec["value"])
        elif kind == "event":
            events[rec["name"]] = events.get(rec["name"], 0) + 1
            if rec["name"] == "drift_sample":
                drift_samples.append(rec.get("fields") or {})
            elif rec["name"] == "drift_alarm":
                alarms.append(rec.get("fields") or {})

    phase_rows = []
    for name, durs in spans.items():
        s = sorted(durs)
        phase_rows.append({
            "phase": name, "count": len(s), "total_s": sum(s),
            "mean_s": sum(s) / len(s), "p50_s": s[len(s) // 2],
            "max_s": s[-1],
        })
    phase_rows.sort(key=lambda r: -r["total_s"])

    trace_path = os.path.join(run_dir, "trace.json")
    trace = None
    if os.path.exists(trace_path):
        with open(trace_path) as f:
            tr = json.load(f)
        trace = {"path": trace_path,
                 "n_events": len(tr.get("traceEvents", []))}

    top_drift = sorted((d for d in drift_samples if not d.get("warmup")),
                       key=lambda d: -abs(d.get("rel_err", 0.0)))[:5]
    return {
        "run_dir": run_dir,
        "n_records": len(records),
        "wall_s": (t_hi - t_lo) if t_lo is not None else 0.0,
        "phases": phase_rows,
        "counters": dict(sorted(counters.items())),
        "gauges": dict(sorted(gauges.items())),
        "events": dict(sorted(events.items())),
        "drift": {"samples": drift_samples, "alarms": alarms,
                  "top": top_drift},
        "trace": trace,
    }


def _fmt_s(sec: float) -> str:
    if sec >= 1.0:
        return f"{sec:8.3f}s "
    return f"{sec * 1e3:8.2f}ms"


def render(summary: dict) -> str:
    out = []
    w = out.append
    w(f"# obs report: {summary['run_dir']}")
    w(f"{summary['n_records']} records over {summary['wall_s']:.3f}s wall")
    if summary["trace"]:
        w(f"trace: {summary['trace']['path']} "
          f"({summary['trace']['n_events']} events — load in Perfetto)")
    w("")

    if summary["phases"]:
        w("## per-phase time breakdown")
        total = sum(r["total_s"] for r in summary["phases"]) or 1.0
        w(f"{'phase':<20}{'count':>7}{'total':>11}{'mean':>11}"
          f"{'p50':>11}{'max':>11}{'share':>8}")
        for r in summary["phases"]:
            w(f"{r['phase']:<20}{r['count']:>7}{_fmt_s(r['total_s']):>11}"
              f"{_fmt_s(r['mean_s']):>11}{_fmt_s(r['p50_s']):>11}"
              f"{_fmt_s(r['max_s']):>11}{100 * r['total_s'] / total:>7.1f}%")
        w("")

    drift = summary["drift"]
    if drift["samples"]:
        w("## predicted vs measured (drift)")
        w(f"{'step':>6}{'metric':>16}{'predicted':>12}{'measured':>12}"
          f"{'rel_err':>10}{'median':>10}  state")
        for d in drift["samples"][-10:]:
            med = d.get("median_rel_err")
            state = ("warmup" if d.get("warmup")
                     else "DRIFT" if d.get("drifting") else "ok")
            w(f"{d.get('step', '?'):>6}{d.get('metric', ''):>16}"
              f"{_fmt_s(d.get('predicted_s', 0.0)):>12}"
              f"{_fmt_s(d.get('measured_s', 0.0)):>12}"
              f"{d.get('rel_err', 0.0):>+10.2f}"
              f"{(f'{med:+.2f}' if med is not None else '—'):>10}  {state}")
        if drift["top"]:
            w("top drift cells (|rel_err|):")
            for d in drift["top"]:
                w(f"  step {d.get('step', '?'):>5}: measured "
                  f"{_fmt_s(d.get('measured_s', 0.0)).strip()} vs predicted "
                  f"{_fmt_s(d.get('predicted_s', 0.0)).strip()} "
                  f"(rel_err {d.get('rel_err', 0.0):+.2f})")
        w(f"alarms: {len(drift['alarms'])}")
        w("")

    if summary["counters"]:
        w("## counters")
        for k, v in summary["counters"].items():
            w(f"  {k:<40}{v:>12g}")
        w("")
    if summary["gauges"]:
        w("## gauges (last value)")
        for k, v in summary["gauges"].items():
            w(f"  {k:<40}{v:>12.6g}")
        w("")
    if summary["events"]:
        w("## events")
        for k, v in summary["events"].items():
            w(f"  {k:<40}{v:>12}")
    return "\n".join(out) + "\n"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize an instrumented run directory "
                    "(events.jsonl + trace.json).")
    ap.add_argument("run_dir", help="directory an ObsConfig.run_dir wrote")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as JSON instead of text")
    args = ap.parse_args(argv)
    summary = summarize(args.run_dir)
    if args.json:
        json.dump(summary, sys.stdout, indent=1, sort_keys=True)
        sys.stdout.write("\n")
    else:
        sys.stdout.write(render(summary))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

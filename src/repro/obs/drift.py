"""Live predicted-vs-measured drift detection.

The repo's prediction stack (CommPlan/HaloPlan/A2APlan bytes, the Roofline
time terms, the TuningDB α/β fits) is asserted against *lowered HLO* in the
dry-run — but a lowered byte count being right says nothing about whether
the latency model still tracks the machine at runtime.  The
:class:`DriftDetector` closes that loop per step: it compares each measured
step (or exposed-comm) time against the prediction for the active config,
publishes the relative error as a ``model_error`` gauge, and raises a
``drift_alarm`` event when the **rolling median** of the error crosses a
threshold — the rolling median so one GC pause or straggler step cannot
fire the alarm, and so a genuine regression (cache behaviour diverging from
the model, the DD-αAMG-on-QPACE-3 failure mode) trips it within a window.

Warmup samples (compile steps, typically 10–1000× steady state) are gauged
but excluded from the alarm window.  The alarm fires on the *transition*
into drift, not once per drifting step; ``drift_alarms`` counts
transitions.
"""

from __future__ import annotations

import statistics
from collections import deque
from dataclasses import dataclass

from repro.obs.bus import NULL_BUS


@dataclass(frozen=True)
class DriftSample:
    """One step's comparison (also emitted as a ``drift_sample`` event)."""

    step: int
    metric: str
    measured_s: float
    predicted_s: float
    rel_err: float                     # (measured - predicted) / predicted
    median_rel_err: float | None      # rolling median (None until the
                                      # window has min_samples)
    drifting: bool
    warmup: bool


class DriftDetector:
    """Per-step comparison of a measured time series against a scalar
    prediction, with rolling-median alarm logic.

    ``predicted_s`` is the model's time for the active config — typically
    :func:`repro.obs.predict.predict_step_time`'s
    ``bound_time_overlapped`` (Roofline constants, or a TuningDB record's
    measured α/β via ``--tuned``).
    """

    def __init__(self, predicted_s: float, *, metric: str = "step_time_s",
                 bus=NULL_BUS, threshold: float = 0.5, window: int = 8,
                 warmup: int = 1, min_samples: int = 3,
                 source: str = "roofline"):
        if not predicted_s > 0:
            raise ValueError(f"predicted_s must be > 0, got {predicted_s}")
        self.predicted_s = float(predicted_s)
        self.metric = metric
        self.bus = bus
        self.threshold = float(threshold)
        self.warmup = int(warmup)
        self.min_samples = max(int(min_samples), 1)
        self.source = source
        self._window: deque = deque(maxlen=max(int(window), 1))
        self._n = 0
        self._drifting = False
        self.alarms = 0

    def update(self, step: int, measured_s: float) -> DriftSample:
        """Record one measurement; emits the ``model_error`` gauge (every
        sample) and a ``drift_alarm`` event on the transition into drift."""
        self._n += 1
        rel = (float(measured_s) - self.predicted_s) / self.predicted_s
        warm = self._n <= self.warmup
        median = None
        drifting = False
        if not warm:
            self._window.append(rel)
            if len(self._window) >= self.min_samples:
                median = statistics.median(self._window)
                drifting = abs(median) > self.threshold
        self.bus.gauge("model_error", rel, metric=self.metric)
        if median is not None:
            self.bus.gauge("model_error_median", median, metric=self.metric)
        if drifting and not self._drifting:
            self.alarms += 1
            self.bus.counter("drift_alarms", metric=self.metric)
            self.bus.event("drift_alarm", step=step, metric=self.metric,
                           median_rel_err=median, rel_err=rel,
                           measured_s=float(measured_s),
                           predicted_s=self.predicted_s,
                           threshold=self.threshold, source=self.source)
        self._drifting = drifting
        sample = DriftSample(step=step, metric=self.metric,
                             measured_s=float(measured_s),
                             predicted_s=self.predicted_s, rel_err=rel,
                             median_rel_err=median, drifting=drifting,
                             warmup=warm)
        self.bus.event("drift_sample", step=step, metric=self.metric,
                       measured_s=float(measured_s),
                       predicted_s=self.predicted_s, rel_err=rel,
                       median_rel_err=median, drifting=drifting,
                       warmup=warm)
        return sample

    @property
    def drifting(self) -> bool:
        return self._drifting

"""Phase-span tracing: host wall-clock spans exportable as Chrome trace JSON.

A :class:`Span` brackets one phase of a step (data, dispatch, collective
wait, checkpoint, decode...) with ``time.perf_counter`` stamps.  Because
jax dispatch is asynchronous, a span that should account for *device* work
must fence: ``sp.fence(tree)`` registers a pytree that the span
``jax.block_until_ready``-s on exit, so the recorded duration covers the
device execution the phase launched, not just the Python that enqueued it.

Spans export two ways:

* mirrored onto the :class:`~repro.obs.bus.MetricsBus` as ``span`` JSONL
  records (what the report CLI aggregates), and
* as Chrome ``trace_event`` complete events (``"ph": "X"``, microsecond
  timestamps) via :meth:`Tracer.export_chrome` — the resulting
  ``trace.json`` loads directly in Perfetto / ``chrome://tracing``.

The disabled tracer hands out a shared no-op span: no clock reads, no
allocation, no fencing — the opt-out leaves the step loop untouched.
"""

from __future__ import annotations

import json
import os
import time

from repro.obs.bus import NULL_BUS, _jsonable


class Span:
    """One phase; use as a context manager (see :meth:`Tracer.span`)."""

    __slots__ = ("_tracer", "name", "labels", "_fence", "t0", "dur_s")

    def __init__(self, tracer: "Tracer", name: str, labels: dict):
        self._tracer = tracer
        self.name = name
        self.labels = labels
        self._fence = None
        self.t0 = None
        self.dur_s = None

    def fence(self, tree):
        """Register a pytree to ``jax.block_until_ready`` before the span
        closes (device work launched in the span lands in its duration).
        Returns ``tree`` so call sites can fence inline."""
        self._fence = tree
        return tree

    def __enter__(self) -> "Span":
        self.t0 = self._tracer._clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._fence is not None:
            import jax  # lazy: the tracer itself stays jax-free

            jax.block_until_ready(self._fence)
            self._fence = None
        self.dur_s = self._tracer._clock() - self.t0
        self._tracer._record(self.name, self.t0, self.dur_s, self.labels)
        return False


class _NullSpan:
    """Shared no-op span: enter/exit/fence do nothing."""

    __slots__ = ()
    name = None
    dur_s = None

    def fence(self, tree):
        return tree

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + Chrome ``trace_event`` exporter."""

    def __init__(self, bus=NULL_BUS, *, enabled: bool = True,
                 clock=time.perf_counter, pid: int | None = None,
                 tid: int = 0):
        self.enabled = enabled
        self.bus = bus
        self._clock = clock
        self.pid = os.getpid() if pid is None else pid
        self.tid = tid
        self.epoch = clock() if enabled else 0.0
        # (name, t0, dur_s, labels) tuples; t0 on the clock's timeline
        self.events: list[tuple] = []

    def span(self, name: str, **labels):
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, labels)

    def _record(self, name: str, t0: float, dur_s: float,
                labels: dict) -> None:
        self.events.append((name, t0, dur_s, labels))
        self.bus.span(name, dur_s, **labels)

    def export_chrome(self, path: str) -> str:
        """Write the spans as a Perfetto-loadable Chrome trace and return
        the path.  Complete (``"ph": "X"``) events, µs since the tracer's
        epoch, labels carried in ``args``."""
        trace_events = [
            {"name": name, "ph": "X", "cat": "obs",
             "ts": (t0 - self.epoch) * 1e6, "dur": dur_s * 1e6,
             "pid": self.pid, "tid": self.tid, "args": labels or {}}
            for name, t0, dur_s, labels in self.events
        ]
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({"traceEvents": trace_events,
                       "displayTimeUnit": "ms"}, f, default=_jsonable)
        return path


class _NullTracer:
    enabled = False
    events: tuple = ()
    bus = NULL_BUS

    def span(self, name, **labels):
        return NULL_SPAN

    def export_chrome(self, path):
        return None


NULL_TRACER = _NullTracer()

"""Bridge from the prediction stack to the DriftDetector.

The dry-run predicts step time offline (AOT lower → compile →
``cost_analysis`` + HLO collective parse → :class:`Roofline`); this module
runs the *same* pipeline against the live step function so the
:class:`~repro.obs.drift.DriftDetector` has a prediction for the exact
program the run executes — not a nearby dry-run cell.  With a TuningDB the
collective term is priced at the record's *measured* α/bandwidth
(:meth:`LatencyModel.from_record`), and the record's fit residuals ride
along as the static ``model_error`` baseline the live gauge is compared
against in the report.

Imported lazily by the Trainer (this module pulls jax + the roofline; the
rest of ``repro.obs`` stays stdlib-only).
"""

from __future__ import annotations

from repro.comm.plan import LatencyModel
from repro.launch.roofline import Roofline, collective_wire_bytes


def predict_step_time(step_fn, example_args, *, mesh,
                      overlap_fraction: float = 0.0,
                      latency: LatencyModel | None = None) -> dict:
    """AOT-lower ``step_fn(*example_args)`` and price it.

    Returns the roofline terms plus ``t_step_s`` (the overlap-honest bound
    the drift detector compares measured steps against).  ``latency``
    replaces the hardcoded α/β constants with measured ones (a tuning-DB
    record); ``overlap_fraction`` is the executed CommSchedule's.
    """
    with mesh:
        lowered = step_fn.lower(*example_args)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax: one dict per computation
        ca = ca[0] if ca else {}
    stats = collective_wire_bytes(compiled.as_text())
    roof_kw = dict(
        flops_per_device=float(ca.get("flops", 0.0)),
        hbm_bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        wire_bytes_per_device=stats.wire_bytes,
        overlap_fraction=overlap_fraction,
        messages_per_device=stats.messages,
    )
    roof = (Roofline.from_latency(latency, **roof_kw) if latency is not None
            else Roofline(**roof_kw))
    return {
        "t_step_s": roof.bound_time_overlapped,
        "t_compute_s": roof.t_compute,
        "t_memory_s": roof.t_memory,
        "t_collective_s": roof.t_collective,
        "t_exposed_collective_s": roof.t_exposed_collective,
        "bottleneck": roof.bottleneck,
        "overlap_fraction": overlap_fraction,
        "wire_bytes_per_device": stats.wire_bytes,
        "messages_per_device": stats.messages,
        "alpha_s": roof.alpha_s,
        "link_bandwidth": roof.link_bandwidth,
        "source": "tuned" if latency is not None else "roofline",
    }


def tuned_latency(db_path: str, *, transport: str | None = None,
                  mesh_label: str | None = None, channels: int | None = None,
                  page_bytes: int | None = None, arch: str | None = None
                  ) -> tuple[LatencyModel, dict, str] | None:
    """Resolve a :class:`LatencyModel` (plus its fit-residual summary and
    DB key) from a tuning DB for the active comm config; ``None`` when no
    record matches — the caller falls back to the hardcoded constants."""
    from repro.tune.db import TuningDB, model_error_summary

    db = TuningDB.load(db_path)
    got = db.lookup(transport=transport, arch=arch, mesh=mesh_label,
                    channels=channels, page_bytes=page_bytes)
    if got is None:
        return None
    key, rec = got
    return LatencyModel.from_record(rec), model_error_summary(rec), key

"""Per-architecture launch settings: DP mode, microbatching, serving weight
residency.  Derived from napkin memory math against 16 GB/chip (validated by
``memory_analysis`` in the dry-run; see EXPERIMENTS.md §Dry-run)."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ArchSettings:
    dp_mode: str            # replicated | zero1 | fsdp
    microbatches: int       # grad-accumulation slices for train_4k
    serve_weights: str      # resident | gathered


SETTINGS: dict[str, ArchSettings] = {
    # small: paper-faithful replicated / ZeRO-1 data parallelism
    "whisper-base": ArchSettings("replicated", 1, "resident"),
    "llama3.2-1b": ArchSettings("zero1", 1, "resident"),
    "minicpm-2b": ArchSettings("zero1", 2, "resident"),
    "hymba-1.5b": ArchSettings("zero1", 2, "resident"),
    # medium/large: ZeRO-3 built from the paper's ring collectives
    "qwen2-7b": ArchSettings("fsdp", 2, "resident"),
    "falcon-mamba-7b": ArchSettings("fsdp", 4, "resident"),
    "phi3-medium-14b": ArchSettings("fsdp", 4, "resident"),
    "llava-next-34b": ArchSettings("fsdp", 8, "resident"),
    "mixtral-8x7b": ArchSettings("fsdp", 4, "resident"),
    # 400B: weights cannot reside on a 16-way model axis; serve gathers
    "llama4-maverick-400b-a17b": ArchSettings("fsdp", 4, "gathered"),
}


def settings_for(arch: str) -> ArchSettings:
    return SETTINGS[arch]

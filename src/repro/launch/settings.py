"""Per-architecture launch settings: DP mode, microbatching, serving weight
residency, and the communication substrate (transport + virtual channels +
arena page size).  Memory numbers derive from napkin math against
16 GB/chip (validated by ``memory_analysis`` in the dry-run; see
EXPERIMENTS.md §Dry-run).

``page_bytes`` is the :mod:`repro.mem` arena quantization granule — the
paper's 2 MB huge page.  Communication buffers (``TrainStepConfig
.use_arena``) are packed into segments whose offsets and sizes are
quantized to it; larger pages mean fewer, better-aligned allocations at
the cost of padding (the dry-run's ``--suite mem`` grid measures the
trade)."""

from __future__ import annotations

from dataclasses import dataclass

from repro.comm import CommConfig


@dataclass(frozen=True)
class ArchSettings:
    dp_mode: str            # replicated | zero1 | fsdp
    microbatches: int       # grad-accumulation slices for train_4k
    serve_weights: str      # resident | gathered
    transport: str = "ring_hier"   # registered repro.comm transport, or
                                   # "auto": measured best from the tuning DB
    channels: int = 0       # virtual comm rails (0 = scheduler-unconstrained;
                            # also the tuner's soft "resolve me" sentinel)
    wire_codec: str | None = None  # None | "int8": quantized gradient wire
                                   # (fused arena pack+quantize + error
                                   # feedback; ~3.9x fewer collective bytes)
    page_bytes: int | str = 2 * 2**20  # arena granule (paper's huge page),
                                       # or "auto": from the tuning DB
    moe_transport: str = "a2a"  # EP dispatch/combine exchange (MoE archs
                                # only): a2a | ring | ring_hier | psum
    moe_channels: int = 0       # EP payload rails (0 = one)

    def comm_config(self, *, chunks: int = 2,
                    bucket_bytes: int = 256 * 2**20,
                    page_bytes: int | None = None) -> CommConfig:
        """The architecture's production communicator config.

        Unresolved ``"auto"`` sentinels (the caller skipped
        :func:`repro.tune.resolve.resolve_settings`) fall back to today's
        defaults with a warning rather than crashing the launch."""
        transport, pb = self.transport, (self.page_bytes if page_bytes is None
                                         else page_bytes)
        if transport == "auto" or pb == "auto":
            import warnings

            from repro.tune.resolve import (FALLBACK_PAGE_BYTES,
                                            FALLBACK_TRANSPORT)
            warnings.warn(
                "comm_config() called with unresolved 'auto' settings; "
                "resolve via repro.tune.resolve.resolve_settings (or pass "
                "--tuned to the launcher) — using defaults", stacklevel=2)
            transport = FALLBACK_TRANSPORT if transport == "auto" else transport
            pb = FALLBACK_PAGE_BYTES if pb == "auto" else pb
        return CommConfig(transport=transport, channels=self.channels,
                          chunks=chunks, bucket_bytes=bucket_bytes,
                          page_bytes=int(pb),
                          wire_codec=self.wire_codec)


SETTINGS: dict[str, ArchSettings] = {
    # small: paper-faithful replicated / ZeRO-1 data parallelism
    "whisper-base": ArchSettings("replicated", 1, "resident"),
    "llama3.2-1b": ArchSettings("zero1", 1, "resident"),
    "minicpm-2b": ArchSettings("zero1", 2, "resident"),
    "hymba-1.5b": ArchSettings("zero1", 2, "resident"),
    # medium/large: ZeRO-3 built from the paper's ring collectives; the big
    # gradient volumes get two guaranteed rails (paper: multi-EP striping)
    "qwen2-7b": ArchSettings("fsdp", 2, "resident", channels=2),
    "falcon-mamba-7b": ArchSettings("fsdp", 4, "resident", channels=2),
    "phi3-medium-14b": ArchSettings("fsdp", 4, "resident", channels=2),
    "llava-next-34b": ArchSettings("fsdp", 8, "resident", channels=2),
    "mixtral-8x7b": ArchSettings("fsdp", 4, "resident", channels=2,
                                 moe_channels=2),
    # 400B: weights cannot reside on a 16-way model axis; serve gathers
    "llama4-maverick-400b-a17b": ArchSettings("fsdp", 4, "gathered",
                                              channels=2, moe_channels=2),
}


def settings_for(arch: str) -> ArchSettings:
    """Lookup; unknown arch names the full menu instead of a bare KeyError
    (every CLI entry point funnels through here)."""
    try:
        return SETTINGS[arch]
    except KeyError:
        raise ValueError(
            f"unknown arch {arch!r}; known archs: "
            f"{', '.join(sorted(SETTINGS))}") from None


def resolve_settings_for(arch: str, *, mesh_label: str | None = None,
                         db_path: str | None = None
                         ) -> tuple[ArchSettings, dict]:
    """:func:`settings_for` plus tuning-DB resolution of any ``"auto"``
    sentinels (see :mod:`repro.tune.resolve`); returns ``(settings,
    info)`` where ``info['source']`` says whether a measured record was
    used.  Settings with no sentinels pass through untouched."""
    from repro.tune.resolve import resolve_settings

    return resolve_settings(settings_for(arch), arch, mesh_label=mesh_label,
                            db_path=db_path)

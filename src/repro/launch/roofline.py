"""Roofline-term extraction from compiled AOT artifacts.

Three terms per (arch x shape x mesh) cell, v5e constants:

    T_compute    = HLO_FLOPs_per_device  / 197e12      (bf16 MXU peak)
    T_memory     = HLO_bytes_per_device  / 819e9       (HBM bandwidth)
    T_collective = ALPHA_S * messages_per_device
                 + wire_bytes_per_device / 50e9        (per-link ICI)

``cost_analysis`` supplies FLOPs/bytes; collective wire bytes are parsed
from the optimized HLO text: every collective op's result shape is
converted to per-device bytes-on-the-wire with the standard ring formulas
(p from its replica-group size).  Models are fully unrolled, so no
while-loop trip-count scaling is needed — the parser asserts that.

The α term (``repro.comm.plan.LatencyModel``) prices per-message launch
latency: it is what separates two tiny all-reduces per CG iteration from
one fused one, which bandwidth-only accounting cannot see.  Cells that do
not supply a message count keep the pure-bandwidth behaviour
(``messages_per_device`` defaults to 0).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.comm.plan import ALPHA_S, HBM_BANDWIDTH, LINK_BANDWIDTH

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = HBM_BANDWIDTH       # bytes/s per chip
ICI_BW = LINK_BANDWIDTH      # bytes/s per link (one direction); single
                             # source in repro.comm.plan so the roofline and
                             # LatencyModel β terms can never desync (and
                             # CommPlan.codec_tradeoff prices pack+quantize
                             # kernel time against the same HBM number)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota replica groups: [num_groups, group_size]
        return int(m.group(2))
    return 2  # conservative default


@dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    op_bytes: dict = field(default_factory=dict)
    op_counts: dict = field(default_factory=dict)
    messages: float = 0.0        # per-device sends (ring hops / ppermutes) —
                                 # same unit as Transport
                                 # .predicted_messages_per_device, so the
                                 # roofline α term prices HLO-parsed and
                                 # plan-predicted traffic identically
    while_loops: int = 0

    def add(self, kind: str, b: float, hops: float = 1.0):
        self.wire_bytes += b
        self.op_bytes[kind] = self.op_bytes.get(kind, 0.0) + b
        self.op_counts[kind] = self.op_counts.get(kind, 0) + 1
        self.messages += hops


def collective_wire_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device bytes placed on ICI links, summed over collective ops.

    Formulas (result-shape based, ring algorithms):
      collective-permute : result            (one hop)
      all-gather         : result * (p-1)/p
      all-reduce         : result * 2(p-1)/p
      reduce-scatter     : result * (p-1)
      all-to-all         : result * (p-1)/p
    ``-start``/``-done`` async pairs are counted once (on the start op).
    ``messages`` accumulates the matching ring hop counts (1 per permute,
    ``2(p−1)`` per all-reduce, ``p−1`` otherwise) for the α latency term.
    """
    stats = CollectiveStats()
    seen_done = 0
    for line in hlo_text.splitlines():
        if "-done(" in line and any(c in line for c in _COLLECTIVES):
            seen_done += 1
            continue  # counted at -start
        m = _OP_RE.search(line)
        if not m:
            if re.search(r"=\s*while\(", line) or " while(" in line:
                stats.while_loops += 1
            continue
        type_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(type_str)
        if kind == "collective-permute":
            stats.add(kind, nbytes)
            continue
        p = _group_size(line)
        if p <= 1:
            continue
        if kind == "all-gather":
            stats.add(kind, nbytes * (p - 1) / p, hops=p - 1)
        elif kind == "all-reduce":
            stats.add(kind, nbytes * 2 * (p - 1) / p, hops=2 * (p - 1))
        elif kind == "reduce-scatter":
            stats.add(kind, nbytes * (p - 1), hops=p - 1)
        elif kind == "all-to-all":
            stats.add(kind, nbytes * (p - 1) / p, hops=p - 1)
    return stats


@dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    model_flops: float = 0.0
    overlap_fraction: float = 0.0   # CommSchedule.overlap_fraction: share of
                                    # collective traffic issued while compute
                                    # remains (0 = serialised after compute)
    messages_per_device: float = 0.0  # collective launches (α latency term)
    padding_wire_bytes_per_device: float = 0.0  # arena page padding that
                                    # rides the fused collectives: wasted
                                    # but *real* wire bytes (repro.mem)
    alpha_s: float = ALPHA_S
    link_bandwidth: float = ICI_BW  # β term; a tuning-DB record replaces
                                    # both constants with *measured* ones
                                    # (see Roofline.from_latency)

    @classmethod
    def from_latency(cls, model, **kw) -> "Roofline":
        """Roofline whose α/β constants come from a
        :class:`~repro.comm.plan.LatencyModel` — typically one rebuilt
        from a tuning-DB record (``LatencyModel.from_record``) so the cell
        is priced with measured rather than guessed constants."""
        return cls(alpha_s=model.alpha_s, link_bandwidth=model.bandwidth,
                   **kw)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        """α·messages + bytes/bw (pure bandwidth when no count supplied).
        Arena page padding is folded into the β term: fused spans carry it
        across the wire, so the prediction charges for it."""
        return (self.alpha_s * self.messages_per_device
                + (self.wire_bytes_per_device
                   + self.padding_wire_bytes_per_device)
                / self.link_bandwidth)

    @property
    def t_exposed_collective(self) -> float:
        """Collective time left *exposed* after hiding under the compute the
        schedule makes overlappable: ``max(0, t_collective −
        overlap_fraction · t_compute)``.  Equals ``t_collective`` for an
        ``accumulate_then_reduce`` schedule (overlap 0); never exceeds it."""
        hidden = min(1.0, max(0.0, self.overlap_fraction)) * self.t_compute
        return max(0.0, self.t_collective - hidden)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def bound_time_overlapped(self) -> float:
        """Step-time bound when the schedule's overlap is realised: only the
        exposed collective time serialises with compute."""
        return max(self.t_compute, self.t_memory, self.t_exposed_collective)

    @property
    def compute_fraction(self) -> float:
        """How close the cell is to the compute roofline (1.0 = perfectly
        compute-bound; the §Perf score)."""
        t = self.bound_time
        return self.t_compute / t if t > 0 else 0.0

    def useful_flops_ratio(self, n_devices: int) -> float:
        if self.flops_per_device <= 0:
            return 0.0
        return self.model_flops / (self.flops_per_device * n_devices)

    def as_dict(self, n_devices: int) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "messages_per_device": self.messages_per_device,
            "padding_wire_bytes_per_device":
                self.padding_wire_bytes_per_device,
            "alpha_s": self.alpha_s,
            "link_bandwidth": self.link_bandwidth,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "t_exposed_collective_s": self.t_exposed_collective,
            "overlap_fraction": self.overlap_fraction,
            "bottleneck": self.bottleneck,
            "compute_fraction": self.compute_fraction,
            "bound_time_overlapped_s": self.bound_time_overlapped,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio(n_devices),
        }


def model_flops_estimate(n_params_active: int, tokens: int,
                         kind: str) -> float:
    """6·N·D for training; 2·N·D for inference forward passes."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens

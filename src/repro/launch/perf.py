import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: re-lower one cell with overrides, record under a
tag in the shared dry-run JSON so report.py can diff baseline vs variants.

    PYTHONPATH=src python -m repro.launch.perf --arch llama3.2-1b \
        --shape train_4k --tag wire_bf16 --set comm_wire_dtype=bfloat16

Override keys: comm_transport, comm_channels, comm_chunks,
comm_bidirectional, comm_wire_dtype, comm_bucket_bytes, comm_page_bytes
(any CommConfig field as comm_<field>), microbatches, schedule
(stream/scheduled issue order -> roofline overlap), use_arena (fused
page-aligned repro.mem reduction), causal_skip, serve_weights,
fsdp_gather, gather_dtype, fsdp_bucket_bytes.  The legacy
accum_microbatches / accum_policy spellings map onto microbatches /
schedule; the old reduce_* string-policy keys are gone with the
core.overlap shim — use comm_transport etc.
"""

import argparse
import json
import time


def parse_val(v: str):
    if v in ("true", "True"):
        return True
    if v in ("false", "False"):
        return False
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="key=value override (repeatable)")
    ap.add_argument("--out", default="experiments/dryrun.json")
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell

    overrides = {}
    for kv in args.set:
        k, _, v = kv.partition("=")
        overrides[k] = parse_val(v)

    t0 = time.time()
    rec = run_cell(args.arch, args.shape, args.mesh == "multi", overrides)
    rec["tag"] = args.tag
    rec["overrides"] = {k: str(v) for k, v in overrides.items()}

    cache = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            cache = json.load(f)
    key = f"{args.tag}|{args.arch}|{args.shape}|{args.mesh}"
    cache[key] = rec
    with open(args.out, "w") as f:
        json.dump(cache, f, indent=1)
    r = rec["roofline"]
    print(f"[{args.tag}] {args.arch}x{args.shape}: "
          f"Tc={r['t_compute_s']:.4f}s Tm={r['t_memory_s']:.4f}s "
          f"Tx={r['t_collective_s']:.4f}s bottleneck={r['bottleneck']} "
          f"frac={r['compute_fraction']:.3f} ({time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()

"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun.json.

    PYTHONPATH=src python -m repro.launch.report experiments/dryrun.json
"""

from __future__ import annotations

import argparse
import json

from repro.configs import SHAPES, applicable_shapes, get_config, list_archs


def fmt_bytes(b: float) -> str:
    if b >= 2**30:
        return f"{b/2**30:.2f}G"
    if b >= 2**20:
        return f"{b/2**20:.2f}M"
    return f"{b/2**10:.1f}K"


def fmt_t(s: float) -> str:
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.2f}ms"
    return f"{s*1e6:.0f}us"


def roofline_table(cache: dict, tag: str = "baseline",
                   mesh: str = "single") -> str:
    rows = []
    header = ("| arch | shape | T_compute | T_memory | T_collective | "
              "T_exposed | bottleneck | compute-frac | useful-FLOPs | "
              "wire/dev | live GB |")
    sep = "|" + "---|" * 11
    rows.append(header)
    rows.append(sep)
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
            if shape not in applicable_shapes(cfg):
                rows.append(f"| {arch} | {shape} | — | — | — | — | "
                            f"skipped (full attention; DESIGN.md) | — | — | — | — |")
                continue
            key = f"{tag}|{arch}|{shape}|{mesh}"
            rec = cache.get(key)
            if rec is None:
                rows.append(f"| {arch} | {shape} | … | … | … | … | pending | "
                            f"… | … | … | … |")
                continue
            if "error" in rec:
                rows.append(f"| {arch} | {shape} | — | — | — | — | "
                            f"FAILED: {rec['error'][:60]} | — | — | — | — |")
                continue
            r = rec["roofline"]
            m = rec["memory"]
            # records written before the CommSchedule refactor lack the
            # exposed term; exposed == raw collective time for them
            t_exp = r.get("t_exposed_collective_s", r["t_collective_s"])
            rows.append(
                f"| {arch} | {shape} | {fmt_t(r['t_compute_s'])} | "
                f"{fmt_t(r['t_memory_s'])} | {fmt_t(r['t_collective_s'])} | "
                f"{fmt_t(t_exp)} | "
                f"{r['bottleneck']} | {r['compute_fraction']:.2f} | "
                f"{r['useful_flops_ratio']:.2f} | "
                f"{fmt_bytes(r['wire_bytes_per_device'])} | "
                f"{m['live_gb']:.1f} |")
    return "\n".join(rows)


def dryrun_table(cache: dict, tag: str = "baseline") -> str:
    rows = ["| arch | shape | mesh | compile | FLOPs/dev | HBM bytes/dev | "
            "wire/dev | collectives | fits 16GB |",
            "|" + "---|" * 9]
    for key, rec in sorted(cache.items()):
        if not key.startswith(tag + "|") or "error" in rec:
            continue
        r = rec["roofline"]
        cc = rec["collectives"]["counts"]
        cstr = " ".join(f"{k.split('-')[0][:3]}×{v}" for k, v in sorted(cc.items()))
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
            f"{rec['compile_s']:.0f}s | {r['flops_per_device']:.2e} | "
            f"{r['hbm_bytes_per_device']:.2e} | "
            f"{fmt_bytes(r['wire_bytes_per_device'])} | {cstr} | "
            f"{'✓' if rec['memory']['fits_16gb'] else 'see note'} |")
    errs = [(k, v) for k, v in sorted(cache.items())
            if k.startswith(tag + "|") and "error" in v]
    for k, v in errs:
        rows.append(f"| {v.get('arch','?')} | {v.get('shape','?')} | — | — | — "
                    f"| — | — | FAILED: {v['error'][:80]} | — |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="*", default=["experiments/dryrun.json"])
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--section", default="both",
                    choices=["roofline", "dryrun", "both"])
    args = ap.parse_args()
    cache = {}
    paths = args.path if isinstance(args.path, list) else [args.path]
    for p in paths:
        import os
        if os.path.exists(p):
            with open(p) as f:
                cache.update(json.load(f))
    if args.section in ("roofline", "both"):
        print("### Roofline (single-pod 16x16, per device)\n")
        print(roofline_table(cache, args.tag, "single"))
    if args.section in ("dryrun", "both"):
        print("\n### Dry-run records (both meshes)\n")
        print(dryrun_table(cache, args.tag))


if __name__ == "__main__":
    main()

"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run must set
``XLA_FLAGS`` before the first jax call.
"""

from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; the multi-pod mesh prepends a pod axis of 2."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(n_devices: int | None = None, model_parallel: int = 2):
    """Small local mesh for tests/examples on host devices."""
    n = n_devices or len(jax.devices())
    model = model_parallel
    while model > 1 and n % model:
        model //= 2
    return compat.make_mesh((n // model, model), ("data", "model"))


def required_devices(multi_pod: bool) -> int:
    return 512 if multi_pod else 256

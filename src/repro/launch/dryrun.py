import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM and unsupported collectives all fail here.
Per cell it records memory_analysis (fits 16 GB?), cost_analysis
(FLOPs/bytes) and the parsed collective wire bytes -> the three roofline
terms of EXPERIMENTS.md §Roofline.

The two XLA_FLAGS lines above MUST run before any other import: jax locks
the device count at first initialisation.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both \
        --out experiments/dryrun.json
"""

import argparse
import json
import time
import traceback

from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.comm import SCHEDULE_POLICIES
from repro.configs import SHAPES, applicable_shapes, get_config, list_archs
from repro.data import make_batch_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (Roofline, collective_wire_bytes,
                                   model_flops_estimate)
from repro.launch.settings import settings_for
from repro.models import build_model
from repro.runtime.serve_step import build_decode_step, build_prefill
from repro.runtime.train_step import (TrainStepConfig, build_step_schedule,
                                      build_train_step, init_train_state)

HBM_PER_CHIP = 16 * 2**30

# canonical implementation lives in repro.tune.db (jax-free, shared with the
# tuning-DB keys); re-exported here because this is where cache-key users
# have always imported it from.  Folded into the cache key by
# :func:`cell_key` so that re-running with a different ``--accum-policy`` /
# schedule / solver override can never be served a stale cached cell.
from repro.tune.db import overrides_fingerprint  # noqa: E402  (re-export)


def _tuned_pricing(db, *, arch: str, mesh_label: str, transport: str,
                   channels: int | None = None,
                   page_bytes: int | None = None) -> dict | None:
    """Measured pricing for one dry-run cell from a tuning DB.

    Returns ``None`` when no record matches the cell's transport (fitted
    constants never transfer across schedules); otherwise a dict with the
    rebuilt :class:`~repro.comm.plan.LatencyModel`, the winning record's
    key, and the ``model_error`` block (the fit's predicted-vs-measured
    residuals) the cell record surfaces."""
    from repro.comm.plan import LatencyModel
    from repro.tune.db import model_error_summary

    hit = db.lookup(transport=transport, arch=arch, mesh=mesh_label,
                    channels=channels, page_bytes=page_bytes)
    if hit is None:
        return None
    key, rec = hit
    model = LatencyModel.from_record(rec)
    return {"model": model, "key": key,
            "alpha_s": model.alpha_s, "bandwidth": model.bandwidth,
            "model_error": model_error_summary(rec)}


def cell_key(tag: str, arch: str, shape: str, mesh_label: str,
             overrides: dict | None = None) -> str:
    """Cache key of one dry-run cell in the output JSON."""
    base = f"{tag}|{arch}|{shape}|{mesh_label}"
    fp = overrides_fingerprint(overrides)
    return f"{base}|ov[{fp}]" if fp else base


def _abstract_batch(model, shape_cfg):
    return model.input_specs(shape_cfg)


def make_step_config(arch: str, overrides: dict | None = None) -> TrainStepConfig:
    """Per-arch step config with override plumbing.

    ``comm_<field>`` keys hit :class:`~repro.comm.CommConfig` directly
    (``comm_page_bytes`` included); every other key is a
    :class:`TrainStepConfig` field (``microbatches``, ``schedule``,
    ``use_arena``, ``dp_mode``, ...).  Legacy ``accum_microbatches`` /
    ``accum_policy`` spellings map onto the new fields, with the
    new-style key winning when both are present.  (The old ``reduce_*``
    string-policy overrides are gone with the ``core.overlap`` shim —
    use ``comm_transport`` etc.)
    """
    st = settings_for(arch)
    ccfg = st.comm_config()
    kw = dict(dp_mode=st.dp_mode, microbatches=st.microbatches,
              schedule="accumulate_then_reduce", causal_skip=False,
              moe_transport=st.moe_transport, moe_channels=st.moe_channels)
    if overrides:
        stale = [k for k in overrides if k.startswith("reduce_")]
        if stale:
            raise ValueError(
                f"reduce_* overrides were removed with the string-policy "
                f"shim; use comm_<field> (e.g. comm_transport, "
                f"comm_wire_dtype) — got {stale}")
        # new-style comm_* keys hit CommConfig fields directly
        comm_over = {k[5:]: v for k, v in overrides.items()
                     if k.startswith("comm_")}
        rest = {k: v for k, v in overrides.items()
                if not k.startswith("comm_")}
        rest.setdefault("microbatches", rest.pop("accum_microbatches", None))
        rest.setdefault("schedule", rest.pop("accum_policy", None))
        rest = {k: v for k, v in rest.items() if v is not None}
        if comm_over:
            ccfg = replace(ccfg, **comm_over)
        kw.update(rest)
    return TrainStepConfig(comm=ccfg, **kw)


def comm_plan_summary(model, mesh, tcfg: TrainStepConfig) -> dict:
    """The :class:`repro.comm.CommPlan` the step will execute, as JSON —
    the dry-run report and the benchmarks read the same object.

    For fsdp the step buckets per parameter group with
    ``fsdp_bucket_bytes`` (see :class:`FsdpPlan`), so the summary
    aggregates one CommPlan per group rather than pretending the whole
    tree rides one plan."""
    from repro.runtime.train_step import FsdpPlan, _local_shapes, build_comm

    if tcfg.dp_mode == "fsdp":
        fplan = FsdpPlan(model, mesh, tcfg)
        plans = [fplan.comm.plan(tree) for tree in fplan.groups.values()]
        head = plans[0].describe()
        return {
            "transport": head["transport"],
            "axes": head["axes"], "axis_sizes": head["axis_sizes"],
            "world": head["world"],
            "n_groups": len(plans),
            "n_buckets": sum(p.n_buckets for p in plans),
            "total_elems": sum(p.total_elems for p in plans),
            "n_channels": head["n_channels"],
            "bytes_per_device": sum(p.bytes_per_device for p in plans),
            "grad_bytes": sum(
                p.predicted_collective_bytes()["grad_bytes"] for p in plans),
            "channel_imbalance": max(p.channel_imbalance for p in plans),
        }
    comm = build_comm(mesh, tcfg)
    pspecs = model.param_specs(mesh)
    local = _local_shapes(model.abstract_params(), pspecs, mesh)
    return comm.plan(local).describe()


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Returns (lowered, n_devices, model, shape_cfg, kind)."""
    cfg = get_config(arch)
    model = build_model(cfg)
    shape_cfg = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    st = settings_for(arch)

    with mesh:
        if shape_cfg.kind == "train":
            tcfg = make_step_config(arch, overrides)
            batch_specs = make_batch_specs(model.cfg, shape_cfg, mesh)
            step = build_train_step(model, mesh, tcfg, batch_specs,
                                    donate=True)
            state_abs, _ = init_train_state(model, mesh, tcfg, abstract=True)
            batch_abs = _abstract_batch(model, shape_cfg)
            lowered = step.lower(state_abs, batch_abs)
        elif shape_cfg.kind == "prefill":
            wm = st.serve_weights
            if overrides and "serve_weights" in overrides:
                wm = overrides["serve_weights"]
            step, pspecs = build_prefill(model, mesh, shape_cfg,
                                         weight_mode=wm)
            params_abs = _abstract_serve_params(model, mesh, wm)
            batch_abs = _abstract_batch(model, shape_cfg)
            lowered = step.lower(params_abs, batch_abs)
        else:  # decode
            wm = st.serve_weights
            if overrides and "serve_weights" in overrides:
                wm = overrides["serve_weights"]
            step, pspecs, _ = build_decode_step(model, mesh, shape_cfg,
                                                weight_mode=wm)
            params_abs = _abstract_serve_params(model, mesh, wm)
            b = shape_cfg.global_batch
            token = jax.ShapeDtypeStruct((b,), jnp.int32)
            state_abs = model.abstract_decode_state(b, shape_cfg.seq_len)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = step.lower(params_abs, token, state_abs, pos)
    return lowered, n_dev, model, shape_cfg


def _abstract_serve_params(model, mesh, weight_mode):
    if weight_mode == "gathered":
        from repro.runtime.train_step import FsdpPlan, TrainStepConfig as TSC

        plan = FsdpPlan(model, mesh, TSC(dp_mode="fsdp"))
        n_dev = mesh.devices.size
        # local shard length is n // dp_world; global flat = local * n_devices
        groups = {name: [jax.ShapeDtypeStruct((n // plan.dp_world * n_dev,),
                                              jnp.float32)
                         for n in p.bucket_sizes]
                  for name, p in plan.plans.items()}
        return {"groups": groups}
    return model.abstract_params()


def _model_size(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)


def analyse(lowered, n_dev: int, model, shape_cfg,
            overlap_fraction: float = 0.0, latency=None) -> dict:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax: one dict per computation
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    stats = collective_wire_bytes(txt)

    tokens = shape_cfg.global_batch * (shape_cfg.seq_len
                                       if shape_cfg.kind != "decode" else 1)
    n_active = model.active_param_count()
    mf = model_flops_estimate(n_active, tokens, shape_cfg.kind)
    roof_kw = dict(
        flops_per_device=float(ca.get("flops", 0.0)),
        hbm_bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        wire_bytes_per_device=stats.wire_bytes,
        model_flops=mf,
        overlap_fraction=overlap_fraction,
        messages_per_device=stats.messages,
    )
    # --tuned: price the collective term with measured α/bandwidth
    roof = (Roofline.from_latency(latency, **roof_kw) if latency is not None
            else Roofline(**roof_kw))
    mem = {
        "argument_gb": ma.argument_size_in_bytes / 2**30,
        "output_gb": ma.output_size_in_bytes / 2**30,
        "temp_gb": ma.temp_size_in_bytes / 2**30,
        "alias_gb": ma.alias_size_in_bytes / 2**30,
    }
    # donated inputs alias outputs: live = args + temp (+ non-aliased out)
    live = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + max(ma.output_size_in_bytes - ma.alias_size_in_bytes, 0))
    mem["live_gb"] = live / 2**30
    mem["fits_16gb"] = bool(live <= HBM_PER_CHIP)
    return {
        "compile_s": compile_s,
        "memory": mem,
        "roofline": roof.as_dict(n_dev),
        "collectives": {"counts": stats.op_counts,
                        "bytes": stats.op_bytes,
                        "while_loops": stats.while_loops},
        "params": model.param_count(),
        "active_params": n_active,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None, tuned_db=None) -> dict:
    lowered, n_dev, model, shape_cfg = lower_cell(arch, shape_name, multi_pod,
                                                  overrides)
    mesh_label = "2x16x16" if multi_pod else "16x16"
    sched = None
    if shape_cfg.kind == "train":
        # the issue schedule the step executes: its overlap fraction makes
        # the roofline honest about compute/comm overlap
        mesh = make_production_mesh(multi_pod=multi_pod)
        tcfg = make_step_config(arch, overrides)
        with mesh:
            sched = build_step_schedule(model, mesh, tcfg)
    pricing = None
    if tuned_db is not None:
        if shape_cfg.kind == "train":
            tr, ch = tcfg.comm.transport, tcfg.comm.channels
        else:
            st = settings_for(arch)
            tr, ch = st.transport, st.channels
        pricing = _tuned_pricing(tuned_db, arch=arch, mesh_label=mesh_label,
                                 transport=tr, channels=ch)
    out = analyse(lowered, n_dev, model, shape_cfg,
                  overlap_fraction=sched.overlap_fraction if sched else 0.0,
                  latency=pricing["model"] if pricing else None)
    if shape_cfg.kind == "train":
        with mesh:
            out["comm_plan"] = comm_plan_summary(model, mesh, tcfg)
        out["schedule"] = sched.describe()
    if pricing:
        out["tuned"] = {"key": pricing["key"], "alpha_s": pricing["alpha_s"],
                        "bandwidth": pricing["bandwidth"]}
        out["model_error"] = pricing["model_error"]
    out.update({"arch": arch, "shape": shape_name,
                "mesh": mesh_label,
                "devices": n_dev})
    return out


MEM_DEFAULT_ARCHS = ["whisper-base", "llama3.2-1b"]


def _entry_param_elems(hlo_text: str, index: int, dtype: str = "f32"
                       ) -> int | None:
    """Element count of ENTRY parameter ``index`` in optimized HLO text —
    the *lowered* size of a buffer we predicted (fusion-internal
    ``parameter(i)`` lines outside ENTRY are ignored)."""
    import re as _re

    in_entry = False
    pat = _re.compile(rf"{dtype}\[(\d+)\]")
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            in_entry = True
            continue
        if in_entry:
            if line.startswith("}"):
                break
            if f"parameter({index})" in line:
                m = pat.search(line)
                return int(m.group(1)) if m else None
    return None


def run_mem_cell(arch: str, page_bytes: int, bucket_mb: float, *,
                 channels: int = 2, transport: str = "psum",
                 tuned_db=None) -> dict:
    """One ``--suite mem`` cell: lower + compile a pack→reduce→unpack step
    over the arch's (reduced) gradient tree with a **donated** arena, then
    hold the :mod:`repro.mem` prediction layer to the optimized HLO with
    zero tolerance:

    * **bytes/pages** — the per-device arena parameter in the compiled
      module must be exactly ``ArenaLayout.total_elems`` fp32 elements
      (page-quantized), i.e. predicted bytes == lowered buffer size and
      predicted page count == lowered bytes / page_bytes;
    * **counts** — the arena path must lower to exactly ``n_spans``
      all-reduce ops (fused segments) and the per-bucket baseline to
      exactly ``n_buckets`` — strictly more whenever fusing collapses
      anything, the paper's fewer-larger-messages claim in HLO;
    * **wire bytes** — parsed collective bytes must equal
      ``CommPlan.arena_bytes_per_device`` (page padding crosses the wire;
      the roofline folds it via ``padding_wire_bytes_per_device``).
    """
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.comm import CommConfig
    from repro.configs import reduced_config
    from repro.runtime.train_step import _local_shapes, build_comm

    mesh = compat.make_mesh((4, 1), ("data", "model"),
                            devices=jax.devices()[:4])
    n_dev = 4
    model = build_model(reduced_config(arch))
    tcfg = TrainStepConfig(
        dp_mode="replicated",
        comm=CommConfig(transport=transport, channels=channels,
                        bucket_bytes=int(bucket_mb * 2**20),
                        page_bytes=int(page_bytes)),
        schedule="scheduled", use_arena=True)
    with mesh:
        comm = build_comm(mesh, tcfg)
        pspecs = model.param_specs(mesh)
        local = _local_shapes(model.abstract_params(), pspecs, mesh)
        cplan = comm.plan(local)
        layout = cplan.arena_layout
        arena = comm.arena(local)
        sched_bucket = comm.schedule(local, "scheduled", 1)
        sched_arena = comm.arena_schedule(local, "scheduled", 1)
        grads_abs = model.abstract_params()
        batch_abs = {"x": jax.ShapeDtypeStruct((1,), jnp.float32)}

        def grad_like(p, mb):
            return jnp.zeros((), jnp.float32), p

        def arena_fn(buf, grads, batch):
            _, (tree, out) = comm.reduce_scheduled(
                grad_like, grads, batch, sched_arena, op="all_reduce",
                arena=arena, arena_buf=buf)
            return out, tree

        def bucket_fn(grads, batch):
            _, tree = comm.reduce_scheduled(grad_like, grads, batch,
                                            sched_bucket, op="all_reduce")
            return tree

        flat = P(tuple(mesh.axis_names))
        arena_abs = jax.ShapeDtypeStruct((n_dev * layout.total_elems,),
                                         jnp.float32)
        fa = jax.jit(compat.shard_map(
            arena_fn, mesh=mesh, in_specs=(flat, pspecs, P()),
            out_specs=(flat, pspecs), check_vma=False), donate_argnums=(0,))
        fb = jax.jit(compat.shard_map(
            bucket_fn, mesh=mesh, in_specs=(pspecs, P()),
            out_specs=pspecs, check_vma=False))
        t0 = time.time()
        ca = fa.lower(arena_abs, grads_abs, batch_abs).compile()
        cb = fb.lower(grads_abs, batch_abs).compile()
        compile_s = time.time() - t0

    txt_a, txt_b = ca.as_text(), cb.as_text()
    stats_a = collective_wire_bytes(txt_a)
    stats_b = collective_wire_bytes(txt_b)
    n_ar_arena = stats_a.op_counts.get("all-reduce", 0)
    n_ar_bucket = stats_b.op_counts.get("all-reduce", 0)

    # --- the zero-tolerance prediction checks -----------------------------
    # the arena is arena_fn's first (donated) argument -> ENTRY parameter 0
    # of the partitioned module; its lowered size must equal the predicted
    # page-quantized layout exactly
    lowered_elems = _entry_param_elems(txt_a, 0)
    if lowered_elems != layout.total_elems:
        raise AssertionError(
            f"lowered arena parameter is f32[{lowered_elems}], predicted "
            f"f32[{layout.total_elems}] ({layout.total_bytes} B, "
            f"{layout.n_pages} pages)")
    if n_ar_arena != layout.n_spans:
        raise AssertionError(
            f"arena path lowered to {n_ar_arena} all-reduce ops, predicted "
            f"{layout.n_spans} fused spans")
    if n_ar_bucket != cplan.n_buckets:
        raise AssertionError(
            f"bucket baseline lowered to {n_ar_bucket} all-reduce ops, "
            f"predicted {cplan.n_buckets} buckets")
    if layout.n_spans < cplan.n_buckets and not n_ar_arena < n_ar_bucket:
        raise AssertionError(
            f"fused spans did not reduce the collective count: "
            f"{n_ar_arena} vs {n_ar_bucket}")
    measured = stats_a.op_bytes.get("all-reduce", 0.0)
    predicted = cplan.arena_bytes_per_device
    if predicted and abs(measured - predicted) / predicted > 1e-9:
        raise AssertionError(
            f"arena wire bytes: predicted {predicted}, HLO {measured}")

    pricing = None
    if tuned_db is not None:
        pricing = _tuned_pricing(tuned_db, arch=arch, mesh_label="4x1",
                                 transport=transport, channels=channels,
                                 page_bytes=int(page_bytes))
    padding_wire = predicted * layout.padding_fraction
    roof_kw = dict(
        flops_per_device=0.0, hbm_bytes_per_device=0.0,
        wire_bytes_per_device=predicted - padding_wire,
        padding_wire_bytes_per_device=padding_wire,
        messages_per_device=cplan.arena_messages_per_device,
        overlap_fraction=sched_arena.overlap_fraction,
    )
    roof = (Roofline.from_latency(pricing["model"], **roof_kw)
            if pricing else Roofline(**roof_kw))
    tuned_extra = ({"tuned": {"key": pricing["key"],
                              "alpha_s": pricing["alpha_s"],
                              "bandwidth": pricing["bandwidth"]},
                    "model_error": pricing["model_error"]}
                   if pricing else {})
    return tuned_extra | {
        "arch": arch, "suite": "mem",
        "page_bytes": int(page_bytes),
        "bucket_mb": bucket_mb,
        "channels": channels,
        "transport": transport,
        "mesh": "4x1",
        "devices": n_dev,
        "compile_s": compile_s,
        "predicted_arena_bytes": layout.total_bytes,
        "predicted_arena_pages": layout.n_pages,
        "lowered_arena_elems": lowered_elems,
        "arena_bytes_match": lowered_elems == layout.total_elems,
        "padding_fraction": layout.padding_fraction,
        "segment_waste": [s.waste for s in layout.segments],
        "n_buckets": cplan.n_buckets,
        "n_spans": layout.n_spans,
        "hlo_allreduce_arena": n_ar_arena,
        "hlo_allreduce_bucket": n_ar_bucket,
        "predicted_wire_bytes": predicted,
        "hlo_wire_bytes": measured,
        "padding_wire_bytes": padding_wire,
        "roofline": roof.as_dict(n_dev),
        "arena": layout.describe() | {"segments": None, "spans": None},
        "comm_plan": cplan.describe() | {"arena": None, "channels": None},
    }


def _count_pallas_calls(jaxpr, name_substr: str) -> int:
    """Recursively count ``pallas_call`` equations whose kernel name
    contains ``name_substr`` (sub-jaxprs in eqn params included)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            name = str(eqn.params.get("name_and_src_info",
                                      eqn.params.get("name", "")))
            if name_substr in name:
                n += 1
        for v in eqn.params.values():
            for u in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(u, "jaxpr"):
                    n += _count_pallas_calls(u.jaxpr, name_substr)
    return n


def run_mem_codec_cell(arch: str, page_bytes: int, bucket_mb: float, *,
                       channels: int = 2, dp_mode: str = "replicated",
                       wire_codec: str = "int8") -> dict:
    """One quantized-wire mem cell: lower the ``dp_mode``'s gradient wire
    path twice — fp32 and under ``wire_codec`` — over the arch's (reduced)
    tree on an explicit ``ring`` transport, and hold the compressed
    prediction to the optimized HLO with zero tolerance:

    * **wire bytes** — parsed ``collective-permute`` operand bytes (int8
      payload + fp32 block scales both ride the ppermutes) must equal
      ``CommPlan.arena_bytes_per_device`` exactly, for the fp32 twin and
      the codec run alike;
    * **compression** — the codec cell must move ≥ 3.5× fewer
      predicted-and-lowered bytes than its fp32 twin (the acceptance
      ratio; ``1 + 4/block`` bytes/elem plus page padding);
    * **kernels** — on a channel-free pack of the same tree, the fused
      pack+quantize must lower to exactly one ``pallas_call`` per span
      (one fused encode per contiguous segment, no per-block dispatch).

    The three DP modes lower their own wire paths — ``replicated``
    all-reduces spans, ``zero1`` reduce-scatters spans then all-gathers
    the shards, ``fsdp`` lowers the reduce-scatter its weight-gather
    transpose executes (half an all-reduce) — over the *same* span layout,
    so the measured ratios must agree exactly across modes.
    """
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.comm import CommConfig
    from repro.configs import reduced_config
    from repro.runtime.train_step import _local_shapes, build_comm

    mesh = compat.make_mesh((4, 1), ("data", "model"),
                            devices=jax.devices()[:4])
    n_dev = 4
    model = build_model(reduced_config(arch))
    op = "all_reduce" if dp_mode == "replicated" else "reduce_scatter"
    gather_back = dp_mode == "zero1"      # fsdp keeps the shards

    def build(codec):
        tcfg = TrainStepConfig(
            dp_mode="replicated",      # the comm config is mode-agnostic
            comm=CommConfig(transport="ring", channels=channels,
                            bucket_bytes=int(bucket_mb * 2**20),
                            page_bytes=int(page_bytes), wire_codec=codec),
            schedule="scheduled", use_arena=True)
        return build_comm(mesh, tcfg)

    def lower(comm):
        pspecs = model.param_specs(mesh)
        local = _local_shapes(model.abstract_params(), pspecs, mesh)
        cplan = comm.plan(local)
        layout = cplan.arena_layout
        arena = comm.arena(local)
        sched = comm.arena_schedule(local, "scheduled", 1)
        quant = comm.codec is not None
        grads_abs = model.abstract_params()
        batch_abs = {"x": jax.ShapeDtypeStruct((1,), jnp.float32)}
        flat = P(tuple(mesh.axis_names))

        def grad_like(p, mb):
            return jnp.zeros((), jnp.float32), p

        def fn(buf, ef, grads, batch):
            kw = dict(arena=arena, arena_buf=buf)
            if quant:
                kw["ef_buf"] = ef
            _, out = comm.reduce_scheduled(grad_like, grads, batch, sched,
                                           op=op, **kw)
            if op == "all_reduce":
                tree, buf = out[0], out[1]
                ef = out[2] if quant else ef
                return buf, ef, tree
            shards, _, buf = out[0], out[1], out[2]
            ef = out[3] if quant else ef
            if gather_back:
                shards = comm.all_gather(shards)
            return buf, ef, shards

        n_out = layout.n_spans if op != "all_reduce" else None
        out_specs = (flat, flat,
                     pspecs if op == "all_reduce" else [flat] * n_out)
        f = jax.jit(compat.shard_map(
            fn, mesh=mesh, in_specs=(flat, flat, pspecs, P()),
            out_specs=out_specs, check_vma=False), donate_argnums=(0, 1))
        arena_abs = jax.ShapeDtypeStruct((n_dev * layout.total_elems,),
                                         jnp.dtype(layout.dtype))
        ef_abs = jax.ShapeDtypeStruct(
            (n_dev * getattr(layout, "payload_elems", 1),), jnp.float32)
        compiled = f.lower(arena_abs, ef_abs, grads_abs, batch_abs).compile()
        stats = collective_wire_bytes(compiled.as_text())
        measured = sum(stats.op_bytes.values())
        predicted = cplan.arena_bytes_per_device
        if dp_mode == "fsdp":
            predicted = predicted / 2.0   # RS is half the AR ring volume
        if predicted and abs(measured - predicted) / predicted > 1e-9:
            raise AssertionError(
                f"{dp_mode}/{comm.codec or 'fp32'} wire bytes: predicted "
                f"{predicted}, HLO {measured}")
        return cplan, layout, predicted, measured

    t0 = time.time()
    with mesh:
        comm_f32, comm_q = build(None), build(wire_codec)
        _, _, pred_f32, meas_f32 = lower(comm_f32)
        cplan_q, layout_q, pred_q, meas_q = lower(comm_q)

        # fused pack+quantize: one kernel per span on a channel-free pack
        tcfg_k = TrainStepConfig(
            dp_mode="replicated",
            comm=CommConfig(transport="ring", channels=0,
                            bucket_bytes=int(bucket_mb * 2**20),
                            page_bytes=int(page_bytes),
                            wire_codec=wire_codec, local_op="pallas"),
            schedule="scheduled", use_arena=True)
        comm_k = build_comm(mesh, tcfg_k)
        pspecs = model.param_specs(mesh)
        local = _local_shapes(model.abstract_params(), pspecs, mesh)
        arena_k = comm_k.arena(local)
        lay_k = arena_k.layout
        bufs = [jax.ShapeDtypeStruct((lay_k.segment_of(b).size,),
                                     jnp.float32)
                for b in range(lay_k.n_segments)]
        jx = jax.make_jaxpr(
            lambda buf, ef, *bs: arena_k.pack_into(buf, list(bs), ef))(
            arena_k.abstract(), arena_k.ef_abstract(), *bufs)
        n_kernels = _count_pallas_calls(jx.jaxpr, "_pack_quant_kernel")
        if n_kernels != lay_k.n_spans:
            raise AssertionError(
                f"fused pack+quantize lowered to {n_kernels} pallas calls, "
                f"expected one per span ({lay_k.n_spans})")
    compile_s = time.time() - t0

    ratio = meas_f32 / meas_q if meas_q else 0.0
    if ratio < 3.5:
        raise AssertionError(
            f"codec wire-byte ratio {ratio:.3f} < 3.5 "
            f"(fp32 {meas_f32} B vs {wire_codec} {meas_q} B; page padding "
            f"too large? use small pages for codec cells)")
    return {
        "arch": arch, "suite": "mem", "cell": "codec",
        "dp_mode": dp_mode,
        "wire_codec": wire_codec,
        "codec_block": cplan_q.codec_block,
        "page_bytes": int(page_bytes),
        "bucket_mb": bucket_mb,
        "channels": channels,
        "transport": "ring",
        "mesh": "4x1",
        "devices": n_dev,
        "compile_s": compile_s,
        "predicted_wire_bytes_fp32": pred_f32,
        "hlo_wire_bytes_fp32": meas_f32,
        "predicted_wire_bytes_codec": pred_q,
        "hlo_wire_bytes_codec": meas_q,
        "wire_ratio": ratio,
        "bytes_match_fp32": abs(meas_f32 - pred_f32) <= 1e-9 * pred_f32,
        "bytes_match_codec": abs(meas_q - pred_q) <= 1e-9 * pred_q,
        "pack_quant_kernels": n_kernels,
        "n_spans_packed": lay_k.n_spans,
        "codec_tradeoff": cplan_q.codec_tradeoff(),
        "arena": layout_q.describe() | {"segments": None, "spans": None},
    }


def run_mem_suite(args, cache: dict, tuned_db=None) -> None:
    """The ``--suite mem`` grid: page_bytes × bucket_mb × arch, each cell
    asserting predicted arena bytes/pages/collective-counts against the
    lowered HLO with zero tolerance.  With ``--wire-codec`` the grid runs
    the quantized-wire codec cells instead — per DP mode, each asserting
    compressed-prediction == lowered bytes at 0 tolerance, a ≥ 3.5×
    fp32/codec wire ratio, and one fused pack+quantize kernel per span —
    then asserts the measured ratio is identical across the three modes."""
    archs = (MEM_DEFAULT_ARCHS if args.arch == "all"
             else args.arch.split(","))
    pages = [int(s) for s in str(args.page_bytes).split(",")]
    buckets = [float(s) for s in str(args.bucket_mb).split(",")]
    if args.wire_codec:
        run_mem_codec_grid(args, cache, archs, pages, buckets)
        return
    for arch in archs:
        for pb in pages:
            for bmb in buckets:
                grid = {"page_bytes": pb, "bucket_mb": bmb,
                        "channels": args.channels}
                if tuned_db is not None:
                    # tuned pricing is part of the cell identity: an
                    # untuned cached cell must not shadow a --tuned run
                    grid["tuned"] = os.path.basename(args.tuned)
                key = cell_key(args.tag, arch, "mem", f"p{pb}", grid)
                if key in cache and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[lower+compile] {key} ...", flush=True)
                t0 = time.time()
                try:
                    rec = run_mem_cell(arch, pb, bmb,
                                       channels=args.channels,
                                       tuned_db=tuned_db)
                    rec["tag"] = args.tag
                    cache[key] = rec
                    print(f"  ok in {time.time()-t0:.1f}s: "
                          f"arena={rec['predicted_arena_bytes']}B "
                          f"pages={rec['predicted_arena_pages']} "
                          f"pad={rec['padding_fraction']:.2%} "
                          f"collectives {rec['hlo_allreduce_arena']}"
                          f"(fused)/{rec['hlo_allreduce_bucket']}(bucket)",
                          flush=True)
                except Exception as e:
                    cache[key] = {"error": str(e), "tag": args.tag,
                                  "arch": arch, "shape": "mem"}
                    print(f"  FAILED: {e}")
                    traceback.print_exc()
                with open(args.out, "w") as f:
                    json.dump(cache, f, indent=1)


def run_mem_codec_grid(args, cache: dict, archs, pages, buckets) -> None:
    """The ``--wire-codec`` arm of the mem suite: one codec cell per
    (arch × page × bucket × DP mode), plus the cross-mode ratio assert."""
    for arch in archs:
        for pb in pages:
            for bmb in buckets:
                ratios = {}
                for dp_mode in ("replicated", "zero1", "fsdp"):
                    grid = {"page_bytes": pb, "bucket_mb": bmb,
                            "channels": args.channels,
                            "wire_codec": args.wire_codec,
                            "dp_mode": dp_mode}
                    key = cell_key(args.tag, arch, "mem-codec",
                                   f"p{pb}-{dp_mode}", grid)
                    if key in cache and not args.force:
                        print(f"[cached] {key}")
                        if "wire_ratio" in cache[key]:
                            ratios[dp_mode] = cache[key]["wire_ratio"]
                        continue
                    print(f"[lower+compile] {key} ...", flush=True)
                    t0 = time.time()
                    try:
                        rec = run_mem_codec_cell(
                            arch, pb, bmb, channels=args.channels,
                            dp_mode=dp_mode, wire_codec=args.wire_codec)
                        rec["tag"] = args.tag
                        cache[key] = rec
                        ratios[dp_mode] = rec["wire_ratio"]
                        print(f"  ok in {time.time()-t0:.1f}s: "
                              f"wire {rec['hlo_wire_bytes_fp32']:.0f}B -> "
                              f"{rec['hlo_wire_bytes_codec']:.0f}B "
                              f"(x{rec['wire_ratio']:.2f}), "
                              f"{rec['pack_quant_kernels']} fused "
                              f"pack+quantize kernels", flush=True)
                    except Exception as e:
                        cache[key] = {"error": str(e), "tag": args.tag,
                                      "arch": arch, "shape": "mem-codec"}
                        print(f"  FAILED: {e}")
                        traceback.print_exc()
                    with open(args.out, "w") as f:
                        json.dump(cache, f, indent=1)
                if len(ratios) == 3 and len(set(ratios.values())) != 1:
                    raise AssertionError(
                        f"codec wire ratio differs across DP modes: "
                        f"{ratios}")


SERVE_DEFAULT_ARCHS = ["llama3.2-1b", "qwen2-7b"]


def run_serve_cell(arch: str, page_tokens: int, model_parallel: int, *,
                   page_bytes: int = 4096, max_seqs: int = 4,
                   max_seq_len: int = 64) -> dict:
    """One ``--suite serve`` cell: lower + compile one paged decode step
    (``repro.serve``) on a ``(1, R)`` mesh and hold the serving prediction
    layer to the optimized HLO with zero tolerance:

    * **bytes/pages** — the donated page arena is the step's first argument
      → ENTRY parameter 0 of the compiled module; its lowered size must be
      exactly ``KVArenaPlan.total_elems`` elements of the cache dtype, i.e.
      predicted KV bytes == lowered buffer bytes and predicted page count
      == lowered bytes / page_bytes;
    * **counts** — one decode token must lower to exactly
      ``predicted_collectives_per_token(plan)`` all-reduce ops (the per-layer
      pmax + fused LSE stats reduce; zero when R == 1);
    * **wire bytes** — parsed all-reduce bytes must equal
      ``predicted_wire_bytes_per_token`` exactly (ring ``2(R-1)/R`` hops
      over the fp32 stats, nothing else crosses the wire per token).

    The roofline prices the per-token exposed comm with the α·messages
    latency term — decode is the α-bound regime, same as the paper's
    strong-scaled CG.
    """
    from repro import compat
    from repro.configs import reduced_config
    from repro.serve.engine import (build_paged_decode_step,
                                    predicted_collectives_per_token,
                                    predicted_wire_bytes_per_token)
    from repro.serve.kv import plan_kv_arena

    r = int(model_parallel)
    mesh = compat.make_mesh((1, r), ("data", "model"),
                            devices=jax.devices()[:r])
    model = build_model(reduced_config(arch))
    plan = plan_kv_arena(model.cfg, mesh, page_tokens=page_tokens,
                         page_bytes=page_bytes, max_seqs=max_seqs,
                         max_seq_len=max_seq_len)
    b = plan.max_seqs
    with mesh:
        step, _, _ = build_paged_decode_step(model, mesh, plan,
                                             attn_impl="ref")
        pages_abs = jax.ShapeDtypeStruct((plan.total_elems,),
                                         plan.layout.dtype)
        table_abs = jax.ShapeDtypeStruct(
            (b, plan.max_blocks, plan.n_layers), jnp.int32)
        vec = jax.ShapeDtypeStruct((b,), jnp.int32)
        valid_abs = jax.ShapeDtypeStruct((b,), jnp.bool_)
        t0 = time.time()
        compiled = step.lower(pages_abs, model.abstract_params(), table_abs,
                              vec, vec, valid_abs).compile()
        compile_s = time.time() - t0

    txt = compiled.as_text()
    stats = collective_wire_bytes(txt)

    # --- the zero-tolerance prediction checks -----------------------------
    hlo_dtype = {"bfloat16": "bf16", "float32": "f32",
                 "float16": "f16"}[jnp.dtype(plan.layout.dtype).name]
    lowered_elems = _entry_param_elems(txt, 0, hlo_dtype)
    if lowered_elems != plan.total_elems:
        raise AssertionError(
            f"lowered page arena is {hlo_dtype}[{lowered_elems}], predicted "
            f"{hlo_dtype}[{plan.total_elems}] ({plan.total_bytes} B, "
            f"{plan.n_arena_pages} pages)")
    n_ar = stats.op_counts.get("all-reduce", 0)
    pred_count = predicted_collectives_per_token(plan)
    if n_ar != pred_count:
        raise AssertionError(
            f"decode step lowered to {n_ar} all-reduce ops per token, "
            f"predicted {pred_count} (2 per layer at R={r})")
    measured = stats.op_bytes.get("all-reduce", 0.0)
    predicted = predicted_wire_bytes_per_token(plan, model.cfg, b)
    if measured != predicted:
        raise AssertionError(
            f"per-token all-reduce wire bytes: predicted {predicted}, "
            f"HLO {measured}")

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    roof = Roofline(
        flops_per_device=float(ca.get("flops", 0.0)),
        hbm_bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        wire_bytes_per_device=predicted,
        messages_per_device=float(stats.messages),
        overlap_fraction=0.0,       # decode comm is on the critical path
    )
    return {
        "arch": arch, "suite": "serve",
        "page_tokens": page_tokens,
        "page_bytes": int(page_bytes),
        "mesh": f"1x{r}",
        "devices": r,
        "batch_slots": b,
        "max_seq_len": max_seq_len,
        "compile_s": compile_s,
        "predicted_kv_bytes": plan.total_bytes,
        "predicted_kv_pages": plan.n_arena_pages,
        "lowered_arena_elems": lowered_elems,
        "kv_bytes_match": lowered_elems == plan.total_elems,
        "padding_fraction": plan.padding_fraction,
        "predicted_collectives_per_token": pred_count,
        "hlo_allreduce_per_token": n_ar,
        "predicted_wire_bytes_per_token": predicted,
        "hlo_wire_bytes_per_token": measured,
        "hlo_messages": stats.messages,
        "roofline": roof.as_dict(r),
        "kv_plan": plan.describe(),
    }


def run_serve_suite(args, cache: dict) -> None:
    """The ``--suite serve`` grid: arch × page_tokens × model-parallel,
    each cell asserting predicted KV-arena bytes/pages and per-decode-token
    collective counts against the lowered HLO with zero tolerance."""
    archs = (SERVE_DEFAULT_ARCHS if args.arch == "all"
             else args.arch.split(","))
    pts = [int(s) for s in str(args.page_tokens).split(",")]
    rs = [int(s) for s in str(args.serve_mp).split(",")]
    for arch in archs:
        for pt in pts:
            for r in rs:
                grid = {"page_tokens": pt, "model_parallel": r}
                key = cell_key(args.tag, arch, "serve", f"r{r}", grid)
                if key in cache and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[lower+compile] {key} ...", flush=True)
                t0 = time.time()
                try:
                    rec = run_serve_cell(arch, pt, r)
                    rec["tag"] = args.tag
                    cache[key] = rec
                    print(f"  ok in {time.time()-t0:.1f}s: "
                          f"kv={rec['predicted_kv_bytes']}B "
                          f"pages={rec['predicted_kv_pages']} "
                          f"pad={rec['padding_fraction']:.2%} "
                          f"collectives/token={rec['hlo_allreduce_per_token']}"
                          f" wire/token={rec['hlo_wire_bytes_per_token']:.0f}B",
                          flush=True)
                except Exception as e:
                    cache[key] = {"error": str(e), "tag": args.tag,
                                  "arch": arch, "shape": "serve"}
                    print(f"  FAILED: {e}")
                    traceback.print_exc()
                with open(args.out, "w") as f:
                    json.dump(cache, f, indent=1)


MOE_DEFAULT_ARCHS = ["mixtral-8x7b", "llama4-maverick-400b-a17b"]


def run_moe_cell(arch: str, transport: str, channels: int,
                 model_parallel: int, parallelism: str, *,
                 batch: int = 8, seq: int = 32) -> dict:
    """One ``--suite moe`` cell: lower + compile one MoE forward loss on a
    ``(1, R)`` mesh and hold the :class:`~repro.comm.plan.A2APlan` to the
    optimized HLO:

    * **counts** — with ``parallelism='ep'`` every MoE layer must lower to
      exactly one dispatch + one combine exchange per rail in the
      transport's op family (``a2a`` → HLO ``all-to-all``, rings →
      ``collective-permute`` hops, ``psum`` → zero-padded ``all-reduce``);
      with ``parallelism='tp'`` the all-to-all count must be zero;
    * **wire bytes** — the parsed bytes of that op family must equal
      ``n_moe_layers * A2APlan.bytes_per_device`` at <1% tolerance (the
      parser and the plan price the same ring formulas, so the observed
      error is 0);
    * **dispatch tax** — the plan's per-device dispatch bytes must be at
      most ``1/R`` of the replicated-psum fallback's prediction for the
      same payload (the PR's headline acceptance bound).
    """
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.comm.registry import get_transport
    from repro.configs import reduced_config
    from repro.models.moe import capacity
    from repro.runtime.train_step import build_moe_comm, make_ctx

    r = int(model_parallel)
    rcfg = reduced_config(arch)
    if rcfg.moe is None:
        raise ValueError(f"{arch} has no MoE block")
    rcfg = rcfg.with_(moe=replace(rcfg.moe, parallelism=parallelism))
    model = build_model(rcfg)
    cfg = model.cfg
    mesh = compat.make_mesh((1, r), ("data", "model"),
                            devices=jax.devices()[:r])
    tcfg = TrainStepConfig(moe_transport=transport, moe_channels=channels)
    ctx = make_ctx(mesh, tcfg)
    comm = build_moe_comm(mesh, tcfg)

    n_moe = sum(1 for i in range(cfg.num_layers)
                if cfg.layer_kind(i)["mlp"] == "moe")
    e, d = cfg.moe.num_experts, cfg.d_model
    cap = capacity(seq, cfg.moe)
    bs = batch // r
    buf_shape = (bs, e, cap, d)          # the local EP dispatch payload
    plan = comm.a2a_plan(buf_shape, dtype=jnp.float32)
    sched = comm.moe_schedule(buf_shape, dtype=jnp.float32)
    sched.validate()

    # the acceptance bound: EP dispatch <= 1/R of the replicated-psum cost
    n_elems = plan.elems_per_device
    _, psum_cls = get_transport("psum")
    psum_t = psum_cls(("model",), None)
    replicated = psum_t.predicted_a2a_bytes_per_device(n_elems, r,
                                                       itemsize=4)
    if transport != "psum" and r > 1 and \
            plan.dispatch_bytes_per_device > replicated / r:
        raise AssertionError(
            f"EP dispatch bytes {plan.dispatch_bytes_per_device:.0f} exceed "
            f"1/R of the replicated-psum cost {replicated:.0f} at R={r}")

    pspecs = model.param_specs(mesh)
    batch_abs = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    bspecs = {"tokens": P(), "labels": P()}

    def lower_with(ctx_):
        def fwd(p, mb):
            return model.loss_fn(p, mb, ctx=ctx_)

        sh = compat.shard_map(fwd, mesh=mesh, in_specs=(pspecs, bspecs),
                              out_specs=P(), check_vma=False)
        with mesh:
            return jax.jit(sh).lower(model.abstract_params(),
                                     batch_abs).compile()

    t0 = time.time()
    compiled = lower_with(ctx)
    compile_s = time.time() - t0
    txt = compiled.as_text()
    stats = collective_wire_bytes(txt)

    # which HLO op family carries the exchange, and the expected op count
    rails = comm.a2a_rails(buf_shape)
    ep_active = parallelism == "ep" and r > 1 and e % r == 0 \
        and batch % r == 0
    family = {"a2a": "all-to-all", "ring": "collective-permute",
              "ring_hier": "collective-permute", "psum": "all-reduce"}[
                  transport]
    if not ep_active:
        want_ops = 0
        predicted_bytes = 0.0
    elif family == "all-to-all":
        want_ops = n_moe * 2 * rails
        predicted_bytes = n_moe * plan.bytes_per_device
    elif family == "collective-permute":
        want_ops = n_moe * 2 * rails * (r - 1)
        predicted_bytes = n_moe * plan.bytes_per_device
    else:                                 # psum fallback
        want_ops = n_moe * 2 * rails
        predicted_bytes = n_moe * plan.bytes_per_device

    n_ops = stats.op_counts.get(family, 0)
    measured = stats.op_bytes.get(family, 0.0)
    if family == "all-reduce" and ep_active:
        # the psum fallback shares its op family with the model's TP
        # all-reduces; diff against the identical graph lowered with the
        # native-a2a transport to isolate the exchange's contribution
        bg = collective_wire_bytes(lower_with(make_ctx(
            mesh, replace(tcfg, moe_transport="a2a"))).as_text())
        n_ops -= bg.op_counts.get(family, 0)
        measured -= bg.op_bytes.get(family, 0.0)
    if parallelism == "tp" and stats.op_counts.get("all-to-all", 0):
        raise AssertionError(
            f"tp parallelism lowered {stats.op_counts['all-to-all']} "
            f"all-to-all ops; expected none")
    if ep_active:
        if n_ops != want_ops:
            raise AssertionError(
                f"{family} op count {n_ops} != predicted {want_ops} "
                f"({n_moe} MoE layers x dispatch+combine x {rails} rails)")
        err = (abs(measured - predicted_bytes) / predicted_bytes
               if predicted_bytes else 0.0)
        if err >= 0.01:
            raise AssertionError(
                f"{family} wire bytes: predicted {predicted_bytes:.0f}, "
                f"HLO {measured:.0f} (err {err:.2%} >= 1%)")
    else:
        err = 0.0

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    roof = Roofline(
        flops_per_device=float(ca.get("flops", 0.0)),
        hbm_bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        wire_bytes_per_device=stats.wire_bytes,
        messages_per_device=float(stats.messages),
        overlap_fraction=sched.overlap_fraction if ep_active else 0.0,
    )
    return {
        "arch": arch, "suite": "moe",
        "transport": transport, "channels": channels, "rails": rails,
        "parallelism": parallelism, "mesh": f"1x{r}", "devices": r,
        "ep_active": ep_active,
        "n_moe_layers": n_moe, "capacity": cap,
        "buf_shape": list(buf_shape),
        "compile_s": compile_s,
        "predicted_a2a_bytes": predicted_bytes,
        "hlo_a2a_bytes": measured,
        "byte_err": err,
        "predicted_a2a_ops": want_ops,
        "hlo_a2a_ops": n_ops,
        "dispatch_bytes_per_device": plan.dispatch_bytes_per_device,
        "replicated_psum_bytes": replicated,
        "dispatch_vs_replicated":
            (plan.dispatch_bytes_per_device / replicated if replicated
             else 0.0),
        "messages_per_device": plan.messages_per_device,
        "overlap_fraction": sched.overlap_fraction,
        "a2a_plan": plan.describe(),
        "roofline": roof.as_dict(r),
    }


def run_moe_suite(args, cache: dict) -> None:
    """The ``--suite moe`` grid: arch × transport × channels × parallelism,
    each cell asserting predicted all-to-all ops/bytes against the lowered
    HLO (<1% tolerance) and the EP-dispatch-tax bound vs the replicated
    psum fallback."""
    archs = (MOE_DEFAULT_ARCHS if args.arch == "all"
             else args.arch.split(","))
    transports = str(args.moe_transports).split(",")
    chans = [int(s) for s in str(args.moe_channels).split(",")]
    rs = [int(s) for s in str(args.moe_mp).split(",")]
    for arch in archs:
        for transport in transports:
            for ch in chans:
                for r in rs:
                    for par in ("ep", "tp"):
                        if par == "tp" and (transport != "a2a" or ch != 0):
                            continue   # tp lowers no exchange; one cell enough
                        grid = {"transport": transport, "channels": ch,
                                "parallelism": par}
                        key = cell_key(args.tag, arch, "moe", f"r{r}", grid)
                        if key in cache and not args.force:
                            print(f"[cached] {key}")
                            continue
                        print(f"[lower+compile] {key} ...", flush=True)
                        t0 = time.time()
                        try:
                            rec = run_moe_cell(arch, transport, ch, r, par)
                            rec["tag"] = args.tag
                            cache[key] = rec
                            print(
                                f"  ok in {time.time()-t0:.1f}s: "
                                f"ops={rec['hlo_a2a_ops']} "
                                f"bytes={rec['hlo_a2a_bytes']:.0f} "
                                f"(err {rec['byte_err']:.2%}) "
                                f"dispatch/replicated="
                                f"{rec['dispatch_vs_replicated']:.3f}",
                                flush=True)
                        except Exception as e:
                            cache[key] = {"error": str(e), "tag": args.tag,
                                          "arch": arch, "shape": "moe"}
                            print(f"  FAILED: {e}")
                            traceback.print_exc()
                        with open(args.out, "w") as f:
                            json.dump(cache, f, indent=1)


STENCIL_MESH = {"single": ((4, 8, 8), 256), "multi": ((8, 8, 8), 512)}


def run_stencil_cell(L: int, schedule: str, multi_pod: bool, *,
                     channels: int = 2, halo: int = 1, components: int = 12,
                     cg_iters: int = 3, solver: str = "cg",
                     precond: str = "none", sstep_s: int = 4) -> dict:
    """One stencil-suite cell: lower + compile ``cg_iters`` unrolled
    iterations of one ``solver × precond`` variant on a Wilson-like operator
    over a 3-D Cartesian mesh, then check the prediction layer against the
    optimized HLO on *two* axes:

    * **bytes** — :class:`~repro.comm.HaloPlan` payloads vs the parsed
      ``collective-permute`` bytes (halo exchanges scale with the variant:
      even-odd hops twice per matvec plus projection/reconstruction);
    * **counts** — :func:`repro.stencil.predicted_reduction_collectives` /
      :func:`~repro.stencil.predicted_halo_exchanges` vs the parsed
      ``all-reduce`` / ``collective-permute`` op counts.  The count check is
      the latency-model (α·messages) analogue of the byte check: it is what
      distinguishes classic CG's ``2·iters+1`` reductions from pipelined's
      ``iters`` and s-step's ``ceil(iters/s)``.

    Inner products ride ``psum`` all-reduces, so the two op kinds separate
    cleanly in the parse."""
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.comm import CommConfig, Communicator
    from repro.core.halo import HaloSpec
    from repro.stencil import (StencilOp, predicted_halo_exchanges,
                               predicted_reduction_collectives, solve)

    mesh_shape, n_dev = STENCIL_MESH["multi" if multi_pod else "single"]
    mesh = compat.make_mesh(mesh_shape, ("x", "y", "z"),
                            devices=jax.devices()[:n_dev])
    specs = (HaloSpec("x", 0, halo), HaloSpec("y", 1, halo),
             HaloSpec("z", 2, halo))
    local = (L, L, L, components)
    gshape = tuple(p * n for p, n in zip(mesh_shape + (1,), local))
    comm = Communicator(mesh, CommConfig(transport="psum",
                                         data_axes=("x", "y", "z"),
                                         channels=channels))
    op = StencilOp(specs=specs, mass=0.8)
    hplan = comm.halo_plan(local, specs, schedule=schedule)
    hsched = comm.halo_schedule(local, specs, schedule=schedule)

    def run(b):
        r = solve(op, b, comm, solver=solver, precond=precond, s=sstep_s,
                  tol=None, maxiter=cg_iters, schedule=schedule,
                  chunks=comm.halo_chunks, channels=channels)
        return r.x, r.rel_residual

    with mesh:
        fn = jax.jit(compat.shard_map(
            run, mesh=mesh, in_specs=P("x", "y", "z", None),
            out_specs=(P("x", "y", "z", None), P()), check_vma=False))
        lowered = fn.lower(jax.ShapeDtypeStruct(gshape, jnp.float32))
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    stats = collective_wire_bytes(compiled.as_text())
    n_exchanges = predicted_halo_exchanges(solver, precond, cg_iters,
                                           s=sstep_s)
    n_reductions = predicted_reduction_collectives(solver, cg_iters,
                                                   s=sstep_s)
    predicted = n_exchanges * hplan.bytes_per_device
    measured = stats.op_bytes.get("collective-permute", 0.0)
    pred_permutes = n_exchanges * hplan.n_units
    hlo_permutes = stats.op_counts.get("collective-permute", 0)
    hlo_reductions = stats.op_counts.get("all-reduce", 0)
    roof = Roofline(
        flops_per_device=float(ca.get("flops", 0.0)),
        hbm_bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        wire_bytes_per_device=stats.wire_bytes,
        overlap_fraction=hsched.overlap_fraction,
        messages_per_device=stats.messages,
    )
    return {
        "arch": "stencil",
        "shape": f"L{L}h{halo}",
        "schedule": schedule,
        "solver": solver,
        "precond": precond,
        "sstep_s": sstep_s,
        "mesh": "x".join(str(s) for s in mesh_shape),
        "devices": n_dev,
        "compile_s": compile_s,
        "cg_iters": cg_iters,
        "predicted_halo_bytes": predicted,
        "hlo_collective_permute_bytes": measured,
        "halo_bytes_rel_err": (abs(measured - predicted) / predicted
                               if predicted else None),
        "predicted_halo_exchanges": n_exchanges,
        "predicted_permute_collectives": pred_permutes,
        "hlo_permute_collectives": hlo_permutes,
        "predicted_reduction_collectives": n_reductions,
        "hlo_reduction_collectives": hlo_reductions,
        "roofline": roof.as_dict(n_dev),
        "collectives": {"counts": stats.op_counts, "bytes": stats.op_bytes,
                        "while_loops": stats.while_loops},
        "halo_plan": hplan.describe(),
        "halo_schedule": hsched.describe(),
    }


def run_stencil_suite(args, meshes, cache: dict) -> None:
    """The ``--suite stencil`` grid: lattice × halo schedule × solver ×
    precond × mesh.  Cells land in the same cache/out file as the train
    suite, keyed through :func:`cell_key` so every grid knob is part of
    the cache identity."""
    from repro.comm import HALO_SCHEDULES
    from repro.stencil import PRECONDS, SOLVERS

    lattices = [int(s) for s in str(args.lattice).split(",")]
    schedules = (list(HALO_SCHEDULES) if args.halo_schedule == "all"
                 else args.halo_schedule.split(","))
    solvers = list(SOLVERS) if args.solver == "all" else args.solver.split(",")
    preconds = (list(PRECONDS) if args.precond == "all"
                else args.precond.split(","))
    for L in lattices:
        for schedule in schedules:
            for solver in solvers:
                for precond in preconds:
                    for multi in meshes:
                        grid = {"schedule": schedule, "solver": solver,
                                "precond": precond, "channels": args.channels,
                                "cg_iters": args.cg_iters,
                                "sstep_s": args.sstep_s}
                        key = cell_key(args.tag, "stencil",
                                       f"L{L}h{args.halo}",
                                       "multi" if multi else "single", grid)
                        if key in cache and not args.force:
                            print(f"[cached] {key}")
                            continue
                        print(f"[lower+compile] {key} ...", flush=True)
                        t0 = time.time()
                        try:
                            rec = run_stencil_cell(
                                L, schedule, multi, channels=args.channels,
                                halo=args.halo, cg_iters=args.cg_iters,
                                solver=solver, precond=precond,
                                sstep_s=args.sstep_s)
                            rec["tag"] = args.tag
                            cache[key] = rec
                            r = rec["roofline"]
                            err = rec["halo_bytes_rel_err"]
                            print(
                                f"  ok in {time.time()-t0:.1f}s: "
                                f"halo_bytes={rec['predicted_halo_bytes']:.0f}"
                                f" (HLO err {err:.2%}) reductions="
                                f"{rec['predicted_reduction_collectives']}"
                                f"/{rec['hlo_reduction_collectives']} "
                                f"permutes="
                                f"{rec['predicted_permute_collectives']}"
                                f"/{rec['hlo_permute_collectives']} "
                                f"Tx={r['t_collective_s']:.6f}s "
                                f"Tx_exposed="
                                f"{r['t_exposed_collective_s']:.6f}s "
                                f"overlap={r['overlap_fraction']:.2f}",
                                flush=True)
                        except Exception as e:
                            cache[key] = {"error": str(e), "tag": args.tag,
                                          "arch": "stencil", "shape": f"L{L}"}
                            print(f"  FAILED: {e}")
                            traceback.print_exc()
                        with open(args.out, "w") as f:
                            json.dump(cache, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--tuned", default=None, metavar="DB",
                    help="tuning DB (repro.tune.probe output): price each "
                         "train/mem cell's collective roofline term with "
                         "the *measured* α/bandwidth of the closest fitted "
                         "record and attach the fit's predicted-vs-measured "
                         "residuals as the cell's model_error field")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="grad-accum slices for train cells; the dry-run "
                         "default of 1 keeps unrolled-HLO compile times "
                         "tractable on this 1-core container (roofline "
                         "FLOP/byte/wire terms are accumulation-invariant)")
    ap.add_argument("--accum-policy", default="accumulate_then_reduce",
                    choices=SCHEDULE_POLICIES,
                    help="issue schedule for the gradient reduction "
                         "(stream/scheduled overlap comm with backward "
                         "compute; reflected in t_exposed_collective)")
    ap.add_argument("--suite", default="train",
                    choices=["train", "stencil", "mem", "serve", "moe"],
                    help="train: the arch x shape grid below; stencil: the "
                         "QCD workload — lattice-volume x halo-schedule "
                         "cells on a 3-D Cartesian mesh, checking HaloPlan "
                         "predictions against lowered collective-permutes; "
                         "mem: the repro.mem arena grid — page_bytes x "
                         "bucket_mb x arch cells asserting predicted arena "
                         "bytes/pages/collective counts against lowered "
                         "HLO with zero tolerance; serve: the repro.serve "
                         "grid — arch x page_tokens x model-parallel paged "
                         "decode steps asserting predicted KV bytes/pages "
                         "and per-token collective counts against lowered "
                         "HLO with zero tolerance; moe: the expert-parallel "
                         "grid — arch x transport x channels x ep/tp MoE "
                         "forward losses asserting predicted all-to-all "
                         "ops/bytes (A2APlan) against lowered HLO at <1%% "
                         "tolerance and the EP dispatch <= replicated/R "
                         "bound")
    ap.add_argument("--page-bytes", default="4096,2097152",
                    help="mem suite: comma-separated arena page sizes "
                         "(default: 4 KiB small-page baseline and the "
                         "paper's 2 MiB huge page)")
    ap.add_argument("--bucket-mb", default="1",
                    help="mem suite: comma-separated bucketer targets in "
                         "MiB")
    ap.add_argument("--wire-codec", default=None, choices=["int8"],
                    help="mem suite: run the quantized-wire codec cells "
                         "instead — per DP mode, asserting compressed "
                         "prediction == lowered collective bytes at zero "
                         "tolerance, a >=3.5x fp32/codec wire ratio, and "
                         "one fused pack+quantize kernel per span (use "
                         "small --page-bytes, e.g. 4096: 2 MiB pages "
                         "quantize the int8 payload 4x coarser and the "
                         "padding eats the ratio)")
    ap.add_argument("--moe-transports", default="a2a,psum",
                    help="moe suite: comma-separated exchange transports "
                         "(a2a,ring,ring_hier,psum)")
    ap.add_argument("--moe-channels", default="0,2",
                    help="moe suite: comma-separated rail counts for the "
                         "EP payload's feature-dim striping (0 = single)")
    ap.add_argument("--moe-mp", default="2",
                    help="moe suite: comma-separated model-axis sizes R")
    ap.add_argument("--page-tokens", default="8,16",
                    help="serve suite: comma-separated KV page sizes in "
                         "token positions")
    ap.add_argument("--serve-mp", default="1,2",
                    help="serve suite: comma-separated model-axis sizes R "
                         "to lower the paged decode step on (host devices "
                         "are forced, so any R works without hardware)")
    ap.add_argument("--lattice", default="8",
                    help="stencil suite: comma-separated local lattice "
                         "extents (local volume = L^3 x 12 components)")
    ap.add_argument("--halo-schedule", default="all",
                    help="stencil suite: comma-separated halo schedules, or "
                         "'all'")
    ap.add_argument("--halo", type=int, default=1,
                    help="stencil suite: face width (1 or 2)")
    ap.add_argument("--channels", type=int, default=2,
                    help="stencil suite: communicator virtual channels")
    ap.add_argument("--cg-iters", type=int, default=3,
                    help="stencil suite: unrolled CG iterations per cell")
    ap.add_argument("--solver", default="cg",
                    help="stencil suite: comma-separated solver variants "
                         "(cg,pipelined,sstep) or 'all' — the predicted "
                         "reduction-collective count drops from 2·iters+1 "
                         "to iters to ceil(iters/s) along that list")
    ap.add_argument("--precond", default="none",
                    help="stencil suite: comma-separated preconditioners "
                         "(none,eo) or 'all'")
    ap.add_argument("--sstep-s", type=int, default=4,
                    help="stencil suite: s-step block size (reductions per "
                         "solve = ceil(cg_iters/s))")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    tuned_db = None
    if args.tuned:
        from repro.tune.db import TuningDB

        tuned_db = TuningDB.load(args.tuned)
        print(f"[tuned] {args.tuned}: {len(tuned_db)} fitted record(s)")

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    cache: dict = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            cache = json.load(f)

    if args.suite in ("stencil", "mem", "serve", "moe"):
        if args.suite == "stencil":
            run_stencil_suite(args, meshes, cache)
        elif args.suite == "mem":
            run_mem_suite(args, cache, tuned_db=tuned_db)
        elif args.suite == "moe":
            run_moe_suite(args, cache)
        else:
            run_serve_suite(args, cache)
        n_ok = sum(1 for v in cache.values() if "error" not in v)
        n_err = sum(1 for v in cache.values() if "error" in v)
        print(f"done: {n_ok} ok, {n_err} failed -> {args.out}")
        return

    for arch in archs:
        cfg = get_config(arch)
        shapes = (applicable_shapes(cfg) if args.shape == "all"
                  else args.shape.split(","))
        for shape_name in shapes:
            if shape_name not in applicable_shapes(cfg):
                print(f"[skip] {arch} x {shape_name}: inapplicable "
                      f"(sub-quadratic rule, see DESIGN.md)")
                continue
            for multi in meshes:
                overrides = {"accum_microbatches": args.microbatches,
                             "accum_policy": args.accum_policy}
                key_over = dict(overrides)
                if tuned_db is not None:
                    # tuned pricing is part of the cell identity (key only:
                    # make_step_config must not see the marker)
                    key_over["tuned"] = os.path.basename(args.tuned)
                key = cell_key(args.tag, arch, shape_name,
                               "multi" if multi else "single", key_over)
                if key in cache and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[lower+compile] {key} ...", flush=True)
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape_name, multi,
                                   overrides=overrides, tuned_db=tuned_db)
                    rec["tag"] = args.tag
                    cache[key] = rec
                    r = rec["roofline"]
                    print(f"  ok in {time.time()-t0:.1f}s: "
                          f"bottleneck={r['bottleneck']} "
                          f"Tc={r['t_compute_s']:.4f}s Tm={r['t_memory_s']:.4f}s "
                          f"Tx={r['t_collective_s']:.4f}s "
                          f"Tx_exposed={r['t_exposed_collective_s']:.4f}s "
                          f"overlap={r['overlap_fraction']:.2f} "
                          f"live={rec['memory']['live_gb']:.2f}GB "
                          f"fits={rec['memory']['fits_16gb']}", flush=True)
                except Exception as e:
                    cache[key] = {"error": str(e), "tag": args.tag,
                                  "arch": arch, "shape": shape_name}
                    print(f"  FAILED: {e}")
                    traceback.print_exc()
                with open(args.out, "w") as f:
                    json.dump(cache, f, indent=1)
    n_ok = sum(1 for v in cache.values() if "error" not in v)
    n_err = sum(1 for v in cache.values() if "error" in v)
    print(f"done: {n_ok} ok, {n_err} failed -> {args.out}")


if __name__ == "__main__":
    main()

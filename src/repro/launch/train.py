"""Production train driver: ``--arch <id>`` selects an assigned architecture.

On real hardware this runs under the cluster launcher (one process per
host); on this container it runs reduced configs on host devices:

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 50
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.comm import CommConfig, SCHEDULE_POLICIES, list_transports
from repro.configs import get_config, list_archs, reduced_config
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.settings import settings_for
from repro.obs import ObsConfig
from repro.tune import resolve
from repro.models import build_model
from repro.optim import OptimConfig
from repro.runtime.train_loop import Trainer, TrainerConfig
from repro.runtime.train_step import DP_MODES, TrainStepConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (host execution)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--transport", default=None, choices=list_transports(),
                    help="repro.comm transport (default: the arch's setting)")
    ap.add_argument("--channels", type=int, default=None,
                    help="virtual comm rails (0 = unconstrained)")
    ap.add_argument("--dp-mode", default=None, choices=DP_MODES)
    ap.add_argument("--accum-policy", default=None, choices=SCHEDULE_POLICIES,
                    help="gradient-reduction issue schedule (default: "
                         "accumulate_then_reduce)")
    ap.add_argument("--use-arena", action="store_true",
                    help="reduce out of the page-aligned repro.mem "
                         "CommArena (fused spans, donated buffer)")
    ap.add_argument("--page-bytes", type=int, default=None,
                    help="arena page size (default 2 MiB)")
    ap.add_argument("--wire-codec", default=None, choices=["int8"],
                    help="quantize the gradient wire (int8 payload + "
                         "per-block scales, error feedback; with "
                         "--use-arena the fused pack+quantize path)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (needs 256 devices)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--tuned", default=None, metavar="DB",
                    help="tuning DB (repro.tune.probe output): resolve the "
                         "arch's 'auto' comm knobs — and any channels=0 — "
                         "to the DB's measured-best config before launch")
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="instrument the run: JSONL event stream + Chrome "
                         "trace under DIR (read with "
                         "python -m repro.obs.report DIR)")
    ap.add_argument("--obs-predict", action="store_true",
                    help="AOT-price the step (roofline; with --tuned, the "
                         "DB's measured alpha/beta) and track live "
                         "predicted-vs-measured drift")
    args = ap.parse_args()

    st = settings_for(args.arch)
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh())
    mesh_label = "x".join(str(d) for d in mesh.devices.shape)
    if args.tuned or resolve.has_auto(st):
        st, info = resolve.resolve_settings(st, args.arch,
                                            mesh_label=mesh_label,
                                            db_path=args.tuned)
        if info["source"] == "db":
            print(f"tuned: {info['key']} "
                  f"(alpha={info['alpha_s']*1e6:.2f}us "
                  f"bw={info['bandwidth']/1e9:.2f}GB/s) -> "
                  f"transport={st.transport} channels={st.channels} "
                  f"page_bytes={st.page_bytes}")
    print(f"arch={args.arch} params={model.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    schedule = "wsd" if args.arch == "minicpm-2b" else "cosine"
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    data = SyntheticTokens(DataConfig(vocab_size=model.cfg.vocab_size,
                                      seq_len=args.seq,
                                      global_batch=args.batch),
                           model_cfg=cfg)
    ccfg = st.comm_config(bucket_bytes=32 * 2**20)
    if args.transport:
        ccfg = dataclasses.replace(ccfg, transport=args.transport)
    if args.channels is not None:
        ccfg = dataclasses.replace(ccfg, channels=args.channels)
    if args.page_bytes is not None:
        ccfg = dataclasses.replace(ccfg, page_bytes=args.page_bytes)
    step_cfg = TrainStepConfig(
        dp_mode=args.dp_mode or (st.dp_mode if not args.reduced else "replicated"),
        comm=ccfg,
        optim=OptimConfig(base_lr=args.lr, warmup=min(20, args.steps // 5),
                          schedule=schedule, total_steps=args.steps),
        microbatches=1 if args.reduced else st.microbatches,
        schedule=args.accum_policy or "accumulate_then_reduce",
        use_arena=args.use_arena, wire_codec=args.wire_codec,
        moe_transport=st.moe_transport, moe_channels=st.moe_channels)
    obs_cfg = None
    if args.obs_dir or args.obs_predict:
        obs_cfg = ObsConfig(run_dir=args.obs_dir,
                            predict=args.obs_predict,
                            tuned_db=args.tuned if args.obs_predict else None)
    trainer = Trainer(model, mesh, step_cfg, data, shape,
                      TrainerConfig(steps=args.steps, ckpt_every=50,
                                    ckpt_dir=args.ckpt_dir, log_every=10,
                                    obs=obs_cfg))
    out = trainer.run()
    if obs_cfg is not None and out.get("obs", {}).get("events"):
        print(f"obs: events={out['obs']['events']} "
              f"trace={out['obs']['trace']}")


if __name__ == "__main__":
    main()

"""Serving driver: prefill+decode loop for an assigned architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
        --tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs, reduced_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.settings import settings_for
from repro.models import build_model
from repro.models.transformer import init_decode_state
from repro.runtime.serve_step import build_decode_step
from repro.sharding import shardings_of


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache", type=int, default=512)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("enc-dec serving demo: use examples/serve_lm.py "
                         "patterns with encdec.init_decode_state")
    model = build_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    shape = ShapeConfig("serve", args.cache, args.batch, "decode")
    wm = settings_for(args.arch).serve_weights if not args.reduced else "resident"
    step, pspecs, sspecs = build_decode_step(model, mesh, shape,
                                             weight_mode=wm)
    params = model.init(jax.random.key(0))
    with mesh:
        params = jax.jit(lambda p: p,
                         out_shardings=shardings_of(pspecs, mesh))(params)
        state = init_decode_state(model.cfg, args.batch, args.cache)
        state = jax.jit(lambda s: s,
                        out_shardings=shardings_of(sspecs, mesh))(state)
    token = jnp.zeros((args.batch,), jnp.int32)
    t0 = time.time()
    for pos in range(args.tokens):
        with mesh:
            logits, state = step(params, token, state, jnp.asarray(pos))
        token = jnp.clip(jnp.argmax(logits, -1).astype(jnp.int32), 0,
                         model.cfg.vocab_size - 1)
    dt = time.time() - t0
    print(f"{args.arch}: {args.tokens * args.batch / dt:.1f} tok/s "
          f"(batch {args.batch}, cache {args.cache})")


if __name__ == "__main__":
    main()

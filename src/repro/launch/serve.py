"""Serving driver: prefill+decode loop for an assigned architecture.

Two paths:

* default — the contiguous-cache decode loop over ``build_decode_step``
  (resident or gathered weights, production mesh optional)::

      PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
          --reduced --tokens 16

* ``--paged`` — the ``repro.serve`` stack: paged KV arena + continuous
  batching scheduler + flash-decode attention, driven over a mixed-length
  synthetic trace.  ``--policy both`` runs the continuous-vs-static A/B
  the paper-style acceptance bar measures::

      PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
          --reduced --paged --policy both
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import get_config, list_archs, reduced_config
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.settings import settings_for
from repro.models import build_model
from repro.models.transformer import init_decode_state
from repro.runtime.serve_step import build_decode_step
from repro.sharding import shardings_of


def run_paged(args) -> None:
    from repro.serve.engine import (PagedDecodeEngine,
                                    predicted_collectives_per_token,
                                    predicted_wire_bytes_per_token)
    from repro.serve.kv import plan_kv_arena
    from repro.serve.scheduler import ServeScheduler, mixed_trace

    from repro.obs import ObsConfig, make_obs

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    r = args.model_parallel
    if r > len(jax.devices()):
        raise SystemExit(f"--model-parallel {r} needs {r} devices, have "
                         f"{len(jax.devices())}")
    mesh = compat.make_mesh((1, r), ("data", "model"),
                            devices=jax.devices()[:r])
    longest = args.prompt_len + max(args.long_len, args.short_len)
    plan = plan_kv_arena(cfg, mesh, page_tokens=args.page_tokens,
                         max_seqs=args.slots, max_seq_len=longest)
    obs = make_obs(ObsConfig(run_dir=args.obs_dir)
                   if args.obs_dir else None)
    engine = PagedDecodeEngine(model, mesh, plan, attn_impl=args.attn_impl,
                               obs=obs)
    params = model.init(jax.random.key(0))
    trace = mixed_trace(groups=args.groups, slots=args.slots,
                        long_len=args.long_len, short_len=args.short_len,
                        prompt_len=args.prompt_len)
    print(f"{args.arch}: paged serve, {len(trace)} requests, "
          f"{plan.n_kv_pages} KV pages ({plan.total_bytes} B arena), "
          f"page_tokens={plan.page_tokens}, R={r} "
          f"({predicted_collectives_per_token(plan)} collectives/token, "
          f"{predicted_wire_bytes_per_token(plan, cfg, plan.max_seqs):.0f} "
          f"wire B/token)")
    policies = (["continuous", "static"] if args.policy == "both"
                else [args.policy])
    results = {}
    for policy in policies:
        sched = ServeScheduler(engine, policy)
        t0 = time.time()
        res = sched.run(params, list(trace))
        res["wall_s"] = time.time() - t0
        res["tokens_per_s"] = res["generated_tokens"] / res["wall_s"]
        results[policy] = res
        print(f"  {policy:10s}: {res['steps']} steps, "
              f"{res['generated_tokens']} tokens, "
              f"{res['tokens_per_step']:.3f} tok/step, "
              f"{res['tokens_per_s']:.1f} tok/s, "
              f"mean live slots {res['mean_live_slots']:.2f}")
    if len(results) == 2:
        ratio = (results["continuous"]["tokens_per_step"]
                 / results["static"]["tokens_per_step"])
        print(f"  continuous / static throughput: {ratio:.2f}x")
    paths = obs.finish()
    if paths and paths.get("events"):
        print(f"  obs: events={paths['events']} trace={paths['trace']}")


def run_contiguous(args) -> None:
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("enc-dec serving demo: use examples/serve_lm.py "
                         "patterns with encdec.init_decode_state")
    model = build_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    shape = ShapeConfig("serve", args.cache, args.batch, "decode")
    wm = settings_for(args.arch).serve_weights if not args.reduced else "resident"
    step, pspecs, sspecs = build_decode_step(model, mesh, shape,
                                             weight_mode=wm)
    params = model.init(jax.random.key(0))
    with mesh:
        params = jax.jit(lambda p: p,
                         out_shardings=shardings_of(pspecs, mesh))(params)
        state = init_decode_state(model.cfg, args.batch, args.cache)
        state = jax.jit(lambda s: s,
                        out_shardings=shardings_of(sspecs, mesh))(state)
    token = jnp.zeros((args.batch,), jnp.int32)
    t0 = time.time()
    for pos in range(args.tokens):
        with mesh:
            logits, state = step(params, token, state, jnp.asarray(pos))
        token = jnp.clip(jnp.argmax(logits, -1).astype(jnp.int32), 0,
                         model.cfg.vocab_size - 1)
    dt = time.time() - t0
    print(f"{args.arch}: {args.tokens * args.batch / dt:.1f} tok/s "
          f"(batch {args.batch}, cache {args.cache})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache", type=int, default=512)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the repro.serve paged KV engine + "
                         "continuous batching scheduler instead of the "
                         "contiguous-cache loop")
    ap.add_argument("--policy", default="continuous",
                    choices=["continuous", "static", "both"],
                    help="paged: batching policy ('both' prints the A/B "
                         "throughput ratio)")
    ap.add_argument("--attn-impl", default="kernel",
                    choices=["kernel", "ref"],
                    help="paged: score pages with the Pallas flash-decode "
                         "kernel or the jnp oracle")
    ap.add_argument("--page-tokens", type=int, default=16,
                    help="paged: token positions per KV page")
    ap.add_argument("--slots", type=int, default=4,
                    help="paged: concurrent sequence slots")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="paged: model-axis size (page-parallel decode + "
                         "LSE all-reduce)")
    ap.add_argument("--groups", type=int, default=4,
                    help="paged: mixed-trace groups (1 long + slots-1 "
                         "short requests each)")
    ap.add_argument("--long-len", type=int, default=64)
    ap.add_argument("--short-len", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=1)
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="paged: instrument the run (JSONL events + Chrome "
                         "trace under DIR)")
    args = ap.parse_args()

    if args.paged:
        run_paged(args)
    else:
        run_contiguous(args)


if __name__ == "__main__":
    main()

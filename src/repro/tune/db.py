"""The tuning database: fitted α/bandwidth records persisted as JSON.

Keyed like the dry-run cache — every knob that changes what was measured is
part of the record identity::

    tune|<arch>|<mesh>|<transport>|ch<channels>|p<page_bytes>[|ov[...]]

with the same order-insensitive overrides fingerprint the dry-run cache
uses (the canonical implementation lives here; ``repro.launch.dryrun``
re-exports it — it cannot be imported the other way because the dry-run
module sets ``XLA_FLAGS`` at import time).

A record stores the fitted constants plus everything needed to (a) rebuild
a :class:`~repro.comm.plan.LatencyModel` (``LatencyModel.from_record``),
(b) report fit quality as the dry-run's per-cell ``model_error``, and
(c) rank configs for ``"auto"`` resolution: ``messages_ref`` (the hop
count of the largest probe cell — size-invariant for ring schedules) and
``wire_factor`` (wire bytes per payload byte, page padding and codec
included) let :meth:`TuningDB.best_config` price any reference payload
under each candidate's *measured* constants.
"""

from __future__ import annotations

import json
import os
import time
from typing import Iterable, Mapping

from repro.tune.fit import FitResult

DB_VERSION = 1
DEFAULT_DB_PATH = "experiments/tuning.json"

# arch the probe runner records when not calibrating for a specific model's
# gradient tree; resolution falls back to it when the exact arch is missing
GENERIC_ARCH = "generic"


def overrides_fingerprint(overrides: dict | None) -> str:
    """Deterministic, order-insensitive fingerprint of a cell's overrides.

    Shared with the dry-run cache key (:func:`repro.launch.dryrun.cell_key`)
    so both stores agree on what makes two measurements "the same cell"."""
    if not overrides:
        return ""
    items = sorted((str(k), json.dumps(v, sort_keys=True, default=str))
                   for k, v in overrides.items())
    return ",".join(f"{k}={v}" for k, v in items)


def tune_key(arch: str, mesh: str, transport: str, channels: int,
             page_bytes: int, overrides: dict | None = None) -> str:
    """DB key of one fitted probe group."""
    base = f"tune|{arch}|{mesh}|{transport}|ch{int(channels)}|p{int(page_bytes)}"
    fp = overrides_fingerprint(overrides)
    return f"{base}|ov[{fp}]" if fp else base


class TuningDB:
    """JSON-persisted map of tune keys → fitted records."""

    def __init__(self, records: dict | None = None, path: str | None = None):
        self.records: dict[str, dict] = dict(records or {})
        self.path = path

    # -- persistence ---------------------------------------------------------

    @classmethod
    def load(cls, path: str) -> "TuningDB":
        """Load a DB file; a missing path yields an empty DB bound to it."""
        if not os.path.exists(path):
            return cls(path=path)
        with open(path) as f:
            data = json.load(f)
        if not isinstance(data, dict) or "records" not in data:
            raise ValueError(f"{path} is not a tuning DB "
                             f"(expected {{'version', 'records'}})")
        return cls(records=data["records"], path=path)

    def save(self, path: str | None = None) -> str:
        path = path or self.path
        if path is None:
            raise ValueError("no path bound to this TuningDB")
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({"version": DB_VERSION, "records": self.records},
                      f, indent=1, sort_keys=True)
        self.path = path
        return path

    # -- writing -------------------------------------------------------------

    def put_fit(self, *, arch: str, mesh: str, transport: str, channels: int,
                page_bytes: int, fit: FitResult,
                cells: Iterable | None = None,
                overrides: dict | None = None) -> str:
        """Store one fitted probe group; returns its key."""
        key = tune_key(arch, mesh, transport, channels, page_bytes, overrides)
        cells = list(cells or [])
        rec = {
            "arch": arch, "mesh": mesh, "transport": transport,
            "channels": int(channels), "page_bytes": int(page_bytes),
            "overrides": overrides_fingerprint(overrides),
            "fit": fit.as_dict(),
            "created": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        if cells:
            ref = max(cells, key=lambda c: c.nbytes)
            payload = max(ref.elems * 4.0, 1.0)
            rec["cells"] = [c.as_dict() for c in cells]
            rec["messages_ref"] = float(ref.messages)
            rec["wire_factor"] = float(ref.nbytes) / payload
        self.records[key] = rec
        return key

    # -- reading -------------------------------------------------------------

    def get(self, arch: str, mesh: str, transport: str, channels: int,
            page_bytes: int, overrides: dict | None = None) -> dict | None:
        return self.records.get(
            tune_key(arch, mesh, transport, channels, page_bytes, overrides))

    def lookup(self, *, transport: str | None = None, arch: str | None = None,
               mesh: str | None = None, channels: int | None = None,
               page_bytes: int | None = None) -> tuple[str, dict] | None:
        """Most-specific record match.

        ``transport`` (when given) is a hard requirement — fitted constants
        from one schedule do not transfer to another.  The soft dimensions
        score exact matches highest, the :data:`GENERIC_ARCH` fallback next,
        and any-value last, so a cell always gets the closest calibration
        available (a probe run on the 2×4 host mesh still prices a 16×16
        cell when nothing closer exists)."""
        best: tuple[int, str, dict] | None = None
        for key, rec in self.records.items():
            if transport is not None and rec.get("transport") != transport:
                continue
            score = 0
            if arch is not None:
                if rec.get("arch") == arch:
                    score += 8
                elif rec.get("arch") == GENERIC_ARCH:
                    score += 4
            if mesh is not None and rec.get("mesh") == mesh:
                score += 2
            if channels is not None and rec.get("channels") == channels:
                score += 2
            if page_bytes is not None and rec.get("page_bytes") == page_bytes:
                score += 1
            if best is None or (score, key) > (best[0], best[1]):
                best = (score, key, rec)
        return (best[1], best[2]) if best is not None else None

    def matching(self, *, arch: str | None = None, mesh: str | None = None
                 ) -> list[tuple[str, dict]]:
        """Records usable for (arch, mesh): exact arch or the generic
        fallback; any mesh (exact matches sort first)."""
        out = []
        for key, rec in self.records.items():
            if arch is not None and rec.get("arch") not in (arch,
                                                            GENERIC_ARCH):
                continue
            exact_mesh = mesh is None or rec.get("mesh") == mesh
            out.append((not exact_mesh, key, rec))
        out.sort(key=lambda x: (x[0], x[1]))
        # keep only the best mesh tier available
        if out and not out[0][0]:
            out = [o for o in out if not o[0]]
        return [(key, rec) for _, key, rec in out]

    def best_config(self, *, arch: str | None = None, mesh: str | None = None,
                    transport: str | None = None,
                    ref_bytes: float = 256 * 2**20) -> dict | None:
        """The measured-best (transport, channels, page_bytes) for a
        reference gradient payload of ``ref_bytes``: each candidate record
        is priced at its *fitted* constants,

            t = α·messages_ref + ref_bytes · wire_factor / bandwidth

        (``messages_ref`` is size-invariant for ring schedules; the wire
        factor carries page padding and codec overhead), and the cheapest
        wins.  ``transport`` (when given) restricts the candidates — used
        when the transport is pinned and only channels/page are ``"auto"``.
        Returns ``None`` when no record matches."""
        best = None
        for key, rec in self.matching(arch=arch, mesh=mesh):
            fit = rec.get("fit", {})
            if "messages_ref" not in rec or not fit:
                continue
            if transport is not None and rec.get("transport") != transport:
                continue
            t = (fit["alpha_s"] * rec["messages_ref"]
                 + ref_bytes * rec.get("wire_factor", 1.0)
                 / max(fit["bandwidth"], 1.0))
            if best is None or t < best["t_ref_s"]:
                best = {"transport": rec["transport"],
                        "channels": rec["channels"],
                        "page_bytes": rec["page_bytes"],
                        "t_ref_s": t, "key": key,
                        "alpha_s": fit["alpha_s"],
                        "bandwidth": fit["bandwidth"]}
        return best

    # -- convenience ---------------------------------------------------------

    def __len__(self) -> int:
        return len(self.records)

    def fit_for(self, key: str) -> FitResult:
        return FitResult.from_dict(self.records[key]["fit"])


def model_error_summary(record: Mapping) -> dict:
    """The ``model_error`` block ``dryrun --tuned`` attaches per cell: how
    far the fitted model's predictions sat from the probe measurements."""
    fit = record.get("fit", record)
    return {
        "mean_rel_err": float(fit["mean_rel_err"]),
        "max_rel_err": float(fit["max_rel_err"]),
        "rms_residual_s": float(fit["rms_residual_s"]),
        "n_cells": int(fit["n_cells"]),
    }

""""auto" resolution: turn measured tuning records into launch settings.

:class:`~repro.launch.settings.ArchSettings` accepts three tunable
sentinels — ``transport="auto"``, ``page_bytes="auto"`` (hard: the user
asked for the measured best, so an empty DB falls back to today's defaults
*with a warning*) and ``channels=0`` (soft: 0 already means
"scheduler-unconstrained" throughout the stack, so it is only upgraded
when a measured record exists and stays 0 silently otherwise).

Resolution ranks the DB's records for (arch, mesh) by
:meth:`~repro.tune.db.TuningDB.best_config` — each candidate priced under
its own *fitted* α/bandwidth — honouring any pinned dimension (a pinned
transport restricts the candidates to records of that transport).  This
module deliberately imports nothing heavier than :mod:`repro.tune.db`, so
``repro.launch.settings`` can call it without dragging jax in.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import TYPE_CHECKING

from repro.tune.db import DEFAULT_DB_PATH, TuningDB

if TYPE_CHECKING:  # pragma: no cover
    from repro.launch.settings import ArchSettings

# today's hand-pinned defaults — what an unresolvable "auto" falls back to
FALLBACK_TRANSPORT = "ring_hier"
FALLBACK_PAGE_BYTES = 2 * 2**20      # the paper's huge page


def has_auto(st: "ArchSettings") -> bool:
    """Any tunable sentinel present (hard or soft)?"""
    return (st.transport == "auto" or st.page_bytes == "auto"
            or st.channels == 0)


def resolve_settings(st: "ArchSettings", arch: str, *,
                     mesh_label: str | None = None,
                     db: TuningDB | None = None,
                     db_path: str | None = None,
                     ref_bytes: float = 256 * 2**20
                     ) -> tuple["ArchSettings", dict]:
    """Resolve ``st``'s ``"auto"`` knobs from the tuning DB.

    Returns ``(settings, info)`` where ``info`` records what happened:
    ``source`` is ``"unchanged"`` (nothing to resolve), ``"db"`` (resolved
    from a measured record; ``key``/``t_ref_s``/``alpha_s``/``bandwidth``
    carry the winning record) or ``"fallback"`` (a *hard* sentinel had no
    matching record — defaults substituted, warning emitted).
    """
    if not has_auto(st):
        return st, {"source": "unchanged"}
    if db is None:
        db = TuningDB.load(db_path or DEFAULT_DB_PATH)

    pinned = st.transport if st.transport != "auto" else None
    best = db.best_config(arch=arch, mesh=mesh_label, transport=pinned,
                          ref_bytes=ref_bytes)
    if best is not None:
        resolved = dataclasses.replace(
            st,
            transport=(best["transport"] if st.transport == "auto"
                       else st.transport),
            channels=(best["channels"] if st.channels == 0
                      else st.channels),
            page_bytes=(best["page_bytes"] if st.page_bytes == "auto"
                        else st.page_bytes))
        info = {"source": "db", "key": best["key"],
                "t_ref_s": best["t_ref_s"], "alpha_s": best["alpha_s"],
                "bandwidth": best["bandwidth"]}
        return resolved, info

    hard = [k for k, is_auto in (("transport", st.transport == "auto"),
                                 ("page_bytes", st.page_bytes == "auto"))
            if is_auto]
    if hard:
        warnings.warn(
            f"no tuning-DB record matches arch={arch!r} "
            f"mesh={mesh_label!r} transport={pinned or 'any'!r} "
            f"(db={db.path or '<memory>'}); falling back to defaults for "
            f"{', '.join(hard)} — run `python -m repro.tune.probe --out "
            f"{db.path or DEFAULT_DB_PATH}` to calibrate", stacklevel=2)
    resolved = dataclasses.replace(
        st,
        transport=(FALLBACK_TRANSPORT if st.transport == "auto"
                   else st.transport),
        page_bytes=(FALLBACK_PAGE_BYTES if st.page_bytes == "auto"
                    else st.page_bytes))
    # channels==0 is soft: it already means "unconstrained", keep it
    return resolved, {"source": "fallback", "hard": hard}

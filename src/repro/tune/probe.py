"""The probe runner: drive the benches as a calibration matrix.

Measured mode (the default) re-uses ``benchmarks/common.py``'s subprocess
harness to run cut-down versions of the existing benches — ``allreduce``
(bucketized gradient reduction on the 2×4 pod/data mesh), ``arena`` (the
fused CommArena path, where the page size actually moves bytes), ``halo``
(the 2×2×2 Cartesian exchange) and ``cg`` (a full solve: reductions +
exchanges) — over the requested transport × channels × page_bytes ×
message-size grid.  Every timed cell prints one ``CELL {json}`` line
carrying the *predicted* message count and wire bytes (straight from
``comm.plan`` / ``comm.halo_plan``, the same numbers the dry-run prices
with) next to the *measured* seconds and dispersion; the fitter then
recovers measured α/bandwidth per (transport, channels, page_bytes) group
and the residuals say how far the model sits from the machine.

``--dry`` mode needs no devices at all: cells are synthesized in pure
Python from the transports' own ``predicted_messages/bytes_per_device``
and a planted :class:`~repro.comm.plan.LatencyModel`, so CI can assert the
whole probe → fit → DB → ``dryrun --tuned`` loop recovers the planted
constants to <1%.

CLI::

    python -m repro.tune.probe --out experiments/tuning.json \
        --benches allreduce arena --transports ring_hier psum \
        --channels 1 2 4 --page-bytes 4096 2097152
    python -m repro.tune.probe --dry --out /tmp/tuning.json   # CI smoke
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
from dataclasses import asdict, dataclass
from typing import Iterable, Mapping, Sequence

from repro.tune.db import GENERIC_ARCH, TuningDB
from repro.tune.fit import FitResult, fit_cells

BENCHES = ("allreduce", "arena", "halo", "cg")


@dataclass(frozen=True)
class ProbeCell:
    """One timed (or synthesized) probe point.

    ``messages``/``nbytes`` are the *model's* per-device predictions for
    this cell (plan-level, the dry-run's own numbers); ``seconds`` is the
    measured median with ``t_min``/``t_max`` the min/max over the timed
    iterations — the dispersion the fitter weights by.
    """

    bench: str
    arch: str
    mesh: str                   # mesh label, e.g. "2x4" or "2x2x2"
    transport: str
    channels: int
    page_bytes: int
    elems: int                  # payload elements (fp32 words)
    messages: float             # predicted discrete sends / device
    nbytes: float               # predicted wire bytes / device
    seconds: float              # measured median seconds per call
    t_min: float
    t_max: float

    @property
    def spread(self) -> float:
        return float(self.t_max) - float(self.t_min)

    def as_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "ProbeCell":
        return cls(**{f: d[f] for f in cls.__dataclass_fields__})


def group_cells(cells: Iterable[ProbeCell]
                ) -> dict[tuple[str, int, int], list[ProbeCell]]:
    """Fit groups: one (transport, channels, page_bytes) per DB record."""
    groups: dict[tuple[str, int, int], list[ProbeCell]] = {}
    for c in cells:
        groups.setdefault((c.transport, c.channels, c.page_bytes),
                          []).append(c)
    return groups


def parse_cells(output: str) -> list[ProbeCell]:
    """Collect the ``CELL {json}`` lines a probe subprocess printed."""
    cells = []
    for line in output.splitlines():
        if line.startswith("CELL "):
            cells.append(ProbeCell.from_dict(json.loads(line[5:])))
    return cells


def _page_padded_elems(elems: int, page_bytes: int) -> int:
    """fp32 payload elements after page-granular arena padding."""
    nbytes = max(int(elems), 1) * 4
    page = max(int(page_bytes), 4)
    return (nbytes + page - 1) // page * page // 4


# ---------------------------------------------------------------------------
# dry mode: pure-python synthesis with planted constants
# ---------------------------------------------------------------------------


def synthesize_cells(*, transports: Sequence[str] = ("psum",),
                     channels: Sequence[int] = (2,),
                     pages: Sequence[int] = (4096,),
                     sizes: Sequence[int] = (1 << 12, 1 << 16),
                     mesh: Sequence[int] = (2, 4),
                     axes: Sequence[str] = ("pod", "data"),
                     arch: str = GENERIC_ARCH,
                     alpha_s: float | None = None,
                     bandwidth: float | None = None) -> list[ProbeCell]:
    """Synthetic probe matrix: message/byte predictions from the real
    transport classes, timings from a planted α/bandwidth model.

    Needs no mesh devices (the transports' ``predicted_*`` methods are pure
    Python), so this runs in-process — it is both the CI smoke for the
    probe → fit → DB loop and the regression oracle that the fitter
    recovers planted constants to <1% (tests/test_tune.py).
    """
    from repro.comm.plan import ALPHA_S, LINK_BANDWIDTH, LatencyModel
    from repro.comm.registry import get_transport
    from repro.core.ring import RingConfig

    model = LatencyModel(alpha_s=ALPHA_S if alpha_s is None else alpha_s,
                         bandwidth=(LINK_BANDWIDTH if bandwidth is None
                                    else bandwidth))
    axis_sizes = tuple(int(d) for d in mesh)
    mesh_label = "x".join(str(d) for d in axis_sizes)
    cells = []
    for tname in transports:
        _, cls = get_transport(tname)
        tr = cls(tuple(axes)[:len(axis_sizes)] or ("data",),
                 RingConfig(chunks=2))
        for ch in channels:
            for page in pages:
                for elems in sizes:
                    padded = _page_padded_elems(elems, page)
                    msgs = tr.predicted_messages_per_device(axis_sizes)
                    nb = tr.predicted_bytes_per_device(padded, axis_sizes)
                    sec = model.collective_seconds(msgs, nb)
                    cells.append(ProbeCell(
                        bench="synthetic", arch=arch, mesh=mesh_label,
                        transport=tname, channels=int(ch),
                        page_bytes=int(page), elems=int(elems),
                        messages=float(msgs), nbytes=float(nb),
                        seconds=float(sec), t_min=float(sec),
                        t_max=float(sec)))
    return cells


# ---------------------------------------------------------------------------
# measured mode: subprocess scripts per bench
# ---------------------------------------------------------------------------

# Each template gets CFG (a dict) injected as JSON and prints one
# ``CELL {json}`` line per timed point.  The predicted messages/bytes come
# from the same plan objects the dry-run prices with, so the fit residual
# really is model-vs-machine.  __CELL_HELPERS__ provides emit()/timing().

_CELL_HELPERS = r"""
import json as _json

def _timing(t):
    lo = float(getattr(t, "t_min", t)); hi = float(getattr(t, "t_max", t))
    return float(t), lo, hi

def emit(**kw):
    sec, lo, hi = _timing(kw.pop("t"))
    kw.update(seconds=sec, t_min=lo, t_max=hi)
    print("CELL " + _json.dumps(kw), flush=True)

CFG = _json.loads('__CFG_JSON__')
"""

_ALLREDUCE_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator

shape = tuple(CFG["mesh"])
axes = ("pod", "data")[:len(shape)] if len(shape) <= 2 else \
    tuple(f"d{i}" for i in range(len(shape)))
mesh = compat.make_mesh(shape, axes)
mesh_label = "x".join(str(d) for d in shape)
rng = np.random.RandomState(0)

def workload(total):
    k = int(min(16, max(1, total // 4096)))
    sizes = np.full(k, total // k); sizes[0] += total - sizes.sum()
    return {f"g{i}": jnp.asarray(rng.randn(int(s)).astype(np.float32))
            for i, s in enumerate(sizes)}

for transport in CFG["transports"]:
    for ch in CFG["channels"]:
        for total in CFG["sizes"]:
            tree = workload(total)
            specs = {k: P() for k in tree}
            comm = Communicator(mesh, CommConfig(
                transport=transport, chunks=2, channels=ch,
                bucket_bytes=CFG["bucket_bytes"],
                page_bytes=CFG["pages"][0], data_axes=axes))
            plan = comm.plan(tree)
            fn = jax.jit(lambda g: comm.reduce(g, specs)[0])
            t = time_call(fn, tree, warmup=CFG["warmup"],
                          iters=CFG["iters"])
            emit(bench="allreduce", arch=CFG["arch"], mesh=mesh_label,
                 transport=transport, channels=ch,
                 page_bytes=CFG["pages"][0], elems=int(total),
                 messages=plan.messages_per_device,
                 nbytes=plan.bytes_per_device, t=t)
"""

_ARENA_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator

n_dev = len(jax.devices())
mesh = compat.make_mesh((n_dev,), ("data",))
rng = np.random.RandomState(0)
batch = jnp.asarray(rng.randn(16, 8).astype(np.float32))

def loss_fn(p, x):
    return sum(jnp.sum(v) for v in p.values()) * 1e-3 + jnp.mean(x) * 0.0

def grad_fn(p, mb):
    return jax.value_and_grad(loss_fn)(p, mb)

transport = CFG["transports"][0]
for page_bytes in CFG["pages"]:
    for ch in CFG["channels"]:
        for total in CFG["sizes"]:
            k = max(4, min(16, total // 4096))
            leaf = max(total // k, 64)
            params = {f"g{i}": jnp.asarray(
                rng.randn(leaf).astype(np.float32)) for i in range(k)}
            comm = Communicator(mesh, CommConfig(
                transport=transport, chunks=2, channels=ch,
                bucket_bytes=4 * leaf, page_bytes=page_bytes,
                data_axes=("data",)))
            plan = comm.plan(params)
            asched = comm.arena_schedule(params, "scheduled", 1)
            arena = comm.arena(params)
            lay = arena.layout

            def arena_run(p, b, buf):
                loss, (tree, out) = comm.reduce_scheduled(
                    grad_fn, p, b, asched, op="all_reduce", arena=arena,
                    arena_buf=buf)
                return loss, tree, out

            fa = jax.jit(compat.shard_map(
                arena_run, mesh=mesh,
                in_specs=(P(), P("data"), P(("data",))),
                out_specs=(P(), P(), P(("data",))), check_vma=False),
                donate_argnums=(2,))
            state = {"buf": jnp.zeros((n_dev * lay.total_elems,),
                                      jnp.float32)}
            def arena_call(p, b):
                loss, tree, out = fa(p, b, state["buf"])
                state["buf"] = out
                return loss
            t = time_call(arena_call, params, batch,
                          warmup=CFG["warmup"], iters=CFG["iters"])
            emit(bench="arena", arch=CFG["arch"], mesh=str(n_dev),
                 transport=transport, channels=ch, page_bytes=page_bytes,
                 elems=int(k * leaf),
                 messages=plan.arena_messages_per_device,
                 nbytes=plan.arena_bytes_per_device, t=t)
"""

_HALO_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator
from repro.core.halo import HaloSpec

mesh = compat.make_mesh((2, 2, 2), ("x", "y", "z"))
SPECS = [HaloSpec("x", 0), HaloSpec("y", 1), HaloSpec("z", 2)]
transport = CFG["transports"][0]
for ch in CFG["channels"]:
    comm = Communicator(mesh, CommConfig(
        transport=transport, data_axes=("x", "y", "z"), channels=ch))
    for total in CFG["sizes"]:
        L = max(4, int(round((total / 16) ** (1.0 / 3.0))))
        local = (L, L, L, 16)
        x = jnp.ones((2 * L, 2 * L, 2 * L, 16), jnp.float32)
        plan = comm.halo_plan(local, SPECS, schedule="concurrent")
        def fn(xl):
            h = comm.halo_exchange(xl, SPECS, schedule="concurrent")
            return sum(v.sum() for v in h.values())
        g = jax.jit(compat.shard_map(fn, mesh=mesh,
                                     in_specs=P("x", "y", "z", None),
                                     out_specs=P(), check_vma=False))
        t = time_call(g, x, warmup=CFG["warmup"], iters=CFG["iters"])
        emit(bench="halo", arch=CFG["arch"], mesh="2x2x2",
             transport=transport, channels=ch,
             page_bytes=CFG["pages"][0],
             elems=int(np.prod(local)),
             messages=plan.messages_per_device,
             nbytes=plan.bytes_per_device, t=t)
"""

_CG_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.comm import CommConfig, Communicator
from repro.core.halo import HaloSpec
from repro.stencil import (StencilOp, predicted_halo_exchanges,
                           predicted_reduction_collectives, solve)

mesh = compat.make_mesh((2, 2, 2), ("x", "y", "z"))
WORLD = 8
SPECS = (HaloSpec("x", 0), HaloSpec("y", 1), HaloSpec("z", 2))
op = StencilOp(specs=SPECS, mass=0.5)
rng = np.random.RandomState(0)
transport = CFG["transports"][0]
for ch in CFG["channels"]:
    comm = Communicator(mesh, CommConfig(
        transport=transport, data_axes=("x", "y", "z"), channels=ch))
    for total in CFG["sizes"]:
        L = max(4, int(round((total / 16) ** (1.0 / 3.0))))
        local = (L, L, L, 16)
        b = jnp.asarray(rng.randn(2*L, 2*L, 2*L, 16).astype(np.float32))
        def run(bl):
            r = solve(op, bl, comm, solver="cg", precond="none",
                      tol=1e-5, maxiter=CFG["cg_iters"],
                      schedule="concurrent", chunks=comm.halo_chunks,
                      channels=ch)
            return r.x, r.iters, r.rel_residual
        fn = jax.jit(compat.shard_map(
            run, mesh=mesh, in_specs=P("x", "y", "z", None),
            out_specs=(P("x", "y", "z", None), P(), P()),
            check_vma=False))
        x, iters, rel = jax.block_until_ready(fn(b))
        iters = int(iters)
        hplan = comm.halo_plan(local, SPECS, schedule="concurrent")
        reds = predicted_reduction_collectives("cg", iters)
        exch = predicted_halo_exchanges("cg", "none", iters)
        msgs = (reds * 2 * (WORLD - 1)
                + exch * hplan.messages_per_device)
        nb = (reds * 2 * (WORLD - 1) / WORLD * 8.0
              + exch * hplan.bytes_per_device)
        t = time_call(fn, b, warmup=CFG["warmup"], iters=CFG["iters"])
        emit(bench="cg", arch=CFG["arch"], mesh="2x2x2",
             transport=transport, channels=ch,
             page_bytes=CFG["pages"][0], elems=int(np.prod(local)),
             messages=msgs, nbytes=nb, t=t)
"""

_SCRIPTS = {"allreduce": _ALLREDUCE_SCRIPT, "arena": _ARENA_SCRIPT,
            "halo": _HALO_SCRIPT, "cg": _CG_SCRIPT}


def _bench_harness():
    """Import ``benchmarks.common`` (not an installed package — it lives in
    the repo's ``benchmarks/`` directory next to ``src/``)."""
    try:
        from benchmarks import common  # repo root on sys.path
        return common
    except ImportError:
        repo = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        if repo not in sys.path:
            sys.path.insert(0, repo)
        from benchmarks import common
        return common


def probe_script(bench: str, cfg: Mapping) -> str:
    """The full subprocess source for one bench's probe sweep."""
    common = _bench_harness()
    if bench not in _SCRIPTS:
        raise ValueError(f"unknown bench {bench!r}; one of {BENCHES}")
    helpers = _CELL_HELPERS.replace("__CFG_JSON__", json.dumps(dict(cfg)))
    return common.TIMER_SNIPPET + helpers + _SCRIPTS[bench]


def run_probe(*, benches: Sequence[str] = ("allreduce",),
              transports: Sequence[str] = ("ring_hier", "psum"),
              channels: Sequence[int] = (1, 2),
              pages: Sequence[int] = (4096, 2 * 2**20),
              sizes: Sequence[int] = (1 << 14, 1 << 18),
              mesh: Sequence[int] = (2, 4),
              arch: str = GENERIC_ARCH,
              bucket_bytes: int = 1 << 20,
              warmup: int = 1, iters: int = 5,
              cg_iters: int = 8,
              n_devices: int | None = None) -> list[ProbeCell]:
    """Measured calibration matrix: one subprocess per bench, all cells
    parsed back as :class:`ProbeCell` records."""
    common = _bench_harness()
    n_dev = n_devices or max(int(math.prod(mesh)), 8)
    cfg = {"transports": list(transports), "channels": list(channels),
           "pages": [int(p) for p in pages],
           "sizes": [int(s) for s in sizes], "mesh": list(mesh),
           "arch": arch, "bucket_bytes": int(bucket_bytes),
           "warmup": int(warmup), "iters": int(iters),
           "cg_iters": int(cg_iters)}
    cells: list[ProbeCell] = []
    for bench in benches:
        out = common.run_on_devices(probe_script(bench, cfg),
                                    n_devices=n_dev)
        cells.extend(parse_cells(out))
    return cells


# ---------------------------------------------------------------------------
# fit + persist
# ---------------------------------------------------------------------------


def fit_and_store(cells: Sequence[ProbeCell], db: TuningDB
                  ) -> dict[str, FitResult]:
    """Fit every (transport, channels, page_bytes) group and store the
    records under each group's (arch, mesh) — returns key → fit."""
    fits: dict[str, FitResult] = {}
    for (transport, ch, page), group in sorted(group_cells(cells).items()):
        fit = fit_cells(group)
        key = db.put_fit(arch=group[0].arch, mesh=group[0].mesh,
                         transport=transport, channels=ch, page_bytes=page,
                         fit=fit, cells=group)
        fits[key] = fit
    return fits


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="probe the comm substrate and fit measured α/bandwidth")
    ap.add_argument("--dry", action="store_true",
                    help="synthesize cells in pure python (CI smoke; "
                    "plants --plant-alpha/--plant-bandwidth)")
    ap.add_argument("--out", default=None,
                    help="tuning DB path to merge fits into")
    ap.add_argument("--benches", nargs="+", default=["allreduce"],
                    choices=list(BENCHES))
    ap.add_argument("--transports", nargs="+",
                    default=None, help="default: psum (dry) / ring_hier+psum")
    ap.add_argument("--channels", nargs="+", type=int, default=[2])
    ap.add_argument("--page-bytes", nargs="+", type=int, default=[4096])
    ap.add_argument("--sizes", nargs="+", type=int,
                    default=[1 << 12, 1 << 16])
    ap.add_argument("--mesh", default="2x4",
                    help="probe mesh label, e.g. 2x4")
    ap.add_argument("--arch", default=GENERIC_ARCH)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--plant-alpha", type=float, default=None,
                    help="--dry only: planted α seconds")
    ap.add_argument("--plant-bandwidth", type=float, default=None,
                    help="--dry only: planted bandwidth B/s")
    args = ap.parse_args(argv)

    mesh = tuple(int(d) for d in args.mesh.lower().split("x"))
    if args.dry:
        cells = synthesize_cells(
            transports=tuple(args.transports or ("psum",)),
            channels=tuple(args.channels), pages=tuple(args.page_bytes),
            sizes=tuple(args.sizes), mesh=mesh, arch=args.arch,
            alpha_s=args.plant_alpha, bandwidth=args.plant_bandwidth)
    else:
        cells = run_probe(
            benches=tuple(args.benches),
            transports=tuple(args.transports or ("ring_hier", "psum")),
            channels=tuple(args.channels), pages=tuple(args.page_bytes),
            sizes=tuple(args.sizes), mesh=mesh, arch=args.arch,
            warmup=args.warmup, iters=args.iters)

    db = TuningDB.load(args.out) if args.out else TuningDB()
    fits = fit_and_store(cells, db)
    print(f"probed {len(cells)} cells -> {len(fits)} fit group(s)")
    for key, fit in sorted(fits.items()):
        print(f"  {key}: alpha={fit.alpha_s*1e6:.2f}us "
              f"bw={fit.bandwidth/1e9:.2f}GB/s "
              f"mean_rel_err={fit.mean_rel_err:.3%} "
              f"max_rel_err={fit.max_rel_err:.3%} "
              f"(n={fit.n_cells})")
    if args.out:
        db.save(args.out)
        print(f"wrote {args.out} ({len(db)} record(s))")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

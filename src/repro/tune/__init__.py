"""repro.tune — measured auto-tuner for the communication substrate.

The paper's methodology is *measured*: the authors benchmarked their way to
the dual-HFI / multi-endpoint / huge-page configuration rather than
predicting it.  This package closes the same loop over our stack:

* :mod:`repro.tune.probe` drives the existing benches (allreduce, arena,
  halo, cg) as a calibration matrix over transport × channels × page_bytes
  × message size, reusing the subprocess harness in ``benchmarks/common.py``;
* :mod:`repro.tune.fit` least-squares the measured timings against
  ``t = α·messages + bytes/bandwidth`` per transport, recovering *measured*
  α and bandwidth with per-cell predicted-vs-measured errors (so
  regressions in the latency *model* become visible, not just in the code);
* :mod:`repro.tune.db` persists the fits as a JSON tuning database keyed
  like the dry-run cache (arch × mesh × transport × channels × page_bytes,
  overrides fingerprint folded in);
* :mod:`repro.tune.resolve` turns ``"auto"`` knobs in
  :class:`repro.launch.settings.ArchSettings` into the DB's measured best
  config at launch, falling back to today's defaults with a warning when
  no entry matches.

``python -m repro.tune.probe --out experiments/tuning.json`` builds the DB;
``python -m repro.launch.dryrun --tuned experiments/tuning.json`` then
prices every dry-run cell with the measured constants and reports the
per-cell ``model_error``.
"""

from repro.tune.db import (DEFAULT_DB_PATH, TuningDB, overrides_fingerprint,
                           tune_key)
from repro.tune.fit import FitResult, fit_cells, fit_latency
from repro.tune.probe import ProbeCell, group_cells, synthesize_cells
from repro.tune.resolve import resolve_settings

__all__ = [
    "DEFAULT_DB_PATH", "FitResult", "ProbeCell", "TuningDB", "fit_cells",
    "fit_latency", "group_cells", "overrides_fingerprint",
    "resolve_settings", "synthesize_cells", "tune_key",
]

"""Least-squares calibration of the α/β latency model from probe timings.

One fit per (transport × channels × page_bytes) probe group, over the
message-size sweep:

    t_i = α · messages_i + bytes_i / bandwidth

is linear in ``(α, β=1/bandwidth)``, so a weighted two-column least squares
recovers the *measured* per-message launch latency and per-link bandwidth
that :class:`repro.comm.plan.LatencyModel` hardcodes as guesses.  The fit
also returns per-cell predicted-vs-measured relative errors — the number
``dryrun --tuned`` surfaces as ``model_error`` — so a regression in the
*model* (a transport whose hop count prediction drifts from what it lowers
to) is as visible as a regression in the code.

Cells carry their timing dispersion (min/max of the timed iterations, see
``benchmarks/common.time_call``); noisy cells are down-weighted by
``1/σ²`` with ``σ = max(spread/2, rel_floor·t)`` so one scheduling hiccup
cannot drag the fitted constants.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Sequence

import numpy as np

# β is clamped to this floor instead of zero/negative so ``bandwidth`` stays
# finite and JSON-serialisable (1e15 B/s ≈ infinitely fast: the β term
# contributes nothing measurable at probe sizes).
_MAX_BANDWIDTH = 1e15
# relative timing-noise floor: even a zero-spread cell is assumed good to
# no better than 1% of its own value
_REL_FLOOR = 0.01


@dataclass(frozen=True)
class FitResult:
    """Measured α/bandwidth plus the fit-quality record.

    ``rel_errors[i]`` is ``|t_pred − t_meas| / t_meas`` for probe cell
    ``i`` under the *fitted* constants; ``max_rel_err``/``mean_rel_err``
    summarise them.  These travel with the tuning-DB record and become the
    per-cell ``model_error`` of ``dryrun --tuned``.
    """

    alpha_s: float              # measured per-message launch latency
    bandwidth: float            # measured per-link bytes/s
    n_cells: int
    rel_errors: tuple[float, ...]
    mean_rel_err: float
    max_rel_err: float
    rms_residual_s: float

    def predicted_seconds(self, messages: float, nbytes: float) -> float:
        return self.alpha_s * float(messages) + float(nbytes) / self.bandwidth

    def as_dict(self) -> dict:
        return {
            "alpha_s": self.alpha_s,
            "bandwidth": self.bandwidth,
            "n_cells": self.n_cells,
            "rel_errors": list(self.rel_errors),
            "mean_rel_err": self.mean_rel_err,
            "max_rel_err": self.max_rel_err,
            "rms_residual_s": self.rms_residual_s,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FitResult":
        return cls(alpha_s=float(d["alpha_s"]),
                   bandwidth=float(d["bandwidth"]),
                   n_cells=int(d["n_cells"]),
                   rel_errors=tuple(float(e) for e in d["rel_errors"]),
                   mean_rel_err=float(d["mean_rel_err"]),
                   max_rel_err=float(d["max_rel_err"]),
                   rms_residual_s=float(d["rms_residual_s"]))


def dispersion_weight(seconds: float, t_min: float, t_max: float,
                      rel_floor: float = _REL_FLOOR) -> float:
    """``1/σ²`` weight from a cell's timing spread (min/max over iters)."""
    sigma = max((float(t_max) - float(t_min)) / 2.0,
                rel_floor * abs(float(seconds)), 1e-12)
    return 1.0 / (sigma * sigma)


def fit_latency(samples: Sequence[tuple[float, float, float, float]]
                ) -> FitResult:
    """Weighted least squares of ``t = α·m + b/bw``.

    ``samples``: iterable of ``(messages, nbytes, seconds, weight)``.
    Coefficients are clamped to the physical octant (α ≥ 0, bandwidth ≤
    1e15 B/s); a clamped coordinate triggers a one-parameter refit of the
    other so the constants stay least-squares optimal on the boundary.
    """
    rows = [(float(m), float(b), float(t), float(w))
            for m, b, t, w in samples]
    if not rows:
        raise ValueError("fit_latency needs at least one probe sample")
    m = np.array([r[0] for r in rows])
    b = np.array([r[1] for r in rows])
    t = np.array([r[2] for r in rows])
    sw = np.sqrt(np.array([r[3] for r in rows]))

    A = np.stack([m * sw, b * sw], axis=1)
    y = t * sw
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)
    alpha, beta = float(coef[0]), float(coef[1])

    def _refit_single(col: np.ndarray) -> float:
        denom = float(np.dot(col * sw, col * sw))
        return float(np.dot(col * sw, y)) / denom if denom > 0 else 0.0

    if alpha < 0.0:
        alpha = 0.0
        beta = _refit_single(b)
    if beta < 1.0 / _MAX_BANDWIDTH:
        beta = 1.0 / _MAX_BANDWIDTH
        if np.any(m > 0):
            alpha = max(_refit_single(m), 0.0)
    bandwidth = 1.0 / beta

    pred = alpha * m + beta * b
    resid = pred - t
    denom = np.where(np.abs(t) > 0, np.abs(t), 1.0)
    rel = np.abs(resid) / denom
    return FitResult(
        alpha_s=alpha, bandwidth=bandwidth, n_cells=len(rows),
        rel_errors=tuple(float(e) for e in rel),
        mean_rel_err=float(np.mean(rel)),
        max_rel_err=float(np.max(rel)),
        rms_residual_s=float(np.sqrt(np.mean(resid * resid))),
    )


def fit_cells(cells: Iterable) -> FitResult:
    """Fit one group of :class:`repro.tune.probe.ProbeCell` records,
    weighting by each cell's measured dispersion."""
    samples = [(c.messages, c.nbytes, c.seconds,
                dispersion_weight(c.seconds, c.t_min, c.t_max))
               for c in cells]
    return fit_latency(samples)

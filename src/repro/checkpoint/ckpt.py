"""Checkpointing: atomic step directories, integrity hashes, async writes.

Layout::

    <dir>/step_000123/
        meta.msgpack       # treedef repr, leaf paths/shapes/dtypes, sha256s
        arr_00000.npy ...  # one file per leaf (np.save, host-gathered)
        COMMITTED          # written last; restore ignores dirs without it

Fault-tolerance contract: a crash mid-write leaves an uncommitted dir that
restore skips; ``keep_n`` GC never deletes the newest committed step.  On
elastic restarts the state is saved as *global* arrays, so a different mesh
shape can reshard on restore (the manual step re-slices per device).
"""

from __future__ import annotations

import hashlib
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

COMMIT_MARK = "COMMITTED"


def _leaf_paths(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(p) for p, _ in flat]


def save(state: Any, step: int, ckpt_dir: str) -> str:
    """Blocking save of a pytree of (possibly sharded) jax arrays."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    meta = {"step": int(step), "treedef": str(treedef), "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        with open(os.path.join(tmp, fname), "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        meta["leaves"].append({
            "path": jax.tree_util.keystr(path), "file": fname,
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": digest,
        })
    with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    with open(os.path.join(tmp, COMMIT_MARK), "w") as f:
        f.write("ok\n")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, name, COMMIT_MARK)):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(like: Any, step: int, ckpt_dir: str, *, verify: bool = True,
            shardings: Any = None, strict: bool = True) -> Any:
    """Restore into the structure of ``like`` (arrays or SDS).  Optional
    ``shardings`` tree re-places leaves (elastic re-mesh).

    ``strict=False`` matches leaves **by path** instead of by position:
    leaves present in ``like`` but absent from the checkpoint keep their
    ``like`` value (they must then be concrete arrays), and checkpoint
    leaves with no counterpart in ``like`` are ignored.  This is what lets
    a run restore across config changes that add or drop *scratch* state —
    e.g. a model trained with ``use_arena=True`` (whose state carries the
    persistent comm-arena buffer) restoring into a non-arena step and vice
    versa.  Shape/dtype checks still apply per matched leaf — a config
    change that *re-shapes* surviving leaves (ZeRO-1's per-span optimizer
    re-layout, a different ``page_bytes`` for the arena leaf) still raises
    rather than silently dropping state.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(like)
    if strict and len(flat_p) != len(meta["leaves"]):
        raise ValueError(
            f"checkpoint has {len(meta['leaves'])} leaves, state expects "
            f"{len(flat_p)} — incompatible structures (pass strict=False "
            f"to match by path)")
    by_path = {rec["path"]: rec for rec in meta["leaves"]}
    sh_flat = (jax.tree_util.tree_flatten(
        shardings, is_leaf=lambda x: hasattr(x, "addressable_devices"))[0]
        if shardings is not None else [None] * len(flat_p))
    out = []
    for i, ((path, leaf), sh) in enumerate(zip(flat_p, sh_flat)):
        key = jax.tree_util.keystr(path)
        rec = meta["leaves"][i] if strict else by_path.get(key)
        if rec is None:                      # not in ckpt: keep like's value
            if isinstance(leaf, jax.ShapeDtypeStruct):
                raise ValueError(
                    f"leaf {key} is missing from the checkpoint and the "
                    f"template is abstract — nothing to keep")
            out.append(leaf)
            continue
        p = os.path.join(d, rec["file"])
        if verify:
            with open(p, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            if digest != rec["sha256"]:
                raise IOError(f"checksum mismatch for {rec['path']} in {d}")
        arr = np.load(p)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {rec['path']}: ckpt {arr.shape} vs "
                f"state {leaf.shape}")
        val = jnp.asarray(arr, dtype=leaf.dtype)
        if sh is not None:
            val = jax.device_put(val, sh)
        out.append(val)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async (thread-offloaded) saves + keep-N garbage collection."""

    def __init__(self, ckpt_dir: str, keep_n: int = 3, async_save: bool = True):
        self.ckpt_dir = ckpt_dir
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, state: Any, step: int):
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def work():
            try:
                save(host_state, step, self.ckpt_dir)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error is not None:
                raise self._error

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.ckpt_dir)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.ckpt_dir, n, COMMIT_MARK)))
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, like: Any, shardings: Any = None,
                       strict: bool = True):
        step = latest_step(self.ckpt_dir)
        if step is None:
            return None, None
        return restore(like, step, self.ckpt_dir, shardings=shardings,
                       strict=strict), step

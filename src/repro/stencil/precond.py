"""Even-odd (red-black) preconditioning of the Wilson-like stencil operator.

The production answer to CG's latency-bound inner products (DD-αAMG on
QPACE 3, MILC staggered CG on KNL) starts with *site splitting*: colour the
periodic lattice by global coordinate parity.  A nearest-neighbour operator
``A = d·I − H`` (``d = StencilOp.diag``, ``H`` the hopping term) only
couples sites of opposite parity, so in the even/odd block ordering

    A = [[ d·I   −H_eo ]        S = d·I − (1/d)·H_eo·H_oe
         [ −H_oe  d·I  ]]

and solving ``A x = b`` reduces to the **Schur complement** system
``S x_e = b_e + (1/d)·H_eo b_o`` over the even sites only — half the
unknowns, with spectrum ``d − σ²/d`` compressed quadratically relative to
``A``'s ``d ± σ`` (σ the singular values of ``H_eo``), so CG needs roughly
half the iterations — and therefore half the latency-bound inner-product
all-reduces, which is the paper's small-message regime.  The odd half is
recovered pointwise: ``x_o = (1/d)(b_o + H_oe x_e)``.

Layout: fields stay full-lattice arrays whose odd (resp. even) sites are
exactly zero.  Because ``H`` maps even-supported fields to odd-supported
ones *exactly* (a sum of neighbour values that are floating-point zeros is
``+0.0``), the Schur CG iterates keep their even support bitwise without any
masking in the hot loop; masks appear only in the one-time right-hand-side
projection and reconstruction.  The solved *system* has half the rank; the
storage deliberately keeps the simple Cartesian sharding of
:mod:`repro.core.halo` (no checkerboard repacking), trading redundant zeros
for an unchanged halo-exchange path — each Schur matvec is two
``StencilOp.apply`` exchanges.

Validity: every direction must have ``halo == 1`` (a second-neighbour
coupling connects *equal* parities, breaking the 2-colouring) and every
stencil direction's **global** extent must be even (an odd periodic ring
makes the colouring inconsistent across the boundary).  Checked in
:func:`repro.stencil.cg.solve`, which owns the mesh information.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from repro.stencil.op import StencilOp


@dataclass(frozen=True)
class EvenOddOp:
    """Schur complement of a nearest-neighbour :class:`StencilOp` on the
    even sites: ``apply(x) = d·x − (1/d)·H(H(x))`` for even-supported ``x``.

    ``distributed=True`` computes site parity from *global* coordinates via
    ``lax.axis_index`` (valid only inside a ``shard_map`` over the spec'd
    mesh axes); ``False`` treats array coordinates as global (the
    single-process reference path).  The object satisfies the same
    ``apply`` / ``apply_reference`` / ``eig_bounds`` protocol as
    :class:`StencilOp`, so every solver in :mod:`repro.stencil.cg` drives it
    unchanged.
    """

    op: StencilOp
    distributed: bool = True

    def __post_init__(self):
        bad = [s for s in self.op.specs if s.halo != 1]
        if bad:
            raise ValueError(
                f"even-odd preconditioning needs halo == 1 in every "
                f"direction (distance-2 hops couple equal parities); got "
                f"halo {tuple(s.halo for s in self.op.specs)}")

    @property
    def diag(self) -> float:
        return self.op.diag

    def eig_bounds(self) -> tuple[float, float]:
        """``S = d − H²/d`` with ``H`` eigenvalues in ``[−off, off]``, so the
        Schur spectrum sits in ``[d − off²/d, d]`` — quadratically tighter
        than the full operator's ``[d − off, d + off]`` (``off`` recovered
        from the operator's own enclosure, not re-derived)."""
        d = self.diag
        off = self.op.eig_bounds()[1] - d
        return d - off * off / d, d

    # -- parity ---------------------------------------------------------------

    def parity_mask(self, shape, even: bool = True) -> jax.Array:
        """f32 indicator of the even (or odd) sites of a local shard.

        Parity is the sum of *global* lattice coordinates over the stencil
        dims only (unsharded dims, e.g. the component axis, carry per-site
        vectors and do not participate).  Distributed shards offset each
        local coordinate by ``axis_index · local_extent``.
        """
        par = jnp.zeros((1,) * len(shape), jnp.int32)
        for spec in self.op.specs:
            n = int(shape[spec.dim])
            coord = jnp.arange(n, dtype=jnp.int32)
            if self.distributed:
                coord = coord + lax.axis_index(spec.axis) * n
            bshape = [1] * len(shape)
            bshape[spec.dim] = n
            par = par + coord.reshape(bshape)
        mask = (par % 2 == 0) if even else (par % 2 == 1)
        return jnp.broadcast_to(mask, tuple(int(n) for n in shape)) \
                  .astype(jnp.float32)

    # -- hopping term ---------------------------------------------------------

    def _hop(self, x: jax.Array, apply_kw: dict) -> jax.Array:
        """``H x = d·x − A x``: one halo exchange, flips site parity."""
        return jnp.asarray(self.diag, x.dtype) * x - self.op.apply(
            x, **apply_kw)

    def _hop_reference(self, xg: jax.Array) -> jax.Array:
        return self.diag * xg - self.op.apply_reference(xg)

    # -- Schur matvec (same protocol as StencilOp.apply) ----------------------

    def apply(self, x: jax.Array, *, schedule: str = "concurrent",
              chunks: int = 4, channels: int = 0) -> jax.Array:
        """Schur matvec on an even-supported local shard: two halo
        exchanges (even → odd → even), no masking needed in the loop."""
        kw = dict(schedule=schedule, chunks=chunks, channels=channels)
        inv = jnp.asarray(1.0 / self.diag, x.dtype)
        return jnp.asarray(self.diag, x.dtype) * x \
            - inv * self._hop(self._hop(x, kw), kw)

    def apply_reference(self, xg: jax.Array) -> jax.Array:
        """Global-lattice Schur matvec via ``jnp.roll`` (no mesh)."""
        return self.diag * xg - self._hop_reference(
            self._hop_reference(xg)) / self.diag

    # -- one-time projection / reconstruction ---------------------------------

    def project_rhs(self, b: jax.Array, *, schedule: str = "concurrent",
                    chunks: int = 4, channels: int = 0) -> jax.Array:
        """Schur right-hand side ``b̂_e = b_e + (1/d)·H b_o`` (one halo
        exchange; even-supported)."""
        kw = dict(schedule=schedule, chunks=chunks, channels=channels)
        me = self.parity_mask(b.shape, even=True)
        mo = self.parity_mask(b.shape, even=False)
        inv = jnp.asarray(1.0 / self.diag, jnp.float32)
        bf = b.astype(jnp.float32)
        return me * (bf + inv * self._hop(mo * bf, kw))

    def reconstruct(self, x_e: jax.Array, b: jax.Array, *,
                    schedule: str = "concurrent", chunks: int = 4,
                    channels: int = 0) -> jax.Array:
        """Full-lattice solution ``x = x_e + (1/d)·𝟙_o·(b + H x_e)`` (one
        halo exchange)."""
        kw = dict(schedule=schedule, chunks=chunks, channels=channels)
        mo = self.parity_mask(b.shape, even=False)
        inv = jnp.asarray(1.0 / self.diag, jnp.float32)
        xf = x_e.astype(jnp.float32)
        return xf + mo * (b.astype(jnp.float32) + self._hop(xf, kw)) * inv

    def project_rhs_reference(self, bg: jax.Array) -> jax.Array:
        me = self.parity_mask(bg.shape, even=True)
        mo = self.parity_mask(bg.shape, even=False)
        bf = bg.astype(jnp.float32)
        return me * (bf + self._hop_reference(mo * bf) / self.diag)

    def reconstruct_reference(self, x_e: jax.Array, bg: jax.Array) -> jax.Array:
        mo = self.parity_mask(bg.shape, even=False)
        xf = x_e.astype(jnp.float32)
        return xf + mo * (bg.astype(jnp.float32)
                          + self._hop_reference(xf)) / self.diag

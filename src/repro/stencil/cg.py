"""Conjugate-gradient solver family on a distributed stencil operator.

Every solver runs *inside* a fully-manual ``shard_map``: the matrix-vector
product is :meth:`repro.stencil.op.StencilOp.apply` (halo exchange + local
stencil), and the global inner products ride the communicator's channelized
``all_reduce`` (:func:`global_sums` packs the partial dots into one flat
buffer padded to the transport's alignment divisor).  The family exists
because the two tiny all-reduces classic CG issues per iteration are pure
small-message latency — the regime the paper's Tables are about — and the
production fixes are *structural*:

``cg``
    Textbook CG: two inner-product reductions per iteration
    (``2·iters + 1`` including the initial ``‖r‖²/‖b‖²`` batch), each on
    the critical path between matvecs.

``pipelined``
    Ghysels–Vanroose pipelined CG: the recurrence is rearranged so each
    iteration issues **one** batched reduction (``γ = ‖r‖²``, ``δ = (w,r)``
    and the latched ``‖b‖²`` share one buffer) that is *data-independent*
    of the same iteration's matvec ``q = A w`` — the reduction hides under
    the halo exchange + stencil compute.  ``iters`` reductions total.

``sstep``
    Communication-avoiding s-step CG (Chronopoulos–Gear blocks): each
    outer block runs ``s`` matvecs building a Newton-basis Krylov block,
    then batches **all** of the block's inner products — the basis Gram
    matrix, the A-conjugation coupling to the previous block, and the
    Galerkin correction — into one fused reduction: ``ceil(iters/s)``
    reductions total.  The monomial basis ``[r, Ar, …]`` is numerically
    unusable in f32 beyond s≈2; the basis here is the Newton basis with
    Leja-ordered Chebyshev shifts (:func:`leja_chebyshev_shifts`) drawn
    from the operator's *analytic* spectral enclosure
    (:meth:`~repro.stencil.op.StencilOp.eig_bounds`), which tracks classic
    CG to the f32 roundoff floor at s = 4.

Preconditioning composes with any of the three: ``precond="eo"`` solves the
even-odd Schur complement (:mod:`repro.stencil.precond`), roughly halving
the iteration count and with it the number of latency-bound reductions.
:func:`solve` dispatches over ``SOLVERS`` × ``PRECONDS``.

Iteration modes (all solvers):

* ``tol`` given — a ``lax.while_loop`` runs to ``‖r‖ ≤ tol·‖b‖`` or
  ``maxiter``; the production path.
* ``tol=None`` — a fixed iteration/block count as an unrolled Python loop:
  deterministic HLO with a statically known collective count, which the
  dry-run's solver cells and the HLO-count tests rely on
  (:func:`predicted_reduction_collectives` /
  :func:`predicted_halo_exchanges` are the exact predictions for this
  mode with ``x0=None``).

``CGResult.history`` records ``‖r‖²`` at each reduction point (iteration
entry for ``cg``/``pipelined``, block entry for ``sstep``) in a fixed-size
buffer; unwritten tail entries stay 0.  ``pipelined`` and ``sstep`` measure
the residual *entering* each step, so their reported ``rel_residual`` lags
the final update by one step/block — by construction it still satisfies the
``tol`` test on exit.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.topology import padded_size
from repro.stencil.precond import EvenOddOp

SOLVERS = ("cg", "pipelined", "sstep")
PRECONDS = ("none", "eo")


class CGResult(NamedTuple):
    """Solution plus convergence record (all local-shard views)."""

    x: jax.Array
    iters: jax.Array        # iterations actually run
    rel_residual: jax.Array  # ‖r‖ / ‖b‖ at exit (recurrence residual)
    history: jax.Array       # ‖r‖² per reduction point; tail entries 0


def global_sums(comm, *vals):
    """Sum scalars over the communicator's data axes on its channelized
    ``all_reduce``: partial dots are stacked into one flat f32 buffer,
    zero-padded to the transport's flat divisor, reduced, and unpacked.
    ``comm=None`` (or a mesh with no data axes) means single-process use —
    the values come back unchanged."""
    if comm is None or not comm.axes:
        return vals if len(vals) > 1 else vals[0]
    vec = jnp.stack([jnp.asarray(v, jnp.float32) for v in vals])
    n = padded_size(len(vals), comm.transport.flat_divisor(comm.axis_sizes))
    vec = jnp.concatenate([vec, jnp.zeros((n - len(vals),), jnp.float32)])
    out = comm.all_reduce([vec])[0]
    return tuple(out[i] for i in range(len(vals))) if len(vals) > 1 \
        else out[0]


def _dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.vdot(a.astype(jnp.float32), b.astype(jnp.float32))


def leja_chebyshev_shifts(lo: float, hi: float, s: int) -> tuple[float, ...]:
    """Leja-ordered Chebyshev points of ``[lo, hi]`` — the Newton-basis
    shifts for one s-step block.  Chebyshev points minimise the basis
    polynomial's sup-norm over the spectral enclosure; Leja ordering (start
    from the extreme point, then greedily maximise the distance product to
    the points already placed) keeps every *prefix* of the shift sequence
    well spread, which is what bounds the Gram conditioning in f32.  Pure
    Python on static floats: the shifts are compile-time constants."""
    if s < 1:
        raise ValueError(f"s must be >= 1, got {s}")
    if not hi > lo:
        raise ValueError(f"need hi > lo, got [{lo}, {hi}]")
    mid, rad = (lo + hi) / 2.0, (hi - lo) / 2.0
    pts = [mid + rad * math.cos((2 * k + 1) * math.pi / (2 * s))
           for k in range(s)]
    ordered = [max(pts, key=abs)]
    pts.remove(ordered[0])
    while pts:
        nxt = max(pts, key=lambda t: math.prod(abs(t - u) for u in ordered))
        pts.remove(nxt)
        ordered.append(nxt)
    return tuple(ordered)


# ---------------------------------------------------------------------------
# prediction helpers (exact for the unrolled mode with x0=None; upper bounds
# for the while_loop mode) — read by the dry-run solver cells, the roofline's
# α·messages latency term and the HLO-count tests
# ---------------------------------------------------------------------------


def predicted_reduction_collectives(solver: str, iters: int, s: int = 4
                                    ) -> int:
    """Inner-product reduction collectives one unrolled solve issues:
    ``cg`` pays two per iteration plus the initial ``(‖r‖², ‖b‖²)`` batch,
    ``pipelined`` one per iteration, ``sstep`` one per block."""
    if solver == "cg":
        return 2 * iters + 1
    if solver == "pipelined":
        return iters
    if solver == "sstep":
        return math.ceil(iters / max(s, 1))
    raise ValueError(f"unknown solver {solver!r}; one of {SOLVERS}")


def predicted_halo_exchanges(solver: str, precond: str, iters: int,
                             s: int = 4, replace_every: int = 6) -> int:
    """Halo exchanges (operator applications) one unrolled solve issues.
    ``pipelined`` pays one extra matvec for ``w₀ = A r₀``, but its *last*
    iteration's matvec feeds only dead state after an unrolled loop and is
    DCE'd by XLA — the two cancel, so ``iters`` exchanges survive in the
    lowered HLO.  Each residual replacement computes four matvecs but nets
    **three**: overwriting both ``w`` and ``z`` leaves the *previous*
    iteration's recurrence matvec with no live consumers, so DCE removes
    it (assumed here not to land on the final iteration, where the
    accounting differs again).  ``sstep`` always completes whole blocks;
    even-odd doubles the per-matvec exchanges (Schur apply hops twice) and
    adds one each for the right-hand-side projection and the odd-site
    reconstruction."""
    if solver == "cg":
        base = iters
    elif solver == "pipelined":
        n_rep = (iters - 1) // replace_every if replace_every > 0 else 0
        base = iters + 3 * n_rep
    elif solver == "sstep":
        base = max(s, 1) * math.ceil(iters / max(s, 1))
    else:
        raise ValueError(f"unknown solver {solver!r}; one of {SOLVERS}")
    if precond == "none":
        return base
    if precond == "eo":
        return 2 * base + 2
    raise ValueError(f"unknown precond {precond!r}; one of {PRECONDS}")


# ---------------------------------------------------------------------------
# classic CG
# ---------------------------------------------------------------------------


def cg_solve(op, b: jax.Array, comm=None, *, x0: jax.Array | None = None,
             tol: float | None = 1e-6, maxiter: int = 100,
             schedule: str = "concurrent", chunks: int = 4,
             channels: int = 0, matvec=None) -> CGResult:
    """Solve ``op x = b`` (SPD ``op``) by classic conjugate gradients.

    ``b`` is this rank's local shard; ``op`` is a :class:`StencilOp` (or any
    object with the same ``apply`` signature).  ``schedule``/``chunks``/
    ``channels`` select the halo schedule for every matvec; ``comm`` carries
    the inner products (``None`` = local sums only).  Pass ``matvec`` to
    override the product entirely — e.g. ``op.apply_reference`` for a
    single-process solve on a global lattice, outside any ``shard_map``.
    """
    if matvec is None:
        matvec = lambda v: op.apply(v, schedule=schedule, chunks=chunks,
                                    channels=channels)
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - matvec(x) if x0 is not None else b
    p = r
    rs, bs = global_sums(comm, _dot(r, r), _dot(b, b))
    hist = jnp.zeros((maxiter + 1,), jnp.float32).at[0].set(rs)

    def step(x, r, p, rs):
        ap = matvec(p)
        pap = global_sums(comm, _dot(p, ap))
        # guarded divisions: identical bits while the denominators are
        # positive (the while_loop exits before they are not); the unrolled
        # mode iterates past convergence and must stall at 0 instead of NaN
        alpha = jnp.where(pap > 0.0, rs / jnp.where(pap > 0.0, pap, 1.0), 0.0)
        x = x + alpha * p.astype(jnp.float32)
        r = r - alpha * ap.astype(jnp.float32)
        rs_new = global_sums(comm, _dot(r, r))
        beta = jnp.where(rs > 0.0, rs_new / jnp.where(rs > 0.0, rs, 1.0), 0.0)
        p = r + beta * p
        return x, r, p, rs_new

    if tol is None:                     # fixed-iteration, unrolled HLO
        x, r, p = x.astype(jnp.float32), r.astype(jnp.float32), \
            p.astype(jnp.float32)
        for k in range(maxiter):
            x, r, p, rs = step(x, r, p, rs)
            hist = hist.at[k + 1].set(rs)
        iters = jnp.asarray(maxiter, jnp.int32)
    else:
        limit = jnp.asarray(tol * tol, jnp.float32) * bs

        def cond(state):
            k, _, _, _, rs, _ = state
            return jnp.logical_and(k < maxiter, rs > limit)

        def body(state):
            k, x, r, p, rs, h = state
            x, r, p, rs = step(x, r, p, rs)
            return k + 1, x, r, p, rs, h.at[k + 1].set(rs)

        iters, x, r, p, rs, hist = jax.lax.while_loop(
            cond, body, (jnp.asarray(0, jnp.int32), x.astype(jnp.float32),
                         r.astype(jnp.float32), p.astype(jnp.float32), rs,
                         hist))
    rel = jnp.sqrt(rs) / jnp.maximum(jnp.sqrt(bs), 1e-30)
    return CGResult(x=x.astype(b.dtype), iters=iters, rel_residual=rel,
                    history=hist)


# ---------------------------------------------------------------------------
# pipelined CG (Ghysels & Vanroose)
# ---------------------------------------------------------------------------


def pipelined_cg_solve(op, b: jax.Array, comm=None, *,
                       x0: jax.Array | None = None,
                       tol: float | None = 1e-6, maxiter: int = 100,
                       schedule: str = "concurrent", chunks: int = 4,
                       channels: int = 0, matvec=None,
                       replace_every: int = 6) -> CGResult:
    """Pipelined CG: one reduction per iteration, issued concurrently with
    the iteration's matvec.

    Each iteration batches ``γ = (r,r)``, ``δ = (w,r)`` and a latched
    ``(b,b)`` into one :func:`global_sums` call whose operands come from the
    *previous* iteration's state — so the lowered all-reduce and the matvec
    ``q = A w`` share no data dependency and the scheduler may run the
    reduction under the halo exchange + stencil compute.  The recurrence
    (Ghysels & Vanroose 2014, alg. 3) reproduces classic CG's iterates up
    to f32 rounding.

    The known cost of pipelining is *residual drift*: the recurrence
    residual ``r`` (and the auxiliary ``w ≈ A r``, ``s ≈ A p``, ``z ≈ A s``
    vectors) decouple from their true values at a rate ``∝ iters·ε·κ``, so
    in f32 the solver would report convergence the true residual has not
    reached.  The standard fix is periodic **residual replacement** (Cools
    et al.): every ``replace_every`` iterations, recompute ``r = b − A x``,
    ``w = A r``, ``s = A p`` and ``z = A s`` from their definitions while
    keeping ``p`` and the scalar recurrences — the CG trajectory is
    preserved, the accumulated rounding is discarded.  This costs four
    extra matvecs per replacement and **zero** extra reductions — it spends
    the cheap resource (halo exchanges) to keep the expensive one
    (latency-bound reductions) at one per iteration.  ``replace_every=0``
    disables replacement.
    """
    if matvec is None:
        matvec = lambda v: op.apply(v, schedule=schedule, chunks=chunks,
                                    channels=channels)
    x = (jnp.zeros_like(b) if x0 is None else x0).astype(jnp.float32)
    r = (b - matvec(x) if x0 is not None else b).astype(jnp.float32)
    w = matvec(r).astype(jnp.float32)
    zero = jnp.zeros_like(r)
    hist0 = jnp.zeros((maxiter + 1,), jnp.float32)
    bf = b.astype(jnp.float32)

    def replace(x, p):
        rr = bf - matvec(x).astype(jnp.float32)
        ss = matvec(p).astype(jnp.float32)
        return rr, matvec(rr).astype(jnp.float32), ss, \
            matvec(ss).astype(jnp.float32)

    def step(k, x, r, w, z, s_, p, g_old, a_old, bs):
        g, de, bsp = global_sums(comm, _dot(r, r), _dot(w, r), _dot(bf, bf))
        bs = jnp.where(k == 0, bsp, bs)
        q = matvec(w)                   # independent of this step's reduction
        beta = jnp.where(
            k == 0, 0.0,
            jnp.where(g_old > 0.0, g / jnp.where(g_old > 0.0, g_old, 1.0),
                      0.0))
        den = de - beta * g / jnp.where(a_old > 0.0, a_old, 1.0)
        alpha = jnp.where(den > 0.0, g / jnp.where(den > 0.0, den, 1.0), 0.0)
        z = q + beta * z
        s_ = w + beta * s_
        p = r + beta * p
        x = x + alpha * p
        r = r - alpha * s_
        w = w - alpha * z
        return x, r, w, z, s_, p, g, alpha, bs, g

    if tol is None:                     # fixed-iteration, unrolled HLO
        z = s_ = p = zero
        g_old = a_old = jnp.asarray(1.0, jnp.float32)
        bs = rs = jnp.asarray(jnp.inf, jnp.float32)
        hist = hist0
        for k in range(maxiter):
            if replace_every > 0 and k > 0 and k % replace_every == 0:
                r, w, s_, z = replace(x, p)
            x, r, w, z, s_, p, g_old, a_old, bs, rs = step(
                jnp.asarray(k, jnp.int32), x, r, w, z, s_, p, g_old, a_old,
                bs)
            hist = hist.at[k].set(rs)
        iters = jnp.asarray(maxiter, jnp.int32)
    else:
        limit2 = jnp.asarray(tol * tol, jnp.float32)

        def cond(state):
            k, *_, bs, rs, _ = state
            return jnp.logical_or(
                k == 0, jnp.logical_and(k < maxiter, rs > limit2 * bs))

        def body(state):
            k, x, r, w, z, s_, p, g_old, a_old, bs, rs, h = state
            if replace_every > 0:
                rep = jnp.logical_and(k > 0, k % replace_every == 0)
                r, w, s_, z = jax.lax.cond(
                    rep, lambda _: replace(x, p),
                    lambda _: (r, w, s_, z), None)
            x, r, w, z, s_, p, g_old, a_old, bs, rs = step(
                k, x, r, w, z, s_, p, g_old, a_old, bs)
            return (k + 1, x, r, w, z, s_, p, g_old, a_old, bs, rs,
                    h.at[k].set(rs))

        state = (jnp.asarray(0, jnp.int32), x, r, w, zero, zero, zero,
                 jnp.asarray(1.0, jnp.float32), jnp.asarray(1.0, jnp.float32),
                 jnp.asarray(jnp.inf, jnp.float32),
                 jnp.asarray(jnp.inf, jnp.float32), hist0)
        iters, x, r, w, z, s_, p, g_old, a_old, bs, rs, hist = \
            jax.lax.while_loop(cond, body, state)
    rel = jnp.sqrt(rs) / jnp.maximum(jnp.sqrt(bs), 1e-30)
    return CGResult(x=x.astype(b.dtype), iters=iters, rel_residual=rel,
                    history=hist)


# ---------------------------------------------------------------------------
# s-step CG (Chronopoulos & Gear blocks, Newton basis)
# ---------------------------------------------------------------------------


def _tri_pairs(s: int) -> list[tuple[int, int]]:
    """Upper-triangle index pairs of the (s+1)×(s+1) basis Gram matrix."""
    return [(i, j) for i in range(s + 1) for j in range(i, s + 1)]


def sstep_cg_solve(op, b: jax.Array, comm=None, *, s: int = 4,
                   x0: jax.Array | None = None,
                   tol: float | None = 1e-6, maxiter: int = 100,
                   schedule: str = "concurrent", chunks: int = 4,
                   channels: int = 0, matvec=None,
                   eig_bounds: tuple[float, float] | None = None) -> CGResult:
    """Communication-avoiding s-step CG: one fused reduction per ``s``
    iterations.

    Each outer block builds the Newton-basis Krylov block ``v₀ = r,
    v_{j+1} = (A − θ_j)·v_j`` (``s`` matvecs; shifts from
    :func:`leja_chebyshev_shifts` over ``eig_bounds``, default
    ``op.eig_bounds()``), then batches every scalar the block needs into
    **one** :func:`global_sums` call: the basis Gram matrix ``G`` (from
    which ``Rᵀ A R`` follows via the shift recurrence), the coupling
    ``C = APᵀ V`` to the previous direction block, and the Galerkin
    correction ``h = Pᵀ r``.  The replicated (s×s) solves then advance
    ``x`` by ``s`` CG-equivalent iterations (Chronopoulos & Gear 1989).
    In exact arithmetic the block-boundary iterates equal classic CG's
    every ``s``-th iterate; the Newton basis keeps that true to the f32
    roundoff floor at ``s = 4``.

    ``maxiter`` counts fine-grained iterations; blocks always complete, so
    up to ``ceil(maxiter/s)`` reductions are issued.  ``x0`` is not
    supported (the first block's reduction doubles as the ``‖b‖²``
    measurement).  The convergence test runs on each block's *entry*
    residual, so the while_loop mode performs one final block beyond the
    block that reached ``tol``.
    """
    if x0 is not None:
        raise ValueError("sstep_cg_solve does not support x0 (the first "
                         "block's reduction doubles as the ‖b‖² batch)")
    if s < 1:
        raise ValueError(f"s must be >= 1, got {s}")
    if matvec is None:
        matvec = lambda v: op.apply(v, schedule=schedule, chunks=chunks,
                                    channels=channels)
    lo, hi = eig_bounds if eig_bounds is not None else op.eig_bounds()
    theta = leja_chebyshev_shifts(lo, hi, s)
    nblocks = math.ceil(max(int(maxiter), 1) / s)
    pairs = _tri_pairs(s)
    rank = b.ndim
    th = jnp.asarray(theta, jnp.float32).reshape((s,) + (1,) * rank)
    eye = jnp.eye(s, dtype=jnp.float32)

    def block(x, r, P, AP, W_old):
        V = [r]
        for j in range(s):
            V.append(matvec(V[j]).astype(jnp.float32)
                     - jnp.asarray(theta[j], jnp.float32) * V[j])
        Vs = jnp.stack(V)                              # (s+1,) + shape
        # one fused reduction: Gram upper triangle + coupling + correction
        dots = [_dot(V[i], V[j]) for i, j in pairs]
        dots += [_dot(AP[i], V[j]) for i in range(s) for j in range(s)]
        dots += [_dot(P[i], r) for i in range(s)]
        red = global_sums(comm, *dots)
        red = jnp.stack(red) if isinstance(red, tuple) else red[None]
        nG = len(pairs)
        G = jnp.zeros((s + 1, s + 1), jnp.float32)
        for n, (i, j) in enumerate(pairs):
            G = G.at[i, j].set(red[n])
            G = G.at[j, i].set(red[n])
        C = red[nG:nG + s * s].reshape(s, s)
        h = red[nG + s * s:nG + s * s + s]
        rs = G[0, 0]
        # RᵀAR via the shift recurrence A v_j = v_{j+1} + θ_j v_j
        M = G[:s, 1:s + 1] + G[:s, :s] * jnp.asarray(theta, jnp.float32)
        # guards: past convergence (unrolled mode) the basis underflows and
        # the Gram solves go singular — stall the block at a = 0 instead of
        # poisoning x/r with NaNs, exactly like classic CG's alpha guard.
        # Dropping B restarts the next block's conjugation from scratch,
        # which is the standard CA-CG recovery and costs nothing once
        # converged.
        ok = rs > 0.0
        W_safe = jnp.where(ok, W_old, eye)
        B = -jnp.linalg.solve(W_safe, C)               # A-conjugation coupling
        B = jnp.where(jnp.isfinite(B).all(), B, jnp.zeros((s, s)))
        W = M + C.T @ B + B.T @ C + B.T @ W_safe @ B
        W = 0.5 * (W + W.T)
        g = G[0, :s] + B.T @ h
        W_solve = jnp.where(ok, W, eye)
        a = jnp.linalg.solve(W_solve, g)
        a = jnp.where(jnp.logical_and(ok, jnp.isfinite(a).all()), a,
                      jnp.zeros((s,)))
        Pn = Vs[:s] + jnp.tensordot(B, P, axes=[[0], [0]])
        APn = (Vs[1:] + th * Vs[:s]) + jnp.tensordot(B, AP, axes=[[0], [0]])
        x = x + jnp.tensordot(a, Pn, axes=[[0], [0]])
        r = r - jnp.tensordot(a, APn, axes=[[0], [0]])
        return x, r, Pn, APn, W_solve, rs

    x = jnp.zeros_like(b, dtype=jnp.float32)
    r = b.astype(jnp.float32)
    P0 = jnp.zeros((s,) + b.shape, jnp.float32)
    hist0 = jnp.zeros((nblocks + 1,), jnp.float32)

    if tol is None:                     # fixed block count, unrolled HLO
        P, AP, W = P0, P0, eye
        hist = hist0
        rs = bs = jnp.asarray(jnp.inf, jnp.float32)
        for k in range(nblocks):
            x, r, P, AP, W, rs = block(x, r, P, AP, W)
            bs = jnp.where(k == 0, rs, bs)
            hist = hist.at[k].set(rs)
        iters = jnp.asarray(nblocks * s, jnp.int32)
    else:
        limit2 = jnp.asarray(tol * tol, jnp.float32)

        def cond(state):
            k = state[0]
            rs, bs = state[6], state[7]
            return jnp.logical_or(
                k == 0, jnp.logical_and(k < nblocks, rs > limit2 * bs))

        def body(state):
            k, x, r, P, AP, W, rs, bs, h = state
            x, r, P, AP, W, rs = block(x, r, P, AP, W)
            bs = jnp.where(k == 0, rs, bs)
            return k + 1, x, r, P, AP, W, rs, bs, h.at[k].set(rs)

        state = (jnp.asarray(0, jnp.int32), x, r, P0, P0, eye,
                 jnp.asarray(jnp.inf, jnp.float32),
                 jnp.asarray(jnp.inf, jnp.float32), hist0)
        k, x, r, P, AP, W, rs, bs, hist = jax.lax.while_loop(
            cond, body, state)
        iters = k * s
    rel = jnp.sqrt(rs) / jnp.maximum(jnp.sqrt(bs), 1e-30)
    return CGResult(x=x.astype(b.dtype), iters=jnp.asarray(iters, jnp.int32),
                    rel_residual=rel, history=hist)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

_SOLVER_FNS = {"cg": cg_solve, "pipelined": pipelined_cg_solve,
               "sstep": sstep_cg_solve}


def _check_even_extents(op, b: jax.Array, comm, reference: bool) -> None:
    """Even-odd needs an even *global* extent along every stencil dim."""
    sizes = {}
    if comm is not None and not reference:
        sizes = dict(zip(comm.mesh.axis_names, comm.mesh.devices.shape))
    for spec in op.specs:
        n = int(b.shape[spec.dim]) * int(sizes.get(spec.axis, 1))
        if n % 2:
            raise ValueError(
                f"even-odd preconditioning needs an even global extent in "
                f"every stencil direction; dim {spec.dim} (axis "
                f"{spec.axis!r}) has global extent {n}")


def solve(op, b: jax.Array, comm=None, *, solver: str = "cg",
          precond: str = "none", s: int = 4, x0: jax.Array | None = None,
          tol: float | None = 1e-6, maxiter: int = 100,
          schedule: str = "concurrent", chunks: int = 4, channels: int = 0,
          replace_every: int = 6, reference: bool = False) -> CGResult:
    """Solve ``op x = b`` with any ``solver`` × ``precond`` combination.

    ``reference=True`` solves on a *global* lattice outside any
    ``shard_map`` via ``op.apply_reference`` (no communicator, parity from
    array coordinates) — the single-process test path.  Otherwise the call
    must run inside a fully-manual ``shard_map`` like :func:`cg_solve`.

    With ``precond="eo"`` the chosen solver runs on the even-odd Schur
    complement (half the unknowns, roughly half the iterations — and half
    the latency-bound reductions); ``iters``/``rel_residual``/``history``
    then describe the Schur solve, while ``x`` is the reconstructed
    full-lattice solution.
    """
    if solver not in _SOLVER_FNS:
        raise ValueError(f"unknown solver {solver!r}; one of {SOLVERS}")
    if precond not in PRECONDS:
        raise ValueError(f"unknown precond {precond!r}; one of {PRECONDS}")
    kw = dict(x0=x0, tol=tol, maxiter=maxiter, schedule=schedule,
              chunks=chunks, channels=channels)
    fn = _SOLVER_FNS[solver]
    if solver == "sstep":
        kw["s"] = s
    elif solver == "pipelined":
        kw["replace_every"] = replace_every

    if precond == "none":
        matvec = op.apply_reference if reference else None
        return fn(op, b, comm, matvec=matvec, **kw)

    if x0 is not None:
        raise ValueError("precond='eo' does not support x0 (the Schur "
                         "right-hand side would need projecting around it)")
    _check_even_extents(op, b, comm, reference)
    distributed = (comm is not None and bool(comm.axes)) and not reference
    eo = EvenOddOp(op, distributed=distributed)
    apply_kw = dict(schedule=schedule, chunks=chunks, channels=channels)
    if reference:
        rhs = eo.project_rhs_reference(b)
        res = fn(eo, rhs, comm, matvec=eo.apply_reference, **kw)
        x = eo.reconstruct_reference(res.x, b)
    else:
        rhs = eo.project_rhs(b, **apply_kw)
        res = fn(eo, rhs, comm, **kw)
        x = eo.reconstruct(res.x, b, **apply_kw)
    return res._replace(x=x.astype(b.dtype))

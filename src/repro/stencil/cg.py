"""Conjugate gradients on a distributed stencil operator.

Runs *inside* a fully-manual ``shard_map``: the matrix-vector product is
:meth:`repro.stencil.op.StencilOp.apply` (halo exchange + local stencil),
and the two global inner products per iteration ride the communicator's
channelized ``all_reduce`` — the same rails, transports and striping rule
as gradient reduction (:func:`global_sums` packs the partial dots into one
flat buffer padded to the transport's alignment divisor).

Two iteration modes:

* ``tol`` given — a ``lax.while_loop`` runs until ``‖r‖² ≤ tol²·‖b‖²`` or
  ``maxiter``; this is the production solver.
* ``tol=None`` — exactly ``maxiter`` iterations as an unrolled Python loop:
  deterministic HLO (no ``while``), which the dry-run's stencil suite and
  the bitwise cross-schedule tests rely on (the roofline's wire-byte parser
  cannot scale loop bodies by trip count).

Because the operator's arithmetic is schedule-independent (see
:mod:`repro.stencil.op`) and ``ppermute``/``all_reduce`` move exact values,
every halo schedule produces bitwise-identical CG iterates.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.topology import padded_size


class CGResult(NamedTuple):
    """Solution plus convergence record (all local-shard views)."""

    x: jax.Array
    iters: jax.Array        # iterations actually run
    rel_residual: jax.Array  # ‖r‖ / ‖b‖ at exit (recurrence residual)


def global_sums(comm, *vals):
    """Sum scalars over the communicator's data axes on its channelized
    ``all_reduce``: partial dots are stacked into one flat f32 buffer,
    zero-padded to the transport's flat divisor, reduced, and unpacked.
    ``comm=None`` (or a mesh with no data axes) means single-process use —
    the values come back unchanged."""
    if comm is None or not comm.axes:
        return vals if len(vals) > 1 else vals[0]
    vec = jnp.stack([jnp.asarray(v, jnp.float32) for v in vals])
    n = padded_size(len(vals), comm.transport.flat_divisor(comm.axis_sizes))
    vec = jnp.concatenate([vec, jnp.zeros((n - len(vals),), jnp.float32)])
    out = comm.all_reduce([vec])[0]
    return tuple(out[i] for i in range(len(vals))) if len(vals) > 1 \
        else out[0]


def _dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.vdot(a.astype(jnp.float32), b.astype(jnp.float32))


def cg_solve(op, b: jax.Array, comm=None, *, x0: jax.Array | None = None,
             tol: float | None = 1e-6, maxiter: int = 100,
             schedule: str = "concurrent", chunks: int = 4,
             channels: int = 0, matvec=None) -> CGResult:
    """Solve ``op x = b`` (SPD ``op``) by conjugate gradients.

    ``b`` is this rank's local shard; ``op`` is a :class:`StencilOp` (or any
    object with the same ``apply`` signature).  ``schedule``/``chunks``/
    ``channels`` select the halo schedule for every matvec; ``comm`` carries
    the inner products (``None`` = local sums only).  Pass ``matvec`` to
    override the product entirely — e.g. ``op.apply_reference`` for a
    single-process solve on a global lattice, outside any ``shard_map``.
    """
    if matvec is None:
        matvec = lambda v: op.apply(v, schedule=schedule, chunks=chunks,
                                    channels=channels)
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - matvec(x) if x0 is not None else b
    p = r
    rs, bs = global_sums(comm, _dot(r, r), _dot(b, b))

    def step(x, r, p, rs):
        ap = matvec(p)
        pap = global_sums(comm, _dot(p, ap))
        # guarded divisions: identical bits while the denominators are
        # positive (the while_loop exits before they are not); the unrolled
        # mode iterates past convergence and must stall at 0 instead of NaN
        alpha = jnp.where(pap > 0.0, rs / jnp.where(pap > 0.0, pap, 1.0), 0.0)
        x = x + alpha * p.astype(jnp.float32)
        r = r - alpha * ap.astype(jnp.float32)
        rs_new = global_sums(comm, _dot(r, r))
        beta = jnp.where(rs > 0.0, rs_new / jnp.where(rs > 0.0, rs, 1.0), 0.0)
        p = r + beta * p
        return x, r, p, rs_new

    if tol is None:                     # fixed-iteration, unrolled HLO
        x, r, p = x.astype(jnp.float32), r.astype(jnp.float32), \
            p.astype(jnp.float32)
        for _ in range(maxiter):
            x, r, p, rs = step(x, r, p, rs)
        iters = jnp.asarray(maxiter, jnp.int32)
    else:
        limit = jnp.asarray(tol * tol, jnp.float32) * bs

        def cond(state):
            k, _, _, _, rs = state
            return jnp.logical_and(k < maxiter, rs > limit)

        def body(state):
            k, x, r, p, rs = state
            x, r, p, rs = step(x, r, p, rs)
            return k + 1, x, r, p, rs

        iters, x, r, p, rs = jax.lax.while_loop(
            cond, body, (jnp.asarray(0, jnp.int32), x.astype(jnp.float32),
                         r.astype(jnp.float32), p.astype(jnp.float32), rs))
    rel = jnp.sqrt(rs) / jnp.maximum(jnp.sqrt(bs), 1e-30)
    return CGResult(x=x.astype(b.dtype), iters=iters, rel_residual=rel)

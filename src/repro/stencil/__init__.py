"""repro.stencil — structured-grid PDE solvers on top of the Communicator.

The paper's first workload end-to-end: a Wilson-like nearest-neighbour
operator over an N-D Cartesian mesh (:mod:`repro.stencil.op`) whose halo
exchange runs any of the four :data:`repro.comm.HALO_SCHEDULES`, and a
conjugate-gradient solver (:mod:`repro.stencil.cg`) whose global inner
products ride the communicator's channelized ``all_reduce`` — the QCD
analogue of the SGD reduction path, sharing the same rails, schedules and
prediction objects (:class:`repro.comm.HaloPlan`,
:func:`repro.comm.build_halo_schedule`).
"""

from repro.stencil.cg import CGResult, cg_solve, global_sums
from repro.stencil.op import StencilOp

__all__ = ["CGResult", "StencilOp", "cg_solve", "global_sums"]

"""repro.stencil — structured-grid PDE solvers on top of the Communicator.

The paper's first workload end-to-end: a Wilson-like nearest-neighbour
operator over an N-D Cartesian mesh (:mod:`repro.stencil.op`) whose halo
exchange runs any of the four :data:`repro.comm.HALO_SCHEDULES`, and a
communication-avoiding conjugate-gradient solver family
(:mod:`repro.stencil.cg`: classic, pipelined, and s-step CG, optionally on
the even-odd Schur complement of :mod:`repro.stencil.precond`) whose global
inner products ride the communicator's channelized ``all_reduce`` — the QCD
analogue of the SGD reduction path, sharing the same rails, schedules and
prediction objects (:class:`repro.comm.HaloPlan`,
:func:`repro.comm.build_halo_schedule`, and the solver-side collective
counts of :func:`predicted_reduction_collectives`).
"""

from repro.stencil.cg import (CGResult, PRECONDS, SOLVERS, cg_solve,
                              global_sums, leja_chebyshev_shifts,
                              pipelined_cg_solve,
                              predicted_halo_exchanges,
                              predicted_reduction_collectives, solve,
                              sstep_cg_solve)
from repro.stencil.op import StencilOp
from repro.stencil.precond import EvenOddOp

__all__ = [
    "CGResult", "EvenOddOp", "PRECONDS", "SOLVERS", "StencilOp", "cg_solve",
    "global_sums", "leja_chebyshev_shifts", "pipelined_cg_solve",
    "predicted_halo_exchanges", "predicted_reduction_collectives", "solve",
    "sstep_cg_solve",
]

"""Wilson-like covariant stencil operator over an N-D Cartesian mesh.

The operator is the 2·d·w-point nearest-neighbour matrix the paper's QCD
workload (Grid's Dslash) applies between halo exchanges:

    (A x)[i] = (mass + 2 Σ_d κ_d w_d) x[i]
               − Σ_d κ_d Σ_{s=1..w_d} ( x[i − s e_d] + x[i + s e_d] )

over a periodic global lattice, with per-direction hopping weights ``κ_d``
and face width ``w_d`` (= ``HaloSpec.halo``).  The matrix is symmetric, and
strictly diagonally dominant — hence SPD — whenever ``mass > 0`` and every
``κ_d > 0``, which is what lets conjugate gradients (:mod:`repro.stencil.cg`)
drive it.

The apply is written as an **interior/boundary split** so the ``overlap``
halo schedule has compute to hide transfers under: each direction's
neighbour-sum is first computed from purely local data (zero halos) — valid
on the interior, no data dependency on any ``ppermute`` — and the two
``halo``-wide boundary slabs are then *overwritten* with values recomputed
from the received faces.  Every site's value is produced by the same
floating-point expression whichever path writes it, and the communication
schedule only reorders exact ``ppermute`` data movement — the operator's
*arithmetic* is schedule-independent by construction.  One backend caveat:
XLA is free to fuse the (schedule-dependent) exchange graph into the
compute and contract mul+add chains to FMAs differently per module, which
can move boundary sites by an ulp between schedules; the distributed tests
therefore assert *bitwise* identity with the fusion pass pinned off
(``--xla_disable_hlo_passes=fusion``) and tolerance-level identity under
default flags.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.halo import HaloSpec, halo_exchange


def _neighbour_sum(xc: jax.Array, start: int, count: int, width: int,
                   dim: int) -> jax.Array:
    """Σ_{s=1..width} (xc[i−s] + xc[i+s]) for sites [start, start+count) of
    the padded array ``xc`` along ``dim``.  Accumulation order is fixed
    (ascending ``s``, minus then plus) so every caller produces bitwise
    identical values for the same inputs."""
    acc = None
    for s in range(1, width + 1):
        a = lax.slice_in_dim(xc, start - s, start - s + count, axis=dim)
        b = lax.slice_in_dim(xc, start + s, start + s + count, axis=dim)
        t = a + b
        acc = t if acc is None else acc + t
    return acc


def _zeros_face(x: jax.Array, dim: int, width: int) -> jax.Array:
    shape = list(x.shape)
    shape[dim] = width
    return jnp.zeros(shape, x.dtype)


@dataclass(frozen=True)
class StencilOp:
    """Wilson-like operator: ``specs`` name the stencil directions (array
    dim × mesh axis × face width), ``hopping`` the per-direction κ.  With
    no ``hopping`` given every direction gets ``κ = 1 / (4 · n_dirs)`` —
    comfortably SPD for any positive ``mass``."""

    specs: tuple[HaloSpec, ...]
    mass: float = 1.0
    hopping: tuple[float, ...] = ()

    def __post_init__(self):
        if not self.specs:
            raise ValueError("StencilOp needs at least one direction spec")
        if self.hopping and len(self.hopping) != len(self.specs):
            raise ValueError(
                f"{len(self.hopping)} hopping weights for "
                f"{len(self.specs)} direction specs")

    @property
    def kappas(self) -> tuple[float, ...]:
        if self.hopping:
            return self.hopping
        return (1.0 / (4.0 * len(self.specs)),) * len(self.specs)

    @property
    def diag(self) -> float:
        """Diagonal coefficient; exceeds the off-diagonal row sum by
        ``mass``, so ``mass > 0`` makes the operator SPD."""
        return self.mass + 2.0 * sum(k * s.halo
                                     for k, s in zip(self.kappas, self.specs))

    def eig_bounds(self) -> tuple[float, float]:
        """Analytic spectral enclosure ``[λmin, λmax]`` of the periodic
        operator.  Fourier-diagonalising gives eigenvalues ``diag − Σ_d κ_d
        Σ_s 2·cos(s·θ_d)``, so every eigenvalue lies within ``off = 2·Σ_d
        κ_d·w_d`` of the diagonal (Gershgorin-exact at ``θ = 0``).  The
        s-step solver's Newton-basis shifts (:func:`repro.stencil.cg
        .leja_chebyshev_shifts`) only need an enclosure, not tight bounds."""
        off = 2.0 * sum(k * s.halo for k, s in zip(self.kappas, self.specs))
        return self.diag - off, self.diag + off

    # -- local compute -------------------------------------------------------

    def _dir_sum(self, x: jax.Array, lo: jax.Array, hi: jax.Array,
                 spec: HaloSpec) -> jax.Array:
        """One direction's neighbour-sum from local data + received faces.

        Interior first (zero halos — issuable before any transfer lands),
        then the two boundary slabs overwritten from the real halos.  Falls
        back to the directly-padded form when the local extent is too small
        to keep the slabs disjoint (``n < 2·halo``)."""
        d, w, n = spec.dim, spec.halo, x.shape[spec.dim]
        if n < 2 * w:
            xc = jnp.concatenate([lo, x, hi], axis=d)
            return _neighbour_sum(xc, w, n, w, d)
        z = _zeros_face(x, d, w)
        s0 = _neighbour_sum(jnp.concatenate([z, x, z], axis=d), w, n, w, d)
        # lo slab: sites [0, w) need the lo halo and x[0, 2w)
        xlo = jnp.concatenate([lo, lax.slice_in_dim(x, 0, 2 * w, axis=d)],
                              axis=d)
        slab_lo = _neighbour_sum(xlo, w, w, w, d)
        # hi slab: sites [n-w, n) need x[n-2w, n) and the hi halo; site n-w
        # sits at offset w of the 3w-long window
        xhi = jnp.concatenate([lax.slice_in_dim(x, n - 2 * w, n, axis=d), hi],
                              axis=d)
        slab_hi = _neighbour_sum(xhi, w, w, w, d)
        s0 = lax.dynamic_update_slice_in_dim(s0, slab_lo, 0, axis=d)
        return lax.dynamic_update_slice_in_dim(s0, slab_hi, n - w, axis=d)

    def apply_halos(self, x: jax.Array, halos: dict) -> jax.Array:
        """Apply the operator given already-received halos (the compute half
        of :meth:`apply`; schedule-independent by construction).

        ``x`` and each received face pass through their own
        ``optimization_barrier`` asking XLA not to fuse the
        (schedule-dependent) exchange graph into the (schedule-independent)
        compute; one barrier *per array* keeps the interior compute
        (downstream of ``x`` only) free to run while faces are still in
        flight.  The CPU backend strips these barriers — hence the fusion
        caveat in the module docstring — but backends that honour them get a
        hard fence between exchange and compute.
        """
        x = lax.optimization_barrier(x)
        halos = {k: lax.optimization_barrier(v) for k, v in halos.items()}
        y = jnp.asarray(self.diag, x.dtype) * x
        for spec, kappa in zip(self.specs, self.kappas):
            s = self._dir_sum(x, halos[(spec.axis, "-")],
                              halos[(spec.axis, "+")], spec)
            y = y - jnp.asarray(kappa, x.dtype) * s
        return y

    # -- distributed apply (inside a fully-manual shard_map) -----------------

    def apply(self, x: jax.Array, *, schedule: str = "concurrent",
              chunks: int = 4, channels: int = 0) -> jax.Array:
        """Halo exchange + apply on one local shard.  The schedule decides
        how the faces move (see :data:`repro.comm.HALO_SCHEDULES`); the
        arithmetic is identical for all of them."""
        halos = halo_exchange(x, self.specs, schedule=schedule,
                              chunks=chunks, channels=channels)
        return self.apply_halos(x, halos)

    # -- references (single process, global lattice) -------------------------

    def apply_reference(self, xg: jax.Array) -> jax.Array:
        """Dense-free reference on a *global* periodic lattice via
        ``jnp.roll`` — what the distributed apply must reproduce."""
        y = self.diag * xg
        for spec, kappa in zip(self.specs, self.kappas):
            for s in range(1, spec.halo + 1):
                y = y - kappa * (jnp.roll(xg, s, axis=spec.dim)
                                 + jnp.roll(xg, -s, axis=spec.dim))
        return y

    def dense_matrix(self, shape: Sequence[int]) -> jax.Array:
        """The operator as an explicit (N, N) matrix over a global lattice of
        ``shape`` — the ``jnp.linalg`` reference the CG property tests solve
        against.  Only sensible for tiny lattices."""
        n = 1
        for s in shape:
            n *= int(s)
        eye = jnp.eye(n, dtype=jnp.float32).reshape((n,) + tuple(shape))
        cols = jax.vmap(self.apply_reference)(eye)
        return cols.reshape(n, n).T

"""ParallelCtx: explicit model-parallel collectives for the manual step.

The whole train/serve step runs inside a *fully-manual* ``shard_map`` (every
mesh axis manual) — the design consequence of making the paper's reducer the
real DP reduction (GSPMD would otherwise insert its own).  Model code
therefore sees *local* weight shards and calls ``ctx.psum`` explicitly after
row-parallel contractions — Megatron-style TP, but with every collective
visible to our scheduler and to the roofline accounting.

``ParallelCtx()`` (no axes) is the single-device context: every collective
degrades to the identity, so the same model code runs in smoke tests.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat


@dataclass(frozen=True)
class ParallelCtx:
    model_axis: str | None = None        # manual TP axis ("model")
    data_axes: tuple[str, ...] = ()      # manual DP axes (("pod","data"))
    # optional Communicator-backed all_to_all (ctx-level EP dispatch);
    # signature (x, *, split_axis, concat_axis).  None -> native
    # lax.all_to_all fallback in :meth:`all_to_all`.
    a2a: Any = field(default=None, compare=False)

    # -- model-axis collectives ------------------------------------------------

    def psum(self, x):
        """Row-parallel completion sum whose *output is replicated* across
        the model axis.  Under ``check_vma=False`` the raw ``lax.psum``
        transpose would re-psum the (replicated) cotangent and scale grads
        by the axis size — so this uses an identity-backward custom VJP
        (correct exactly because every consumer treats the output as
        replicated)."""
        return _psum_id_bwd(x, self.model_axis) if self.model_axis else x

    def fan_out(self, x):
        """Megatron's ``f``: identity forward on a replicated activation
        that is about to feed rank-sharded (column-parallel) branches;
        backward psums the per-rank varying cotangents so upstream
        cotangents are replicated again.  Dual of :meth:`psum` (``g``)."""
        return _psum_grad(x, self.model_axis) if self.model_axis else x

    def pmax(self, x):
        return lax.pmax(x, self.model_axis) if self.model_axis else x

    def model_size(self) -> int:
        return compat.axis_size(self.model_axis) if self.model_axis else 1

    def model_index(self):
        return lax.axis_index(self.model_axis) if self.model_axis else 0

    def all_to_all(self, x, *, split_axis: int, concat_axis: int):
        """Tiled all-to-all over the model axis (EP dispatch/combine).

        Routes through the attached :class:`repro.comm.Communicator`
        transport when one was wired in (``TrainStepConfig.moe_transport``),
        else the native ``lax.all_to_all``.  A tiled all-to-all is a pure
        permutation, so its autodiff transpose — the inverse all-to-all —
        is already correct under ``check_vma=False``; no custom VJP.
        """
        if self.model_axis is None:
            return x
        if self.a2a is not None:
            return self.a2a(x, split_axis=split_axis, concat_axis=concat_axis)
        return lax.all_to_all(x, self.model_axis, split_axis, concat_axis,
                              tiled=True)

    def gather_replicated(self, x):
        """All-gather a model-axis batch shard back to a replicated tensor
        (identity backward: the output is consumed as replicated, so each
        rank's true cotangent is just its own slice — the gather dual of
        :meth:`psum`)."""
        return _gather_id_bwd(x, self.model_axis) if self.model_axis else x

    # -- data-axis helpers -----------------------------------------------------

    def dp_world(self) -> int:
        n = 1
        for a in self.data_axes:
            n *= compat.axis_size(a)
        return n

    def psum_data(self, x):
        for a in self.data_axes:
            x = lax.psum(x, a)
        return x

    def pmean_data(self, x):
        n = self.dp_world()
        return self.psum_data(x) / n if self.data_axes else x


SINGLE = ParallelCtx()


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _psum_id_bwd(x, axis):
    return lax.psum(x, axis)


def _psum_id_fwd(x, axis):
    return lax.psum(x, axis), None


def _psum_id_bwd_rule(axis, _, ct):
    return (ct,)


_psum_id_bwd.defvjp(_psum_id_fwd, _psum_id_bwd_rule)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _gather_id_bwd(x, axis):
    return lax.all_gather(x, axis, tiled=True)


def _gather_id_fwd(x, axis):
    return lax.all_gather(x, axis, tiled=True), x.shape[0]


def _gather_id_bwd_rule(axis, n_local, ct):
    i = lax.axis_index(axis)
    return (lax.dynamic_slice_in_dim(ct, i * n_local, n_local, axis=0),)


_gather_id_bwd.defvjp(_gather_id_fwd, _gather_id_bwd_rule)


# ---------------------------------------------------------------------------
# gradient synchronisation for model-replicated weights with rank-dependent
# use (kv projections under the GQA head-gather): forward identity, backward
# psum over the model axis — each rank's partial cotangent sums to the true
# gradient.  Works identically under replicated/zero1/fsdp because the sum
# happens before the FSDP gather-transpose sees the cotangent.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _psum_grad(x, axis):
    return x


def _psum_grad_fwd(x, axis):
    return x, None


def _psum_grad_bwd(axis, _, ct):
    return (lax.psum(ct, axis),)


_psum_grad.defvjp(_psum_grad_fwd, _psum_grad_bwd)


def sum_grads_over_model(tree, ctx: ParallelCtx):
    """Identity on values; cotangents are psum'd over the model axis."""
    if ctx.model_axis is None:
        return tree
    return jax.tree.map(lambda t: _psum_grad(t, ctx.model_axis), tree)

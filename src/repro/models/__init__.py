"""Pure-JAX model zoo: the 10 assigned architectures as one composable stack.

Families: dense GQA decoders (llama/qwen/phi/minicpm + the llava backbone),
token-choice MoE (mixtral top-2 TP, llama4 top-1 EP with interleaved
chunked attention), Mamba-1 SSM (falcon-mamba), parallel attn+SSM hybrid
(hymba), and a Whisper-style encoder-decoder.  Modality frontends (audio,
vision) are stubs per the assignment: ``input_specs()`` supplies precomputed
frame/patch embeddings.

Layers are *unrolled* (python loop), not scanned: XLA's cost analysis counts
a ``while`` body once, which would corrupt the dry-run roofline terms
(verified in DESIGN.md).  Smoke tests use reduced configs, so unrolling is
cheap everywhere it runs for real.
"""

from repro.models.model_api import build_model

__all__ = ["build_model"]

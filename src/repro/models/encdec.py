"""Whisper-style encoder-decoder (audio frontend stubbed per assignment:
``input_specs()`` provides precomputed mel-frame embeddings (B, F, d))."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.common import (dense, dense_init, embed, embed_init,
                                 glu_mlp, glu_mlp_init, rmsnorm, rmsnorm_init,
                                 softmax_xent)


def _enc_block_init(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn_mod.attn_init(k1, cfg.attn, cfg.d_model, dtype=dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": glu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype)}


def _dec_block_init(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "self_attn": attn_mod.attn_init(k1, cfg.attn, cfg.d_model, dtype=dtype),
            "ln_x": rmsnorm_init(cfg.d_model, dtype),
            "cross_attn": attn_mod.attn_init(k2, cfg.attn, cfg.d_model, dtype=dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": glu_mlp_init(k3, cfg.d_model, cfg.d_ff, dtype=dtype)}


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    n_enc = cfg.enc_layers or cfg.num_layers
    ks = jax.random.split(key, n_enc + cfg.num_layers + 3)
    return {
        "enc_blocks": [_enc_block_init(ks[i], cfg, dtype) for i in range(n_enc)],
        "enc_norm": rmsnorm_init(cfg.d_model, dtype),
        "embed": embed_init(ks[n_enc], cfg.vocab_size, cfg.d_model, dtype=dtype),
        "dec_blocks": [_dec_block_init(ks[n_enc + 1 + i], cfg, dtype)
                       for i in range(cfg.num_layers)],
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
        "lm_head": dense_init(ks[-1], cfg.d_model, cfg.vocab_size, dtype=dtype),
    }


def encode(params: dict, frames: jax.Array, cfg: ModelConfig, ctx) -> jax.Array:
    cdt = jnp.dtype(cfg.dtype)
    x = frames.astype(cdt)
    for bp in params["enc_blocks"]:
        fn = lambda p_, x_: _enc_block(p_, x_, cfg, ctx)
        if cfg.remat == "layer":
            fn = jax.checkpoint(fn)
        x = fn(bp, x)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _enc_block(bp, x, cfg: ModelConfig, ctx):
    cdt = jnp.dtype(cfg.dtype)
    h = ctx.fan_out(rmsnorm(bp["ln1"], x, cfg.norm_eps))
    x = x + attn_mod.attn_apply(bp["attn"], h, cfg.attn, is_global=True,
                                ctx=ctx, compute_dtype=cdt,
                                causal=False).astype(x.dtype)
    h = ctx.fan_out(rmsnorm(bp["ln2"], x, cfg.norm_eps))
    return x + glu_mlp(bp["mlp"], h, cfg.act, cdt, ctx, cfg.d_ff).astype(x.dtype)


def _dec_block(bp, x, enc_out, cfg: ModelConfig, ctx, positions, causal_skip):
    cdt = jnp.dtype(cfg.dtype)
    h = ctx.fan_out(rmsnorm(bp["ln1"], x, cfg.norm_eps))
    x = x + attn_mod.attn_apply(bp["self_attn"], h, cfg.attn, is_global=True,
                                ctx=ctx, positions=positions,
                                compute_dtype=cdt,
                                causal_skip=causal_skip).astype(x.dtype)
    h = ctx.fan_out(rmsnorm(bp["ln_x"], x, cfg.norm_eps))
    x = x + attn_mod.attn_apply(bp["cross_attn"], h, cfg.attn, is_global=True,
                                ctx=ctx, compute_dtype=cdt, causal=False,
                                cross_kv=ctx.fan_out(enc_out)).astype(x.dtype)
    h = ctx.fan_out(rmsnorm(bp["ln2"], x, cfg.norm_eps))
    return x + glu_mlp(bp["mlp"], h, cfg.act, cdt, ctx, cfg.d_ff).astype(x.dtype)


def forward(params: dict, frames: jax.Array, tokens: jax.Array,
            cfg: ModelConfig, *, ctx, causal_skip: bool = False) -> jax.Array:
    cdt = jnp.dtype(cfg.dtype)
    enc_out = encode(params, frames, cfg, ctx)
    x = embed(params["embed"], tokens, cdt, ctx, cfg.vocab_size)
    positions = jnp.arange(x.shape[1])
    for bp in params["dec_blocks"]:
        fn = lambda p_, x_: _dec_block(p_, x_, enc_out, cfg, ctx, positions,
                                       causal_skip)
        if cfg.remat == "layer":
            fn = jax.checkpoint(fn)
        x = fn(bp, x)
    x = ctx.fan_out(rmsnorm(params["final_norm"], x, cfg.norm_eps))
    return dense(params["lm_head"], x, cdt)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig, *, ctx,
            causal_skip: bool = False) -> jax.Array:
    logits = forward(params, batch["frames"], batch["tokens"], cfg, ctx=ctx,
                     causal_skip=causal_skip)
    return softmax_xent(logits, batch["labels"], batch.get("mask"), ctx,
                        cfg.vocab_size)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_decode_state(params: dict, frames: jax.Array, cfg: ModelConfig,
                      batch: int, seq_len: int, cache_dtype=jnp.bfloat16,
                      ctx=None) -> list:
    """Runs the encoder once; caches cross k/v + empty self-KV per layer."""
    from repro.models.parallel import SINGLE
    ctx = ctx or SINGLE
    cdt = jnp.dtype(cfg.dtype)
    enc_out = encode(params, frames, cfg, ctx)
    state = []
    for bp in params["dec_blocks"]:
        hkv = bp["cross_attn"]["wk"]["w"].shape[1] // cfg.attn.head_dim
        ck = attn_mod._split_heads(dense(bp["cross_attn"]["wk"], enc_out, cdt), hkv)
        cv = attn_mod._split_heads(dense(bp["cross_attn"]["wv"], enc_out, cdt), hkv)
        st = {"kv": attn_mod.init_cache(cfg.attn, batch, seq_len,
                                        is_global=True, dtype=cache_dtype),
              "cross_k": ck.astype(cache_dtype),
              "cross_v": cv.astype(cache_dtype)}
        state.append(st)
    return state


def decode_step(params: dict, token: jax.Array, state: list, pos: jax.Array,
                cfg: ModelConfig, *, ctx) -> tuple[jax.Array, list]:
    cdt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], token[:, None], cdt, ctx, cfg.vocab_size)
    new_state = []
    for bp, st in zip(params["dec_blocks"], state):
        st = dict(st)
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        mix, st["kv"] = attn_mod.attn_decode(bp["self_attn"], h, cfg.attn,
                                             st["kv"], is_global=True,
                                             ctx=ctx, pos=pos,
                                             compute_dtype=cdt)
        x = x + mix.astype(x.dtype)
        h = rmsnorm(bp["ln_x"], x, cfg.norm_eps)
        hq = bp["cross_attn"]["wq"]["w"].shape[1] // cfg.attn.head_dim
        q = attn_mod._split_heads(dense(bp["cross_attn"]["wq"], h, cdt), hq)
        if hq != st["cross_k"].shape[1]:
            ck, cv = attn_mod._gather_kv_for_local_q(
                st["cross_k"], st["cross_v"], cfg.attn, hq, ctx)
        else:
            ck, cv = st["cross_k"], st["cross_v"]
        f = ck.shape[2]
        o = attn_mod.decode_attention(q, ck, cv, jnp.asarray(f - 1),
                                      rolling=False)
        y = dense(bp["cross_attn"]["wo"], attn_mod._merge_heads(o), cdt)
        if attn_mod._needs_psum(bp["cross_attn"], cfg.attn):
            y = ctx.psum(y)
        x = x + y.astype(x.dtype)
        h = rmsnorm(bp["ln2"], x, cfg.norm_eps)
        x = x + glu_mlp(bp["mlp"], h, cfg.act, cdt, ctx, cfg.d_ff).astype(x.dtype)
        new_state.append(st)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return dense(params["lm_head"], x, cdt)[:, 0], new_state

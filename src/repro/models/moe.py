"""Token-choice MoE with sort-based capacity dispatch (no fake-FLOP one-hot
einsums — the dry-run roofline only sees real expert matmuls plus data
movement, which is what a production dispatch does).

Per batch row: route tokens to ``top_k`` experts, sort the (token, expert)
pairs by expert, scatter into a (E, C, d) capacity buffer, run every expert
as one batched GLU matmul, gather back with gate weights.  Tokens beyond an
expert's capacity are dropped (standard capacity-factor semantics) and
reported via the ``drop_fraction`` metric; a shared expert (llama4) adds a
dense always-on path.

Parallelism modes (applied by ``sharding.rules``):
* ``ep`` — expert dim of the weights sharded over "model"; the capacity
  buffer is built per *batch shard* and exchanged through
  ``ctx.all_to_all`` (dispatch: batch-sharded in, expert-sharded out;
  combine: the inverse), so only ``1/R``-th of the buffer crosses the wire
  per hop instead of the old replicated psum's full copy.  When the batch
  does not divide the EP axis (e.g. decode micro-batches) the honest
  replicated-psum fallback below is used.
* ``tp`` — expert ffn dim sharded over "model" (for E smaller than the axis).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import activation, dense_init, trunc_normal


def moe_init(key, cfg: MoEConfig, d_model: int, *, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    e, f = cfg.num_experts, cfg.expert_ff
    std = 1.0 / math.sqrt(d_model)
    p = {
        "router": dense_init(k1, d_model, e, dtype=dtype),
        "w_gate": trunc_normal(k2, (e, d_model, f), std, dtype),
        "w_up": trunc_normal(k3, (e, d_model, f), std, dtype),
        "w_down": trunc_normal(k4, (e, f, d_model), 1.0 / math.sqrt(f), dtype),
    }
    if cfg.shared_expert_ff:
        from repro.models.common import glu_mlp_init

        p["shared"] = glu_mlp_init(k5, d_model, cfg.shared_expert_ff, dtype=dtype)
    return p


def capacity(tokens_per_row: int, cfg: MoEConfig) -> int:
    c = int(math.ceil(tokens_per_row * cfg.top_k * cfg.capacity_factor
                      / cfg.num_experts))
    return max(8, int(math.ceil(c / 8) * 8))  # sublane-aligned


def load_balance_aux(gates_all: jax.Array, expert_ids: jax.Array,
                     num_experts: int, top_k: int) -> jax.Array:
    """Switch-style load-balancing loss, normalized so perfect balance is
    exactly 1.0 for *every* ``top_k``.

    ``me[e]`` is the mean router probability of expert ``e``; ``pe[e]`` is
    the mean number of top-k slots assigned to it divided by ``top_k``, so
    ``sum(pe) == 1`` regardless of k (the previous form collapsed top-k
    multiplicity through ``> 0`` and skipped the ``1/k``, making the
    balanced fixed point of ``E * sum(me * pe)`` drift to ``k`` — mixtral
    k=2 and llama4 k=1 losses were not comparable).
    """
    e = num_experts
    me = jnp.mean(gates_all, axis=(0, 1))                          # (E,)
    pe = jnp.mean(jax.nn.one_hot(expert_ids, e).sum(axis=2),
                  axis=(0, 1)) / top_k                             # (E,)
    return e * jnp.sum(me * pe)


def dropped_fraction(expert_ids: jax.Array, num_experts: int,
                     cap: int) -> jax.Array:
    """Fraction of (token, expert) assignments past capacity — the tokens
    :func:`moe_apply` silently zeroes.  Computed from the (replicated)
    routing decision alone, so it costs one one-hot sum and is identical on
    every rank."""
    b = expert_ids.shape[0]
    flat_ids = expert_ids.reshape(b, -1)                           # (B, S*k)
    t = flat_ids.shape[1]
    counts = jnp.sum(jax.nn.one_hot(flat_ids, num_experts,
                                    dtype=jnp.float32), axis=1)    # (B, E)
    over = jnp.maximum(counts - cap, 0.0)
    return jnp.sum(over) / (b * t)


def moe_apply(p: dict, x: jax.Array, cfg: MoEConfig, act: str, *, ctx,
              compute_dtype=jnp.bfloat16
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss, drop_fraction).

    Activations are replicated over the TP axis (Megatron-style), so routing
    is computed identically on every model rank.
    * EP (batch divides the axis): each rank builds the capacity buffer for
      its *batch shard* only, ``ctx.all_to_all`` turns it expert-sharded
      (dispatch), local experts compute, the inverse all-to-all brings the
      outputs home, and an identity-backward all-gather replicates the
      combined result — no replicated buffer, no zero-pad psum.
    * EP (fallback): replicated buffer, slice own experts, zero-pad,
      psum — the honest replicated cost, also used by transport="psum".
    * TP: every rank runs all experts on its ffn shard; psum after w_down.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = capacity(s, cfg)
    xf = x.astype(compute_dtype)
    e_local = p["w_gate"].shape[0]
    f_local = p["w_gate"].shape[2]
    ep_sharded = e_local < e
    tp_sharded = f_local < cfg.expert_ff

    logits = jnp.einsum("bsd,de->bse", xf, p["router"]["w"].astype(compute_dtype))
    logits = logits.astype(jnp.float32)
    gates_all = jax.nn.softmax(logits, axis=-1)                    # (B,S,E)
    gate_vals, expert_ids = jax.lax.top_k(logits, k)               # (B,S,k)
    if k == 1:
        # llama4-style: sigmoid gate (renorm-softmax of one logit is a
        # constant 1 and would starve the router of gradient)
        gate_w = jax.nn.sigmoid(gate_vals)
    else:
        gate_w = jax.nn.softmax(gate_vals, axis=-1)                # mixtral renorm

    aux = load_balance_aux(gates_all, expert_ids, e, k)
    drop_frac = dropped_fraction(expert_ids, e, cap)

    # ---- sort-based dispatch, vmapped over batch rows ----
    flat_ids = expert_ids.reshape(b, s * k)                        # (B, T)
    flat_gate = gate_w.reshape(b, s * k)
    tok_of = jnp.tile(jnp.arange(s)[:, None], (1, k)).reshape(s * k)
    sharded = ep_sharded or tp_sharded
    if sharded:
        # f-boundaries: dispatch input and gate values feed rank-partial
        # compute (local experts / local ffn shards); their cotangents are
        # per-rank partial sums.  The router-logits path stays replicated.
        xd = ctx.fan_out(xf)
        flat_gate = ctx.fan_out(flat_gate)
    else:
        xd = xf

    def dispatch_row(ids, xrow):
        order = jnp.argsort(ids, stable=True)                      # (T,)
        sorted_ids = ids[order]
        starts = jnp.searchsorted(sorted_ids, jnp.arange(e))       # (E,)
        pos_in_grp = jnp.arange(s * k) - starts[sorted_ids]
        keep = pos_in_grp < cap
        dest = jnp.where(keep, sorted_ids * cap + pos_in_grp, e * cap)
        buf = jnp.zeros((e * cap + 1, d), compute_dtype)
        buf = buf.at[dest].set(xrow[tok_of[order]].astype(compute_dtype))
        return buf[:-1].reshape(e, cap, d), order, dest, keep

    def combine_row(obuf, order_r, dest_r, keep_r, gate_r):
        flat = obuf.reshape(e * cap, d)
        vals = flat[jnp.minimum(dest_r, e * cap - 1)]              # (T, d)
        vals = vals * keep_r[:, None].astype(vals.dtype)
        g = gate_r[order_r][:, None].astype(vals.dtype)
        y = jnp.zeros((s, d), vals.dtype)
        return y.at[tok_of[order_r]].add(vals * g)

    wg = p["w_gate"].astype(compute_dtype)
    wu = p["w_up"].astype(compute_dtype)
    wd = p["w_down"].astype(compute_dtype)

    def glu(buf_c):
        h = activation(act)(jnp.einsum("becd,edf->becf", buf_c, wg)) * \
            jnp.einsum("becd,edf->becf", buf_c, wu)
        return jnp.einsum("becf,efd->becd", h, wd)

    r = ctx.model_size() if ep_sharded else 1
    if ep_sharded and r > 1 and b % r == 0:
        # ---- expert-parallel via all-to-all ----
        bs = b // r
        i0 = ctx.model_index() * bs
        xd_s = jax.lax.dynamic_slice_in_dim(xd, i0, bs, axis=0)
        ids_s = jax.lax.dynamic_slice_in_dim(flat_ids, i0, bs, axis=0)
        gate_s = jax.lax.dynamic_slice_in_dim(flat_gate, i0, bs, axis=0)
        buf_s, order_s, dest_s, keep_s = jax.vmap(dispatch_row)(ids_s, xd_s)
        # dispatch: (bs, E, C, d) batch-sharded -> (B, E_l, C, d) expert-sharded
        recv = ctx.all_to_all(buf_s, split_axis=1, concat_axis=0)
        out = glu(recv)                                      # (B, E_l, C, d)
        # combine: the inverse exchange brings expert outputs home
        back = ctx.all_to_all(out, split_axis=0, concat_axis=1)
        y_s = jax.vmap(combine_row)(back, order_s, dest_s, keep_s, gate_s)
        y = ctx.gather_replicated(y_s)                       # (B, S, d)
    else:
        buf, order, dest, keep = jax.vmap(dispatch_row)(flat_ids, xd)
        if ep_sharded:
            # replicated-psum fallback: slice this rank's expert rows out of
            # the (replicated) buffer, zero-pad back, psum merges subsets
            e0 = ctx.model_index() * e_local
            buf_c = jax.lax.dynamic_slice_in_dim(buf, e0, e_local, axis=1)
        else:
            buf_c = buf
        out_buf = glu(buf_c)                                 # (B, E_l, C, d)
        if ep_sharded:
            full = jnp.zeros((b, e, cap, d), out_buf.dtype)
            out_buf = jax.lax.dynamic_update_slice_in_dim(full, out_buf, e0,
                                                          axis=1)
        y = jax.vmap(combine_row)(out_buf, order, dest, keep, flat_gate)
        if ep_sharded or tp_sharded:
            y = ctx.psum(y)

    if "shared" in p:
        from repro.models.common import glu_mlp

        xs = ctx.fan_out(xf) if p["shared"]["w_down"]["w"].shape[0] < \
            cfg.shared_expert_ff else xf
        y = y + glu_mlp(p["shared"], xs, act, compute_dtype, ctx,
                        cfg.shared_expert_ff)
    return y.astype(x.dtype), aux, drop_frac

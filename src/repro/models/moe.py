"""Token-choice MoE with sort-based capacity dispatch (no fake-FLOP one-hot
einsums — the dry-run roofline only sees real expert matmuls plus data
movement, which is what a production dispatch does).

Per batch row: route tokens to ``top_k`` experts, sort the (token, expert)
pairs by expert, scatter into a (E, C, d) capacity buffer, run every expert
as one batched GLU matmul, gather back with gate weights.  Tokens beyond an
expert's capacity are dropped (standard capacity-factor semantics); a shared
expert (llama4) adds a dense always-on path.

Parallelism modes (applied by ``sharding.rules``):
* ``ep`` — expert dim of the weights and the (E, C, d) buffer sharded over
  "model"; GSPMD inserts the all-to-all on the buffer boundary.
* ``tp`` — expert ffn dim sharded over "model" (for E smaller than the axis).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import activation, dense_init, trunc_normal


def moe_init(key, cfg: MoEConfig, d_model: int, *, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    e, f = cfg.num_experts, cfg.expert_ff
    std = 1.0 / math.sqrt(d_model)
    p = {
        "router": dense_init(k1, d_model, e, dtype=dtype),
        "w_gate": trunc_normal(k2, (e, d_model, f), std, dtype),
        "w_up": trunc_normal(k3, (e, d_model, f), std, dtype),
        "w_down": trunc_normal(k4, (e, f, d_model), 1.0 / math.sqrt(f), dtype),
    }
    if cfg.shared_expert_ff:
        from repro.models.common import glu_mlp_init

        p["shared"] = glu_mlp_init(k5, d_model, cfg.shared_expert_ff, dtype=dtype)
    return p


def capacity(tokens_per_row: int, cfg: MoEConfig) -> int:
    c = int(math.ceil(tokens_per_row * cfg.top_k * cfg.capacity_factor
                      / cfg.num_experts))
    return max(8, int(math.ceil(c / 8) * 8))  # sublane-aligned


def moe_apply(p: dict, x: jax.Array, cfg: MoEConfig, act: str, *, ctx,
              compute_dtype=jnp.bfloat16) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    Activations are replicated over the TP axis (Megatron-style), so routing
    and the capacity buffer are computed identically on every model rank.
    * EP: each rank slices its expert rows from the buffer, computes them,
      combines its partial output, and a final psum merges expert subsets.
    * TP: every rank runs all experts on its ffn shard; psum after w_down.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = capacity(s, cfg)
    xf = x.astype(compute_dtype)
    e_local = p["w_gate"].shape[0]
    f_local = p["w_gate"].shape[2]
    ep_sharded = e_local < e
    tp_sharded = f_local < cfg.expert_ff

    logits = jnp.einsum("bsd,de->bse", xf, p["router"]["w"].astype(compute_dtype))
    logits = logits.astype(jnp.float32)
    gates_all = jax.nn.softmax(logits, axis=-1)                    # (B,S,E)
    gate_vals, expert_ids = jax.lax.top_k(logits, k)               # (B,S,k)
    if k == 1:
        # llama4-style: sigmoid gate (renorm-softmax of one logit is a
        # constant 1 and would starve the router of gradient)
        gate_w = jax.nn.sigmoid(gate_vals)
    else:
        gate_w = jax.nn.softmax(gate_vals, axis=-1)                # mixtral renorm

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(gates_all, axis=(0, 1))                          # (E,)
    pe = jnp.mean(
        (jax.nn.one_hot(expert_ids, e).sum(axis=2) > 0).astype(jnp.float32),
        axis=(0, 1))
    aux = e * jnp.sum(me * pe)

    # ---- sort-based dispatch, vmapped over batch rows ----
    flat_ids = expert_ids.reshape(b, s * k)                        # (B, T)
    flat_gate = gate_w.reshape(b, s * k)
    tok_of = jnp.tile(jnp.arange(s)[:, None], (1, k)).reshape(s * k)
    sharded = ep_sharded or tp_sharded
    if sharded:
        # f-boundaries: dispatch input and gate values feed rank-partial
        # compute (local experts / local ffn shards); their cotangents are
        # per-rank partial sums.  The router-logits path stays replicated.
        xd = ctx.fan_out(xf)
        flat_gate = ctx.fan_out(flat_gate)
    else:
        xd = xf

    def dispatch_row(ids, xrow):
        order = jnp.argsort(ids, stable=True)                      # (T,)
        sorted_ids = ids[order]
        starts = jnp.searchsorted(sorted_ids, jnp.arange(e))       # (E,)
        pos_in_grp = jnp.arange(s * k) - starts[sorted_ids]
        keep = pos_in_grp < cap
        dest = jnp.where(keep, sorted_ids * cap + pos_in_grp, e * cap)
        buf = jnp.zeros((e * cap + 1, d), compute_dtype)
        buf = buf.at[dest].set(xrow[tok_of[order]].astype(compute_dtype))
        return buf[:-1].reshape(e, cap, d), order, dest, keep

    buf, order, dest, keep = jax.vmap(dispatch_row)(flat_ids, xd)  # (B,E,C,d)

    # ---- expert compute: one batched GLU over the capacity buffer ----
    wg = p["w_gate"].astype(compute_dtype)
    wu = p["w_up"].astype(compute_dtype)
    wd = p["w_down"].astype(compute_dtype)
    if ep_sharded:
        # slice this rank's expert rows out of the (replicated) buffer
        e0 = ctx.model_index() * e_local
        buf_c = jax.lax.dynamic_slice_in_dim(buf, e0, e_local, axis=1)
    else:
        buf_c = buf
    h = activation(act)(jnp.einsum("becd,edf->becf", buf_c, wg)) * \
        jnp.einsum("becd,edf->becf", buf_c, wu)
    out_buf = jnp.einsum("becf,efd->becd", h, wd)            # (B,E_l,C,d)
    if ep_sharded:
        # scatter local experts' outputs back into the full-E layout; the
        # final psum (below) merges the disjoint expert subsets.
        full = jnp.zeros((b, e, cap, d), out_buf.dtype)
        out_buf = jax.lax.dynamic_update_slice_in_dim(full, out_buf, e0, axis=1)

    # ---- combine: gather back and weight by gates ----
    def combine_row(obuf, order_r, dest_r, keep_r, gate_r):
        flat = obuf.reshape(e * cap, d)
        vals = flat[jnp.minimum(dest_r, e * cap - 1)]              # (T, d)
        vals = vals * keep_r[:, None].astype(vals.dtype)
        g = gate_r[order_r][:, None].astype(vals.dtype)
        y = jnp.zeros((s, d), vals.dtype)
        return y.at[tok_of[order_r]].add(vals * g)

    y = jax.vmap(combine_row)(out_buf, order, dest, keep, flat_gate)
    if ep_sharded or tp_sharded:
        y = ctx.psum(y)

    if "shared" in p:
        from repro.models.common import glu_mlp

        xs = ctx.fan_out(xf) if p["shared"]["w_down"]["w"].shape[0] <             cfg.shared_expert_ff else xf
        y = y + glu_mlp(p["shared"], xs, act, compute_dtype, ctx,
                        cfg.shared_expert_ff)
    return y.astype(x.dtype), aux

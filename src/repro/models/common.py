"""Shared building blocks: inits, norms, linears, RoPE, activations.

Parameters are plain nested dicts of ``jnp`` arrays (framework-neutral
pytrees); every constructor returns ``(params, apply_fn)``-style helpers as
free functions so the transformer assembly stays explicit and auditable.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def trunc_normal(key, shape, std: float, dtype=jnp.float32):
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) \
        .astype(dtype) * jnp.asarray(std, dtype)


def dense_init(key, d_in: int, d_out: int, *, dtype=jnp.float32,
               bias: bool = False, std: float | None = None) -> dict:
    std = std if std is not None else 1.0 / math.sqrt(d_in)
    p = {"w": trunc_normal(key, (d_in, d_out), std, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: dict, x: jax.Array, compute_dtype) -> jax.Array:
    w = p["w"].astype(compute_dtype)
    y = x.astype(compute_dtype) @ w
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                          # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def glu_mlp_init(key, d: int, f: int, *, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, d, f, dtype=dtype),
            "w_up": dense_init(k2, d, f, dtype=dtype),
            "w_down": dense_init(k3, f, d, dtype=dtype)}


def glu_mlp(p: dict, x: jax.Array, act: str, compute_dtype, ctx=None,
            global_ff: int | None = None) -> jax.Array:
    """Col-parallel gate/up, row-parallel down; psum iff ff dim is a local
    TP shard (detected from the weight shape vs the config's global ff)."""
    g = dense(p["w_gate"], x, compute_dtype)
    u = dense(p["w_up"], x, compute_dtype)
    y = dense(p["w_down"], activation(act)(g) * u, compute_dtype)
    if ctx is not None and global_ff is not None \
            and p["w_down"]["w"].shape[0] < global_ff:
        y = ctx.psum(y)
    return y


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d: int, *, dtype=jnp.float32) -> dict:
    # 0.02 (GPT-2/llama-style): keeps tied-unembedding logits O(1) at init
    return {"table": trunc_normal(key, (vocab, d), 0.02, dtype)}


def embed(p: dict, tokens: jax.Array, compute_dtype, ctx, global_vocab: int) -> jax.Array:
    """Vocab-parallel embedding: local table shard, masked take, psum."""
    table = p["table"].astype(compute_dtype)
    v_local = table.shape[0]
    if v_local == global_vocab:
        return jnp.take(table, tokens, axis=0)
    off = ctx.model_index() * v_local
    idx = tokens - off
    valid = (idx >= 0) & (idx < v_local)
    out = jnp.take(table, jnp.clip(idx, 0, v_local - 1), axis=0)
    out = jnp.where(valid[..., None], out, 0)
    return ctx.psum(out)


def unembed(p: dict, x: jax.Array, compute_dtype) -> jax.Array:
    """Tied unembedding: col-parallel — local logits over the vocab shard."""
    return x.astype(compute_dtype) @ p["table"].astype(compute_dtype).T


def softmax_xent(logits: jax.Array, labels: jax.Array, mask: jax.Array | None,
                 ctx=None, global_vocab: int | None = None):
    """Token-mean cross entropy in fp32.  ``logits`` may be the *local* vocab
    shard (B, S, V_local) — pass ``ctx`` + ``global_vocab`` for the
    vocab-parallel reduction (max / logsumexp / gold-pick psums)."""
    lf = logits.astype(jnp.float32)
    v_local = lf.shape[-1]
    sharded = (ctx is not None and global_vocab is not None
               and v_local != global_vocab)
    if not sharded:
        logz = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    else:
        # the shared max is a numerical-stability shift only; stop_gradient
        # keeps it out of autodiff (pmax has no VJP, and logsumexp is
        # invariant to the shift anyway)
        m_loc = jax.lax.stop_gradient(jnp.max(lf, axis=-1))
        m = ctx.pmax(m_loc)
        se = ctx.psum(jnp.sum(jnp.exp(lf - m[..., None]), axis=-1))
        logz = jnp.log(se) + m
        off = ctx.model_index() * v_local
        idx = labels - off
        valid = (idx >= 0) & (idx < v_local)
        g = jnp.take_along_axis(lf, jnp.clip(idx, 0, v_local - 1)[..., None],
                                axis=-1)[..., 0]
        gold = ctx.psum(jnp.where(valid, g, 0.0))
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    m_ = mask.astype(jnp.float32)
    return jnp.sum(nll * m_) / jnp.maximum(jnp.sum(m_), 1.0)

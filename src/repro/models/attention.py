"""GQA attention: blockwise training path, flash-kernel prefill, cached decode.

Masking flavours cover the assigned archs: full causal, sliding-window
(mixtral/hymba), and chunked-local (llama4 iRoPE-style).  Query heads are
zero-padded up to a multiple of ``head_pad_to`` so tensor parallelism tiles
the mesh's model axis exactly (the framework *guarantees* shardability —
the paper's determinism ethos; the pad is recorded in the param count).

The training path blocks over both q and kv in unrolled python loops with an
fp32 online softmax: differentiable, bounded VMEM/HBM working set, and —
because the loops are unrolled — honestly counted by the dry-run cost
analysis.  ``causal_skip`` statically skips fully-masked (future) kv blocks,
halving attention FLOPs; it is OFF by default so §Perf can show the
before/after.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import AttnConfig
from repro.models.common import apply_rope, dense, dense_init
from repro.models.parallel import sum_grads_over_model

NEG_INF = -1e30
HEAD_PAD_TO = 16  # model-axis size the padded head count must tile


def padded_heads(n: int, pad_to: int = HEAD_PAD_TO) -> int:
    return int(math.ceil(n / pad_to) * pad_to)


def attn_init(key, cfg: AttnConfig, d_model: int, *, dtype=jnp.float32,
              pad_to: int = HEAD_PAD_TO) -> dict:
    """Query heads are zero-padded to tile the model axis; the padded rows of
    ``wo`` are zero so padded heads never influence the output.  KV heads are
    never padded (they replicate across TP ranks; each rank gathers the kv
    heads its local q heads group to)."""
    hq = padded_heads(cfg.num_heads, pad_to)
    hkv = cfg.num_kv_heads
    hd = cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    wo = dense_init(k4, hq * hd, d_model, dtype=dtype)
    if hq > cfg.num_heads:
        wo["w"] = wo["w"].at[cfg.num_heads * hd:].set(0.0)
    return {
        "wq": dense_init(k1, d_model, hq * hd, dtype=dtype, bias=cfg.qkv_bias),
        "wk": dense_init(k2, d_model, hkv * hd, dtype=dtype, bias=cfg.qkv_bias),
        "wv": dense_init(k3, d_model, hkv * hd, dtype=dtype, bias=cfg.qkv_bias),
        "wo": wo,
    }


def _gather_kv_for_local_q(k: jax.Array, v: jax.Array, cfg: AttnConfig,
                           hq_local: int, ctx):
    """TP rank-local GQA mapping: q head ``h`` (global) reads kv head
    ``h // true_group`` (clipped for padded heads).  Returns per-q-head kv."""
    true_group = max(cfg.num_heads // cfg.num_kv_heads, 1)
    h_global = ctx.model_index() * hq_local + jnp.arange(hq_local)
    kv_idx = jnp.clip(h_global // true_group, 0, cfg.num_kv_heads - 1)
    return jnp.take(k, kv_idx, axis=1), jnp.take(v, kv_idx, axis=1)


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, -1).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def _mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
          window: int | None, chunk: int | None) -> jax.Array:
    m = jnp.ones(jnp.broadcast_shapes(q_pos.shape, k_pos.shape), bool)
    if causal:
        m &= k_pos <= q_pos
    if window is not None:
        m &= k_pos > q_pos - window
    if chunk is not None:
        m &= (k_pos // chunk) == (q_pos // chunk)
    return m


# ---------------------------------------------------------------------------
# blockwise attention (training / prefill; differentiable)
# ---------------------------------------------------------------------------


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        chunk: int | None = None, block_q: int = 2048,
                        block_k: int = 2048, causal_skip: bool = False) -> jax.Array:
    """q: (B,Hq,S,D), k/v: (B,Hkv,S,D).  Online-softmax over kv blocks."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    nq = math.ceil(sq / bq)
    nk = math.ceil(sk / bk)

    outs = []
    for i in range(nq):
        q0, q1 = i * bq, min((i + 1) * bq, sq)
        qi = q[:, :, q0:q1].astype(jnp.float32) * scale
        m = jnp.full((b, hq, q1 - q0, 1), NEG_INF, jnp.float32)
        l = jnp.zeros((b, hq, q1 - q0, 1), jnp.float32)
        acc = jnp.zeros((b, hq, q1 - q0, d), jnp.float32)
        for j in range(nk):
            k0, k1_ = j * bk, min((j + 1) * bk, sk)
            if causal_skip and causal and k0 > q1 - 1:
                continue  # statically future-only block: zero contribution
            if causal_skip and window is not None and k1_ - 1 <= q0 - window:
                continue  # statically out-of-window block
            if causal_skip and chunk is not None and (k1_ - 1) // chunk < q0 // chunk:
                continue  # statically before this q-range's first chunk
            kj = k[:, :, k0:k1_].astype(jnp.float32)
            vj = v[:, :, k0:k1_].astype(jnp.float32)
            if group > 1:
                kj = jnp.repeat(kj, group, axis=1)
                vj = jnp.repeat(vj, group, axis=1)
            s = jnp.einsum("bhqd,bhkd->bhqk", qi, kj)
            q_pos = jnp.arange(q0, q1)[:, None]
            k_pos = jnp.arange(k0, k1_)[None, :]
            msk = _mask(q_pos, k_pos, causal=causal, window=window, chunk=chunk)
            s = jnp.where(msk[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new)
            l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vj)
            m = m_new
        outs.append(acc / jnp.maximum(l, 1e-30))
    return jnp.concatenate(outs, axis=2).astype(q.dtype)


# ---------------------------------------------------------------------------
# cached single-token decode
# ---------------------------------------------------------------------------


def decode_attention(q1: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int | None = None,
                     chunk: int | None = None, rolling: bool = False) -> jax.Array:
    """q1: (B,Hq,1,D); caches: (B,Hkv,C,D); ``pos``: current position (scalar).

    With ``rolling`` the cache is a circular buffer of size C holding the
    last C positions; slot ``t`` holds absolute position
    ``pos - ((pos - t) mod C)`` — masking handles validity.
    """
    b, hq, _, d = q1.shape
    hkv, c = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    k = jnp.repeat(k_cache, group, axis=1) if group > 1 else k_cache
    v = jnp.repeat(v_cache, group, axis=1) if group > 1 else v_cache
    s = jnp.einsum("bhqd,bhkd->bhqk", q1.astype(jnp.float32) / math.sqrt(d),
                   k.astype(jnp.float32))
    slot = jnp.arange(c)
    if rolling:
        delta = jnp.mod(pos - slot, c)          # age of each slot
        k_pos = pos - delta
    else:
        k_pos = slot
    valid = (k_pos <= pos) & (k_pos >= 0)       # >=0 excludes unwritten slots
    if window is not None:
        valid &= k_pos > pos - window
    if chunk is not None:
        valid &= (k_pos // chunk) == (pos // chunk)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q1.dtype)


# ---------------------------------------------------------------------------
# full layer
# ---------------------------------------------------------------------------


def _needs_psum(p: dict, cfg: AttnConfig) -> bool:
    """Row-parallel wo: psum iff the merged-head dim is a local shard."""
    return p["wo"]["w"].shape[0] < padded_heads(cfg.num_heads) * cfg.head_dim


def attn_apply(p: dict, x: jax.Array, cfg: AttnConfig, *, is_global: bool,
               ctx, positions: jax.Array | None = None,
               compute_dtype=jnp.bfloat16, causal: bool = True,
               causal_skip: bool = False, cross_kv: jax.Array | None = None,
               block_q: int = 2048, block_k: int = 2048) -> jax.Array:
    """Self (or cross) attention over a full sequence (train / prefill).
    Weights may be local TP shards; ``ctx.psum`` completes the row-parallel
    output projection."""
    b, s, _ = x.shape
    hq = p["wq"]["w"].shape[1] // cfg.head_dim
    hkv = p["wk"]["w"].shape[1] // cfg.head_dim
    q = _split_heads(dense(p["wq"], x, compute_dtype), hq)
    kv_src = cross_kv if cross_kv is not None else x
    tp_kv = hq < padded_heads(cfg.num_heads)   # TP-sharded q, replicated kv
    wk, wv = p["wk"], p["wv"]
    if tp_kv:
        # kv use is rank-dependent (head gather): sum grads over model axis
        wk = sum_grads_over_model(wk, ctx)
        wv = sum_grads_over_model(wv, ctx)
    k = _split_heads(dense(wk, kv_src, compute_dtype), hkv)
    v = _split_heads(dense(wv, kv_src, compute_dtype), hkv)
    if cross_kv is None:
        pos = positions if positions is not None else jnp.arange(s)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    if hq != hkv:
        # uniform true-group GQA mapping (single-device and TP agree)
        k, v = _gather_kv_for_local_q(k, v, cfg, hq, ctx)
    window = None if is_global else cfg.window
    chunk = None if is_global else cfg.chunk
    o = blockwise_attention(q, k, v, causal=causal and cross_kv is None,
                            window=window, chunk=chunk, block_q=block_q,
                            block_k=block_k, causal_skip=causal_skip)
    y = dense(p["wo"], _merge_heads(o), compute_dtype)
    return ctx.psum(y) if _needs_psum(p, cfg) else y


def attn_decode(p: dict, x1: jax.Array, cfg: AttnConfig, cache: dict, *,
                is_global: bool, ctx, pos: jax.Array,
                compute_dtype=jnp.bfloat16,
                cache_len_global: int | None = None) -> tuple:
    """One-token decode. ``cache``: {"k","v"}: (B,Hkv,C_local,D).

    When ``C_local < cache_len_global`` the cache is *sequence-sharded* over
    the model axis (context-parallel decode — the only way a 32k x 128 KV
    cache fits when kv heads replicate): each rank scores its slot range and
    the softmax is combined with pmax/psum partial statistics.
    """
    hq = p["wq"]["w"].shape[1] // cfg.head_dim
    hkv = p["wk"]["w"].shape[1] // cfg.head_dim
    q = _split_heads(dense(p["wq"], x1, compute_dtype), hq)        # (B,Hq,1,D)
    k1 = _split_heads(dense(p["wk"], x1, compute_dtype), hkv)
    v1 = _split_heads(dense(p["wv"], x1, compute_dtype), hkv)
    posv = jnp.asarray(pos)
    pos1 = posv.reshape(1)
    q = apply_rope(q, pos1, cfg.rope_theta)
    k1 = apply_rope(k1, pos1, cfg.rope_theta)
    c_local = cache["k"].shape[2]
    c_total = cache_len_global or c_local
    seq_sharded = c_local < c_total

    if not seq_sharded:
        slot = jnp.mod(posv, c_local)
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k1.astype(cache["k"].dtype), slot, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v1.astype(cache["v"].dtype), slot, axis=2)
        kc, vc = k_cache, v_cache
        if hq != hkv:
            kc, vc = _gather_kv_for_local_q(kc, vc, cfg, hq, ctx)
        window = None if is_global else cfg.window
        chunk = None if is_global else cfg.chunk
        o = decode_attention(q, kc, vc, posv, window=window, chunk=chunk,
                             rolling=True)
    else:
        r = ctx.model_index()
        slot_g = jnp.mod(posv, c_total)
        ls = slot_g - r * c_local
        owner = (ls >= 0) & (ls < c_local)
        lsc = jnp.clip(ls, 0, c_local - 1)
        # masked single-slot write: only the owning rank's value changes
        def wr(buf, new):
            old = jax.lax.dynamic_slice_in_dim(buf, lsc, 1, axis=2)
            val = jnp.where(owner, new.astype(buf.dtype), old)
            return jax.lax.dynamic_update_slice_in_dim(buf, val, lsc, axis=2)
        k_cache = wr(cache["k"], k1)
        v_cache = wr(cache["v"], v1)
        kc, vc = k_cache, v_cache
        if hq != hkv:
            kc, vc = _gather_kv_for_local_q(kc, vc, cfg, hq, ctx)
        # local partial attention over this rank's slots
        slot_l = r * c_local + jnp.arange(c_local)            # global slots
        delta = jnp.mod(posv - slot_l, c_total)
        k_pos = posv - delta
        valid = (k_pos <= posv) & (k_pos >= 0)
        window = None if is_global else cfg.window
        chunk = None if is_global else cfg.chunk
        if window is not None:
            valid &= k_pos > posv - window
        if chunk is not None:
            valid &= (k_pos // chunk) == (posv // chunk)
        d = cfg.head_dim
        s = jnp.einsum("bhqd,bhkd->bhqk",
                       q.astype(jnp.float32) / math.sqrt(d),
                       kc.astype(jnp.float32))
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        m = ctx.pmax(jnp.max(s, axis=-1, keepdims=True))
        e = jnp.exp(s - m)
        num = ctx.psum(jnp.einsum("bhqk,bhkd->bhqd", e,
                                  vc.astype(jnp.float32)))
        den = ctx.psum(jnp.sum(e, axis=-1, keepdims=True))
        o = (num / jnp.maximum(den, 1e-30)).astype(q.dtype)

    y = dense(p["wo"], _merge_heads(o), compute_dtype)
    y = ctx.psum(y) if _needs_psum(p, cfg) else y
    return y, {"k": k_cache, "v": v_cache}


def init_cache(cfg: AttnConfig, batch: int, seq_len: int, *, is_global: bool,
               dtype=jnp.bfloat16) -> dict:
    """Cache length: full seq for global layers, window/chunk for local."""
    c = seq_len
    if not is_global:
        if cfg.window is not None:
            c = min(c, cfg.window)
        elif cfg.chunk is not None:
            c = min(c, cfg.chunk)
    hkv = cfg.num_kv_heads
    return {"k": jnp.zeros((batch, hkv, c, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, hkv, c, cfg.head_dim), dtype)}

"""Mamba-1 selective SSM block (falcon-mamba / hymba's SSM heads).

TPU adaptation: the CUDA selective-scan kernel becomes a
``jax.lax.associative_scan`` over time — log-depth, MXU/VPU-friendly, and
(unlike ``scan``) fully visible to the dry-run cost analysis.  The inner
dimension ``d_inner`` is tensor-parallel over "model" (in_proj col-parallel,
out_proj row-parallel; conv/scan are elementwise in ``d_inner``), which also
bounds the (B, S, d_inner/shards, N) scan intermediates per device.

Decode keeps O(1) state: ``h`` (B, d_inner, N) + a (conv_width-1)-tap conv
tail — the property that makes ``long_500k`` feasible.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.common import dense, dense_init, trunc_normal


def ssm_dims(cfg: SSMConfig, d_model: int) -> tuple[int, int]:
    d_inner = cfg.expand * d_model
    dt_rank = cfg.dt_rank or int(math.ceil(d_model / 16))
    return d_inner, dt_rank


def ssm_init(key, cfg: SSMConfig, d_model: int, *, dtype=jnp.float32) -> dict:
    d_inner, dt_rank = ssm_dims(cfg, d_model)
    n = cfg.state_dim
    ks = jax.random.split(key, 7)
    # S4D-real initialisation for A
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (d_inner, 1))
    return {
        # split x/z projections so TP shards each branch contiguously
        "in_proj_x": dense_init(ks[0], d_model, d_inner, dtype=dtype),
        "in_proj_z": dense_init(ks[5], d_model, d_inner, dtype=dtype),
        "conv_w": trunc_normal(ks[1], (cfg.conv_width, d_inner),
                               1.0 / math.sqrt(cfg.conv_width), dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * n, dtype=dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner, dtype=dtype, bias=True),
        "a_log": jnp.log(a).astype(dtype),
        "d_skip": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[4], d_inner, d_model, dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv over time. x: (B,S,D); w: (W,D); tail: (B,W-1,D)."""
    width = w.shape[0]
    pad = tail if tail is not None else jnp.zeros(
        (x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # (B, S+W-1, D)
    out = jnp.zeros_like(x)
    for t in range(width):
        out = out + xp[:, t:t + x.shape[1]] * w[t][None, None, :]
    return out + b[None, None, :]


def _ssm_params(p: dict, xc: jax.Array, cfg: SSMConfig, dt_rank: int,
                compute_dtype, ctx, sharded: bool):
    """Input-dependent (delta, B, C) from the conv'd activation (B,S,Din).
    ``x_proj`` is row-parallel under TP: psum completes the contraction."""
    n = cfg.state_dim
    proj = dense(p["x_proj"], xc, compute_dtype)
    if sharded:
        # g then f: the psum'd projection is consumed by rank-sharded
        # (Din-local) scan branches, so its cotangent must be re-psum'd
        proj = ctx.fan_out(ctx.psum(proj))
    dt_raw, b_ssm, c_ssm = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    delta = jax.nn.softplus(dense(p["dt_proj"], dt_raw, compute_dtype)
                            .astype(jnp.float32))           # (B,S,Din)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))             # (Din,N)
    return delta, a, b_ssm.astype(jnp.float32), c_ssm.astype(jnp.float32)


def ssm_apply(p: dict, x: jax.Array, cfg: SSMConfig, *, ctx,
              compute_dtype=jnp.bfloat16, d_model: int | None = None) -> jax.Array:
    """Full-sequence selective scan. x: (B, S, d_model).  Weights may be
    local TP shards of ``d_inner``; row-parallel outputs are psum'd."""
    d_inner = p["conv_w"].shape[1]
    dt_rank = p["dt_proj"]["w"].shape[0]
    sharded = d_inner < cfg.expand * (d_model or x.shape[-1])
    xpart = dense(p["in_proj_x"], x, compute_dtype)          # (B,S,Din_local)
    z = dense(p["in_proj_z"], x, compute_dtype)
    xc = jax.nn.silu(_causal_conv(xpart, p["conv_w"].astype(compute_dtype),
                                  p["conv_b"].astype(compute_dtype)))
    delta, a, b_ssm, c_ssm = _ssm_params(p, xc, cfg, dt_rank, compute_dtype,
                                         ctx, sharded)

    # discretise: abar = exp(delta*A) (B,S,Din,N); bbar*x = delta*B*x
    xf = xc.astype(jnp.float32)
    abar = jnp.exp(delta[..., None] * a[None, None])                    # (B,S,Din,N)
    bx = (delta * xf)[..., None] * b_ssm[:, :, None, :]                 # (B,S,Din,N)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (abar, bx), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", hs, c_ssm)                          # (B,S,Din)
    y = y + xf * p["d_skip"].astype(jnp.float32)[None, None, :]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = dense(p["out_proj"], y.astype(compute_dtype), compute_dtype)
    return ctx.psum(out) if sharded else out


def ssm_decode(p: dict, x1: jax.Array, cfg: SSMConfig, state: dict, *, ctx,
               compute_dtype=jnp.bfloat16, d_model: int | None = None
               ) -> tuple[jax.Array, dict]:
    """One-token step. state: {"h": (B,Din,N), "conv": (B,W-1,Din)}."""
    dt_rank = p["dt_proj"]["w"].shape[0]
    d_inner = p["conv_w"].shape[1]
    sharded = d_inner < cfg.expand * (d_model or x1.shape[-1])
    xpart = dense(p["in_proj_x"], x1, compute_dtype)         # (B,1,Din_local)
    z = dense(p["in_proj_z"], x1, compute_dtype)
    xc = jax.nn.silu(_causal_conv(xpart, p["conv_w"].astype(compute_dtype),
                                  p["conv_b"].astype(compute_dtype),
                                  tail=state["conv"].astype(compute_dtype)))
    new_conv = jnp.concatenate([state["conv"][:, 1:],
                                xpart.astype(state["conv"].dtype)], axis=1)
    delta, a, b_ssm, c_ssm = _ssm_params(p, xc, cfg, dt_rank, compute_dtype,
                                         ctx, sharded)
    xf = xc.astype(jnp.float32)
    abar = jnp.exp(delta[:, 0, :, None] * a[None])           # (B,Din,N)
    bx = (delta * xf)[:, 0, :, None] * b_ssm[:, 0, None, :]
    h = state["h"].astype(jnp.float32) * abar + bx
    y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0])[:, None, :]
    y = y + xf * p["d_skip"].astype(jnp.float32)[None, None, :]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = dense(p["out_proj"], y.astype(compute_dtype), compute_dtype)
    if sharded:
        out = ctx.psum(out)
    return out, {"h": h.astype(state["h"].dtype), "conv": new_conv}


def init_ssm_state(cfg: SSMConfig, d_model: int, batch: int,
                   dtype=jnp.float32) -> dict:
    d_inner, _ = ssm_dims(cfg, d_model)
    return {"h": jnp.zeros((batch, d_inner, cfg.state_dim), dtype),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, d_inner), dtype)}

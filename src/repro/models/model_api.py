"""Model facade: one object per architecture with init / loss / decode /
input_specs / param_specs — everything the launcher, dry-run and serving
paths need, uniform across families.

Vocab sizes are padded to a multiple of 128 ('guaranteed shardability' —
labels never reference pad rows; the pad is included in reported N).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer
from repro.models.parallel import SINGLE, ParallelCtx
from repro.sharding import rules as shard_rules

VOCAB_PAD_TO = 128


def padded_vocab(v: int) -> int:
    return int(math.ceil(v / VOCAB_PAD_TO) * VOCAB_PAD_TO)


@dataclass
class Model:
    cfg: ModelConfig

    def __post_init__(self):
        self.cfg = self.cfg.with_(vocab_size=padded_vocab(self.cfg.vocab_size))
        self._encdec = self.cfg.family == "encdec" or self.cfg.frontend == "audio_stub"

    # -- params ---------------------------------------------------------------

    def init(self, key) -> dict:
        if self._encdec:
            return encdec.init_params(key, self.cfg)
        return transformer.init_params(key, self.cfg)

    def abstract_params(self) -> dict:
        return jax.eval_shape(lambda k: self.init(k), jax.random.key(0))

    def param_specs(self, mesh: Mesh):
        """TP-only specs: inside the fully-manual step the data axes are
        realised by FSDP bucket shards (runtime), never by param specs."""
        return shard_rules.param_specs(self.abstract_params(),
                                       self.cfg.with_(sharding="tp"), mesh)

    def param_count(self) -> int:
        import math

        return sum(math.prod(l.shape) for l in
                   jax.tree.leaves(self.abstract_params()))

    def active_param_count(self) -> int:
        """MoE: only top_k of num_experts per MoE layer are active per token."""
        total = self.param_count()
        if self.cfg.moe is None:
            return total
        moe = self.cfg.moe
        expert_leaf = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self.abstract_params())[0]:
            keys = "/".join(shard_rules._key_name(k) for k in path)
            if "moe" in keys and any(n in keys for n in ("w_gate", "w_up", "w_down")) \
                    and len(leaf.shape) == 3:
                import math

                expert_leaf += math.prod(leaf.shape)
        active_frac = moe.top_k / moe.num_experts
        return int(total - expert_leaf * (1 - active_frac))

    # -- steps -----------------------------------------------------------------

    def loss_fn(self, params, batch, *, ctx: ParallelCtx = SINGLE,
                causal_skip: bool = False, block_resolver=None,
                stats_out: list | None = None):
        if self._encdec:
            if block_resolver is not None:
                raise NotImplementedError(
                    "FSDP block_resolver is decoder-only; enc-dec archs use "
                    "tp/zero1 sharding")
            if stats_out is not None:   # no MoE layers in enc-dec stacks
                stats_out.append({"moe_drop_fraction":
                                  jnp.zeros((), jnp.float32)})
            return encdec.loss_fn(params, batch, self.cfg, ctx=ctx,
                                  causal_skip=causal_skip)
        return transformer.loss_fn(params, batch, self.cfg, ctx=ctx,
                                   causal_skip=causal_skip,
                                   block_resolver=block_resolver,
                                   stats_out=stats_out)

    def forward(self, params, batch, *, ctx: ParallelCtx = SINGLE,
                causal_skip: bool = False):
        if self._encdec:
            return encdec.forward(params, batch["frames"], batch["tokens"],
                                  self.cfg, ctx=ctx, causal_skip=causal_skip)
        logits, _, _ = transformer.forward(params, batch["tokens"], self.cfg,
                                           ctx=ctx,
                                           extra_embeds=batch.get("extra_embeds"),
                                           causal_skip=causal_skip)
        return logits

    def init_decode_state(self, batch: int, seq_len: int, params=None,
                          frames=None, ctx: ParallelCtx = SINGLE):
        if self._encdec:
            return encdec.init_decode_state(params, frames, self.cfg, batch,
                                            seq_len, ctx=ctx)
        return transformer.init_decode_state(self.cfg, batch, seq_len)

    def abstract_decode_state(self, batch: int, seq_len: int):
        if self._encdec:
            params = self.abstract_params()
            frames = jax.ShapeDtypeStruct(
                (batch, self.cfg.enc_seq, self.cfg.d_model), jnp.bfloat16)
            return jax.eval_shape(
                lambda p, f: encdec.init_decode_state(p, f, self.cfg, batch,
                                                      seq_len), params, frames)
        return jax.eval_shape(
            lambda: transformer.init_decode_state(self.cfg, batch, seq_len))

    def decode_step(self, params, token, state, pos, *,
                    ctx: ParallelCtx = SINGLE, seq_len: int | None = None,
                    block_resolver=None):
        if self._encdec:
            return encdec.decode_step(params, token, state, pos, self.cfg,
                                      ctx=ctx)
        return transformer.decode_step(params, token, state, pos, self.cfg,
                                       ctx=ctx, seq_len=seq_len,
                                       block_resolver=block_resolver)

    # -- shapes ------------------------------------------------------------------

    def input_specs(self, shape: ShapeConfig, mesh: Mesh | None = None):
        """ShapeDtypeStructs (+ PartitionSpecs when mesh given) for one cell."""
        b, s = shape.global_batch, shape.seq_len
        cfg = self.cfg
        specs: dict[str, Any] = {}
        pspecs: dict[str, Any] = {}
        bspec = shard_rules.batch_spec(b, mesh) if mesh is not None else P()

        if shape.kind in ("train", "prefill"):
            if self._encdec:
                specs["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
                pspecs["frames"] = P(*(tuple(bspec) + (None, None)))
            text = s
            if cfg.frontend == "vision_stub" and cfg.frontend_seq:
                text = s - cfg.frontend_seq
                specs["extra_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.frontend_seq, cfg.d_model), jnp.bfloat16)
                pspecs["extra_embeds"] = P(*(tuple(bspec) + (None, None)))
            specs["tokens"] = jax.ShapeDtypeStruct((b, text), jnp.int32)
            pspecs["tokens"] = P(*(tuple(bspec) + (None,)))
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((b, text), jnp.int32)
                pspecs["labels"] = P(*(tuple(bspec) + (None,)))
        else:  # decode: one token against a seq_len-deep state
            specs["token"] = jax.ShapeDtypeStruct((b,), jnp.int32)
            pspecs["token"] = P(*tuple(bspec)) if len(bspec) else P()
            specs["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
            pspecs["pos"] = P()
        if mesh is not None:
            return specs, pspecs
        return specs


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)

"""Decoder-only transformer assembly covering dense / MoE / SSM / hybrid
families, with unrolled layers, per-layer remat, KV/SSM decode state, and
modality-stub extra embeddings (VLM patches, audio frames).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (dense_init, embed, embed_init, glu_mlp,
                                 glu_mlp_init, rmsnorm, rmsnorm_init,
                                 softmax_xent, unembed)

AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def block_init(key, cfg: ModelConfig, i: int, dtype) -> dict:
    kind = cfg.layer_kind(i)
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": rmsnorm_init(cfg.d_model, dtype),
               "ln2": rmsnorm_init(cfg.d_model, dtype)}
    if kind["mixer"] in ("attn", "hybrid"):
        p["attn"] = attn_mod.attn_init(ks[0], cfg.attn, cfg.d_model, dtype=dtype)
    if kind["mixer"] in ("ssm", "hybrid"):
        p["ssm"] = ssm_mod.ssm_init(ks[1], cfg.ssm, cfg.d_model, dtype=dtype)
    if kind["mixer"] == "hybrid":
        p["beta"] = jnp.ones((2,), dtype)
    if kind["mlp"] == "moe":
        p["moe"] = moe_mod.moe_init(ks[2], cfg.moe, cfg.d_model, dtype=dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = glu_mlp_init(ks[3], cfg.d_model, cfg.d_ff, dtype=dtype)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, cfg.num_layers + 2)
    params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype=dtype),
        "blocks": [block_init(ks[1 + i], cfg, i, dtype)
                   for i in range(cfg.num_layers)],
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[-1], cfg.d_model, cfg.vocab_size,
                                       dtype=dtype)
    return params


def param_count(params) -> int:
    return sum(int(jnp.size(l)) for l in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def block_apply(p: dict, x: jax.Array, cfg: ModelConfig, i: int, *, ctx,
                positions, causal_skip: bool
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    kind = cfg.layer_kind(i)
    cdt = jnp.dtype(cfg.dtype)
    aux = jnp.zeros((), jnp.float32)
    drop = jnp.zeros((), jnp.float32)
    h = ctx.fan_out(rmsnorm(p["ln1"], x, cfg.norm_eps))
    if kind["mixer"] == "attn":
        mix = attn_mod.attn_apply(p["attn"], h, cfg.attn,
                                  is_global=kind.get("attn_global", True),
                                  ctx=ctx, positions=positions,
                                  compute_dtype=cdt, causal_skip=causal_skip)
    elif kind["mixer"] == "ssm":
        mix = ssm_mod.ssm_apply(p["ssm"], h, cfg.ssm, ctx=ctx,
                                compute_dtype=cdt, d_model=cfg.d_model)
    else:  # hybrid: parallel attention + SSM heads on the same input
        a = attn_mod.attn_apply(p["attn"], h, cfg.attn,
                                is_global=kind.get("attn_global", False),
                                ctx=ctx, positions=positions,
                                compute_dtype=cdt, causal_skip=causal_skip)
        s = ssm_mod.ssm_apply(p["ssm"], h, cfg.ssm, ctx=ctx,
                              compute_dtype=cdt, d_model=cfg.d_model)
        beta = p["beta"].astype(cdt)
        mix = 0.5 * (a * beta[0] + s * beta[1])
    x = x + mix.astype(x.dtype)

    if "moe" not in p and "mlp" not in p:     # pure-SSM stacks (d_ff == 0)
        return x, aux, drop
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind["mlp"] != "moe":      # moe places its own f-boundaries
        h = ctx.fan_out(h)
    if kind["mlp"] == "moe":
        y, aux, drop = moe_mod.moe_apply(p["moe"], h, cfg.moe, cfg.act,
                                         ctx=ctx, compute_dtype=cdt)
    else:
        y = glu_mlp(p["mlp"], h, cfg.act, cdt, ctx, cfg.d_ff)
    return x + y.astype(x.dtype), aux, drop


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig, *, ctx,
            extra_embeds: jax.Array | None = None,
            causal_skip: bool = False,
            block_resolver=None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """tokens: (B, S_text).  ``extra_embeds`` (B, P, d) are prepended
    (modality stub).  Returns (logits (B, S_total, V_local), aux_loss,
    drop_fraction) — the latter averaged over the MoE layers (0 for dense
    stacks)."""
    cdt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens, cdt, ctx, cfg.vocab_size)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cdt), x], axis=1)
    positions = jnp.arange(x.shape[1])
    aux_total = jnp.zeros((), jnp.float32)
    drop_total = jnp.zeros((), jnp.float32)
    n_moe = sum(1 for i in range(cfg.num_layers)
                if cfg.layer_kind(i)["mlp"] == "moe")

    for i, raw in enumerate(params["blocks"]):
        # ``raw`` is either the block's param dict or (FSDP) its flat shard
        # list; the resolver ring-all-gathers INSIDE the remat boundary so
        # backward re-gathers instead of pinning gathered weights.
        def fn(p_, x_, i_=i):
            bp = block_resolver("blocks", i_, p_) if block_resolver else p_
            return block_apply(bp, x_, cfg, i_, ctx=ctx, positions=positions,
                               causal_skip=causal_skip)
        if cfg.remat == "layer":
            fn = jax.checkpoint(fn)
        x, aux, drop = fn(raw, x)
        aux_total = aux_total + aux
        drop_total = drop_total + drop

    x = ctx.fan_out(rmsnorm(params["final_norm"], x, cfg.norm_eps))
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, cdt)
    else:
        from repro.models.common import dense

        logits = dense(params["lm_head"], x, cdt)
    return logits, aux_total, drop_total / max(n_moe, 1)


def loss_fn(params: dict, batch: dict, cfg: ModelConfig, *, ctx,
            causal_skip: bool = False, block_resolver=None,
            stats_out: list | None = None) -> jax.Array:
    """batch: {"tokens": (B,S), "labels": (B,S), optional "mask",
    optional "extra_embeds" (B,P,d)} — loss over text positions only.

    ``stats_out``, when given, receives one ``{"moe_drop_fraction": scalar}``
    dict per call — the side channel the train step uses to surface routing
    health without changing the loss signature ``value_and_grad`` sees."""
    extra = batch.get("extra_embeds")
    logits, aux, drop = forward(params, batch["tokens"], cfg, ctx=ctx,
                                extra_embeds=extra, causal_skip=causal_skip,
                                block_resolver=block_resolver)
    if extra is not None:
        logits = logits[:, extra.shape[1]:]
    loss = softmax_xent(logits, batch["labels"], batch.get("mask"), ctx,
                        cfg.vocab_size)
    if stats_out is not None:
        stats_out.append({"moe_drop_fraction": drop})
    return loss + AUX_LOSS_WEIGHT * aux


# ---------------------------------------------------------------------------
# decode (single token against running state)
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, seq_len: int,
                      cache_dtype=jnp.bfloat16) -> list:
    state = []
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        st: dict = {}
        if kind["mixer"] in ("attn", "hybrid"):
            st["kv"] = attn_mod.init_cache(cfg.attn, batch, seq_len,
                                           is_global=kind.get("attn_global",
                                                              kind["mixer"] == "attn"),
                                           dtype=cache_dtype)
        if kind["mixer"] in ("ssm", "hybrid"):
            st["ssm"] = ssm_mod.init_ssm_state(cfg.ssm, cfg.d_model, batch,
                                               dtype=jnp.float32)
        state.append(st)
    return state


def cache_len(cfg: ModelConfig, i: int, seq_len: int) -> int:
    """Global KV-cache length for layer ``i`` (mirrors init_cache)."""
    kind = cfg.layer_kind(i)
    is_global = kind.get("attn_global", kind["mixer"] == "attn")
    c = seq_len
    if not is_global and cfg.attn is not None:
        if cfg.attn.window is not None:
            c = min(c, cfg.attn.window)
        elif cfg.attn.chunk is not None:
            c = min(c, cfg.attn.chunk)
    return c


def decode_step(params: dict, token: jax.Array, state: list, pos: jax.Array,
                cfg: ModelConfig, *, ctx, seq_len: int | None = None,
                block_resolver=None) -> tuple[jax.Array, list]:
    """token: (B,) ints; returns (local-vocab logits (B, V_l), new_state)."""
    cdt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], token[:, None], cdt, ctx, cfg.vocab_size)
    new_state = []
    for i, raw in enumerate(params["blocks"]):
        bp = block_resolver("blocks", i, raw) if block_resolver else raw
        kind = cfg.layer_kind(i)
        st = dict(state[i])
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        clen = cache_len(cfg, i, seq_len) if seq_len else None
        if kind["mixer"] == "attn":
            mix, st["kv"] = attn_mod.attn_decode(
                bp["attn"], h, cfg.attn, st["kv"],
                is_global=kind.get("attn_global", True), ctx=ctx, pos=pos,
                compute_dtype=cdt, cache_len_global=clen)
        elif kind["mixer"] == "ssm":
            mix, st["ssm"] = ssm_mod.ssm_decode(bp["ssm"], h, cfg.ssm,
                                                st["ssm"], ctx=ctx,
                                                compute_dtype=cdt,
                                                d_model=cfg.d_model)
        else:
            a, st["kv"] = attn_mod.attn_decode(
                bp["attn"], h, cfg.attn, st["kv"],
                is_global=kind.get("attn_global", False), ctx=ctx, pos=pos,
                compute_dtype=cdt, cache_len_global=clen)
            s, st["ssm"] = ssm_mod.ssm_decode(bp["ssm"], h, cfg.ssm,
                                              st["ssm"], ctx=ctx,
                                              compute_dtype=cdt,
                                              d_model=cfg.d_model)
            beta = bp["beta"].astype(cdt)
            mix = 0.5 * (a * beta[0] + s * beta[1])
        x = x + mix.astype(x.dtype)
        if "moe" in bp or "mlp" in bp:
            h = rmsnorm(bp["ln2"], x, cfg.norm_eps)
            if kind["mlp"] == "moe":
                y, _, _ = moe_mod.moe_apply(bp["moe"], h, cfg.moe, cfg.act,
                                            ctx=ctx, compute_dtype=cdt)
            else:
                y = glu_mlp(bp["mlp"], h, cfg.act, cdt, ctx, cfg.d_ff)
            x = x + y.astype(x.dtype)
        new_state.append(st)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, cdt)
    else:
        from repro.models.common import dense

        logits = dense(params["lm_head"], x, cdt)
    return logits[:, 0], new_state

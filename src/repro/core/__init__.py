"""Core of the reproduction: the communication *kernels* underneath
:mod:`repro.comm`.

The paper's contribution — near-wirespeed gradient reduction and halo
exchange via guaranteed large buffers + multi-channel concurrency — is
surfaced through the unified :class:`repro.comm.Communicator` API: named
transports in a registry, virtual-channel striping as a config knob, and a
:class:`repro.comm.CommPlan` fusing bucket layout, channel assignment and
predicted wire bytes.  This package provides the building blocks those
transports are made of:

* :mod:`repro.core.ring`        — ppermute ring collectives (bi-directional,
  chunked, hierarchical/pod-aware, codec-capable).
* :mod:`repro.core.bucketing`   — fused persistent gradient buckets (the
  'guaranteed huge pages' analogue).
* :mod:`repro.core.halo`        — Cartesian halo exchange (QCD workload);
  reachable as ``Communicator.halo_exchange``.
* :mod:`repro.core.reducer`     — DEPRECATED ``GradientReducer`` shim kept
  for legacy string-policy call sites (incl. ``POLICY_TO_TRANSPORT``);
  delegates to ``repro.comm``.

New code should construct a ``Communicator`` rather than reaching for these
modules directly::

    from repro.comm import CommConfig, Communicator
    comm = Communicator(mesh, CommConfig(transport="ring_hier", channels=2))
"""

from repro.core.bucketing import BucketPlan, GradientBucketer
# wire codecs moved to repro.comm.wire_codec; re-exported here for compat
from repro.comm.wire_codec import ErrorFeedback, Int8BlockCodec, IdentityCodec, make_codec
from repro.core.halo import HaloSpec, halo_exchange, pad_with_halos
from repro.core.reducer import GradientReducer, ReduceConfig, per_tensor_reducer
from repro.core.ring import (RingConfig, flat_all_reduce, hierarchical_all_reduce,
                             ring_all_gather, ring_all_reduce, ring_reduce_scatter)

__all__ = [
    "BucketPlan", "ErrorFeedback", "GradientBucketer",
    "GradientReducer", "HaloSpec", "IdentityCodec", "Int8BlockCodec",
    "ReduceConfig", "RingConfig", "flat_all_reduce",
    "halo_exchange", "hierarchical_all_reduce", "make_codec",
    "pad_with_halos", "per_tensor_reducer", "ring_all_gather",
    "ring_all_reduce", "ring_reduce_scatter",
]

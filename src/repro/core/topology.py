"""Ring/mesh topology helpers for explicit collective schedules.

The paper drives a fixed set of point-to-point channels (8 comm threads, one
per direction / chunk) through the fabric.  On TPU the analogous schedule is a
set of ``lax.ppermute`` chains over named mesh axes; this module centralises
the permutation tables and axis bookkeeping so every collective in
``core.ring`` / ``core.halo`` draws from one audited source.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

import jax
from jax import lax

from repro import compat

Axis = str


def ring_perm(size: int, direction: int = +1) -> list[tuple[int, int]]:
    """Permutation table sending rank ``i`` -> ``i + direction (mod size)``."""
    if direction not in (+1, -1):
        raise ValueError(f"ring direction must be +-1, got {direction}")
    return [(i, (i + direction) % size) for i in range(size)]


def axis_size(axis: Axis) -> int:
    return compat.axis_size(axis)


def axis_index(axis: Axis):
    return lax.axis_index(axis)


def mesh_axis_size(mesh: jax.sharding.Mesh, axis: Axis) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[axis]


@dataclass(frozen=True)
class ChannelSpec:
    """One concurrent communication channel (paper: one comm thread/endpoint).

    ``direction`` is the ring orientation; ``chunk`` indexes the payload slice
    this channel carries.  A schedule with ``2 * n_chunks`` channels is the
    bidirectional, chunked configuration that mirrors the paper's eight
    threaded endpoints over dual rails.
    """

    direction: int
    chunk: int


def channel_schedule(n_chunks: int, bidirectional: bool) -> list[ChannelSpec]:
    dirs = (+1, -1) if bidirectional else (+1,)
    return [ChannelSpec(d, c) for c in range(n_chunks) for d in dirs]


def order_token(dep, x):
    """Thread a scalar data dependency into ``x`` so XLA cannot reorder it
    before ``dep`` is available (one rail / one sequential schedule step).
    ``dep is None`` means no constraint.  The zero-multiply keeps the value
    unchanged while making ``x`` data-dependent on ``dep``."""
    import jax.numpy as jnp

    if dep is None:
        return x
    return x + jnp.zeros((), x.dtype) * dep.astype(x.dtype)


def padded_size(n: int, multiple: int) -> int:
    """Smallest ``m >= n`` with ``m % multiple == 0`` (lane/ring alignment)."""
    if multiple <= 0:
        raise ValueError(f"multiple must be positive, got {multiple}")
    return int(math.ceil(n / multiple) * multiple)


def reduce_axes_of(mesh_axis_names: Sequence[Axis], data_axes: Sequence[Axis]) -> tuple[Axis, ...]:
    """The subset of ``data_axes`` actually present on the mesh, mesh-ordered."""
    present = [a for a in mesh_axis_names if a in set(data_axes)]
    return tuple(present)
